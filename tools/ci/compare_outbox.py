#!/usr/bin/env python3
"""Compare two mcubes service outboxes for semantic equality.

Usage: compare_outbox.py <outbox-dir-a> <outbox-dir-b>

The CI `service-durability` job runs the same demo job suite in two
stores — one uninterrupted, one `kill -9`-ed mid-run and restarted —
and this script asserts the published results are identical where the
durability contract says they must be: same jobs, same digests, and
bit-for-bit the same numbers (the store writes floats in a canonical
round-trippable format, so string equality of a number field IS f64
bit equality).

Delivery metadata is deliberately ignored: `cached` and
`resumed_iteration` legitimately differ between an interrupted and an
uninterrupted run, and the `sha256` seal differs with them.
"""

import json
import sys
from pathlib import Path

# The fields the durability contract covers. Everything else in the
# result manifest is delivery metadata.
SEMANTIC_FIELDS = [
    "$schema",
    "job_id",
    "digest",
    "integrand",
    "dim",
    "status",
    "integral",
    "sigma",
    "chi2_dof",
    "rel_err",
    "iterations",
    "converged",
    "calls_used",
    "stop",
    "error",
]


def load_outbox(d):
    out = {}
    for p in sorted(Path(d).glob("*.json")):
        # parse_float=str keeps the canonical text of every number, so
        # the comparison below is bitwise, not within-epsilon.
        out[p.stem] = json.loads(p.read_text(), parse_float=str)
    return out


def main():
    if len(sys.argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    a_dir, b_dir = sys.argv[1], sys.argv[2]
    a, b = load_outbox(a_dir), load_outbox(b_dir)
    failures = []

    if set(a) != set(b):
        only_a = sorted(set(a) - set(b))
        only_b = sorted(set(b) - set(a))
        failures.append(f"job sets differ: only in {a_dir}: {only_a}; only in {b_dir}: {only_b}")

    for job in sorted(set(a) & set(b)):
        for field in SEMANTIC_FIELDS:
            va, vb = a[job].get(field), b[job].get(field)
            if va != vb:
                failures.append(f"{job}.{field}: {va!r} != {vb!r}")

    if failures:
        print(f"outbox mismatch ({a_dir} vs {b_dir}):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"outboxes match: {len(a)} job(s), bitwise-identical semantic fields")
    return 0


if __name__ == "__main__":
    sys.exit(main())
