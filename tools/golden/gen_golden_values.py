#!/usr/bin/env python3
"""Golden-value generator for rust/tests/golden_values.rs.

An operation-exact pure-Python port of the native engine's golden-run
configuration: Philox4x32-10 counters, the VEGAS importance-grid change
of variables, the fixed 64-task reduction partition, the VEGAS+
allocation (damped absorb + largest-remainder reallocate), the weighted
estimator, and the `RunPlan::classic(3, 0, 0)` driver loop.

Every floating-point operation mirrors the Rust source in both kind and
order (CPython floats are IEEE f64 and `math.*` calls the same libm),
so on the machine that generated the frozen table the oracle agrees
with the engine bit for bit; the Rust test then compares at 1e-9
relative tolerance to absorb cross-platform libm ulp differences.

Self-validation before emitting anything:
  1. the pinned anchor from `engine::mod::tests::
     matches_python_first_iteration_estimate` (f4 d=5 calls=4096 nb=20
     seed=42 it=0) must reproduce to < 1e-12 relative;
  2. the stratified path at beta = 0 must equal the uniform engine
     exactly (repr-identical) over the full 3-iteration run.

Usage: python3 tools/golden/gen_golden_values.py
Emits the GOLDEN table (Rust source) on stdout.
"""

import math
import sys

# --- Philox4x32-10 (rust/src/rng/philox.rs) -------------------------------

M0 = 0xD2511F53
M1 = 0xCD9E8D57
W0 = 0x9E3779B9
W1 = 0xBB67AE85
CTR_MAGIC = 0x6D435542
KEY_MAGIC = 0x6D637562
BLOCK_BITS = 8
MASK = 0xFFFFFFFF
INV32 = 1.0 / 4294967296.0


def philox4x32(c0, c1, c2, c3, k0, k1):
    for _ in range(10):
        p0 = c0 * M0
        p1 = c2 * M1
        hi0, lo0 = p0 >> 32, p0 & MASK
        hi1, lo1 = p1 >> 32, p1 & MASK
        c0 = hi1 ^ c1 ^ k0
        c1 = lo1
        c2 = hi0 ^ c3 ^ k1
        c3 = lo0
        k0 = (k0 + W0) & MASK
        k1 = (k1 + W1) & MASK
    return c0, c1, c2, c3


def uniforms(sidx, iteration, seed, d, out):
    """philox.uniforms_into: word w of block j is dimension 4j + w."""
    w0 = sidx & MASK
    w1_hi = (sidx >> 32) << BLOCK_BITS
    i = 0
    j = 0
    while i < d:
        blk = philox4x32(w0, j | w1_hi, iteration, CTR_MAGIC, seed, KEY_MAGIC)
        n = min(d - i, 4)
        for w in range(n):
            out[i + w] = (blk[w] + 0.5) * INV32
        i += n
        j += 1


# --- Layout / grid (rust/src/strat/mod.rs, rust/src/grid/bins.rs) ---------


def layout_compute(d, maxcalls, nb):
    g = max(int(math.floor((maxcalls / 2.0) ** (1.0 / d))), 1)
    while (g + 1) ** d <= maxcalls // 2:
        g += 1
    m = g**d
    p = max(maxcalls // m, 2)
    return g, m, p


def bins_uniform(d, nb):
    edges = []
    for _ in range(d):
        for b in range(1, nb + 1):
            edges.append(b / nb)
    return edges


def reduction_tasks(m):
    return max(min(m, 64), 1)


def reduction_task_span(m, ntasks, t):
    q, r = m // ntasks, m % ntasks
    lo = t * q + min(t, r)
    return lo, lo + q + (1 if t < r else 0)


def cube_coords(idx, g, d, out):
    for i in range(d):
        out[i] = idx % g
        idx //= g


# --- Integrands (rust/src/integrands/) — unit box, scalar op order --------


def powi(x, n):
    """LLVM powi expansion: square-and-multiply, reciprocal for n < 0."""
    neg = n < 0
    e = -n if neg else n
    result = 1.0
    base = x
    while True:
        if e & 1:
            result = result * base
        e >>= 1
        if e == 0:
            break
        base = base * base
    return 1.0 / result if neg else result


def make_f1(d):
    def f(x):
        s = 0.0
        for i in range(d):
            s += (i + 1) * x[i]
        return math.cos(s)

    return f


def make_f2(d):
    a = 1.0 / 2500.0

    def f(x):
        prod = 1.0
        for i in range(d):
            t = x[i] - 0.5
            prod *= 1.0 / (a + t * t)
        return prod

    return f


def make_f3(d):
    e = -d - 1

    def f(x):
        s = 1.0
        for i in range(d):
            s += (i + 1) * x[i]
        return powi(s, e)

    return f


def make_f4(d):
    def f(x):
        s = 0.0
        for i in range(d):
            t = x[i] - 0.5
            s += t * t
        return math.exp(-625.0 * s)

    return f


def make_f5(d):
    def f(x):
        s = 0.0
        for i in range(d):
            s += abs(x[i] - 0.5)
        return math.exp(-10.0 * s)

    return f


def make_f6(d):
    def f(x):
        s = 0.0
        for i in range(d):
            c = float(i + 1)
            if x[i] >= (3.0 + c) / 10.0:
                return 0.0
            s += (c + 4.0) * x[i]
        return math.exp(s)

    return f


COSMO_KNOTS = 64


def cosmo_tables():
    t0, t1 = [], []
    for i in range(COSMO_KNOTS):
        x = i / (COSMO_KNOTS - 1)
        t0.append(1.0 + 0.5 * math.sin(2.0 * math.pi * x) + 0.25 * x * x)
        t1.append(math.exp(-2.0 * (x - 0.3) * (x - 0.3)) + 0.1)
    return t0, t1


def interp_eval(vals, x):
    k = len(vals)
    t = (x - 0.0) / (1.0 - 0.0) * (k - 1)
    hi = k - 1.000001
    if t < 0.0:
        t = 0.0
    elif t > hi:
        t = hi
    i0 = int(math.floor(t))
    frac = t - i0
    return vals[i0] + frac * (vals[i0 + 1] - vals[i0])


def make_cosmo():
    t0, t1 = cosmo_tables()

    def f(x):
        a = interp_eval(t0, x[0])
        b = interp_eval(t1, x[1])
        g = math.exp(-(x[2] * x[2] + x[3] * x[3]))
        p = 1.0 + 0.5 * x[4] * x[5]
        return a * b * g * p

    return f


# --- VEGAS+ allocation (rust/src/strat/alloc.rs) --------------------------

FLOOR = 2  # MIN_SAMPLES_PER_CUBE
CEIL = 0xFFFFFFFF


def prefix_sums(counts):
    offsets = []
    acc = 0
    for c in counts:
        offsets.append(acc)
        acc += c
    return offsets


def absorb(damped, cube, d_new):
    damped[cube] = (1.0 - 0.5) * damped[cube] + 0.5 * max(d_new, 0.0)


def reallocate(counts, damped, budget, beta):
    m = len(counts)
    weights = [max(dk, 0.0) ** beta for dk in damped]
    total_w = 0.0
    for w in weights:
        total_w += w
    if beta == 0.0 or not (total_w > 0.0) or not math.isfinite(total_w):
        if budget >= FLOOR * m:
            q, r = budget // m, budget % m
        else:
            q, r = FLOOR, 0
        for i in range(m):
            counts[i] = q + (1 if i < r else 0)
        return prefix_sums(counts)

    spendable = max(budget - FLOOR * m, 0)
    fracs = [0.0] * m
    allocated = FLOOR * m
    for i in range(m):
        share = float(spendable) * (weights[i] / total_w)
        base_f = math.floor(share)
        fracs[i] = share - base_f
        base = min(int(base_f), spendable, CEIL - FLOOR)
        counts[i] = FLOOR + base
        allocated += base
    if allocated < budget:
        order = sorted(range(m), key=lambda i: (-fracs[i], i))
        left = budget - allocated
        for i in order:
            if left == 0:
                break
            if counts[i] < CEIL:
                counts[i] += 1
                left -= 1
        if left > 0:
            for i in range(m):
                if left == 0:
                    break
                grant = min(CEIL - counts[i], left)
                counts[i] += grant
                left -= grant
    elif allocated > budget:
        excess = allocated - budget
        while excess > 0:
            progressed = False
            for i in range(m):
                if excess == 0:
                    break
                if counts[i] > FLOOR:
                    counts[i] -= 1
                    excess -= 1
                    progressed = True
            if not progressed:
                break
    return prefix_sums(counts)


# --- Engine passes (rust/src/engine/{mod,stratified}.rs) ------------------


def vsample_uniform(fv, d, g, m, p, edges, nb, seed, iteration):
    inv_g = 1.0 / g
    nbf = float(nb)
    pf = float(p)
    mf = float(m)
    u = [0.0] * d
    x = [0.0] * d
    coords = [0] * d
    ntasks = reduction_tasks(m)
    integral = 0.0
    variance = 0.0
    for t in range(ntasks):
        lo, hi = reduction_task_span(m, ntasks, t)
        t_int = 0.0
        t_var = 0.0
        for cube in range(lo, hi):
            cube_coords(cube, g, d, coords)
            base = cube * p
            s1 = 0.0
            s2 = 0.0
            for k in range(p):
                uniforms(base + k, iteration, seed, d, u)
                jac = 1.0
                for i in range(d):
                    z = (coords[i] + u[i]) * inv_g
                    loc = z * nbf
                    b = min(int(loc), nb - 1)
                    row = i * nb
                    right = edges[row + b]
                    left = 0.0 if b == 0 else edges[row + b - 1]
                    w = right - left
                    xt = left + (loc - b) * w
                    jac *= nbf * w
                    x[i] = xt
                v = fv(x) * jac
                s1 += v
                s2 += v * v
            mean = s1 / pf
            var = max(s2 / pf - mean * mean, 0.0) / (pf - 1.0)
            t_int += mean / mf
            t_var += var / (mf * mf)
        integral += t_int
        variance += t_var
    return integral, variance


def vsample_stratified(fv, d, g, m, edges, nb, seed, iteration, counts, offsets, damped):
    inv_g = 1.0 / g
    nbf = float(nb)
    mf = float(m)
    u = [0.0] * d
    x = [0.0] * d
    coords = [0] * d
    ntasks = reduction_tasks(m)
    partials = []
    for t in range(ntasks):
        lo, hi = reduction_task_span(m, ntasks, t)
        t_int = 0.0
        t_var = 0.0
        d_new = []
        for cube in range(lo, hi):
            cube_coords(cube, g, d, coords)
            n = max(counts[cube], 2)
            nf = float(n)
            base = offsets[cube]
            s1 = 0.0
            s2 = 0.0
            for k in range(n):
                uniforms(base + k, iteration, seed, d, u)
                jac = 1.0
                for i in range(d):
                    z = (coords[i] + u[i]) * inv_g
                    loc = z * nbf
                    b = min(int(loc), nb - 1)
                    row = i * nb
                    right = edges[row + b]
                    left = 0.0 if b == 0 else edges[row + b - 1]
                    w = right - left
                    xt = left + (loc - b) * w
                    jac *= nbf * w
                    x[i] = xt
                v = fv(x) * jac
                s1 += v
                s2 += v * v
            mean = s1 / nf
            var = max(s2 / nf - mean * mean, 0.0) / (nf - 1.0)
            t_int += mean / mf
            t_var += var / (mf * mf)
            d_new.append(var * nf)
        partials.append((lo, t_int, t_var, d_new))
    integral = 0.0
    variance = 0.0
    for lo, t_int, t_var, d_new in partials:
        integral += t_int
        variance += t_var
        for i, dn in enumerate(d_new):
            absorb(damped, lo + i, dn)
    return integral, variance


# --- Estimator + driver (estimator/mod.rs, coordinator driver) ------------

VAR_FLOOR = 1e-300


class Estimator:
    def __init__(self):
        self.sum_w = 0.0
        self.sum_wi = 0.0
        self.sum_wi2 = 0.0
        self.n = 0

    def push(self, integral, variance):
        var = max(variance, VAR_FLOOR)
        w = 1.0 / var
        self.sum_w += w
        self.sum_wi += w * integral
        self.sum_wi2 += w * integral * integral
        self.n += 1

    def integral(self):
        return self.sum_wi / self.sum_w if self.sum_w > 0.0 else 0.0

    def sigma(self):
        return math.sqrt(1.0 / self.sum_w) if self.sum_w > 0.0 else math.inf

    def chi2_dof(self):
        if self.n < 2:
            return 0.0
        ibar = self.integral()
        chi2 = max(self.sum_wi2 - ibar * self.sum_wi, 0.0)
        return chi2 / (self.n - 1)


def run_classic3(fv, d, maxcalls, nb, seed, beta=None):
    """RunPlan::classic(3, 0, 0): three non-adjusting sample iterations.

    beta=None runs the uniform engine; a float runs the VEGAS+
    stratified engine (absorb every pass, reallocate after every
    iteration — exactly `VegasPlusEngine::update` as driven by
    `EngineBackend::run`).
    """
    g, m, p = layout_compute(d, maxcalls, nb)
    edges = bins_uniform(d, nb)
    est = Estimator()
    if beta is None:
        for it in range(3):
            r_int, r_var = vsample_uniform(fv, d, g, m, p, edges, nb, seed, it)
            est.push(r_int, r_var)
    else:
        counts = [p] * m
        offsets = prefix_sums(counts)
        damped = [0.0] * m
        budget = m * p
        for it in range(3):
            r_int, r_var = vsample_stratified(
                fv, d, g, m, edges, nb, seed, it, counts, offsets, damped
            )
            est.push(r_int, r_var)
            offsets = reallocate(counts, damped, budget, beta)
    return est


# --- Self-validation ------------------------------------------------------


def validate():
    # 1. The pinned anchor from engine::mod::tests.
    g, m, p = layout_compute(5, 4096, 20)
    assert (g, m, p) == (4, 1024, 4), (g, m, p)
    edges = bins_uniform(5, 20)
    i0, v0 = vsample_uniform(make_f4(5), 5, g, m, p, edges, 20, 42, 0)
    ri = abs(i0 - 2.7858176280788316e-05) / 2.7858176280788316e-05
    rv = abs(v0 - 7.757123669326781e-10) / 7.757123669326781e-10
    assert ri < 1e-12, f"anchor integral off: {i0!r} (rel {ri:.2e})"
    assert rv < 1e-10, f"anchor variance off: {v0!r} (rel {rv:.2e})"

    # 2. beta = 0 must reproduce the uniform engine exactly.
    for name, fv, d in [("f4", make_f4(5), 5), ("cosmo", make_cosmo(), 6)]:
        a = run_classic3(fv, d, 4096, 50, 42)
        b = run_classic3(fv, d, 4096, 50, 42, beta=0.0)
        for attr in ("integral", "sigma", "chi2_dof"):
            x, y = getattr(a, attr)(), getattr(b, attr)()
            assert repr(x) == repr(y), f"{name} beta=0 {attr}: {x!r} != {y!r}"

    print("// oracle self-validation passed", file=sys.stderr)


# --- Emit the golden table ------------------------------------------------


def main():
    validate()
    cases = [
        ("f1", make_f1(5), 5),
        ("f2", make_f2(5), 5),
        ("f3", make_f3(5), 5),
        ("f4", make_f4(5), 5),
        ("f5", make_f5(5), 5),
        ("f6", make_f6(5), 5),
        ("cosmo", make_cosmo(), 6),
    ]
    rows = []
    for name, fv, d in cases:
        for label, beta in [("Uniform", None), ("VegasPlus", 0.75)]:
            est = run_classic3(fv, d, 4096, 50, 42, beta=beta)
            rows.append(
                (name, d, label, est.integral(), est.sigma(), est.chi2_dof())
            )
            print(
                f"// {name} d={d} {label}: I={est.integral()!r} "
                f"sigma={est.sigma()!r} chi2={est.chi2_dof()!r}",
                file=sys.stderr,
            )
    print("const GOLDEN: &[Golden] = &[")
    for name, d, label, integral, sigma, chi2 in rows:
        print(
            f'    Golden {{ name: "{name}", d: {d}, sampling: '
            f"SamplingKind::{label}, integral: {integral!r}, "
            f"sigma: {sigma!r}, chi2_dof: {chi2!r} }},"
        )
    print("];")


if __name__ == "__main__":
    main()
