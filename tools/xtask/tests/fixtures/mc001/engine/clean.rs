// No index-like identifier feeds these casts.
fn shape(dim: usize, lanes: u64) -> (u32, u32) {
    (dim as u32, lanes as u32)
}

// The comma ends the expression scan: `total_calls` is a sibling
// argument, not part of the cast operand.
fn call(total_calls: u64, dim: usize) -> u64 {
    total_calls + pack(total_calls, dim as u32)
}

fn pack(a: u64, b: u32) -> u64 {
    a + u64::from(b)
}

#[cfg(test)]
mod tests {
    #[test]
    fn casts_in_tests_are_fine() {
        let sample_idx = 7u64;
        assert_eq!(sample_idx as u32, 7);
    }
}
