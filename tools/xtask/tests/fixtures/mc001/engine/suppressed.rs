// MC001 suppressed: both placements of the directive.
fn offsets(sample_idx: u64, counter: u64) -> (u32, u32) {
    let lo = sample_idx as u32; // lint:allow(MC001, low half of a deliberately split counter)
    // lint:allow(MC001, bounded by the 4-draw block size asserted above)
    let c = (counter * 4) as u32;
    (lo, c)
}
