// MC001 true positive: narrowing a 64-bit sample index.
fn offsets(sample_idx: u64, counter: u64) -> (u32, u32) {
    let lo = sample_idx as u32;
    let c = (counter * 4) as u32;
    (lo, c)
}
