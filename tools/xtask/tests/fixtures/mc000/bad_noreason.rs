// A directive without a reason is an error (MC000): the written
// justification is the point of the mechanism.
fn f(slot: Option<u32>) -> u32 {
    slot.unwrap() // lint:allow(MC005)
}
