// An unknown rule ID in a directive is itself an error (MC000): a
// typo'd suppression must not silently do nothing.
// lint:allow(MC999, this rule does not exist)
fn f() {}
