// std::time outside the core sampling modules (api/) is allowed —
// MC003 scopes to rng/, engine/, strat/, grid/, estimator/, baselines/.
use std::time::Instant;

fn stamp() -> Instant {
    Instant::now()
}
