// MC003 true positives: wall clock + foreign RNG in a core module.
use std::time::Instant;

fn jitter() -> f64 {
    let t = Instant::now();
    let r: f64 = rand::random();
    let mut g = thread_rng();
    r + f64::from(t.elapsed().subsec_millis()) + g.gen::<f64>()
}
