// MC003 suppressed: timing a report, never feeding the sampler.
use std::time::Instant; // lint:allow(MC003, wall-clock timing for throughput reports only — never feeds sampling)

fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}
