// HashMap outside the deterministic core (report/) is not MC002's
// business — output formatting may hash freely.
use std::collections::HashMap;

fn counts(xs: &[u32]) -> HashMap<u32, usize> {
    let mut m = HashMap::new();
    for x in xs {
        *m.entry(*x).or_insert(0) += 1;
    }
    m
}
