// MC002 true positive: hash containers in a core module.
use std::collections::HashMap;

fn tally(keys: &[u64]) -> HashMap<u64, usize> {
    let mut m = HashMap::new();
    for k in keys {
        *m.entry(*k).or_insert(0) += 1;
    }
    m
}
