// lint:allow(MC002, build-time interning only — never iterated, so order cannot leak)
use std::collections::HashMap;

fn intern(names: &[&str]) -> HashMap<String, usize> { // lint:allow(MC002, lookups only)
    // lint:allow(MC002, same map as above; insert + lookups only)
    let mut m: HashMap<String, usize> = HashMap::with_capacity(names.len());
    for (i, n) in names.iter().enumerate() {
        m.insert((*n).to_string(), i);
    }
    m
}
