// engine/ is a blessed reduction module: the fixed 64-task partition
// lives here, so `+=` inside its spawn closures is the design.
fn reduce(pool: &Pool, parts: &[f64]) -> f64 {
    let mut acc = 0.0;
    pool.spawn(|| {
        for p in parts {
            acc += p;
        }
    });
    acc
}
