// MC004 suppressed: integer progress counter, not a float reduction.
fn dispatch(pool: &Pool, jobs: &[Job]) -> usize {
    let mut done = 0usize;
    pool.spawn(|| {
        for _job in jobs {
            // lint:allow(MC004, chunk-local integer progress counter — not a floating-point accumulator)
            done += 1;
        }
    });
    done
}
