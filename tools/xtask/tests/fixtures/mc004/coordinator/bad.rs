// MC004 true positive: accumulation inside a parallel closure outside
// the blessed reduction modules.
fn total(pool: &Pool, xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    pool.spawn(|| {
        for x in xs {
            acc += x;
        }
    });
    acc
}
