// util/ is allowlisted: the in-repo dev harnesses may panic freely.
fn parse(s: &str) -> u32 {
    s.trim().parse().unwrap()
}
