// MC005 true positives: panicking extractors in library code.
fn read(path: &str) -> String {
    let text = std::fs::read_to_string(path).unwrap();
    let first = text.lines().next().expect("non-empty file");
    first.to_string()
}
