// MC005 suppressed and exempt forms.
use std::sync::Mutex;

fn get(slot: &Option<u32>, m: &Mutex<u32>) -> u32 {
    // The .lock().unwrap() idiom is exempt without any directive:
    // poisoning means a sibling thread already panicked.
    let held = *m.lock().unwrap();
    // lint:allow(MC005, checked is_some() on the previous line of the real call site)
    held + slot.as_ref().expect("slot just checked")
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
