//! Fixture-based tests for the determinism linter, plus the self-lint
//! gate: the repo's own `rust/src` tree must lint clean, so `cargo
//! test` fails the moment a new violation lands without a reasoned
//! `lint:allow`.
//!
//! Each rule gets three fixtures under `tests/fixtures/mcNNN/`:
//! a true positive (`bad.rs`), the same pattern suppressed with
//! written reasons (`suppressed.rs`), and code the rule must leave
//! alone (`clean.rs` — wrong pattern, exempt idiom, or out-of-scope
//! module). Fixture subdirectories (`engine/`, `rng/`, ...) exercise
//! the path-based rule scoping.

use std::path::{Path, PathBuf};

use xtask_lint::{lint_root, Report};

fn fixtures(sub: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(sub)
}

fn lint_fixture(sub: &str) -> Report {
    lint_root(&fixtures(sub), "").expect("fixture tree readable")
}

fn keys(r: &Report) -> Vec<(&str, usize, &str)> {
    r.diagnostics
        .iter()
        .map(|d| (d.file.as_str(), d.line, d.rule))
        .collect()
}

#[test]
fn mc001_fires_suppresses_and_spares() {
    let r = lint_fixture("mc001");
    assert_eq!(
        keys(&r),
        [("engine/bad.rs", 3, "MC001"), ("engine/bad.rs", 4, "MC001")],
        "{:#?}",
        r.diagnostics
    );
    assert!(r.warnings.is_empty(), "{:?}", r.warnings);
}

#[test]
fn mc002_fires_suppresses_and_spares() {
    let r = lint_fixture("mc002");
    assert_eq!(
        keys(&r),
        [
            ("engine/bad.rs", 2, "MC002"),
            ("engine/bad.rs", 4, "MC002"),
            ("engine/bad.rs", 5, "MC002"),
        ],
        "{:#?}",
        r.diagnostics
    );
    assert!(r.warnings.is_empty(), "{:?}", r.warnings);
}

#[test]
fn mc003_fires_suppresses_and_spares() {
    let r = lint_fixture("mc003");
    assert_eq!(
        keys(&r),
        [
            ("rng/bad.rs", 2, "MC003"),
            ("rng/bad.rs", 6, "MC003"),
            ("rng/bad.rs", 7, "MC003"),
        ],
        "{:#?}",
        r.diagnostics
    );
    assert!(r.warnings.is_empty(), "{:?}", r.warnings);
}

#[test]
fn mc004_fires_suppresses_and_spares() {
    let r = lint_fixture("mc004");
    assert_eq!(
        keys(&r),
        [("coordinator/bad.rs", 7, "MC004")],
        "{:#?}",
        r.diagnostics
    );
    assert!(r.warnings.is_empty(), "{:?}", r.warnings);
}

#[test]
fn mc005_fires_suppresses_and_spares() {
    let r = lint_fixture("mc005");
    assert_eq!(
        keys(&r),
        [("api/bad.rs", 3, "MC005"), ("api/bad.rs", 4, "MC005")],
        "{:#?}",
        r.diagnostics
    );
    assert!(r.warnings.is_empty(), "{:?}", r.warnings);
}

#[test]
fn mc000_rejects_unknown_rules_and_missing_reasons() {
    let r = lint_fixture("mc000");
    // The broken suppression does not suppress: the MC005 finding
    // under it still surfaces alongside the MC000 directive error.
    assert_eq!(
        keys(&r),
        [
            ("bad_noreason.rs", 4, "MC000"),
            ("bad_noreason.rs", 4, "MC005"),
            ("bad_unknown.rs", 3, "MC000"),
        ],
        "{:#?}",
        r.diagnostics
    );
}

/// Scope pinning for the engine module layout. The rule scopes are
/// path prefixes (`engine/`, ...), so they follow the tree — but the
/// *documented* layout ("one copy of the hot loop", see
/// docs/architecture.md) is a file-level promise this test pins: the
/// shared tile walk and the stratified engine live where MC001–MC004
/// fence them, and the pre-refactor `engine/streaming.rs` (whose walk
/// was folded into `engine/walk.rs`) is gone, not lingering outside
/// anyone's attention.
#[test]
fn engine_layout_matches_rule_scope() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    for kept in ["engine/walk.rs", "engine/stratified.rs", "engine/mod.rs"] {
        assert!(
            src.join(kept).is_file(),
            "{kept} moved — update the MC001–MC004 scope notes in \
             rules.rs and docs/invariants.md"
        );
    }
    assert!(
        !src.join("engine/streaming.rs").exists(),
        "engine/streaming.rs is back — the shared walk must stay the \
         one copy of the fill→eval→reduce loop (engine/walk.rs)"
    );
}

/// The gate: the real tree lints clean. Every narrowing cast, hash
/// container, clock read, parallel accumulation, and panicking
/// extractor in rust/src is either fixed or carries a reasoned
/// lint:allow — and no suppression is stale.
#[test]
fn repo_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let r = lint_root(&root, "rust/src").expect("rust/src readable");
    assert!(
        r.diagnostics.is_empty(),
        "determinism lint violations:\n{:#?}\nfix the code or add \
         `// lint:allow(RULE, reason)` — see docs/invariants.md",
        r.diagnostics
    );
    assert!(
        r.warnings.is_empty(),
        "stale suppressions (nothing left to suppress):\n{:#?}",
        r.warnings
    );
}
