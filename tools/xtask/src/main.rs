//! `cargo xtask lint` — CLI front end for the determinism linter.
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use xtask_lint::{lint_root, Report, RULES};

const USAGE: &str = "\
usage: cargo xtask lint [--root DIR] [--format text|json] [--list-rules]

Lints rust/src (or DIR) against the determinism invariants MC001..MC005.
See docs/invariants.md for the rules and the lint:allow(RULE, reason)
suppression syntax.

  --root DIR      scan DIR instead of the repo's rust/src
  --format FMT    text (default) or json (one object per line)
  --list-rules    print the rule table and exit
";

enum Format {
    Text,
    Json,
}

struct Opts {
    root: Option<PathBuf>,
    format: Format,
    list_rules: bool,
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    let mut opts = Opts {
        root: None,
        format: Format::Text,
        list_rules: false,
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => {
                let dir = it.next().ok_or("--root requires a directory")?;
                opts.root = Some(PathBuf::from(dir));
            }
            "--format" => match it.next().map(String::as_str) {
                Some("text") => opts.format = Format::Text,
                Some("json") => opts.format = Format::Json,
                _ => return Err("--format requires `text` or `json`".into()),
            },
            "--list-rules" => opts.list_rules = true,
            "--help" | "-h" => return Err(String::new()),
            other => return Err(format!("unknown flag `{other}`")),
        }
    }
    Ok(opts)
}

/// Minimal JSON string escaping — the only JSON this binary emits.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn emit(report: &Report, format: &Format) {
    match format {
        Format::Text => {
            for d in &report.diagnostics {
                println!("{}:{}: {} {}", d.file, d.line, d.rule, d.message);
            }
            for w in &report.warnings {
                println!("warning: {w}");
            }
            if report.is_clean() {
                println!(
                    "xtask lint: clean ({} warning{})",
                    report.warnings.len(),
                    if report.warnings.len() == 1 { "" } else { "s" },
                );
            } else {
                println!(
                    "xtask lint: {} finding{}",
                    report.diagnostics.len(),
                    if report.diagnostics.len() == 1 { "" } else { "s" },
                );
            }
        }
        Format::Json => {
            for d in &report.diagnostics {
                println!(
                    "{{\"level\":\"error\",\"rule\":{},\"file\":{},\"line\":{},\"message\":{}}}",
                    json_str(d.rule),
                    json_str(&d.file),
                    d.line,
                    json_str(&d.message),
                );
            }
            for w in &report.warnings {
                println!(
                    "{{\"level\":\"warning\",\"message\":{}}}",
                    json_str(w),
                );
            }
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) != Some("lint") {
        eprint!("{USAGE}");
        return ExitCode::from(2);
    }
    let opts = match parse_opts(&args[1..]) {
        Ok(o) => o,
        Err(msg) => {
            if !msg.is_empty() {
                eprintln!("error: {msg}\n");
            }
            eprint!("{USAGE}");
            return ExitCode::from(2);
        }
    };

    if opts.list_rules {
        for r in RULES {
            println!("{}  {}\n       scope: {}", r.id, r.summary, r.scope);
        }
        return ExitCode::SUCCESS;
    }

    // Default scan root: the repo's rust/src, located relative to this
    // crate so the command works from any working directory.
    let (root, prefix) = match &opts.root {
        Some(dir) => (dir.clone(), dir.to_string_lossy().into_owned()),
        None => (
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../rust/src"),
            "rust/src".to_string(),
        ),
    };

    match lint_root(&root, &prefix) {
        Ok(report) => {
            emit(&report, &opts.format);
            if report.is_clean() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(err) => {
            eprintln!("error: cannot lint {}: {err}", root.display());
            ExitCode::from(2)
        }
    }
}
