//! A minimal Rust lexer — just enough structure for the determinism
//! lint rules (tools/xtask), with zero dependencies so the workspace
//! keeps building fully offline (no `syn`, no `proc-macro2`).
//!
//! The token stream deliberately stays close to the source text:
//! comments and string/char literals are recognized (so rule patterns
//! never match inside them) but their contents are not interpreted,
//! and numeric literals are single opaque tokens. Line numbers are
//! tracked through every multi-line construct (block comments, plain
//! and raw strings) because diagnostics and `lint:allow` suppression
//! are line-addressed.

/// Token classification — only as fine-grained as the rules need.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    CharLit,
    Lifetime,
    Punct,
}

/// One source token with its starting line (1-based).
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// One `//` comment (block comments are skipped entirely — suppression
/// directives must be line comments).
#[derive(Debug, Clone)]
pub struct Comment {
    /// Full comment text including the leading `//`.
    pub text: String,
    /// Line the comment starts on (1-based).
    pub line: usize,
    /// True when a token precedes the comment on the same line — a
    /// trailing comment annotates its own line, a full-line comment
    /// annotates the line directly below it.
    pub trailing: bool,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Length (in chars) and newline count of a double-quoted string
/// starting at `c[0] == '"'`.
fn dq_string_len(c: &[char]) -> (usize, usize) {
    let mut i = 1;
    let mut nl = 0;
    while i < c.len() {
        match c[i] {
            '\\' => {
                if c.get(i + 1) == Some(&'\n') {
                    nl += 1;
                }
                i += 2;
            }
            '"' => return (i + 1, nl),
            '\n' => {
                nl += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (c.len(), nl)
}

/// Length of a char/byte literal starting at `c[0] == '\''`.
fn char_lit_len(c: &[char]) -> usize {
    let mut i = 1;
    while i < c.len() {
        match c[i] {
            '\\' => i += 2,
            '\'' => return i + 1,
            _ => i += 1,
        }
    }
    c.len()
}

/// Recognize `r".."`, `r#".."#`, `br".."`, ... starting at `c[0]`.
/// Returns the total length and newline count, or `None` if this is
/// not a raw string (e.g. an identifier that merely starts with `r`).
fn raw_string_len(c: &[char]) -> Option<(usize, usize)> {
    let mut i = 0;
    if c.get(i) == Some(&'b') {
        i += 1;
    }
    if c.get(i) != Some(&'r') {
        return None;
    }
    i += 1;
    let mut hashes = 0;
    while c.get(i) == Some(&'#') {
        hashes += 1;
        i += 1;
    }
    if c.get(i) != Some(&'"') {
        return None;
    }
    i += 1;
    let mut nl = 0;
    loop {
        match c.get(i) {
            None => return Some((i, nl)),
            Some('\n') => {
                nl += 1;
                i += 1;
            }
            Some('"') => {
                i += 1;
                let mut h = 0;
                while h < hashes && c.get(i) == Some(&'#') {
                    h += 1;
                    i += 1;
                }
                if h == hashes {
                    return Some((i, nl));
                }
            }
            Some(_) => i += 1,
        }
    }
}

/// Multi-character punctuation recognized as single tokens. Kept
/// deliberately small: the rule engine's backward expression scan
/// treats a bare `=` as a statement boundary, so `==` is left as two
/// `=` tokens (a comparison also ends the expression being cast).
const MULTI_PUNCT: &[&str] = &[
    "+=", "-=", "*=", "/=", "::", "->", "=>", "..", "&&", "||", "<<", ">>",
];

/// Tokenize `src`, returning the token stream and the line comments.
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let c: Vec<char> = src.chars().collect();
    let n = c.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0;
    let mut line = 1;
    let mut last_tok_line = 0;

    while i < n {
        let ch = c[i];
        if ch == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if ch == ' ' || ch == '\t' || ch == '\r' {
            i += 1;
            continue;
        }
        // Line comment (also covers `///` and `//!` docs).
        if ch == '/' && c.get(i + 1) == Some(&'/') {
            let start = i;
            while i < n && c[i] != '\n' {
                i += 1;
            }
            comments.push(Comment {
                text: c[start..i].iter().collect(),
                line,
                trailing: last_tok_line == line,
            });
            continue;
        }
        // Block comment, nesting allowed.
        if ch == '/' && c.get(i + 1) == Some(&'*') {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if c[i] == '/' && c.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if c[i] == '*' && c.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if c[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw strings and byte strings/chars before plain identifiers,
        // since they share their first characters with idents.
        if ch == 'r' || ch == 'b' {
            if let Some((len, nl)) = raw_string_len(&c[i..]) {
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: String::new(),
                    line,
                });
                line += nl;
                last_tok_line = line;
                i += len;
                continue;
            }
            if ch == 'b' && c.get(i + 1) == Some(&'"') {
                let (len, nl) = dq_string_len(&c[i + 1..]);
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: String::new(),
                    line,
                });
                line += nl;
                last_tok_line = line;
                i += 1 + len;
                continue;
            }
            if ch == 'b' && c.get(i + 1) == Some(&'\'') {
                let len = char_lit_len(&c[i + 1..]);
                toks.push(Tok {
                    kind: TokKind::CharLit,
                    text: String::new(),
                    line,
                });
                last_tok_line = line;
                i += 1 + len;
                continue;
            }
            // Fall through: plain identifier starting with r/b.
        }
        if ch == '"' {
            let (len, nl) = dq_string_len(&c[i..]);
            toks.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line,
            });
            line += nl;
            last_tok_line = line;
            i += len;
            continue;
        }
        if ch == '\'' {
            // Lifetime (`'a`, `'static`) vs char literal (`'x'`, `'\n'`).
            if i + 1 < n && is_ident_start(c[i + 1]) {
                let mut j = i + 1;
                while j < n && is_ident_cont(c[j]) {
                    j += 1;
                }
                if c.get(j) == Some(&'\'') {
                    toks.push(Tok {
                        kind: TokKind::CharLit,
                        text: String::new(),
                        line,
                    });
                    last_tok_line = line;
                    i = j + 1;
                } else {
                    toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: c[i..j].iter().collect(),
                        line,
                    });
                    last_tok_line = line;
                    i = j;
                }
                continue;
            }
            let len = char_lit_len(&c[i..]);
            toks.push(Tok {
                kind: TokKind::CharLit,
                text: String::new(),
                line,
            });
            last_tok_line = line;
            i += len;
            continue;
        }
        if is_ident_start(ch) {
            let mut j = i + 1;
            while j < n && is_ident_cont(c[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: c[i..j].iter().collect(),
                line,
            });
            last_tok_line = line;
            i = j;
            continue;
        }
        if ch.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && (c[j].is_ascii_alphanumeric() || c[j] == '_') {
                j += 1;
            }
            // Fractional part — but `0..n` is a range and `1.max(2)` a
            // method call, so the dot must not be followed by another
            // dot or an identifier start.
            if c.get(j) == Some(&'.')
                && !matches!(c.get(j + 1), Some(&d) if d == '.' || is_ident_start(d))
            {
                j += 1;
                while j < n && (c[j].is_ascii_alphanumeric() || c[j] == '_') {
                    j += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: c[i..j].iter().collect(),
                line,
            });
            last_tok_line = line;
            i = j;
            continue;
        }
        // Punctuation: longest match first.
        let mut matched = false;
        for p in MULTI_PUNCT {
            let pc: Vec<char> = p.chars().collect();
            if c[i..].starts_with(&pc) {
                toks.push(Tok {
                    kind: TokKind::Punct,
                    text: (*p).to_string(),
                    line,
                });
                last_tok_line = line;
                i += pc.len();
                matched = true;
                break;
            }
        }
        if !matched {
            toks.push(Tok {
                kind: TokKind::Punct,
                text: ch.to_string(),
                line,
            });
            last_tok_line = line;
            i += 1;
        }
    }
    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).0.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_and_casts() {
        assert_eq!(texts("let x = idx as u32;"), ["let", "x", "=", "idx", "as", "u32", ";"]);
    }

    #[test]
    fn range_vs_float() {
        assert_eq!(texts("0..10"), ["0", "..", "10"]);
        assert_eq!(texts("1.5e3"), ["1.5e3"]);
        assert_eq!(texts("1.max(2)"), ["1", ".", "max", "(", "2", ")"]);
    }

    #[test]
    fn strings_and_chars_are_opaque() {
        let (toks, _) = lex("f(\"as u32 // not a comment\", 'x', b'\\n')");
        assert!(toks.iter().all(|t| t.text != "u32"));
    }

    #[test]
    fn raw_string_with_hashes() {
        let (toks, comments) = lex("let s = r#\"multi\nline // no\"#; // yes");
        assert!(toks.iter().any(|t| t.kind == TokKind::Str));
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].line, 2);
        assert!(comments[0].trailing);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let (toks, _) = lex("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Lifetime).count(), 3);
    }

    #[test]
    fn comment_lines_and_trailing() {
        let (_, comments) = lex("// top\nlet x = 1; // side\n// bottom\n");
        assert_eq!(comments.len(), 3);
        assert!(!comments[0].trailing);
        assert!(comments[1].trailing);
        assert_eq!(comments[1].line, 2);
        assert!(!comments[2].trailing);
        assert_eq!(comments[2].line, 3);
    }

    #[test]
    fn block_comments_nest_and_count_lines() {
        let (toks, _) = lex("/* a /* b\n */ c\n*/ token");
        assert_eq!(toks.len(), 1);
        assert_eq!(toks[0].line, 3);
    }
}
