//! The determinism-invariant rules (MC001–MC005).
//!
//! Each rule is a small token-pattern check over the lexed stream from
//! [`crate::lexer`]. They over-approximate on purpose: a false positive
//! costs one `// lint:allow(RULE, reason)` line with a written
//! justification, while a false negative silently re-opens a bug class
//! this project has already shipped once (the PR 5 sample-counter
//! truncation). docs/invariants.md maps every rule to the
//! reproducibility contract clause it protects.

use crate::lexer::{Tok, TokKind};

/// A single rule finding or directive error, before suppression.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub line: usize,
    pub message: String,
}

/// Static description of one rule, for `--list-rules` and docs tests.
pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
    pub scope: &'static str,
}

/// Every real rule. `MC000` (malformed/unknown `lint:allow`) is a
/// meta-rule emitted by the directive parser, not listed here.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "MC001",
        summary: "no lossy narrowing cast on sample-index/counter/offset expressions",
        scope: "all of rust/src",
    },
    RuleInfo {
        id: "MC002",
        summary: "no HashMap/HashSet in deterministic core modules",
        scope: "engine/, strat/, estimator/, grid/, shard/",
    },
    RuleInfo {
        id: "MC003",
        summary: "no std::time, rand::, or thread_rng in core sampling modules",
        scope: "rng/, engine/, strat/, grid/, estimator/, baselines/, store/, shard/",
    },
    RuleInfo {
        id: "MC004",
        summary: "no `+=` accumulation inside parallel closures outside blessed reduction modules",
        scope: "all of rust/src except engine/, estimator/",
    },
    RuleInfo {
        id: "MC005",
        summary: "no unwrap()/expect() in non-test library code",
        scope: "all of rust/src except util/, main.rs",
    },
];

/// True if `id` names a suppressible rule.
pub fn is_known_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// Narrow integer types whose `as` casts can truncate a 64-bit index.
const NARROW: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32", "usize"];

/// Identifier substrings that mark an expression as index/counter-like.
const INDEX_WORDS: &[&str] = &[
    "sample",
    "sidx",
    "idx",
    "index",
    "counter",
    "offset",
    "cube",
    "iteration",
    "ncall",
    "total_calls",
];

/// Tokens that end the backward scan for the expression being cast
/// (statement/argument boundaries at nesting depth zero).
const EXPR_STOP: &[&str] = &[
    ",", ";", "=", "{", "}", "=>", "let", "return", "+=", "..",
];

fn path_in(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.contains(p))
}

fn in_spans(spans: &[(usize, usize)], i: usize) -> bool {
    spans.iter().any(|&(a, b)| a <= i && i <= b)
}

/// Token-index spans covered by `#[cfg(test)]` items and `#[test]`
/// functions — rule findings inside them are dropped (tests may use
/// unwrap, HashMap scratch state, wall clocks, ...).
pub fn test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].text != "#" || toks.get(i + 1).map(|t| t.text.as_str()) != Some("[") {
            i += 1;
            continue;
        }
        // Collect the attribute tokens up to the matching `]`.
        let mut j = i + 2;
        let mut depth = 1;
        let mut attr: Vec<&str> = Vec::new();
        while j < toks.len() && depth > 0 {
            match toks[j].text.as_str() {
                "[" => depth += 1,
                "]" => depth -= 1,
                _ => {}
            }
            if depth > 0 {
                attr.push(&toks[j].text);
            }
            j += 1;
        }
        // `test` counts unless it is negated as `not(test)` (the
        // `#[cfg(not(test))]` guard marks *production*-only code).
        let is_test = attr.iter().enumerate().any(|(k, t)| {
            *t == "test"
                && !(k >= 2 && attr[k - 2] == "not" && attr[k - 1] == "(")
        });
        if !is_test {
            i = j;
            continue;
        }
        // Span runs to the `}` closing the first `{` after the
        // attribute (the annotated fn/mod body).
        let mut k = j;
        while k < toks.len() && toks[k].text != "{" {
            k += 1;
        }
        let open = k;
        let mut braces = 0;
        while k < toks.len() {
            match toks[k].text.as_str() {
                "{" => braces += 1,
                "}" => {
                    braces -= 1;
                    if braces == 0 {
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        spans.push((open, k));
        i = j;
    }
    spans
}

/// MC001 — walk backwards from each `as <narrow-int>` collecting the
/// identifiers of the expression being cast; flag the cast if any of
/// them looks like a sample index, counter, or offset.
fn mc001(toks: &[Tok], spans: &[(usize, usize)], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        if toks[i].text != "as" || toks[i].kind != TokKind::Ident {
            continue;
        }
        let Some(ty) = toks.get(i + 1) else { continue };
        if !NARROW.contains(&ty.text.as_str()) || in_spans(spans, i) {
            continue;
        }
        let mut idents: Vec<&str> = Vec::new();
        let mut depth = 0usize;
        let mut j = i;
        while j > 0 {
            j -= 1;
            let t = toks[j].text.as_str();
            if t == ")" || t == "]" {
                depth += 1;
            } else if t == "(" || t == "[" {
                if depth == 0 {
                    break;
                }
                depth -= 1;
            } else if depth == 0 && EXPR_STOP.contains(&t) {
                break;
            } else if toks[j].kind == TokKind::Ident {
                idents.push(t);
            }
        }
        let hit = idents.iter().find(|id| {
            let lower = id.to_ascii_lowercase();
            INDEX_WORDS.iter().any(|w| lower.contains(w))
        });
        if let Some(id) = hit {
            out.push(Finding {
                rule: "MC001",
                line: toks[i].line,
                message: format!(
                    "lossy `as {}` cast on index-like expression (involves `{id}`); \
                     use u64 end-to-end or prove the bound and lint:allow with the proof",
                    ty.text
                ),
            });
        }
    }
}

/// MC002 — hash containers iterate in randomized order; the
/// deterministic core must use BTreeMap/BTreeSet/Vec instead.
fn mc002(rel: &str, toks: &[Tok], spans: &[(usize, usize)], out: &mut Vec<Finding>) {
    if !path_in(rel, &["engine/", "strat/", "estimator/", "grid/", "shard/"]) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if (t.text == "HashMap" || t.text == "HashSet") && !in_spans(spans, i) {
            out.push(Finding {
                rule: "MC002",
                line: t.line,
                message: format!(
                    "`{}` in a deterministic core module — iteration order is \
                     randomized per-process; use BTreeMap/BTreeSet or a Vec",
                    t.text
                ),
            });
        }
    }
}

/// MC003 — core sampling modules must draw entropy from Philox only
/// and must not read wall clocks.
fn mc003(rel: &str, toks: &[Tok], spans: &[(usize, usize)], out: &mut Vec<Finding>) {
    if !path_in(
        rel,
        &[
            "rng/", "engine/", "strat/", "grid/", "estimator/", "baselines/", "store/", "shard/",
        ],
    ) {
        return;
    }
    for i in 0..toks.len() {
        if in_spans(spans, i) {
            continue;
        }
        let t = toks[i].text.as_str();
        let next = toks.get(i + 1).map(|t| t.text.as_str());
        let hit = if t == "std"
            && next == Some("::")
            && toks.get(i + 2).map(|t| t.text.as_str()) == Some("time")
        {
            Some("std::time")
        } else if t == "rand" && next == Some("::") {
            Some("rand::")
        } else if t == "thread_rng" {
            Some("thread_rng")
        } else {
            None
        };
        if let Some(what) = hit {
            out.push(Finding {
                rule: "MC003",
                line: toks[i].line,
                message: format!(
                    "`{what}` in a core sampling module — Philox counters are the \
                     only entropy source and runs must not depend on wall clocks"
                ),
            });
        }
    }
}

/// MC004 — `+=` inside the argument list of `spawn(..)` or
/// `parallel_chunks(..)` outside the blessed reduction modules.
/// Over-approximates (any `+=`, not just f64): accumulation order
/// inside parallel closures is exactly what the fixed 64-task
/// reduction partition exists to control.
fn mc004(rel: &str, toks: &[Tok], spans: &[(usize, usize)], out: &mut Vec<Finding>) {
    if path_in(rel, &["engine/", "estimator/"]) {
        return;
    }
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident
            || (toks[i].text != "spawn" && toks[i].text != "parallel_chunks")
            || toks.get(i + 1).map(|t| t.text.as_str()) != Some("(")
        {
            continue;
        }
        let callee = &toks[i].text;
        let mut depth = 0i64;
        let mut j = i + 1;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "(" => depth += 1,
                ")" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                "+=" => {
                    if !in_spans(spans, j) {
                        out.push(Finding {
                            rule: "MC004",
                            line: toks[j].line,
                            message: format!(
                                "`+=` inside a `{callee}(..)` closure — parallel \
                                 accumulation belongs in the fixed reduction \
                                 partition (engine/, estimator/)"
                            ),
                        });
                    }
                }
                _ => {}
            }
            j += 1;
        }
    }
}

/// MC005 — panicking extractors in non-test library code. `util/` and
/// `main.rs` are allowlisted (dev harness + CLI top level), and the
/// `.lock().unwrap()` idiom is exempt: lock poisoning already means a
/// sibling thread panicked, so propagating is the right move.
fn mc005(rel: &str, toks: &[Tok], spans: &[(usize, usize)], out: &mut Vec<Finding>) {
    if path_in(rel, &["util/"]) || rel.ends_with("main.rs") {
        return;
    }
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident
            || (toks[i].text != "unwrap" && toks[i].text != "expect")
            || i == 0
            || toks[i - 1].text != "."
            || toks.get(i + 1).map(|t| t.text.as_str()) != Some("(")
            || in_spans(spans, i)
        {
            continue;
        }
        let after_lock = i >= 4
            && toks[i - 2].text == ")"
            && toks[i - 3].text == "("
            && toks[i - 4].text == "lock";
        if after_lock {
            continue;
        }
        out.push(Finding {
            rule: "MC005",
            line: toks[i].line,
            message: format!(
                "`.{}()` in library code — return Error (see rust/src/error.rs) \
                 or prove infallibility and lint:allow with the proof",
                toks[i].text
            ),
        });
    }
}

/// Run every rule over one file. `rel` is the path relative to the
/// scan root, with `/` separators (used for module scoping).
pub fn check_tokens(rel: &str, toks: &[Tok]) -> Vec<Finding> {
    let spans = test_spans(toks);
    let mut out = Vec::new();
    mc001(toks, &spans, &mut out);
    mc002(rel, toks, &spans, &mut out);
    mc003(rel, toks, &spans, &mut out);
    mc004(rel, toks, &spans, &mut out);
    mc005(rel, toks, &spans, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    // Nested `spawn(spawn(..))` style code can report one site twice;
    // a (rule, line) pair is one finding.
    out.dedup_by(|a, b| a.rule == b.rule && a.line == b.line);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        check_tokens(rel, &lex(src).0)
    }

    #[test]
    fn mc001_flags_index_cast_and_spares_dim_cast() {
        let f = run("engine/block.rs", "let a = sample_idx as u32;\nlet b = dim as u32;\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "MC001");
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn mc001_scan_stops_at_argument_boundary() {
        // The comma separates `total_calls` from the expression
        // actually being cast.
        let f = run("engine/block.rs", "f(total_calls, dim as u32);\n");
        assert!(f.is_empty());
    }

    #[test]
    fn mc002_only_in_core_modules() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(run("strat/mod.rs", src).len(), 1);
        assert!(run("report/mod.rs", src).is_empty());
    }

    #[test]
    fn mc003_patterns() {
        let src = "use std::time::Instant;\nlet r = rand::random();\nlet t = thread_rng();\n";
        assert_eq!(run("rng/philox.rs", src).len(), 3);
        assert!(run("api/session.rs", src).is_empty());
    }

    #[test]
    fn mc004_blessed_modules_pass() {
        let src = "pool.spawn(move || { acc += x; });\n";
        assert_eq!(run("coordinator/service.rs", src).len(), 1);
        assert!(run("engine/mod.rs", src).is_empty());
    }

    #[test]
    fn shared_walk_is_in_rule_scope() {
        // The shared tile walk (engine/walk.rs) — the one copy of the
        // fill→eval→reduce loop every native `Engine` samples through —
        // must sit inside the same fences as the rest of engine/:
        // MC002/MC003 flag hash containers and clocks there, while
        // MC004 blesses its per-task tile accumulation (it *is* the
        // fixed 64-task reduction partition) — and keeps flagging
        // everyone else. The stratified engine shares the fences too.
        let clock = "use std::time::Instant;\n";
        let f = run("engine/walk.rs", clock);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "MC003");
        let hash = "use std::collections::HashMap;\n";
        assert_eq!(run("engine/walk.rs", hash)[0].rule, "MC002");
        assert_eq!(run("engine/stratified.rs", hash)[0].rule, "MC002");
        let cast = "let lo = sample_idx as u32;\n";
        assert_eq!(run("engine/walk.rs", cast)[0].rule, "MC001");
        let acc = "parallel_chunks(n, t, |a, b| { s += a; });\n";
        assert!(run("engine/walk.rs", acc).is_empty());
        assert!(run("engine/stratified.rs", acc).is_empty());
        assert_eq!(run("coordinator/backend.rs", acc).len(), 1);
    }

    #[test]
    fn shard_module_is_in_rule_scope() {
        // shard/ merges the distributed partials, so it sits inside
        // the same determinism fences as the engine core: hash
        // containers, clocks, and parallel `+=` are all flagged there
        // (the shard sources justify their timeout clocks with
        // per-line lint:allow directives).
        let hash = "use std::collections::HashMap;\n";
        assert_eq!(run("shard/plan.rs", hash)[0].rule, "MC002");
        let clock = "use std::time::Instant;\n";
        assert_eq!(run("shard/worker.rs", clock)[0].rule, "MC003");
        let acc = "parallel_chunks(n, t, |a, b| { s += a; });\n";
        assert_eq!(run("shard/backend.rs", acc)[0].rule, "MC004");
    }

    #[test]
    fn mc005_lock_unwrap_exempt() {
        let src = "let g = m.lock().unwrap();\nlet v = o.unwrap();\n";
        let f = run("api/session.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn test_code_is_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { o.unwrap(); }\n}\n";
        assert!(run("api/session.rs", src).is_empty());
    }

    #[test]
    fn cfg_not_test_is_production_code() {
        let src = "#[cfg(not(test))]\nfn f() { o.unwrap(); }\n";
        assert_eq!(run("api/session.rs", src).len(), 1);
    }
}
