//! `xtask_lint` — the m-Cubes determinism-invariant linter behind
//! `cargo xtask lint`.
//!
//! The reproducibility contract (docs/invariants.md) promises that a
//! `(seed, grid, call-budget)` triple fully determines every sample
//! and therefore every result, independent of thread count, chunk
//! size, and SIMD lane width. Five rule IDs guard the code patterns
//! that historically break that promise:
//!
//! * **MC001** — lossy narrowing casts on sample-index/counter/offset
//!   expressions (the PR 5 truncation bug class).
//! * **MC002** — HashMap/HashSet in deterministic core modules.
//! * **MC003** — wall clocks or foreign RNGs in core sampling modules.
//! * **MC004** — `+=` accumulation inside parallel closures outside
//!   the blessed reduction modules.
//! * **MC005** — `unwrap()`/`expect()` in non-test library code.
//!
//! False positives are suppressed in-source with a written reason:
//!
//! ```text
//! let lo = sample_idx as u32; // lint:allow(MC001, deliberate split — low 32 bits)
//! ```
//!
//! A trailing directive suppresses its own line; a directive on a line
//! of its own suppresses the line directly below it. The reason is
//! mandatory, unknown rule IDs are themselves an error (**MC000**),
//! and suppressions that match nothing are reported as warnings so
//! stale allows surface when the code under them improves.

pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::Path;

pub use rules::{Finding, RuleInfo, RULES};

/// A finding that survived suppression, tagged with its file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// Lint result for one file or one whole tree.
#[derive(Debug, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    /// Non-fatal notes (currently: unused suppressions).
    pub warnings: Vec<String>,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// One parsed `lint:allow(RULE, reason)` directive.
#[derive(Debug)]
struct Directive {
    /// Line the directive suppresses (its own line if trailing, the
    /// next line otherwise).
    applies_to: usize,
    rule: String,
    used: bool,
}

const DIRECTIVE: &str = "lint:allow(";

/// Parse every directive out of the file's line comments. Malformed
/// directives become MC000 findings — a suppression that silently
/// failed to parse must not look like a clean file.
fn parse_directives(comments: &[lexer::Comment]) -> (Vec<Directive>, Vec<Finding>) {
    let mut dirs = Vec::new();
    let mut errors = Vec::new();
    for c in comments {
        let mut rest = c.text.as_str();
        while let Some(pos) = rest.find(DIRECTIVE) {
            let body = &rest[pos + DIRECTIVE.len()..];
            // Directive arguments run to the matching close paren
            // (reasons may contain balanced parentheses).
            let mut depth = 1usize;
            let mut end = None;
            for (i, ch) in body.char_indices() {
                match ch {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            end = Some(i);
                            break;
                        }
                    }
                    _ => {}
                }
            }
            let Some(end) = end else {
                errors.push(Finding {
                    rule: "MC000",
                    line: c.line,
                    message: "unterminated lint:allow directive — missing `)`".into(),
                });
                break;
            };
            let args = &body[..end];
            rest = &body[end + 1..];
            let (rule, reason) = match args.split_once(',') {
                Some((r, why)) => (r.trim(), why.trim()),
                None => (args.trim(), ""),
            };
            if !rules::is_known_rule(rule) {
                errors.push(Finding {
                    rule: "MC000",
                    line: c.line,
                    message: format!(
                        "unknown rule `{rule}` in lint:allow (known: MC001..MC005)"
                    ),
                });
                continue;
            }
            if reason.is_empty() {
                errors.push(Finding {
                    rule: "MC000",
                    line: c.line,
                    message: format!(
                        "lint:allow({rule}) without a reason — write down why the \
                         invariant holds here"
                    ),
                });
                continue;
            }
            dirs.push(Directive {
                applies_to: if c.trailing { c.line } else { c.line + 1 },
                rule: rule.to_string(),
                used: false,
            });
        }
    }
    (dirs, errors)
}

/// Lint one file's source text. `rel` is its path relative to the scan
/// root using `/` separators — rule scoping matches on it, and it
/// becomes the `file` field of each diagnostic.
pub fn lint_source(rel: &str, src: &str) -> Report {
    let (toks, comments) = lexer::lex(src);
    let findings = rules::check_tokens(rel, &toks);
    let (mut dirs, directive_errors) = parse_directives(&comments);

    let mut report = Report::default();
    for f in findings {
        let mut suppressed = false;
        for d in dirs
            .iter_mut()
            .filter(|d| d.rule == f.rule && d.applies_to == f.line)
        {
            d.used = true;
            suppressed = true;
        }
        if !suppressed {
            report.diagnostics.push(Diagnostic {
                file: rel.to_string(),
                line: f.line,
                rule: f.rule,
                message: f.message,
            });
        }
    }
    for e in directive_errors {
        report.diagnostics.push(Diagnostic {
            file: rel.to_string(),
            line: e.line,
            rule: e.rule,
            message: e.message,
        });
    }
    for d in dirs.iter().filter(|d| !d.used) {
        report.warnings.push(format!(
            "{rel}:{line}: unused lint:allow({rule}) — nothing to suppress here",
            line = d.applies_to,
            rule = d.rule,
        ));
    }
    report
        .diagnostics
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    report
}

/// Collect `*.rs` files under `root`, sorted by relative path so runs
/// are deterministic regardless of directory-entry order.
fn walk(root: &Path) -> io::Result<Vec<std::path::PathBuf>> {
    let mut files = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

/// Lint every `*.rs` file under `root`. Diagnostics carry paths of the
/// form `{prefix}/{relative}` so output is readable from the repo root
/// (pass `prefix = "rust/src"` when scanning that tree).
pub fn lint_root(root: &Path, prefix: &str) -> io::Result<Report> {
    let mut total = Report::default();
    for path in walk(root)? {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let display = if prefix.is_empty() {
            rel
        } else {
            format!("{}/{rel}", prefix.trim_end_matches('/'))
        };
        let src = fs::read_to_string(&path)?;
        // Scoping matches on the root-relative path, display on the
        // prefixed one; both agree on every suffix the rules test.
        let mut rep = lint_source(&display, &src);
        total.diagnostics.append(&mut rep.diagnostics);
        total.warnings.append(&mut rep.warnings);
    }
    total
        .diagnostics
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trailing_directive_suppresses_own_line() {
        let r = lint_source(
            "engine/x.rs",
            "let a = sample_idx as u32; // lint:allow(MC001, low half of a split counter)\n",
        );
        assert!(r.is_clean(), "{:?}", r.diagnostics);
        assert!(r.warnings.is_empty());
    }

    #[test]
    fn own_line_directive_suppresses_next_line() {
        let r = lint_source(
            "engine/x.rs",
            "// lint:allow(MC001, low half of a split counter)\nlet a = sample_idx as u32;\n",
        );
        assert!(r.is_clean(), "{:?}", r.diagnostics);
    }

    #[test]
    fn directive_does_not_reach_past_one_line() {
        let r = lint_source(
            "engine/x.rs",
            "// lint:allow(MC001, too far away)\n\nlet a = sample_idx as u32;\n",
        );
        assert_eq!(r.diagnostics.len(), 1);
        assert_eq!(r.diagnostics[0].line, 3);
        assert_eq!(r.warnings.len(), 1);
    }

    #[test]
    fn unknown_rule_is_mc000() {
        let r = lint_source("api/x.rs", "// lint:allow(MC999, bogus)\nfn f() {}\n");
        assert_eq!(r.diagnostics.len(), 1);
        assert_eq!(r.diagnostics[0].rule, "MC000");
    }

    #[test]
    fn missing_reason_is_mc000() {
        let r = lint_source(
            "api/x.rs",
            "let v = o.unwrap(); // lint:allow(MC005)\n",
        );
        assert_eq!(r.diagnostics.len(), 2, "{:?}", r.diagnostics);
        assert!(r.diagnostics.iter().any(|d| d.rule == "MC000"));
        assert!(r.diagnostics.iter().any(|d| d.rule == "MC005"));
    }

    #[test]
    fn wrong_rule_does_not_suppress() {
        let r = lint_source(
            "api/x.rs",
            "let v = o.unwrap(); // lint:allow(MC001, wrong rule)\n",
        );
        assert!(r.diagnostics.iter().any(|d| d.rule == "MC005"));
        assert_eq!(r.warnings.len(), 1);
    }

    #[test]
    fn unused_suppression_warns_but_passes() {
        let r = lint_source(
            "api/x.rs",
            "// lint:allow(MC005, nothing here anymore)\nlet v = 1;\n",
        );
        assert!(r.is_clean());
        assert_eq!(r.warnings.len(), 1);
    }
}
