//! Shard-execution equivalence suite: an N-shard run must be bitwise
//! identical to the single-worker run — through the facade, on both
//! execution schedules, on both sampling modes, over both transports
//! (in-process pool and spool directory), across straggler fallbacks,
//! and through a suspend/checkpoint/resume cycle.
//!
//! The unit layers (rust/src/shard/*) pin the per-component contracts;
//! this suite pins the end-to-end ones the README advertises.

use mcubes::coordinator::VSampleBackend;
use mcubes::integrands::by_name;
use mcubes::prelude::*;
use mcubes::shard::spool_file_name;
use std::path::PathBuf;
use std::time::Duration;

fn scratch(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("mcubes-shard-equiv-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn assert_same_bits(a: &IntegrationOutput, b: &IntegrationOutput) {
    assert_eq!(a.integral.to_bits(), b.integral.to_bits());
    assert_eq!(a.sigma.to_bits(), b.sigma.to_bits());
    assert_eq!(a.iterations, b.iterations);
}

/// The headline contract: 8 shards reproduce the single worker
/// bitwise through the `Integrator` facade, for every combination of
/// sampling mode (uniform m-Cubes, VEGAS+) and execution schedule
/// (fused streaming, block pipeline).
#[test]
fn eight_shards_match_single_worker_across_modes_and_schedules() {
    for sampling in [Sampling::Uniform, Sampling::vegas_plus()] {
        for exec in [ExecPath::Streaming, ExecPath::Block] {
            let run = |shards: usize| {
                Integrator::from_registry("f4", 5)
                    .unwrap()
                    .maxcalls(1 << 12)
                    .tolerance(1e-12)
                    .plan(RunPlan::classic(5, 3, 0))
                    .seed(23)
                    .threads(2)
                    .sampling(sampling)
                    .exec(exec)
                    .shards(shards)
                    .run()
                    .unwrap()
            };
            let single = run(1);
            let sharded = run(8);
            assert_same_bits(&sharded, &single);
        }
    }
}

/// Shard planning stays exact past the 2^32-call boundary (the PR 5
/// truncation-bug class): a d=1 layout with 2^33 total calls
/// partitions tasks, cubes, and 64-bit Philox counters with no
/// overlap and no loss. Pure arithmetic — nothing is evaluated.
#[test]
fn plan_arithmetic_is_exact_past_two_to_the_32_calls() {
    let layout = Layout::compute(1, 1usize << 33, 50, 8).unwrap();
    assert!(layout.calls() > 1usize << 32, "layout must exceed 2^32 calls");
    let plan = ShardPlan::uniform(&layout, 8);
    assert_eq!(plan.nshards(), 8);
    let spans = plan.spans();
    assert_eq!(spans[0].task_lo, 0);
    assert_eq!(spans[0].cube_lo, 0);
    assert_eq!(spans[0].counter_lo, 0);
    for w in spans.windows(2) {
        assert_eq!(w[0].task_hi, w[1].task_lo);
        assert_eq!(w[0].cube_hi, w[1].cube_lo);
        assert_eq!(w[0].counter_hi, w[1].counter_lo);
    }
    let last = spans[spans.len() - 1];
    assert_eq!(last.task_hi, plan.ntasks());
    assert_eq!(last.cube_hi, layout.m);
    assert_eq!(last.counter_hi, layout.calls() as u64);
    assert!(last.counter_hi > u64::from(u32::MAX), "counters span past u32");
}

/// The spool (process) transport reproduces both the in-process
/// sharded run and the single worker bitwise, with an external-style
/// worker loop (here: a thread running the same `run_spool_worker`
/// the `mcubes shard-worker` CLI calls) computing every span.
#[test]
fn spool_transport_matches_in_process_and_single_worker_bitwise() {
    let run = |shards: usize, dir: Option<&PathBuf>| {
        let mut intg = Integrator::from_registry("f4", 4)
            .unwrap()
            .maxcalls(1 << 11)
            .tolerance(1e-12)
            .plan(RunPlan::classic(4, 2, 0))
            .seed(77)
            .threads(2)
            .sampling(Sampling::vegas_plus())
            .shards(shards);
        if let Some(d) = dir {
            intg = intg.shard_dir(d.to_str().unwrap());
        }
        intg.run().unwrap()
    };
    let single = run(1, None);
    let in_process = run(4, None);
    assert_same_bits(&in_process, &single);

    let dir = scratch("spool-run");
    let worker_dir = dir.clone();
    let worker = std::thread::spawn(move || {
        run_spool_worker(&worker_dir, 1, Duration::from_millis(1), None).unwrap()
    });
    let spooled = run(4, Some(&dir));
    spool_close(&dir).unwrap();
    let outcome = worker.join().unwrap();
    assert_same_bits(&spooled, &single);
    assert!(outcome.processed > 0, "the spool worker computed spans");
    let _ = std::fs::remove_dir_all(dir);
}

/// Straggler policy: with no live worker and a pre-poisoned (torn)
/// report in the spool, every shard takes the local-fallback path —
/// and the merged result is still bitwise the in-process one.
#[test]
fn torn_reports_and_dead_workers_fall_back_bitwise() {
    let layout = Layout::compute(4, 2048, 10, 2).unwrap();
    let bins = Bins::uniform(4, 10);
    let f = by_name("f2", 4).unwrap();
    let mut reference =
        ShardedBackend::new(f.clone(), layout, 4, 2, Sampling::Uniform, None).unwrap();
    let want = reference.run(&bins, 11, 0, true).unwrap();

    let dir = scratch("straggler");
    let opts = SpoolOptions {
        timeout: Duration::from_millis(100),
        poll: Duration::from_millis(1),
        max_retries: 1,
        local_fallback: true,
    };
    let transport = SpoolTransport::open(&dir, opts).unwrap();
    // Shard 0's report is already present but torn mid-write.
    std::fs::write(dir.join("reports").join(spool_file_name(0, 0)), b"{\"$schema").unwrap();
    let mut spooled = ShardedBackend::new(f, layout, 4, 2, Sampling::Uniform, None)
        .unwrap()
        .with_spool(transport);
    let got = spooled.run(&bins, 11, 0, true).unwrap();
    assert_eq!(got.0.integral.to_bits(), want.0.integral.to_bits());
    assert_eq!(got.0.variance.to_bits(), want.0.variance.to_bits());
    let stats = spooled.shard_stats().unwrap();
    assert_eq!(stats.straggler_retries, 4, "all four spans took the fallback");
    let _ = std::fs::remove_dir_all(dir);
}

/// Strict deployments (`local_fallback: false`) surface a typed
/// `Error::Shard` instead of silently recomputing — and instead of
/// hanging.
#[test]
fn strict_spool_mode_fails_typed_instead_of_hanging() {
    let layout = Layout::compute(3, 1024, 8, 1).unwrap();
    let bins = Bins::uniform(3, 8);
    let f = by_name("f3", 3).unwrap();
    let dir = scratch("strict");
    let opts = SpoolOptions {
        timeout: Duration::from_millis(50),
        poll: Duration::from_millis(1),
        max_retries: 1,
        local_fallback: false,
    };
    let transport = SpoolTransport::open(&dir, opts).unwrap();
    let mut strict = ShardedBackend::new(f, layout, 4, 1, Sampling::Uniform, None)
        .unwrap()
        .with_spool(transport);
    let err = strict.run(&bins, 3, 0, false).unwrap_err();
    assert!(matches!(err, Error::Shard(_)), "got {err}");
    let _ = std::fs::remove_dir_all(dir);
}

/// A sharded session survives suspend → JSON checkpoint → resume with
/// no bit of drift: the resumed 8-shard run equals both the
/// uninterrupted 8-shard run and the single worker.
#[test]
fn sharded_checkpoint_resumes_bitwise() {
    let builder = |shards: usize| {
        Integrator::from_registry("f4", 5)
            .unwrap()
            .maxcalls(1 << 12)
            .tolerance(1e-12)
            .plan(RunPlan::classic(7, 5, 1))
            .seed(41)
            .threads(4)
            .sampling(Sampling::vegas_plus())
            .shards(shards)
    };
    let single = builder(1).run().unwrap();
    let straight = builder(8).run().unwrap();
    assert_same_bits(&straight, &single);

    let mut session = builder(8).session().unwrap();
    for _ in 0..3 {
        session.step().unwrap().unwrap();
    }
    assert_eq!(session.shard_stats().shards, 8);
    let path = std::env::temp_dir().join(format!(
        "mcubes-shard-equiv-{}-checkpoint.json",
        std::process::id()
    ));
    session.suspend().save(&path).unwrap();
    drop(session);

    let checkpoint = Checkpoint::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(checkpoint.iteration(), 3);
    let resumed = builder(8)
        .resume_session(&checkpoint)
        .unwrap()
        .finish()
        .unwrap()
        .output;
    assert_same_bits(&resumed, &straight);
}
