//! Durability suite for the persistent store (`rust/src/store/`):
//!
//! * checkpoint-file schema versioning — the frozen pre-`schema_version`
//!   fixture must load forever, future versions must be rejected,
//!   never misread (satellite of PR 7);
//! * the torn-write contract — for *every* byte-level mutilation of a
//!   store file (prefix truncation, bit corruption, digit swaps,
//!   leftover temp files) the store returns either the previous
//!   durable state or a typed `StoreError`. Never a panic, never a
//!   half-read checkpoint.

use mcubes::api::{Checkpoint, RunPlan, Session, StopReason};
use mcubes::coordinator::JobConfig;
use mcubes::integrands::by_name;
use mcubes::store::{
    CheckpointStore, JobManifest, ResultCache, ResultManifest, ResultNumbers, ServiceStore,
    StoreError,
};
use mcubes::strat::Sampling;
use mcubes::util::json::parse;
use std::path::PathBuf;

fn scratch(tag: &str) -> PathBuf {
    let p = PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join(format!("store-durability-{tag}"));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// A checkpoint with real content: an adapted grid, a VEGAS+
/// stratification snapshot, and non-trivial estimator sums.
fn suspended_checkpoint(steps: usize) -> Checkpoint {
    let f = by_name("f3", 3).unwrap();
    let mut cfg = JobConfig::default();
    cfg.maxcalls = 1 << 12;
    cfg.nb = 8;
    cfg.nblocks = 4;
    cfg.plan = RunPlan::classic(6, 3, 1);
    cfg.seed = 7;
    cfg.sampling = Sampling::vegas_plus();
    let mut s = Session::new(f, cfg).unwrap();
    for _ in 0..steps {
        s.step().unwrap();
    }
    s.suspend()
}

// ---------------------------------------------------------------- //
// Satellite: explicit checkpoint schema versioning                 //
// ---------------------------------------------------------------- //

/// FROZEN: a checkpoint file exactly as written *before* the
/// `schema_version` field existed. Do not regenerate — this string is
/// the backward-compatibility contract.
const PRE_VERSION_CHECKPOINT: &str = r#"{"d":1,"nb":2,"mode":"per_axis","edges":[0.5,1],"session":{"iteration":3,"stage":1,"stage_iter":1,"calls_used":12288,"estimator":{"sum_w":2,"sum_wi":3,"sum_wi2":5,"n":2}}}"#;

#[test]
fn pre_schema_version_checkpoint_loads_forever() {
    let cp = Checkpoint::from_json(&parse(PRE_VERSION_CHECKPOINT).unwrap()).unwrap();
    assert_eq!(cp.iteration(), 3);
    assert_eq!((cp.stage(), cp.stage_iter()), (1, 1));
    assert_eq!(cp.calls_used(), 12288);
    assert_eq!(cp.estimator().n, 2);
    assert_eq!(cp.estimator().sum_wi, 3.0);
    assert_eq!(cp.stop(), None);
    // Re-serializing stamps the current version; the result still
    // round-trips to the same checkpoint.
    let v = cp.to_json();
    assert_eq!(
        v.get("schema_version").and_then(|x| x.as_usize()),
        Some(Checkpoint::SCHEMA_VERSION)
    );
    assert_eq!(Checkpoint::from_json(&v).unwrap(), cp);
}

#[test]
fn bare_grid_file_loads_as_fresh_start() {
    let v = parse(r#"{"d":1,"nb":2,"mode":"per_axis","edges":[0.5,1]}"#).unwrap();
    let cp = Checkpoint::from_json(&v).unwrap();
    assert_eq!(cp.iteration(), 0);
    assert_eq!(cp.calls_used(), 0);
}

#[test]
fn future_schema_version_is_rejected_not_misread() {
    let with_version = PRE_VERSION_CHECKPOINT.replacen('{', r#"{"schema_version":99,"#, 1);
    let err = Checkpoint::from_json(&parse(&with_version).unwrap()).unwrap_err();
    assert!(
        err.to_string().contains("newer than supported"),
        "got: {err}"
    );
    // An explicit current version loads normally.
    let current = PRE_VERSION_CHECKPOINT.replacen('{', r#"{"schema_version":1,"#, 1);
    assert!(Checkpoint::from_json(&parse(&current).unwrap()).is_ok());
    // A malformed version field is an error, not a silent default.
    let garbage = PRE_VERSION_CHECKPOINT.replacen('{', r#"{"schema_version":"new","#, 1);
    assert!(Checkpoint::from_json(&parse(&garbage).unwrap()).is_err());
}

#[test]
fn round_trip_through_the_store_is_bitwise() {
    let store = CheckpointStore::open(scratch("roundtrip")).unwrap();
    let key = "c".repeat(64);
    for steps in [0, 1, 4] {
        let cp = suspended_checkpoint(steps);
        store.save(&key, &cp).unwrap();
        assert_eq!(store.load(&key).unwrap().unwrap(), cp);
    }
}

// ---------------------------------------------------------------- //
// Satellite: the torn-write suite                                  //
// ---------------------------------------------------------------- //

/// Assert the store's durability contract against one mutilated file
/// state: a load yields the intact original, or a typed error — never
/// a panic, never `Ok(None)` (the file *exists*), never a half-read.
fn assert_all_or_nothing(
    store: &CheckpointStore,
    key: &str,
    original: &Checkpoint,
    what: &str,
) -> bool {
    match store.load(key) {
        Ok(Some(read)) => {
            assert_eq!(&read, original, "{what}: returned a DIFFERENT checkpoint");
            true
        }
        Ok(None) => panic!("{what}: file exists but the store reported it absent"),
        Err(e) => {
            // Exercise Display while we're here — it must not panic
            // either, and every variant names its file or key.
            assert!(!e.to_string().is_empty());
            false
        }
    }
}

#[test]
fn truncation_at_every_byte_boundary_is_all_or_nothing() {
    let dir = scratch("truncate");
    let store = CheckpointStore::open(&dir).unwrap();
    let key = "a".repeat(64);
    let cp = suspended_checkpoint(3);
    store.save(&key, &cp).unwrap();
    let path = dir.join(format!("{key}.json"));
    let bytes = std::fs::read(&path).unwrap();
    let mut intact_reads = 0;
    for len in 0..=bytes.len() {
        std::fs::write(&path, &bytes[..len]).unwrap();
        if assert_all_or_nothing(&store, &key, &cp, &format!("truncated to {len} bytes")) {
            intact_reads += 1;
            assert_eq!(len, bytes.len(), "a PROPER prefix read back as intact");
        }
    }
    assert_eq!(intact_reads, 1, "only the full file may load");
}

#[test]
fn bit_corruption_at_every_byte_is_detected() {
    let dir = scratch("bitflip");
    let store = CheckpointStore::open(&dir).unwrap();
    let key = "b".repeat(64);
    let cp = suspended_checkpoint(2);
    store.save(&key, &cp).unwrap();
    let path = dir.join(format!("{key}.json"));
    let bytes = std::fs::read(&path).unwrap();
    for i in 0..bytes.len() {
        let mut mutated = bytes.clone();
        mutated[i] ^= 0xFF;
        std::fs::write(&path, &mutated).unwrap();
        // An XORed ASCII byte is never valid UTF-8 in context, so
        // every one of these must surface as a typed error.
        let intact = assert_all_or_nothing(&store, &key, &cp, &format!("byte {i} xor 0xFF"));
        assert!(!intact, "byte {i}: corruption read back as intact");
    }
}

#[test]
fn digit_swaps_are_caught_by_the_seal() {
    let dir = scratch("digits");
    let store = CheckpointStore::open(&dir).unwrap();
    let key = "d".repeat(64);
    let cp = suspended_checkpoint(3);
    store.save(&key, &cp).unwrap();
    let path = dir.join(format!("{key}.json"));
    let bytes = std::fs::read(&path).unwrap();
    for i in 0..bytes.len() {
        if !bytes[i].is_ascii_digit() {
            continue;
        }
        let mut mutated = bytes.clone();
        mutated[i] = if bytes[i] == b'0' { b'1' } else { b'0' };
        std::fs::write(&path, &mutated).unwrap();
        // Still valid UTF-8 and (almost always) valid JSON — only the
        // sha256 seal can tell. The one legitimate `true` outcome is a
        // swap deep in a float's 17-digit tail that rounds to the
        // *identical* f64: the canonical re-serialization then matches
        // and the value really is the original, which the helper
        // asserts.
        assert_all_or_nothing(&store, &key, &cp, &format!("digit swap at byte {i}"));
    }
}

#[test]
fn leftover_tmp_garbage_is_invisible() {
    let dir = scratch("tmpfile");
    let store = CheckpointStore::open(&dir).unwrap();
    let key = "e".repeat(64);
    let cp = suspended_checkpoint(2);
    store.save(&key, &cp).unwrap();
    // Simulate a crash mid-write of the NEXT save: a torn temp file
    // sits beside the intact final file.
    std::fs::write(dir.join(format!("{key}.json.tmp")), b"{\"torn\":").unwrap();
    assert_eq!(store.load(&key).unwrap().unwrap(), cp);
    assert_eq!(store.digests().unwrap(), vec![key.clone()]);
    // And a crash BEFORE the first rename: only a temp file, no final
    // file — the store correctly reports "no checkpoint".
    let key2 = "f".repeat(64);
    std::fs::write(dir.join(format!("{key2}.json.tmp")), b"{\"torn\":").unwrap();
    assert!(store.load(&key2).unwrap().is_none());
}

#[test]
fn result_cache_truncation_is_all_or_nothing() {
    let dir = scratch("cache-torn");
    let cache = ResultCache::open(&dir).unwrap();
    let job = JobManifest::new("torn", "f3", 3, JobConfig::default());
    let digest = job.digest();
    let result = ResultManifest::success(
        &job,
        digest.clone(),
        ResultNumbers {
            integral: 1.0 / 3.0,
            sigma: 2.5e-5,
            chi2_dof: 0.875,
            rel_err: 7.5e-5,
            iterations: 12,
            converged: true,
            calls_used: 98304,
            stop: StopReason::Converged,
        },
    );
    cache.put(&digest, &result).unwrap();
    let path = dir.join(format!("{digest}.json"));
    let bytes = std::fs::read(&path).unwrap();
    let reference = result.to_json().to_json();
    for len in 0..bytes.len() {
        std::fs::write(&path, &bytes[..len]).unwrap();
        match cache.get(&digest) {
            Ok(Some(read)) => {
                assert_eq!(read.to_json().to_json(), reference);
                panic!("proper prefix {len} read back as intact");
            }
            Ok(None) => panic!("prefix {len}: file exists but cache reported a miss"),
            Err(StoreError::Corrupt { .. } | StoreError::Io { .. }) => {}
            Err(e) => panic!("prefix {len}: unexpected error class: {e}"),
        }
    }
    std::fs::write(&path, &bytes).unwrap();
    assert!(cache.get(&digest).unwrap().is_some());
}

#[test]
fn spool_submission_truncation_is_a_typed_error() {
    let root = scratch("spool-torn");
    let store = ServiceStore::open(&root).unwrap();
    let job = JobManifest::new("torn-sub", "f4", 5, JobConfig::default());
    let path = store.spool().submit(&job).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    for len in 0..bytes.len() {
        std::fs::write(&path, &bytes[..len]).unwrap();
        match store.spool().load(&path) {
            Ok(_) => panic!("proper prefix {len} parsed as a complete manifest"),
            Err(StoreError::Corrupt { .. } | StoreError::Io { .. }) => {}
            Err(e) => panic!("prefix {len}: unexpected error class: {e}"),
        }
    }
    std::fs::write(&path, &bytes).unwrap();
    assert_eq!(store.spool().load(&path).unwrap().job_id, "torn-sub");
}
