//! Panic-isolation regression tests for the threaded fill/eval paths.
//!
//! Two guarantees are locked down here:
//!
//! 1. **Payload fidelity.** When a user integrand panics inside a
//!    worker thread of either execution schedule (the fused streaming
//!    tile loop or the materialized block reference),
//!    `util::threadpool::parallel_chunks` re-raises the *original*
//!    panic payload on the caller thread (`resume_unwind`), so an
//!    upstream `catch_unwind` sees the user's own message instead of a
//!    generic "worker panicked" or a poisoned-lock error.
//!
//! 2. **Per-job isolation.** Inside the `coordinator::Scheduler`, one
//!    panicking job must neither take down its worker nor poison the
//!    queue: every other submitted job still completes and the
//!    panicking job surfaces as an `Err` outcome carrying the payload.
//!
//! Both properties existed before the streaming schedule landed; these
//! tests pin them *through* the new code path (scoped threads + fused
//! tiles), where a regression would otherwise only show up as a hung
//! `thread::scope` or a swallowed payload in production.

use std::panic::{catch_unwind, AssertUnwindSafe};

use mcubes::api::{Bounds, FnIntegrand, RunPlan};
use mcubes::coordinator::{JobConfig, JobRequest, Scheduler};
use mcubes::engine::{ExecPath, FillPath, NativeEngine, VSampleOpts};
use mcubes::grid::Bins;
use mcubes::integrands::{by_name, IntegrandRef};
use mcubes::strat::Layout;

/// An integrand that detonates once sampling reaches the upper half of
/// axis 0 — deterministically hit on every seed (the VEGAS map covers
/// the whole unit cube each iteration).
fn exploding(d: usize) -> IntegrandRef {
    FnIntegrand::new(d, Bounds::unit(d), |x: &[f64]| {
        if x[0] > 0.5 {
            panic!("integrand exploded at x0={:.3}", x[0]);
        }
        1.0
    })
    .unwrap()
    .into_ref()
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default()
}

/// The original panic payload survives the scoped-thread boundary on
/// both execution schedules, and the engine stays fully usable
/// afterwards (no poisoned global state — the scratch is per-call).
#[test]
fn threaded_fill_panic_preserves_payload_on_both_schedules() {
    let d = 4;
    let f = exploding(d);
    let layout = Layout::compute(d, 4096, 20, 4).unwrap();
    let bins = Bins::uniform(d, 20);
    let opts = VSampleOpts {
        seed: 11,
        iteration: 0,
        adjust: true,
        threads: 4,
    };
    for exec in [ExecPath::Streaming, ExecPath::Block] {
        let payload = catch_unwind(AssertUnwindSafe(|| {
            NativeEngine.vsample_exec(&*f, &layout, &bins, &opts, FillPath::Simd, exec)
        }))
        .expect_err("the integrand panic must propagate");
        let msg = panic_message(&*payload);
        assert!(
            msg.contains("integrand exploded"),
            "{exec:?}: payload lost or rewritten: {msg:?}"
        );
    }

    // Regression: a panic in one run must not wedge later runs (the
    // seed's failure mode would have been a hung scope join or a
    // poisoned pool). A well-behaved integrand still samples cleanly
    // on the identical layout and thread count, on both schedules.
    let ok = by_name("f5", d).unwrap();
    let (stream, _) =
        NativeEngine.vsample_exec(&*ok, &layout, &bins, &opts, FillPath::Simd, ExecPath::Streaming);
    let (block, _) =
        NativeEngine.vsample_exec(&*ok, &layout, &bins, &opts, FillPath::Simd, ExecPath::Block);
    assert!(stream.integral.is_finite());
    assert_eq!(stream.integral.to_bits(), block.integral.to_bits());
}

/// One panicking job inside the scheduler: its result is an `Err`
/// carrying the original payload, every sibling job completes
/// normally, and the failure count is exact.
#[test]
fn scheduler_isolates_panicking_job_from_siblings() {
    let cfg = JobConfig::default()
        .with_maxcalls(2048)
        .with_bins(16)
        .with_plan(RunPlan::classic(2, 0, 0))
        .with_tolerance(1e-12)
        .with_seed(7)
        .with_threads(2);
    let mut sched = Scheduler::new(2);
    for id in 0..4u64 {
        sched.submit(JobRequest::registry(id, "f5", 3, cfg.clone()));
    }
    sched.submit(JobRequest::custom(99, exploding(3), cfg.clone()));

    let (results, metrics) = sched.drain().unwrap();
    assert_eq!(results.len(), 5, "every submitted job must yield a result");
    assert_eq!(metrics.failures, 1, "exactly the panicking job fails");
    for r in &results {
        if r.id == 99 {
            let err = r.outcome.as_ref().expect_err("job 99 panics");
            assert!(
                err.contains("integrand exploded"),
                "panic payload lost in the scheduler: {err:?}"
            );
        } else {
            let out = r
                .outcome
                .as_ref()
                .unwrap_or_else(|e| panic!("job {} poisoned by sibling panic: {e}", r.id));
            assert!(out.integral.is_finite());
            assert_eq!(out.iterations, 2);
        }
    }
}
