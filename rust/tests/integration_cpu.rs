//! Integration tests: the full m-Cubes driver (via the `Integrator`
//! facade) on the native engine against the paper's integrand suite
//! and known true values.

use mcubes::api::{Integrator, RunPlan};
use mcubes::baselines::{
    gvegas_integrate, miser_integrate, plain_mc_integrate, vegas_serial_integrate, zmc_integrate,
    GvegasConfig, MiserConfig, PlainMcConfig, ZmcConfig,
};
use mcubes::grid::GridMode;
use mcubes::integrands::{by_name, IntegrandRef};

fn facade(f: &IntegrandRef, calls: usize, tau: f64, seed: u32) -> Integrator {
    Integrator::new(f.clone())
        .maxcalls(calls)
        .tolerance(tau)
        .plan(RunPlan::classic(20, 12, 2))
        .seed(seed)
}

/// The paper's evaluation suite at 3 digits of precision.
#[test]
fn paper_suite_three_digits() {
    let cases = [
        ("f2", 6, 1 << 15),
        ("f3", 3, 1 << 14),
        ("f3", 8, 1 << 16),
        ("f4", 5, 1 << 16),
        ("f5", 8, 1 << 15),
        ("f6", 6, 1 << 16),
        ("cosmo", 6, 1 << 14),
    ];
    for (name, d, calls) in cases {
        let f = by_name(name, d).unwrap();
        let out = facade(&f, calls, 1e-3, 17).run().unwrap();
        assert!(out.converged, "{name} d={d}: {out:?}");
        let truth = f.true_value().unwrap();
        let rel = ((out.integral - truth) / truth).abs();
        assert!(
            rel < 6e-3,
            "{name} d={d}: true rel err {rel:.2e} (claimed {:.2e})",
            out.rel_err
        );
    }
}

/// Error estimates must be *honest*: achieved error within a few
/// claimed sigmas across seeds (the paper's Fig. 1 criterion).
#[test]
fn error_estimates_honest_across_seeds() {
    let f = by_name("f5", 8).unwrap();
    let truth = f.true_value().unwrap();
    let mut within_3_sigma = 0;
    let n_runs = 10;
    for seed in 0..n_runs {
        let out = facade(&f, 1 << 14, 1e-3, 100 + seed).run().unwrap();
        if (out.integral - truth).abs() <= 3.0 * out.sigma {
            within_3_sigma += 1;
        }
    }
    // 3-sigma coverage should be ~99.7%; allow one escape in 10 runs.
    assert!(
        within_3_sigma >= n_runs - 1,
        "only {within_3_sigma}/{n_runs} runs within 3 sigma"
    );
}

/// Higher precision targets require more work but must still be honest.
#[test]
fn precision_ladder_first_rungs() {
    let f = by_name("f2", 6).unwrap();
    let truth = f.true_value().unwrap();
    for (tau, calls) in [(1e-3, 1 << 15), (2e-4, 1 << 19)] {
        let out = facade(&f, calls, tau, 5).run().unwrap();
        assert!(out.converged, "tau={tau}: {out:?}");
        assert!(out.rel_err <= tau, "claimed {} > tau {tau}", out.rel_err);
        let rel = ((out.integral - truth) / truth).abs();
        assert!(rel < 8.0 * tau, "tau={tau}: true rel {rel:.2e}");
    }
}

/// m-Cubes1D on symmetric integrands: same answer, shared grid.
#[test]
fn onedim_variant_matches_on_symmetric() {
    for (name, d, calls) in [("f4", 8, 1 << 15), ("f5", 8, 1 << 14)] {
        let f = by_name(name, d).unwrap();
        let per_axis = facade(&f, calls, 1e-3, 3).run().unwrap();
        let onedim = facade(&f, calls, 1e-3, 3)
            .grid_mode(GridMode::Shared1D)
            .run()
            .unwrap();
        let truth = f.true_value().unwrap();
        for (label, out) in [("per-axis", &per_axis), ("1d", &onedim)] {
            let rel = ((out.integral - truth) / truth).abs();
            assert!(rel < 1e-2, "{name} {label}: rel {rel:.2e}");
        }
    }
}

/// The adaptive escalation driver reaches tighter tolerances than a
/// single fixed budget would.
#[test]
fn adaptive_escalation_reaches_tight_tau() {
    let f = by_name("f3", 3).unwrap();
    let out = facade(&f, 1 << 13, 4e-5, 9).escalate(5, 4).run().unwrap();
    assert!(out.converged, "{out:?}");
    let truth = f.true_value().unwrap();
    let rel = ((out.integral - truth) / truth).abs();
    assert!(rel < 4e-4, "rel {rel:.2e}");
}

/// All five baselines produce statistically-consistent estimates on a
/// common smooth integrand.
#[test]
fn baselines_agree_on_smooth_integrand() {
    let f = by_name("f5", 4).unwrap();
    let truth = f.true_value().unwrap();
    let check = |label: &str, integral: f64, sigma: f64| {
        assert!(
            (integral - truth).abs() < 6.0 * sigma + 1e-9 * truth.abs(),
            "{label}: I={integral} truth={truth} sigma={sigma}"
        );
    };
    let v = vegas_serial_integrate(&f, 1 << 14, 1e-3, 20, 21);
    check("vegas_serial", v.integral, v.sigma);
    let p = plain_mc_integrate(
        &*f,
        &PlainMcConfig {
            calls: 1 << 17,
            seed: 21,
        },
    );
    check("plain_mc", p.integral, p.sigma);
    let m = miser_integrate(
        &*f,
        &MiserConfig {
            calls: 1 << 17,
            seed: 21,
            ..Default::default()
        },
    );
    check("miser", m.integral, m.sigma);
    let g = gvegas_integrate(
        &*f,
        &GvegasConfig {
            maxcalls: 1 << 14,
            seed: 21,
            ..Default::default()
        },
    );
    check("gvegas_sim", g.integral, g.sigma);
    let z = zmc_integrate(
        &*f,
        &ZmcConfig {
            samples_per_block: 256,
            depth: 3,
            seed: 21,
            ..Default::default()
        },
    );
    check("zmc_sim", z.integral, z.sigma);
}

/// gVegas-sim and m-Cubes draw the same Philox stream: their
/// *first-iteration* estimates are identical before designs diverge.
#[test]
fn gvegas_and_mcubes_share_the_stream() {
    let f = by_name("f3", 3).unwrap();
    // One iteration each, no adaptation: same estimate expected.
    let mc = Integrator::new(f.clone())
        .maxcalls(1 << 12)
        .plan(RunPlan::classic(1, 0, 0))
        .tolerance(1e-12)
        .seed(77)
        .run()
        .unwrap();
    let gv = gvegas_integrate(
        &*f,
        &GvegasConfig {
            maxcalls: 1 << 12,
            itmax: 1,
            ita: 0,
            tau_rel: 1e-12,
            seed: 77,
            ..Default::default()
        },
    );
    let rel = ((mc.integral - gv.integral) / mc.integral).abs();
    assert!(rel < 1e-12, "mc {} vs gv {}", mc.integral, gv.integral);
}

/// fA needs a large budget (oscillatory, huge cancellation); verify the
/// estimate lands near the paper's true value with adaptive escalation.
#[test]
fn fa_table1_estimate() {
    let f = by_name("fA", 6).unwrap();
    let out = Integrator::new(f.clone())
        .maxcalls(1 << 17)
        .tolerance(2e-2)
        .plan(RunPlan::classic(10, 10, 1))
        .seed(33)
        .escalate(2, 4)
        .run()
        .unwrap();
    let truth = f.true_value().unwrap(); // -49.165073
    assert!(
        (out.integral - truth).abs() < 4.0 * out.sigma.max(truth.abs() * 5e-2),
        "I={} truth={truth} sigma={}",
        out.integral,
        out.sigma
    );
}
