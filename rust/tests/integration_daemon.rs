//! End-to-end daemon durability (the PR 7 acceptance criteria):
//!
//! * **Bitwise crash recovery, both engines** — run a job N iterations,
//!   kill the daemon at a durable checkpoint with zero cleanup
//!   (exactly what `kill -9` leaves behind), restart a fresh daemon on
//!   the same store, and the resumed run publishes estimate/sigma/chi2
//!   bitwise-identical to an uninterrupted run — on the Uniform
//!   m-Cubes engine and the VEGAS+ stratified engine alike.
//! * **Cache hits cost zero evaluations** — re-submitting a
//!   semantically identical manifest (different job id, priority,
//!   checkpoint interval) is answered from the content-addressed
//!   cache without calling the integrand once, asserted with an
//!   evaluation counter compiled into the resolver.

use mcubes::api::{FnIntegrand, RunPlan};
use mcubes::coordinator::{read_result, submit_job, Daemon, JobConfig};
use mcubes::store::JobManifest;
use mcubes::strat::Sampling;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn scratch(tag: &str) -> PathBuf {
    let p = PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join(format!("daemon-{tag}"));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn job(id: &str, sampling: Sampling) -> JobManifest {
    let mut cfg = JobConfig::default();
    cfg.maxcalls = 1 << 12;
    cfg.plan = RunPlan::classic(6, 3, 1);
    cfg.tau_rel = 1e-12; // never converges early → fixed iteration count
    cfg.seed = 11;
    cfg.sampling = sampling;
    JobManifest::new(id, "f4", 5, cfg).with_checkpoint_interval(1)
}

/// One full crash/restart cycle for a given engine: asserts the
/// resumed run is bitwise-identical to an uninterrupted one.
fn crash_and_resume(tag: &str, sampling: Sampling) {
    // Uninterrupted reference run (single-threaded).
    let base_root = scratch(&format!("{tag}-base"));
    submit_job(&base_root, &job("steady", sampling)).unwrap();
    let mut base = Daemon::open(&base_root).unwrap().with_threads(1);
    let report = base.run_pending().unwrap();
    assert_eq!((report.completed, report.resumed), (1, 0));
    let reference = read_result(&base_root, "steady").unwrap().unwrap();
    let reference = reference.outcome.expect("reference run succeeds");

    // Same job on a second store; the daemon "dies" (no cleanup at
    // all) right after the second durable checkpoint flush. More
    // worker threads on purpose: results are thread-count invariant.
    let killed_root = scratch(&format!("{tag}-killed"));
    submit_job(&killed_root, &job("steady", sampling)).unwrap();
    let mut victim = Daemon::open(&killed_root)
        .unwrap()
        .with_threads(3)
        .with_crash_after_flushes(2);
    let report = victim.run_pending().unwrap();
    assert!(report.crashed, "the injected kill must fire");
    assert_eq!(report.completed, 0);
    drop(victim);

    // The kill left the exact on-disk state a real SIGKILL would:
    // submission still spooled, no result, a durable checkpoint.
    assert!(read_result(&killed_root, "steady").unwrap().is_none());
    let inspect = Daemon::open(&killed_root).unwrap();
    assert_eq!(inspect.store().spool().pending().unwrap().len(), 1);
    assert_eq!(inspect.store().checkpoints().digests().unwrap().len(), 1);
    drop(inspect);

    // Restart: a fresh daemon re-scans the store and finishes the job
    // from the checkpoint.
    let mut revived = Daemon::open(&killed_root).unwrap().with_threads(2);
    let report = revived.run_pending().unwrap();
    assert_eq!((report.completed, report.resumed), (1, 1));
    let resumed = read_result(&killed_root, "steady").unwrap().unwrap();
    assert!(
        resumed.resumed_iteration > 0,
        "the revived run must start from a checkpoint, not from scratch"
    );
    let resumed = resumed.outcome.expect("resumed run succeeds");

    // The acceptance bar: bitwise equality, not tolerance equality.
    assert_eq!(
        reference.integral.to_bits(),
        resumed.integral.to_bits(),
        "integral differs after crash/resume ({tag})"
    );
    assert_eq!(reference.sigma.to_bits(), resumed.sigma.to_bits());
    assert_eq!(reference.chi2_dof.to_bits(), resumed.chi2_dof.to_bits());
    assert_eq!(reference.calls_used, resumed.calls_used);
    assert_eq!(reference.iterations, resumed.iterations);
    assert_eq!(reference.stop, resumed.stop);

    // Cleanup happened on completion: no leftover checkpoint or spool.
    let done = Daemon::open(&killed_root).unwrap();
    assert!(done.store().spool().pending().unwrap().is_empty());
    assert!(done.store().checkpoints().digests().unwrap().is_empty());
}

#[test]
fn crash_resume_is_bitwise_on_the_uniform_engine() {
    crash_and_resume("uniform", Sampling::Uniform);
}

#[test]
fn crash_resume_is_bitwise_on_the_vegas_plus_engine() {
    crash_and_resume("vegasplus", Sampling::vegas_plus());
}

/// A resolver that counts every single integrand evaluation.
fn counting_resolver(
    counter: Arc<AtomicUsize>,
) -> impl Fn(&JobManifest) -> mcubes::Result<mcubes::integrands::IntegrandRef> + Send + 'static {
    move |manifest: &JobManifest| {
        if manifest.integrand != "counted" {
            return Err(mcubes::Error::Unknown {
                kind: "integrand",
                name: manifest.integrand.clone(),
            });
        }
        let counter = counter.clone();
        let f = FnIntegrand::unit(3, move |x: &[f64]| {
            counter.fetch_add(1, Ordering::Relaxed);
            x[0] * x[1] + x[2]
        })
        .named("counted");
        Ok(Arc::new(f))
    }
}

#[test]
fn cache_hit_serves_identical_resubmission_with_zero_evaluations() {
    let root = scratch("zero-evals");
    let evals = Arc::new(AtomicUsize::new(0));

    let mut cfg = JobConfig::default();
    cfg.maxcalls = 1 << 12;
    cfg.plan = RunPlan::classic(5, 3, 1);
    cfg.tau_rel = 1e-12;
    cfg.seed = 3;

    submit_job(&root, &JobManifest::new("first", "counted", 3, cfg.clone())).unwrap();
    let mut daemon = Daemon::open(&root)
        .unwrap()
        .with_resolver(counting_resolver(evals.clone()));
    let report = daemon.run_pending().unwrap();
    assert_eq!(report.completed, 1);
    let first = read_result(&root, "first").unwrap().unwrap();
    assert!(!first.cached);
    let evals_after_first = evals.load(Ordering::Relaxed);
    assert!(evals_after_first > 0, "the first run must actually sample");

    // Semantically identical job, different id + service metadata —
    // and a *daemon restart* in between: the cache is durable, not an
    // in-memory memo.
    let resubmission = JobManifest::new("second", "counted", 3, cfg)
        .with_priority(7)
        .with_checkpoint_interval(3);
    submit_job(&root, &resubmission).unwrap();
    drop(daemon);
    let mut daemon = Daemon::open(&root)
        .unwrap()
        .with_resolver(counting_resolver(evals.clone()));
    let report = daemon.run_pending().unwrap();
    assert_eq!((report.completed, report.cache_hits), (1, 1));

    let second = read_result(&root, "second").unwrap().unwrap();
    assert!(second.cached, "resubmission must be served from the cache");
    assert_eq!(
        evals.load(Ordering::Relaxed),
        evals_after_first,
        "a cache hit must cost ZERO integrand evaluations"
    );
    let (a, b) = (first.outcome.unwrap(), second.outcome.unwrap());
    assert_eq!(a.integral.to_bits(), b.integral.to_bits());
    assert_eq!(a.sigma.to_bits(), b.sigma.to_bits());
    assert_eq!(a.calls_used, b.calls_used);

    // A different seed is a different content address: it must MISS.
    let mut other_cfg = JobConfig::default();
    other_cfg.maxcalls = 1 << 12;
    other_cfg.plan = RunPlan::classic(5, 3, 1);
    other_cfg.tau_rel = 1e-12;
    other_cfg.seed = 4;
    submit_job(&root, &JobManifest::new("third", "counted", 3, other_cfg)).unwrap();
    let report = daemon.run_pending().unwrap();
    assert_eq!((report.completed, report.cache_hits), (1, 0));
    assert!(
        evals.load(Ordering::Relaxed) > evals_after_first,
        "a different seed must re-integrate"
    );
}
