//! Tier-1 determinism gate: run `cargo xtask lint` (as a library
//! call) from the root package's own test suite, so plain
//! `cargo test -q` fails on a contract violation even when nobody
//! invokes the linter or tests the workspace members.
//!
//! The full per-rule fixture matrix lives in
//! `tools/xtask/tests/lint_rules.rs`; this file keeps tier-1 honest
//! with the gate itself plus one smoke check per direction (a rule
//! fires, a reasoned suppression holds, a malformed suppression is an
//! error). See docs/invariants.md for the rules (MC001–MC005) and the
//! `lint:allow(RULE, reason)` syntax.

use std::path::Path;

use xtask_lint::{lint_root, lint_source};

/// The real tree lints clean: every violation is fixed or carries a
/// reasoned `lint:allow`, and no suppression is stale.
#[test]
fn rust_src_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let r = lint_root(&root, "rust/src").expect("rust/src readable");
    assert!(
        r.diagnostics.is_empty(),
        "determinism lint violations:\n{:#?}\nfix the code or add \
         `// lint:allow(RULE, reason)` — see docs/invariants.md",
        r.diagnostics
    );
    assert!(
        r.warnings.is_empty(),
        "stale suppressions (nothing left to suppress):\n{:#?}",
        r.warnings
    );
}

/// The gate is live: the PR 5 truncation pattern still fires.
#[test]
fn truncation_pattern_still_fires() {
    let r = lint_source(
        "engine/mod.rs",
        "let key = (cube_idx * samples_per_cube + i) as u32;\n",
    );
    assert_eq!(r.diagnostics.len(), 1, "{:#?}", r.diagnostics);
    assert_eq!(r.diagnostics[0].rule, "MC001");
}

/// A reasoned suppression holds, and is consumed (no stale warning).
#[test]
fn reasoned_suppression_holds() {
    let r = lint_source(
        "engine/mod.rs",
        "let lo = sample_idx as u32; // lint:allow(MC001, low half of a deliberately split counter)\n",
    );
    assert!(r.diagnostics.is_empty(), "{:#?}", r.diagnostics);
    assert!(r.warnings.is_empty(), "{:?}", r.warnings);
}

/// A typo'd suppression is an error, and suppresses nothing.
#[test]
fn malformed_suppression_is_an_error() {
    let r = lint_source(
        "api/session.rs",
        "let v = o.unwrap(); // lint:allow(MC05, typo in the rule id)\n",
    );
    let rules: Vec<&str> = r.diagnostics.iter().map(|d| d.rule).collect();
    assert_eq!(rules, ["MC000", "MC005"], "{:#?}", r.diagnostics);
}
