//! Property-based tests (via the in-repo `util::prop` driver) on grid,
//! strat, estimator, and engine invariants — including the batch-API
//! contract: for every registry integrand, the hand-batched
//! `eval_batch` path must be *bitwise* identical to the scalar default
//! through the identical engine pipeline.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use mcubes::api::{Checkpoint, Integrator, RunPlan, Session, StratSnapshot};
use mcubes::coordinator::{EngineBackend, JobConfig, VSampleBackend};
use mcubes::engine::{
    merge_task_partials, reduction_tasks, vsample_stratified, Engine, ExecPath, FillPath,
    NativeEngine, ScalarEval, UniformEngine, VSampleOpts, VegasPlusEngine,
};
use mcubes::estimator::{Convergence, IterationResult, WeightedEstimator};
use mcubes::grid::{rebin, smooth_weights, Bins, GridMode};
use mcubes::integrands::{by_name, Integrand, ALL_NAMES};
use mcubes::strat::{Allocation, Layout, Sampling, MIN_SAMPLES_PER_CUBE};
use mcubes::util::prop::{property, Gen};

/// Any rebin of a valid grid with positive weights stays a valid grid.
#[test]
fn prop_rebin_preserves_grid_invariants() {
    property("rebin_valid", 200, |g: &mut Gen, _| {
        let nb = g.usize_range(2, 64);
        // Random monotone edges ending at 1.
        let mut edges: Vec<f64> = (0..nb).map(|_| g.f64_range(1e-9, 1.0)).collect();
        edges.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Force strict monotonicity + final edge 1.0.
        for i in 0..nb {
            let min = if i == 0 { 0.0 } else { edges[i - 1] };
            if edges[i] <= min {
                edges[i] = min + 1e-9;
            }
        }
        edges[nb - 1] = 1.0;
        let w = g.weights(nb, 0.3).iter().map(|x| x.max(1e-30)).collect::<Vec<_>>();
        rebin(&mut edges, &w);
        let mut prev = 0.0;
        for (i, &e) in edges.iter().enumerate() {
            if e <= prev {
                return Err(format!("edge {i} not increasing: {e} <= {prev}"));
            }
            prev = e;
        }
        if (edges[nb - 1] - 1.0).abs() > 1e-12 {
            return Err(format!("last edge {} != 1", edges[nb - 1]));
        }
        Ok(())
    });
}

/// smooth_weights never yields negatives/NaN, and hot bins outweigh
/// cold ones after smoothing.
#[test]
fn prop_smooth_weights_sane() {
    property("smooth_weights", 200, |g: &mut Gen, _| {
        let nb = g.usize_range(2, 80);
        let c = g.weights(nb, 0.5);
        let mut scratch = vec![0.0; nb];
        match smooth_weights(&c, &mut scratch) {
            None => {
                if c.iter().any(|&x| x > 0.0) {
                    return Err("None despite signal".into());
                }
            }
            Some(w) => {
                for &x in w {
                    if !(x > 0.0) || !x.is_finite() {
                        return Err(format!("bad weight {x}"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Repeated adjustment with a fixed histogram converges to a fixed
/// point (the equal-weight partition of that histogram's density).
#[test]
fn prop_adjust_converges_to_fixed_point() {
    property("adjust_fixed_point", 25, |g: &mut Gen, _| {
        let nb = g.usize_range(8, 32);
        let mut bins = Bins::uniform(1, nb);
        let contrib = g.weights(nb, 0.2);
        if contrib.iter().all(|&x| x == 0.0) {
            return Ok(());
        }
        // NOTE: the histogram is a function of the *bins* in the real
        // loop; with a fixed histogram the map is a contraction toward
        // equal-weight edges. Expect edge motion to shrink.
        let mut prev = bins.flat().to_vec();
        let mut motion_prev = f64::INFINITY;
        for round in 0..30 {
            bins.adjust(&contrib);
            let motion: f64 = bins
                .flat()
                .iter()
                .zip(&prev)
                .map(|(a, b)| (a - b).abs())
                .sum();
            prev = bins.flat().to_vec();
            if round > 20 && motion > motion_prev * 2.0 + 1e-9 {
                return Err(format!("motion diverging: {motion} > {motion_prev}"));
            }
            motion_prev = motion.max(1e-18);
        }
        bins.validate().map_err(|e| e.to_string())
    });
}

/// Layout invariants hold over random (d, maxcalls).
#[test]
fn prop_layout_invariants() {
    property("layout", 300, |g: &mut Gen, _| {
        let d = g.usize_range(1, 12);
        let maxcalls = g.usize_range(4, 2_000_000);
        let nblocks = g.usize_range(1, 64);
        let l = Layout::compute(d, maxcalls, 50, nblocks).map_err(|e| e.to_string())?;
        if l.m != l.g.pow(d as u32) {
            return Err(format!("m {} != g^d", l.m));
        }
        if l.p < 2 {
            return Err("p < 2".into());
        }
        if l.g.pow(d as u32) > maxcalls / 2 && l.g > 1 {
            return Err(format!("g too large: {l:?}"));
        }
        if l.cpb * l.nblocks < l.m {
            return Err("blocks don't cover cubes".into());
        }
        if l.nblocks > 1 && l.cpb * (l.nblocks - 1) >= l.m {
            return Err(format!("empty trailing block: {l:?}"));
        }
        // decode/encode roundtrip on a few random cubes
        let mut buf = vec![0usize; d];
        for _ in 0..10 {
            let cube = g.usize_range(0, l.m - 1);
            l.cube_coords(cube, &mut buf);
            if l.cube_index(&buf) != cube {
                return Err(format!("roundtrip failed at {cube}"));
            }
        }
        Ok(())
    });
}

/// Estimator algebra: combining iterations never increases sigma, and
/// the combined integral lies within the inputs' envelope.
#[test]
fn prop_estimator_combination() {
    property("estimator", 300, |g: &mut Gen, _| {
        let n = g.usize_range(2, 12);
        let mut est = WeightedEstimator::new();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut min_sigma = f64::INFINITY;
        for _ in 0..n {
            let i = g.f64_range(-5.0, 5.0);
            let v = g.f64_range(1e-8, 2.0);
            est.push(IterationResult {
                integral: i,
                variance: v,
            });
            lo = lo.min(i);
            hi = hi.max(i);
            min_sigma = min_sigma.min(v.sqrt());
        }
        let combined = est.integral();
        if !(lo - 1e-12 <= combined && combined <= hi + 1e-12) {
            return Err(format!("combined {combined} outside [{lo}, {hi}]"));
        }
        if est.sigma() > min_sigma + 1e-12 {
            return Err(format!(
                "combined sigma {} > best input {min_sigma}",
                est.sigma()
            ));
        }
        if est.chi2_dof() < 0.0 {
            return Err("negative chi2".into());
        }
        Ok(())
    });
}

/// Engine invariance: the estimate is independent of the block/thread
/// partition, and histogram mass equals sum(v^2) on every axis.
#[test]
fn prop_engine_partition_invariance() {
    property("engine_partition", 12, |g: &mut Gen, _| {
        let d = g.usize_range(2, 6);
        let maxcalls = g.usize_range(512, 4096);
        let f = by_name("f5", d).map_err(|e| e.to_string())?;
        let layout = Layout::compute(d, maxcalls, 20, 4).map_err(|e| e.to_string())?;
        let bins = Bins::uniform(d, 20);
        let seed = g.usize_range(0, 10_000) as u32;
        let mut results = Vec::new();
        for threads in [1, 3, 8] {
            let (r, c) = NativeEngine.vsample(
                &*f,
                &layout,
                &bins,
                &VSampleOpts {
                    seed,
                    iteration: 0,
                    adjust: true,
                    threads,
                },
            );
            results.push((r, c.unwrap()));
        }
        let (r0, c0) = &results[0];
        for (r, c) in &results[1..] {
            if ((r.integral - r0.integral) / r0.integral).abs() > 1e-13 {
                return Err(format!("integral varies: {} vs {}", r.integral, r0.integral));
            }
            for (a, b) in c.iter().zip(c0) {
                if (a - b).abs() > 1e-11 * a.abs().max(1.0) {
                    return Err("histogram varies with threads".into());
                }
            }
        }
        // mass conservation
        let total_v2: f64 = c0[0..20].iter().sum();
        for axis in 1..d {
            let s: f64 = c0[axis * 20..(axis + 1) * 20].iter().sum();
            if ((s - total_v2) / total_v2).abs() > 1e-12 {
                return Err(format!("axis {axis} mass {s} != {total_v2}"));
            }
        }
        Ok(())
    });
}

/// The batch evaluation path (hand-batched `eval_batch` overrides fed
/// through the fill-block → eval_batch → reduce pipeline) reproduces
/// the scalar default-impl path *bitwise* — integral, variance, and
/// every histogram cell — for every registry integrand across random
/// (seed, iteration, d, calls, nb, threads, adjust) draws.
#[test]
fn prop_batch_engine_bitwise_matches_scalar() {
    property("batch_vs_scalar_engine", 40, |g: &mut Gen, i| {
        let name = ALL_NAMES[i % ALL_NAMES.len()];
        let d = match name {
            "fA" | "cosmo" => 6,
            "fB" => 9,
            _ => g.usize_range(1, 8),
        };
        let calls = g.usize_range(512, 8192);
        let nb = g.usize_range(2, 50);
        let nblocks = g.usize_range(1, 8);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let iteration = g.usize_range(0, 25) as u32;
        let adjust = g.f64() < 0.7;
        let threads = g.usize_range(1, 4);
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let layout = Layout::compute(d, calls, nb, nblocks).map_err(|e| e.to_string())?;
        let bins = Bins::uniform(d, nb);
        let opts = VSampleOpts {
            seed,
            iteration,
            adjust,
            threads,
        };
        let (rb, cb) = NativeEngine.vsample(&*f, &layout, &bins, &opts);
        let scalar = ScalarEval(&*f);
        let (rs, cs) = NativeEngine.vsample(&scalar, &layout, &bins, &opts);
        if rb.integral.to_bits() != rs.integral.to_bits() {
            return Err(format!(
                "{name} d={d}: integral {} != scalar {}",
                rb.integral, rs.integral
            ));
        }
        if rb.variance.to_bits() != rs.variance.to_bits() {
            return Err(format!(
                "{name} d={d}: variance {} != scalar {}",
                rb.variance, rs.variance
            ));
        }
        match (cb, cs) {
            (None, None) => {}
            (Some(hb), Some(hs)) => {
                for (j, (a, b)) in hb.iter().zip(&hs).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("{name} d={d}: histogram cell {j}: {a} != {b}"));
                    }
                }
            }
            _ => return Err(format!("{name}: histogram presence differs")),
        }
        Ok(())
    });
}

/// **SIMD determinism contract.** The lane-parallel fill
/// (`FillPath::Simd`, the default) is *bitwise* identical to the
/// scalar per-point reference (`FillPath::Scalar`) — integral,
/// variance, every histogram cell, and (stratified) every damped
/// accumulator entry — on BOTH engines and BOTH `Sampling` modes.
/// `d ∈ {1, 4, 7, 16}` pins the partial-lane-group and
/// partial-Philox-block shapes: d=1 uses 1 of 4 words per block, d=7
/// spans two blocks with a ragged tail, d=16 is `MAX_DIM` (m = 1, so
/// one cube absorbs the whole budget and every lane tail shows up).
#[test]
fn prop_simd_fill_bitwise_matches_scalar() {
    let dims = [1usize, 4, 7, 16];
    let names = ["f1", "f3", "f4", "f5"];
    property("simd_vs_scalar_fill", 24, |g: &mut Gen, i| {
        let d = dims[i % dims.len()];
        let name = names[(i / dims.len()) % names.len()];
        let calls = g.usize_range(512, 8192);
        let nb = g.usize_range(2, 40);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let iteration = g.usize_range(0, 25) as u32;
        let adjust = g.f64() < 0.7;
        let threads = g.usize_range(1, 4);
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let layout = Layout::compute(d, calls, nb, 4).map_err(|e| e.to_string())?;
        let bins = Bins::uniform(d, nb);
        let opts = VSampleOpts {
            seed,
            iteration,
            adjust,
            threads,
        };
        let tag = format!("{name} d={d} calls={calls} nb={nb}");

        // Engine 1, Sampling::Uniform: the uniform m-Cubes engine.
        let simd =
            NativeEngine.vsample_exec(&*f, &layout, &bins, &opts, FillPath::Simd, ExecPath::default());
        let scal = NativeEngine.vsample_exec(
            &*f,
            &layout,
            &bins,
            &opts,
            FillPath::Scalar,
            ExecPath::default(),
        );
        check_bitwise(&tag, "uniform engine", &simd, &scal)?;

        // Engine 2, Sampling::VegasPlus: the stratified engine on a
        // skewed allocation (wild per-cube counts → ragged lane tails).
        // Both passes resume the same snapshot, so they sample the same
        // per-cube counts.
        let snap = snapshot_of(&skewed_allocation(g, &layout, 0.75), 0.75);
        let (s1, d1) = strat_pass(
            &*f, layout, &bins, 0.75, Some(&snap), &opts, FillPath::Simd, ExecPath::default(),
        )?;
        let (s2, d2) = strat_pass(
            &*f, layout, &bins, 0.75, Some(&snap), &opts, FillPath::Scalar, ExecPath::default(),
        )?;
        check_bitwise(&tag, "stratified skewed", &s1, &s2)?;
        for (j, (x, y)) in d1.iter().zip(&d2).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{tag}: damped {j}: {x} != {y}"));
            }
        }

        // Stratified engine with the uniform allocation (the
        // `VegasPlus { beta: 0 }` ≡ `Uniform` mode) — and it must also
        // equal the uniform engine, closing the triangle.
        let (u1, _) = strat_pass(
            &*f, layout, &bins, 0.0, None, &opts, FillPath::Simd, ExecPath::default(),
        )?;
        let (u2, _) = strat_pass(
            &*f, layout, &bins, 0.0, None, &opts, FillPath::Scalar, ExecPath::default(),
        )?;
        check_bitwise(&tag, "stratified uniform", &u1, &u2)?;
        check_bitwise(&tag, "uniform-vs-stratified", &simd, &u1)?;
        Ok(())
    });
}

/// Bitwise comparison of two engine passes (estimate + histogram) for
/// the simd-vs-scalar property above.
fn check_bitwise(
    tag: &str,
    label: &str,
    a: &(IterationResult, Option<Vec<f64>>),
    b: &(IterationResult, Option<Vec<f64>>),
) -> Result<(), String> {
    if a.0.integral.to_bits() != b.0.integral.to_bits()
        || a.0.variance.to_bits() != b.0.variance.to_bits()
    {
        return Err(format!(
            "{tag} [{label}]: simd ({}, {}) != scalar ({}, {})",
            a.0.integral, a.0.variance, b.0.integral, b.0.variance
        ));
    }
    match (&a.1, &b.1) {
        (None, None) => Ok(()),
        (Some(ha), Some(hb)) => {
            for (j, (x, y)) in ha.iter().zip(hb).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("{tag} [{label}]: histogram cell {j}: {x} != {y}"));
                }
            }
            Ok(())
        }
        _ => Err(format!("{tag} [{label}]: histogram presence differs")),
    }
}

/// Adversarial `rebin` weight vectors — one-hot (exact zeros
/// elsewhere), TINY-floored one-hot, and near-equal (a few ulps
/// apart) — must always leave a strictly monotone grid ending exactly
/// at 1.0, even when fp drift runs the consume loop off the end.
#[test]
fn prop_rebin_adversarial_weights_keep_grid_valid() {
    property("rebin_adversarial", 300, |g: &mut Gen, i| {
        let nb = g.usize_range(2, 64);
        // Random monotone starting grid ending at 1.
        let mut edges: Vec<f64> = (0..nb).map(|_| g.f64_range(1e-9, 1.0)).collect();
        edges.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for k in 0..nb {
            let min = if k == 0 { 0.0 } else { edges[k - 1] };
            if edges[k] <= min {
                edges[k] = min + 1e-9;
            }
        }
        edges[nb - 1] = 1.0;
        let hot = g.usize_range(0, nb - 1);
        let w: Vec<f64> = match i % 3 {
            0 => (0..nb).map(|k| if k == hot { 7.5 } else { 0.0 }).collect(),
            1 => (0..nb)
                .map(|k| if k == hot { 1.0 } else { 1e-30 })
                .collect(),
            _ => (0..nb)
                .map(|k| 1.0 + ((k * 31 + i) % 11) as f64 * 1e-16)
                .collect(),
        };
        // Compound a few rounds so drift accumulates.
        for round in 0..5 {
            rebin(&mut edges, &w);
            let mut prev = 0.0;
            for (k, &e) in edges.iter().enumerate() {
                if !(e > prev && e <= 1.0) {
                    return Err(format!(
                        "round {round} edge {k}: {e} not in ({prev}, 1] ({w:?})"
                    ));
                }
                prev = e;
            }
            if edges[nb - 1] != 1.0 {
                return Err(format!("last edge {} != 1.0", edges[nb - 1]));
            }
        }
        Ok(())
    });
}

/// Build a deliberately skewed allocation (random damped accumulator,
/// one hot cube) so per-cube counts differ wildly, then re-apportion.
fn skewed_allocation(g: &mut Gen, layout: &Layout, beta: f64) -> Allocation {
    let mut alloc = Allocation::uniform(layout);
    let hot = g.usize_range(0, layout.m - 1);
    for cube in 0..layout.m {
        let d = if cube == hot {
            g.f64_range(10.0, 1000.0)
        } else {
            g.f64_range(0.0, 0.2)
        };
        alloc.absorb(cube, d);
    }
    alloc.reallocate(layout.calls(), beta);
    alloc
}

/// Freeze an allocation into the checkpoint form `VegasPlusEngine`
/// resumes from.
fn snapshot_of(alloc: &Allocation, beta: f64) -> StratSnapshot {
    StratSnapshot {
        beta,
        counts: alloc.counts().to_vec(),
        damped: alloc.damped().to_vec(),
    }
}

/// One stratified pass with explicit fill/exec paths, run through the
/// public [`Engine`] trait: build a `VegasPlusEngine` (resuming `snap`
/// when given — reallocation is a deterministic function of
/// `(damped, budget, beta)`, so two engines resumed from the same
/// snapshot sample identical per-cube counts), sample every reduction
/// task, merge in task order, fold the observations back, and return
/// the merged pass plus the engine's damped accumulator.
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn strat_pass(
    f: &dyn Integrand,
    layout: Layout,
    bins: &Bins,
    beta: f64,
    snap: Option<&StratSnapshot>,
    opts: &VSampleOpts,
    fill: FillPath,
    exec: ExecPath,
) -> Result<((IterationResult, Option<Vec<f64>>), Vec<f64>), String> {
    let mut engine = VegasPlusEngine::new(layout, beta, snap).map_err(|e| e.to_string())?;
    let ntasks = reduction_tasks(layout.m);
    let partials = engine.sample_tasks(f, bins, opts, fill, exec, 0, ntasks);
    let out = merge_task_partials(layout.d, layout.nb, opts.adjust, &partials);
    engine.update(&partials);
    let snap = engine.export().ok_or("vegas+ engine must export")?;
    Ok((out, snap.damped))
}

/// Same bitwise contract for the VEGAS+ stratified engine, whose
/// variable per-cube sample counts exercise the chunked block path.
#[test]
fn prop_batch_stratified_bitwise_matches_scalar() {
    property("batch_vs_scalar_stratified", 12, |g: &mut Gen, i| {
        let names = ["f1", "f3", "f4", "f6"];
        let name = names[i % names.len()];
        let d = g.usize_range(2, 5);
        let calls = g.usize_range(1024, 8192);
        let nb = g.usize_range(4, 30);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let threads = g.usize_range(1, 4);
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let layout = Layout::compute(d, calls, nb, 1).map_err(|e| e.to_string())?;
        let bins = Bins::uniform(d, nb);
        let mut a_batch = skewed_allocation(g, &layout, 0.75);
        let mut a_scalar = a_batch.clone();
        let opts = VSampleOpts {
            seed,
            iteration: 1,
            adjust: true,
            threads,
        };
        let (rb, hb) = vsample_stratified(&*f, &layout, &bins, &mut a_batch, &opts);
        let scalar = ScalarEval(&*f);
        let (rs, hs) = vsample_stratified(&scalar, &layout, &bins, &mut a_scalar, &opts);
        if rb.integral.to_bits() != rs.integral.to_bits()
            || rb.variance.to_bits() != rs.variance.to_bits()
        {
            return Err(format!(
                "{name} d={d}: stratified estimate differs: ({}, {}) vs ({}, {})",
                rb.integral, rb.variance, rs.integral, rs.variance
            ));
        }
        for (j, (a, b)) in hb.unwrap().iter().zip(&hs.unwrap()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("{name} d={d}: histogram cell {j}: {a} != {b}"));
            }
        }
        for (j, (a, b)) in a_batch.damped().iter().zip(a_scalar.damped()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("{name} d={d}: damped {j}: {a} != {b}"));
            }
        }
        Ok(())
    });
}

/// Allocation invariants over random layouts / damped accumulators /
/// betas: counts sum to the call budget, never dip below the per-cube
/// floor, offsets are exclusive prefix sums, and `beta = 0` is the
/// exact uniform split regardless of the accumulator.
#[test]
fn prop_allocation_invariants() {
    property("allocation", 150, |g: &mut Gen, _| {
        let d = g.usize_range(1, 8);
        let calls = g.usize_range(64, 200_000);
        let layout = Layout::compute(d, calls, 20, 1).map_err(|e| e.to_string())?;
        let budget = layout.calls(); // >= 2m by construction (p >= 2)
        let beta = g.f64_range(0.0, 1.0);
        let mut alloc = Allocation::uniform(&layout);
        for cube in 0..layout.m {
            alloc.absorb(cube, g.f64_range(0.0, 100.0));
        }
        alloc.reallocate(budget, beta);
        if alloc.total() != budget {
            return Err(format!(
                "total {} != budget {budget} (m={}, beta={beta})",
                alloc.total(),
                layout.m
            ));
        }
        if let Some(&c) = alloc.counts().iter().find(|&&c| c < MIN_SAMPLES_PER_CUBE) {
            return Err(format!("count {c} below floor"));
        }
        let mut acc = 0u64;
        for (i, (&o, &c)) in alloc.offsets().iter().zip(alloc.counts()).enumerate() {
            if o != acc {
                return Err(format!("offset {i}: {o} != prefix sum {acc}"));
            }
            acc += c as u64;
        }
        // beta = 0: exact uniform split (p everywhere for this budget).
        let mut zero = alloc.clone();
        zero.reallocate(budget, 0.0);
        if zero.counts().iter().any(|&c| c as usize != layout.p) {
            return Err(format!(
                "beta=0 must reproduce the uniform split p={}",
                layout.p
            ));
        }
        Ok(())
    });
}

/// The stratified engine is bitwise thread-count invariant (fixed task
/// partition), and with a uniform allocation it reproduces the uniform
/// engine bitwise — the `Sampling::VegasPlus { beta: 0 }` contract.
#[test]
fn prop_stratified_thread_invariance_and_beta0_equivalence() {
    property("stratified_invariance", 10, |g: &mut Gen, i| {
        let names = ["f2", "f4", "f5"];
        let name = names[i % names.len()];
        let d = g.usize_range(2, 6);
        let calls = g.usize_range(512, 8192);
        let nb = g.usize_range(4, 30);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let adjust = g.f64() < 0.7;
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let layout = Layout::compute(d, calls, nb, 1).map_err(|e| e.to_string())?;
        let bins = Bins::uniform(d, nb);
        let opts = |threads: usize| VSampleOpts {
            seed,
            iteration: 2,
            adjust,
            threads,
        };

        // Thread invariance on a skewed allocation.
        let mut a1 = skewed_allocation(g, &layout, 0.75);
        let mut a4 = a1.clone();
        let (r1, h1) = vsample_stratified(&*f, &layout, &bins, &mut a1, &opts(1));
        let (r4, h4) = vsample_stratified(&*f, &layout, &bins, &mut a4, &opts(4));
        if r1.integral.to_bits() != r4.integral.to_bits()
            || r1.variance.to_bits() != r4.variance.to_bits()
        {
            return Err(format!("{name} d={d}: thread counts change the estimate"));
        }
        match (h1, h4) {
            (None, None) => {}
            (Some(h1), Some(h4)) => {
                for (a, b) in h1.iter().zip(&h4) {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("{name} d={d}: histogram varies with threads"));
                    }
                }
            }
            _ => return Err("histogram presence differs".into()),
        }

        // Uniform allocation == uniform engine, any thread counts.
        let threads_u = g.usize_range(1, 4);
        let threads_s = g.usize_range(1, 4);
        let (ru, hu) = NativeEngine.vsample(&*f, &layout, &bins, &opts(threads_u));
        let mut au = Allocation::uniform(&layout);
        let (rs, hs) = vsample_stratified(&*f, &layout, &bins, &mut au, &opts(threads_s));
        if ru.integral.to_bits() != rs.integral.to_bits()
            || ru.variance.to_bits() != rs.variance.to_bits()
        {
            return Err(format!(
                "{name} d={d}: uniform allocation != uniform engine: {} vs {}",
                rs.integral, ru.integral
            ));
        }
        if let (Some(hu), Some(hs)) = (hu, hs) {
            for (a, b) in hu.iter().zip(&hs) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("{name} d={d}: uniform histograms differ"));
                }
            }
        }
        Ok(())
    });
}

/// A frozen reimplementation of the *pre-redesign* driver loop
/// (`itmax`/`ita`/`skip` flat knobs, built from the same public
/// building blocks): the oracle the session-based rewrite must
/// reproduce bitwise.
#[allow(clippy::too_many_arguments)]
fn legacy_driver_oracle(
    backend: &mut dyn VSampleBackend,
    d: usize,
    nb: usize,
    seed: u32,
    tau: f64,
    itmax: usize,
    ita: usize,
    skip: usize,
) -> (WeightedEstimator, Bins, usize, bool) {
    let conv = Convergence::with_tau(tau);
    let mut bins = Bins::uniform(d, nb);
    let mut est = WeightedEstimator::new();
    let mut iterations = 0usize;
    let mut converged = false;
    for it in 0..itmax {
        let adjust = it < ita;
        let (r, contrib) = backend.run(&bins, seed, it as u32, adjust).unwrap();
        iterations += 1;
        if it >= skip {
            est.push(r);
        }
        if adjust {
            if let Some(c) = contrib {
                bins.adjust(&c);
            }
            if est.iterations() >= 2 && est.chi2_dof() > conv.max_chi2_dof {
                est.reset();
            }
        }
        if conv.satisfied(&est) {
            converged = true;
        }
        if converged {
            break;
        }
    }
    (est, bins, iterations, converged)
}

/// **Acceptance property.** `RunPlan::classic` driven through
/// `Session::step()` (which is what `Integrator::run()` now drains) is
/// bitwise identical — integral, sigma, chi^2/dof, iteration count,
/// and the final importance grid — to the pre-redesign flat-knob
/// driver loop, on BOTH engines (uniform m-Cubes and VEGAS+
/// stratified), across random shapes, schedules, seeds, and thread
/// counts.
#[test]
fn prop_classic_session_bitwise_matches_legacy_driver() {
    property("classic_vs_legacy_driver", 16, |g: &mut Gen, i| {
        let names = ["f2", "f3", "f4", "f5"];
        let name = names[i % names.len()];
        let d = g.usize_range(2, 5);
        let calls = g.usize_range(1024, 8192);
        let nb = g.usize_range(8, 40);
        let nblocks = g.usize_range(1, 8);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let itmax = g.usize_range(1, 8);
        let ita = g.usize_range(0, itmax);
        let skip = g.usize_range(0, itmax.saturating_sub(1));
        // Loose tau sometimes converges mid-run; tiny tau never does —
        // both stop paths must agree with the oracle.
        let tau = if g.f64() < 0.5 { 5e-2 } else { 1e-12 };
        let threads = g.usize_range(1, 4);
        let vegas = g.f64() < 0.5;
        let beta = g.f64_range(0.0, 1.0);
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let layout = Layout::compute(d, calls, nb, nblocks).map_err(|e| e.to_string())?;

        let (est, bins, iters, converged) = if vegas {
            let mut backend = EngineBackend::vegas_plus(f.clone(), layout, threads, beta, None)
                .map_err(|e| e.to_string())?;
            legacy_driver_oracle(&mut backend, d, nb, seed, tau, itmax, ita, skip)
        } else {
            let mut backend = EngineBackend::uniform(f.clone(), layout, threads);
            legacy_driver_oracle(&mut backend, d, nb, seed, tau, itmax, ita, skip)
        };

        let sampling = if vegas {
            Sampling::VegasPlus { beta }
        } else {
            Sampling::Uniform
        };
        let cfg = JobConfig::default()
            .with_maxcalls(calls)
            .with_bins(nb)
            .with_blocks(nblocks)
            .with_tolerance(tau)
            .with_plan(RunPlan::classic(itmax, ita, skip))
            .with_seed(seed)
            .with_threads(threads)
            .with_sampling(sampling);

        // Drive the plan one Session::step() at a time...
        let mut session = Session::new(f.clone(), cfg.clone()).map_err(|e| e.to_string())?;
        let mut stepped = 0usize;
        while session.step().map_err(|e| e.to_string())?.is_some() {
            stepped += 1;
        }
        let outcome = session.finish().map_err(|e| e.to_string())?;
        let out = &outcome.output;

        // ...and confirm the blocking facade is the same thing drained.
        let facade = Integrator::new(f)
            .config(cfg)
            .run()
            .map_err(|e| e.to_string())?;

        let tag = format!(
            "{name} d={d} calls={calls} nb={nb} ({itmax},{ita},{skip}) \
             tau={tau:.0e} vegas={vegas}"
        );
        if stepped != out.iterations {
            return Err(format!("{tag}: {stepped} steps != {} iterations", out.iterations));
        }
        if facade.integral.to_bits() != out.integral.to_bits()
            || facade.sigma.to_bits() != out.sigma.to_bits()
        {
            return Err(format!("{tag}: facade run() != stepped session"));
        }
        if out.integral.to_bits() != est.integral().to_bits() {
            return Err(format!(
                "{tag}: integral {} != legacy {}",
                out.integral,
                est.integral()
            ));
        }
        if out.sigma.to_bits() != est.sigma().to_bits() {
            return Err(format!("{tag}: sigma {} != legacy {}", out.sigma, est.sigma()));
        }
        if out.chi2_dof.to_bits() != est.chi2_dof().to_bits() {
            return Err(format!(
                "{tag}: chi2 {} != legacy {}",
                out.chi2_dof,
                est.chi2_dof()
            ));
        }
        if out.iterations != iters || out.converged != converged {
            return Err(format!(
                "{tag}: (iters, converged) ({}, {}) != legacy ({iters}, {converged})",
                out.iterations, out.converged
            ));
        }
        for (j, (a, b)) in outcome.grid.bins().flat().iter().zip(bins.flat()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("{tag}: grid edge {j}: {a} != {b}"));
            }
        }
        Ok(())
    });
}

/// **Acceptance property.** Suspend → JSON checkpoint → resume
/// mid-run reproduces the uninterrupted run bitwise (estimates, grid,
/// strat snapshot, call accounting) on both engines — including when
/// the resuming config uses a different thread count (1 ↔ 4), since
/// the engine reduction is thread-invariant.
#[test]
fn prop_suspend_resume_reproduces_uninterrupted_run_bitwise() {
    property("suspend_resume_bitwise", 12, |g: &mut Gen, i| {
        let names = ["f3", "f4", "f5"];
        let name = names[i % names.len()];
        let d = g.usize_range(2, 5);
        let calls = g.usize_range(1024, 6144);
        let nb = g.usize_range(8, 30);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let itmax = g.usize_range(2, 8);
        let ita = g.usize_range(0, itmax);
        let skip = g.usize_range(0, itmax - 1);
        let vegas = g.f64() < 0.5;
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let cfg = |threads: usize| {
            JobConfig::default()
                .with_maxcalls(calls)
                .with_bins(nb)
                .with_plan(RunPlan::classic(itmax, ita, skip))
                .with_tolerance(1e-12) // fixed work: run the whole plan
                .with_seed(seed)
                .with_threads(threads)
                .with_sampling(if vegas {
                    Sampling::VegasPlus { beta: 0.75 }
                } else {
                    Sampling::Uniform
                })
        };
        let tag = format!("{name} d={d} calls={calls} ({itmax},{ita},{skip}) vegas={vegas}");

        let straight = Session::new(f.clone(), cfg(1))
            .map_err(|e| e.to_string())?
            .finish()
            .map_err(|e| e.to_string())?;

        // Step a twin up to a random cut, suspend, round-trip the
        // checkpoint through its JSON form, resume on 4 threads.
        let cut = g.usize_range(1, itmax - 1);
        let mut first_leg = Session::new(f.clone(), cfg(1)).map_err(|e| e.to_string())?;
        for _ in 0..cut {
            if first_leg.step().map_err(|e| e.to_string())?.is_none() {
                break;
            }
        }
        let checkpoint = first_leg.suspend();
        drop(first_leg);
        let json = checkpoint.to_json().to_json();
        let restored = Checkpoint::from_json(&mcubes::util::json::parse(&json).unwrap())
            .map_err(|e| e.to_string())?;
        if restored != checkpoint {
            return Err(format!("{tag}: checkpoint JSON round-trip changed state"));
        }
        let resumed = Session::resume(f, cfg(4), &restored)
            .map_err(|e| e.to_string())?
            .finish()
            .map_err(|e| e.to_string())?;

        let (a, b) = (&straight.output, &resumed.output);
        if a.integral.to_bits() != b.integral.to_bits()
            || a.sigma.to_bits() != b.sigma.to_bits()
            || a.chi2_dof.to_bits() != b.chi2_dof.to_bits()
        {
            return Err(format!(
                "{tag} cut={cut}: resumed ({}, {}) != straight ({}, {})",
                b.integral, b.sigma, a.integral, a.sigma
            ));
        }
        if a.iterations != b.iterations || a.calls_used != b.calls_used {
            return Err(format!(
                "{tag} cut={cut}: accounting differs: ({}, {}) vs ({}, {})",
                b.iterations, b.calls_used, a.iterations, a.calls_used
            ));
        }
        for (j, (x, y)) in straight
            .grid
            .bins()
            .flat()
            .iter()
            .zip(resumed.grid.bins().flat())
            .enumerate()
        {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{tag} cut={cut}: grid edge {j} differs"));
            }
        }
        match (straight.grid.strat(), resumed.grid.strat()) {
            (None, None) => {}
            (Some(sa), Some(sb)) => {
                if sa.counts != sb.counts {
                    return Err(format!("{tag} cut={cut}: strat counts differ"));
                }
                for (x, y) in sa.damped.iter().zip(&sb.damped) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("{tag} cut={cut}: strat damped differs"));
                    }
                }
            }
            _ => return Err(format!("{tag} cut={cut}: strat presence differs")),
        }
        Ok(())
    });
}

/// **Tentpole acceptance property.** The fused streaming schedule
/// (`ExecPath::Streaming`, the default) is *bitwise* identical to the
/// materialized block reference (`ExecPath::Block`) — integral,
/// variance, every histogram cell, and (stratified) every damped
/// accumulator entry — on BOTH engines, BOTH fill paths, and across
/// thread counts {1, 4, 8}. `d ∈ {1, 4, 7, 16}` pins the
/// partial-lane-group shapes (d=1 packs 4 points per Philox block,
/// d=7 spans two blocks with a ragged tail, d=16 is `MAX_DIM` with
/// m = 1 so a single cube absorbs the entire budget and every tile
/// boundary lands mid-cube).
#[test]
fn prop_streaming_thread_invariance_bitwise_matches_block() {
    let dims = [1usize, 4, 7, 16];
    let names = ["f1", "f3", "f4", "f5"];
    property("streaming_vs_block", 16, |g: &mut Gen, i| {
        let d = dims[i % dims.len()];
        let name = names[(i / dims.len()) % names.len()];
        let calls = g.usize_range(512, 8192);
        let nb = g.usize_range(2, 40);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let iteration = g.usize_range(0, 25) as u32;
        let adjust = g.f64() < 0.7;
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let layout = Layout::compute(d, calls, nb, 4).map_err(|e| e.to_string())?;
        let bins = Bins::uniform(d, nb);
        let opts = |threads: usize| VSampleOpts {
            seed,
            iteration,
            adjust,
            threads,
        };
        let tag = format!("{name} d={d} calls={calls} nb={nb}");

        // Uniform engine: block reference at one thread count vs the
        // streaming schedule across several.
        let block =
            NativeEngine.vsample_exec(&*f, &layout, &bins, &opts(1), FillPath::Simd, ExecPath::Block);
        for threads in [1usize, 4, 8] {
            let stream = NativeEngine.vsample_exec(
                &*f,
                &layout,
                &bins,
                &opts(threads),
                FillPath::Simd,
                ExecPath::Streaming,
            );
            check_bitwise(&tag, &format!("uniform streaming t={threads}"), &stream, &block)?;
        }

        // Scalar fill: the schedule equivalence must hold per fill path.
        let sb = NativeEngine.vsample_exec(
            &*f,
            &layout,
            &bins,
            &opts(3),
            FillPath::Scalar,
            ExecPath::Block,
        );
        let ss = NativeEngine.vsample_exec(
            &*f,
            &layout,
            &bins,
            &opts(8),
            FillPath::Scalar,
            ExecPath::Streaming,
        );
        check_bitwise(&tag, "uniform scalar fill", &ss, &sb)?;

        // Stratified engine on a skewed allocation: wildly uneven
        // per-cube counts make tiles split cubes at every offset. Both
        // schedules resume the same frozen snapshot through the public
        // `Engine` trait.
        let snap = snapshot_of(&skewed_allocation(g, &layout, 0.75), 0.75);
        let (r_block, d_block) = strat_pass(
            &*f, layout, &bins, 0.75, Some(&snap), &opts(4), FillPath::Simd, ExecPath::Block,
        )?;
        for threads in [1usize, 8] {
            let (r_stream, d_stream) = strat_pass(
                &*f,
                layout,
                &bins,
                0.75,
                Some(&snap),
                &opts(threads),
                FillPath::Simd,
                ExecPath::Streaming,
            )?;
            check_bitwise(&tag, &format!("stratified streaming t={threads}"), &r_stream, &r_block)?;
            for (j, (x, y)) in d_stream.iter().zip(&d_block).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("{tag}: stratified damped {j}: {x} != {y}"));
                }
            }
        }
        Ok(())
    });
}

/// **Satellite acceptance property.** Trait-object dispatch is
/// invisible: driving `Box<dyn Engine>` through [`Engine::vsample`]
/// produces the same bits as the concrete engine — estimate,
/// histogram, and (VEGAS+) the exported allocation snapshot — for
/// both native engines across random shapes, fill paths, and thread
/// counts.
#[test]
fn prop_dyn_engine_dispatch_bitwise_matches_static() {
    property("dyn_vs_static_engine", 12, |g: &mut Gen, i| {
        let names = ["f1", "f3", "f4", "f6"];
        let name = names[i % names.len()];
        let d = g.usize_range(1, 6);
        let calls = g.usize_range(512, 8192);
        let nb = g.usize_range(4, 30);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let threads = g.usize_range(1, 4);
        let fill = if g.f64() < 0.5 {
            FillPath::Simd
        } else {
            FillPath::Scalar
        };
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let layout = Layout::compute(d, calls, nb, 1).map_err(|e| e.to_string())?;
        let bins = Bins::uniform(d, nb);
        let opts = VSampleOpts {
            seed,
            iteration: 1,
            adjust: true,
            threads,
        };
        let tag = format!("{name} d={d} calls={calls} nb={nb}");

        // Uniform engine: static vs boxed.
        let mut st = UniformEngine::new(layout);
        let mut dy: Box<dyn Engine> = Box::new(UniformEngine::new(layout));
        let a = st.vsample(&*f, &bins, &opts, fill, ExecPath::default());
        let b = dy.vsample(&*f, &bins, &opts, fill, ExecPath::default());
        check_bitwise(&tag, "uniform dyn-vs-static", &b, &a)?;

        // VEGAS+ engine, both sides resumed from one frozen snapshot so
        // they sample identical per-cube counts.
        let snap = snapshot_of(&skewed_allocation(g, &layout, 0.75), 0.75);
        let mut st =
            VegasPlusEngine::new(layout, 0.75, Some(&snap)).map_err(|e| e.to_string())?;
        let mut dy: Box<dyn Engine> =
            Box::new(VegasPlusEngine::new(layout, 0.75, Some(&snap)).map_err(|e| e.to_string())?);
        let a = st.vsample(&*f, &bins, &opts, fill, ExecPath::default());
        let b = dy.vsample(&*f, &bins, &opts, fill, ExecPath::default());
        check_bitwise(&tag, "vegas+ dyn-vs-static", &b, &a)?;
        let (sa, sb) = (
            st.export().ok_or("static engine must export")?,
            dy.export().ok_or("boxed engine must export")?,
        );
        if sa.counts != sb.counts {
            return Err(format!("{tag}: dyn vs static counts differ"));
        }
        for (j, (x, y)) in sa.damped.iter().zip(&sb.damped).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{tag}: dyn vs static damped {j}: {x} != {y}"));
            }
        }
        Ok(())
    });
}

/// **Tentpole acceptance property.** Suspending a *streaming* session
/// mid-plan, round-tripping the checkpoint through JSON, and resuming
/// under the *block* schedule on a different thread count reproduces
/// the uninterrupted streaming run bitwise (estimates, grid, strat
/// snapshot, call accounting) — the schedule is a performance knob,
/// never a results knob, so checkpoints are freely portable between
/// the two.
#[test]
fn prop_streaming_suspend_resume_matches_block_resume_bitwise() {
    property("streaming_suspend_resume", 10, |g: &mut Gen, i| {
        let names = ["f3", "f4", "f5"];
        let name = names[i % names.len()];
        let d = g.usize_range(2, 5);
        let calls = g.usize_range(1024, 6144);
        let nb = g.usize_range(8, 30);
        let seed = g.usize_range(0, 1 << 30) as u32;
        let itmax = g.usize_range(2, 6);
        let ita = g.usize_range(0, itmax);
        let vegas = g.f64() < 0.5;
        let f = by_name(name, d).map_err(|e| e.to_string())?;
        let cfg = |threads: usize, exec: ExecPath| {
            JobConfig::default()
                .with_maxcalls(calls)
                .with_bins(nb)
                .with_plan(RunPlan::classic(itmax, ita, 0))
                .with_tolerance(1e-12) // fixed work: run the whole plan
                .with_seed(seed)
                .with_threads(threads)
                .with_exec(exec)
                .with_sampling(if vegas {
                    Sampling::VegasPlus { beta: 0.75 }
                } else {
                    Sampling::Uniform
                })
        };
        let tag = format!("{name} d={d} calls={calls} ({itmax},{ita}) vegas={vegas}");

        let straight = Session::new(f.clone(), cfg(2, ExecPath::Streaming))
            .map_err(|e| e.to_string())?
            .finish()
            .map_err(|e| e.to_string())?;

        let cut = g.usize_range(1, itmax - 1);
        let mut first_leg =
            Session::new(f.clone(), cfg(8, ExecPath::Streaming)).map_err(|e| e.to_string())?;
        for _ in 0..cut {
            if first_leg.step().map_err(|e| e.to_string())?.is_none() {
                break;
            }
        }
        let checkpoint = first_leg.suspend();
        drop(first_leg);
        let json = checkpoint.to_json().to_json();
        let restored = Checkpoint::from_json(&mcubes::util::json::parse(&json).unwrap())
            .map_err(|e| e.to_string())?;
        let resumed = Session::resume(f, cfg(1, ExecPath::Block), &restored)
            .map_err(|e| e.to_string())?
            .finish()
            .map_err(|e| e.to_string())?;

        let (a, b) = (&straight.output, &resumed.output);
        if a.integral.to_bits() != b.integral.to_bits()
            || a.sigma.to_bits() != b.sigma.to_bits()
            || a.chi2_dof.to_bits() != b.chi2_dof.to_bits()
        {
            return Err(format!(
                "{tag} cut={cut}: block-resumed ({}, {}) != streaming ({}, {})",
                b.integral, b.sigma, a.integral, a.sigma
            ));
        }
        if a.iterations != b.iterations || a.calls_used != b.calls_used {
            return Err(format!(
                "{tag} cut={cut}: accounting differs: ({}, {}) vs ({}, {})",
                b.iterations, b.calls_used, a.iterations, a.calls_used
            ));
        }
        for (j, (x, y)) in straight
            .grid
            .bins()
            .flat()
            .iter()
            .zip(resumed.grid.bins().flat())
            .enumerate()
        {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{tag} cut={cut}: grid edge {j} differs"));
            }
        }
        match (straight.grid.strat(), resumed.grid.strat()) {
            (None, None) => {}
            (Some(sa), Some(sb)) => {
                if sa.counts != sb.counts {
                    return Err(format!("{tag} cut={cut}: strat counts differ"));
                }
                for (x, y) in sa.damped.iter().zip(&sb.damped) {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("{tag} cut={cut}: strat damped differs"));
                    }
                }
            }
            _ => return Err(format!("{tag} cut={cut}: strat presence differs")),
        }
        Ok(())
    });
}

/// Shared1D grids keep all axes identical under any histogram.
#[test]
fn prop_shared1d_axes_identical() {
    property("shared1d", 50, |g: &mut Gen, _| {
        let d = g.usize_range(2, 8);
        let nb = g.usize_range(4, 32);
        let mut bins = Bins::uniform_mode(d, nb, GridMode::Shared1D);
        for _ in 0..3 {
            let contrib = g.weights(d * nb, 0.3);
            bins.adjust(&contrib);
        }
        bins.validate().map_err(|e| e.to_string())?;
        let first = bins.axis(0).to_vec();
        for axis in 1..d {
            if bins.axis(axis) != &first[..] {
                return Err(format!("axis {axis} differs"));
            }
        }
        Ok(())
    });
}
