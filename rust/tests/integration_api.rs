//! Facade-level integration tests: closure integrands over per-axis
//! bounds, batch closures, grid export/warm-start, observers,
//! escalation, and resumable sessions through `api::Integrator`.

use mcubes::prelude::*;

/// A batch closure (`Integrator::custom_batch`) runs end-to-end and
/// reproduces the equivalent scalar closure bitwise: both feed the
/// same engine pipeline, one via hand-written column math, one via the
/// default gather-and-eval bridge.
#[test]
fn custom_batch_closure_matches_scalar_closure_bitwise() {
    let bounds = Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)]).unwrap();
    let scalar = Integrator::from_fn(2, bounds.clone(), |x| x[0] * x[1])
        .unwrap()
        .maxcalls(1 << 12)
        .tolerance(1e-3)
        .seed(7)
        .run()
        .unwrap();
    let batch = Integrator::custom_batch(2, bounds, |block, out| {
        let (x, y) = (block.axis(0), block.axis(1));
        for (k, o) in out.iter_mut().enumerate() {
            *o = x[k] * y[k];
        }
    })
    .unwrap()
    .maxcalls(1 << 12)
    .tolerance(1e-3)
    .seed(7)
    .run()
    .unwrap();
    assert_eq!(scalar.integral.to_bits(), batch.integral.to_bits());
    assert_eq!(scalar.sigma.to_bits(), batch.sigma.to_bits());
    assert_eq!(scalar.iterations, batch.iterations);
    // ∫∫ x·y over [0,2]×[1,3] = 2 · 4 = 8.
    assert!(batch.converged, "{batch:?}");
    assert!(
        ((batch.integral - 8.0) / 8.0).abs() < 5e-3,
        "I = {}",
        batch.integral
    );
}

/// Batch closures carry names/true values through `FnBatchIntegrand`
/// and work wherever an `IntegrandRef` does (spec, service path).
#[test]
fn batch_integrand_ref_flows_through_spec() {
    let f = FnBatchIntegrand::unit(3, |block: &PointBlock, out: &mut [f64]| {
        let (x, y, z) = (block.axis(0), block.axis(1), block.axis(2));
        for (k, o) in out.iter_mut().enumerate() {
            *o = x[k] + y[k] + z[k];
        }
    })
    .named("sum3-batch")
    .with_true_value(1.5);
    let spec = IntegrandSpec::custom(f.into_ref());
    assert_eq!(spec.label(), "sum3-batch");
    assert_eq!(spec.dim(), 3);
    let out = Integrator::from_spec(spec)
        .maxcalls(1 << 12)
        .tolerance(1e-3)
        .seed(5)
        .run()
        .unwrap();
    assert!(out.converged, "{out:?}");
    assert!(
        ((out.integral - 1.5) / 1.5).abs() < 5e-3,
        "I = {}",
        out.integral
    );
}

/// A closure integrand over a non-uniform box integrates end-to-end on
/// the native backend with the correct result vs analytic truth.
#[test]
fn closure_per_axis_bounds_matches_analytic_truth() {
    // ∫∫∫ x·y·z over [0,2]×[1,3]×[0,1]:
    //   (2²/2) · ((3²-1²)/2) · (1/2) = 2 · 4 · 0.5 = 4.
    let bounds = Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0), (0.0, 1.0)]).unwrap();
    let out = Integrator::from_fn(3, bounds, |x| x[0] * x[1] * x[2])
        .unwrap()
        .maxcalls(1 << 14)
        .tolerance(1e-3)
        .seed(3)
        .run()
        .unwrap();
    assert!(out.converged, "{out:?}");
    let rel = ((out.integral - 4.0) / 4.0).abs();
    assert!(rel < 5e-3, "I = {} (rel {rel:.2e})", out.integral);
}

/// A closure over per-axis bounds agrees with the affinely rescaled
/// registry integrand it was built from: same seed, same layout, the
/// two runs sample the same unit-box points, so the estimates agree to
/// affine-roundtrip rounding.
#[test]
fn closure_agrees_with_rescaled_registry_integrand() {
    let d = 5;
    let f4 = mcubes::integrands::by_name("f4", d).unwrap();

    // Physical box [a_i, b_i] per axis; g(y) = f4(u(y)) / vol where
    // u_i = (y_i - a_i) / (b_i - a_i). Then ∫_box g = ∫_unit f4.
    let pairs = [(0.0, 2.0), (-1.0, 1.0), (0.5, 1.5), (10.0, 14.0), (0.0, 0.5)];
    let bounds = Bounds::per_axis(&pairs).unwrap();
    let vol = bounds.volume();
    let f4_inner = f4.clone();
    let rescaled = move |y: &[f64]| {
        let mut u = [0.0f64; 5];
        for i in 0..5 {
            u[i] = (y[i] - pairs[i].0) / (pairs[i].1 - pairs[i].0);
        }
        f4_inner.eval(&u) / vol
    };

    let mk_cfg = |intg: Integrator| {
        intg.maxcalls(1 << 14)
            .tolerance(1e-12) // run a fixed number of iterations
            .plan(RunPlan::classic(6, 4, 0))
            .seed(99)
    };
    let reference = mk_cfg(Integrator::new(f4.clone())).run().unwrap();
    let scaled = mk_cfg(Integrator::from_fn(d, bounds, rescaled).unwrap())
        .run()
        .unwrap();

    assert_eq!(reference.iterations, scaled.iterations);
    let rel = ((reference.integral - scaled.integral) / reference.integral).abs();
    assert!(
        rel < 1e-9,
        "unit-box {} vs rescaled {} (rel {rel:.2e})",
        reference.integral,
        scaled.integral
    );
    let rel_s = ((reference.sigma - scaled.sigma) / reference.sigma).abs();
    assert!(rel_s < 1e-6, "sigma rel {rel_s:.2e}");
}

/// GridState round-trips (export → JSON → import) and the imported
/// grid is the donor grid.
#[test]
fn grid_state_round_trips() {
    let mut donor = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(1 << 13)
        .tolerance(1e-3)
        .seed(21);
    donor.run().unwrap();
    let grid = donor.export_grid().expect("grid after run");
    assert_eq!(grid.d(), 5);

    let json = grid.to_json().to_json();
    let back = GridState::from_json(&mcubes::util::json::parse(&json).unwrap()).unwrap();
    assert_eq!(back, grid);

    let path = std::env::temp_dir().join("mcubes_api_grid_roundtrip.json");
    grid.save(&path).unwrap();
    let from_file = GridState::load(&path).unwrap();
    assert_eq!(from_file, grid);
    let _ = std::fs::remove_file(path);
}

/// Warm-started runs are seed-reproducible: the same donor grid and
/// seed produce bit-identical outputs.
#[test]
fn warm_start_is_seed_reproducible() {
    let mut donor = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(1 << 13)
        .tolerance(1e-3)
        .seed(5);
    donor.run().unwrap();
    let grid = donor.export_grid().unwrap();

    let warm_run = || {
        Integrator::from_registry("f4", 5)
            .unwrap()
            .maxcalls(1 << 13)
            .tolerance(1e-3)
            .seed(1234)
            .warm_start(grid.clone())
            .plan(RunPlan::classic(15, 0, 0))
            .run()
            .unwrap()
    };
    let a = warm_run();
    let b = warm_run();
    assert_eq!(a.integral, b.integral);
    assert_eq!(a.sigma, b.sigma);
    assert_eq!(a.iterations, b.iterations);
}

/// A warm start reproduces the donor's adapted-grid behavior: it
/// converges in fewer iterations than a cold start, because the
/// importance grid no longer has to be learned.
#[test]
fn warm_start_converges_faster_than_cold() {
    // f4 (sharp 5-D Gaussian) at a modest budget needs several
    // adjustment iterations from a uniform grid.
    let cold_builder = || {
        Integrator::from_registry("f4", 5)
            .unwrap()
            .maxcalls(1 << 14)
            .tolerance(1e-3)
            .plan(RunPlan::classic(20, 12, 2))
            .seed(17)
    };
    let mut cold = cold_builder();
    let cold_out = cold.run().unwrap();
    assert!(cold_out.converged, "{cold_out:?}");
    let grid = cold.export_grid().unwrap();

    let warm_out = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(1 << 14)
        .tolerance(1e-3)
        .plan(RunPlan::classic(20, 0, 0)) // grid already adapted
        .seed(18)
        .warm_start(grid)
        .run()
        .unwrap();
    assert!(warm_out.converged, "{warm_out:?}");
    assert!(
        warm_out.iterations < cold_out.iterations,
        "warm {} !< cold {}",
        warm_out.iterations,
        cold_out.iterations
    );
}

/// VEGAS+ through the facade: the exported grid carries the
/// stratification snapshot, it round-trips through JSON, and feeding
/// it back resumes the allocation (same layout) without erroring.
#[test]
fn vegas_plus_grid_exports_and_round_trips_strat_state() {
    let mut donor = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(4096) // g=4, m=1024, p=4: allocation headroom
        .tolerance(1e-12)
        .plan(RunPlan::classic(6, 4, 0))
        .seed(31)
        .sampling(Sampling::vegas_plus())
        .observe(|ev| {
            assert!(ev.alloc.is_some(), "vegas+ events carry alloc stats");
        });
    let out = donor.run().unwrap();
    assert_eq!(out.backend, "native-vegas+");
    let grid = donor.export_grid().expect("grid after run");
    let snap = grid.strat().expect("vegas+ grids carry a strat snapshot");
    assert_eq!(snap.beta, 0.75);
    assert_eq!(snap.counts.len(), 1024);
    assert_eq!(snap.counts.iter().map(|&c| c as usize).sum::<usize>(), 4096);

    let path = std::env::temp_dir().join("mcubes_api_vegas_grid.json");
    grid.save(&path).unwrap();
    let back = GridState::load(&path).unwrap();
    assert_eq!(back, grid);
    let _ = std::fs::remove_file(path);

    let warm = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(4096)
        .tolerance(1e-3)
        .plan(RunPlan::classic(10, 0, 0))
        .seed(32)
        .sampling(Sampling::vegas_plus())
        .warm_start(back)
        .run()
        .unwrap();
    assert!(warm.iterations >= 1, "{warm:?}");
}

/// Observer events narrate the whole run: indices are consecutive and
/// cumulative across escalation levels, the last event is converged
/// when the output is, and running estimates match the output.
#[test]
fn observer_trace_is_consistent() {
    use std::sync::{Arc, Mutex};
    let events: Arc<Mutex<Vec<(usize, f64, bool)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&events);
    let out = Integrator::from_registry("f3", 3)
        .unwrap()
        .maxcalls(1 << 12)
        .tolerance(2e-4)
        .escalate(3, 4)
        .seed(8)
        .observe(move |ev| {
            sink.lock()
                .unwrap()
                .push((ev.iteration, ev.rel_err, ev.converged));
        })
        .run()
        .unwrap();
    let events = events.lock().unwrap();
    assert_eq!(events.len(), out.iterations);
    for (i, &(it, _, _)) in events.iter().enumerate() {
        assert_eq!(it, i, "iteration indices must be cumulative");
    }
    let last = events.last().unwrap();
    assert_eq!(last.2, out.converged);
    if out.converged {
        assert!(last.1 <= 2e-4, "final rel_err {} > tau", last.1);
    }
}

/// The CPU baselines honor per-axis bounds too (they sample through
/// `Integrand::bounds()`, not the legacy scalar hull).
#[test]
fn baselines_honor_per_axis_bounds() {
    use mcubes::baselines::{miser_integrate, plain_mc_integrate, MiserConfig, PlainMcConfig};
    // ∫∫ x·y over [0,2]×[1,3] = 8; the hull box [0,3]² would give a
    // very different answer (81/4), so this catches hull sampling.
    let bounds = Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)]).unwrap();
    let f = FnIntegrand::new(2, bounds, |x: &[f64]| x[0] * x[1])
        .unwrap()
        .into_ref();
    let p = plain_mc_integrate(
        &*f,
        &PlainMcConfig {
            calls: 100_000,
            seed: 9,
        },
    );
    assert!(
        (p.integral - 8.0).abs() < 6.0 * p.sigma + 0.05,
        "plain MC I = {} sigma = {}",
        p.integral,
        p.sigma
    );
    let m = miser_integrate(
        &*f,
        &MiserConfig {
            calls: 100_000,
            seed: 9,
            ..Default::default()
        },
    );
    assert!(
        (m.integral - 8.0).abs() < 6.0 * m.sigma + 0.05,
        "MISER I = {} sigma = {}",
        m.integral,
        m.sigma
    );
}

/// Sessions pull iterations one at a time; mid-run state is
/// inspectable and the stage labels narrate the plan.
#[test]
fn session_steps_expose_typed_iterations() {
    let mut session = Integrator::from_registry("f5", 4)
        .unwrap()
        .maxcalls(1 << 12)
        .tolerance(1e-12) // fixed work
        .plan(RunPlan::classic(6, 4, 1))
        .seed(9)
        .session()
        .unwrap();
    let mut labels = Vec::new();
    while let Some(it) = session.step().unwrap() {
        assert_eq!(it.index, labels.len());
        assert_eq!(it.calls_used, session.calls_used());
        labels.push((it.stage_label.clone(), it.adjusting, it.discarded));
        if it.stop.is_none() {
            assert!(!session.is_finished());
        }
    }
    assert_eq!(labels.len(), 6);
    assert_eq!(labels[0], ("adapt+discard".to_string(), true, true));
    assert_eq!(labels[1], ("adapt".to_string(), true, false));
    assert_eq!(labels[5], ("sample".to_string(), false, false));
    assert_eq!(session.stop_reason(), Some(StopReason::Exhausted));
    let outcome = session.finish().unwrap();
    assert_eq!(outcome.stop, StopReason::Exhausted);
    assert_eq!(outcome.output.iterations, 6);
}

/// Suspend/resume round-trips through the JSON checkpoint file and
/// continues bit-identically (the full bitwise property sweep lives in
/// rust/tests/properties.rs).
#[test]
fn checkpoint_file_round_trip_resumes_bitwise() {
    let builder = || {
        Integrator::from_registry("f4", 5)
            .unwrap()
            .maxcalls(1 << 12)
            .tolerance(1e-12)
            .plan(RunPlan::classic(7, 5, 1))
            .seed(23)
            .sampling(Sampling::vegas_plus())
    };
    let straight = builder().run().unwrap();

    let mut session = builder().session().unwrap();
    for _ in 0..3 {
        session.step().unwrap().unwrap();
    }
    let path = std::env::temp_dir().join("mcubes_api_checkpoint.json");
    session.suspend().save(&path).unwrap();
    drop(session);

    let checkpoint = Checkpoint::load(&path).unwrap();
    assert_eq!(checkpoint.iteration(), 3);
    let _ = std::fs::remove_file(&path);
    let resumed = builder()
        .resume_session(&checkpoint)
        .unwrap()
        .finish()
        .unwrap()
        .output;
    assert_eq!(straight.integral.to_bits(), resumed.integral.to_bits());
    assert_eq!(straight.sigma.to_bits(), resumed.sigma.to_bits());
    assert_eq!(straight.iterations, resumed.iterations);
}

/// A checkpoint taken from a *finished* session stays finished when
/// resumed (the stop reason round-trips through JSON), instead of
/// silently un-finishing and folding extra iterations.
#[test]
fn resuming_a_finished_checkpoint_stays_finished() {
    let builder = || {
        Integrator::from_registry("f3", 3)
            .unwrap()
            .maxcalls(1 << 13)
            .tolerance(1e-3)
            .plan(RunPlan::classic(12, 8, 1))
            .seed(6)
    };
    let mut session = builder().session().unwrap();
    while session.step().unwrap().is_some() {}
    assert_eq!(session.stop_reason(), Some(StopReason::Converged));
    let final_integral = session.integral();
    let final_iters = session.iterations();
    let checkpoint = session.suspend();
    assert_eq!(checkpoint.stop(), Some(StopReason::Converged));

    let json = checkpoint.to_json().to_json();
    let restored = Checkpoint::from_json(&mcubes::util::json::parse(&json).unwrap()).unwrap();
    assert_eq!(restored, checkpoint);

    let mut resumed = builder().resume_session(&restored).unwrap();
    assert!(resumed.is_finished(), "finished checkpoints resume finished");
    assert_eq!(resumed.stop_reason(), Some(StopReason::Converged));
    assert!(resumed.step().unwrap().is_none(), "no extra iterations run");
    let outcome = resumed.finish().unwrap();
    assert_eq!(outcome.stop, StopReason::Converged);
    assert_eq!(outcome.output.integral.to_bits(), final_integral.to_bits());
    assert_eq!(outcome.output.iterations, final_iters);
}

/// Warm-start edge cases: a strat snapshot whose cube count doesn't
/// match the new layout silently refreshes to the uniform allocation
/// (the grid itself still warm-starts), and pre-checkpoint grid JSON
/// (no "session" field, even no "strat" field) still loads.
#[test]
fn checkpoint_and_grid_state_edge_cases() {
    // Donor at 4096 calls (m=1024); warm start at 2^13 (different m).
    let mut donor = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(4096)
        .tolerance(1e-12)
        .plan(RunPlan::classic(5, 3, 0))
        .seed(41)
        .sampling(Sampling::vegas_plus());
    donor.run().unwrap();
    let grid = donor.export_grid().unwrap();
    assert!(grid.strat().is_some());

    let mismatched = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(1 << 13)
        .tolerance(1e-12)
        .plan(RunPlan::classic(3, 2, 0))
        .seed(42)
        .sampling(Sampling::vegas_plus())
        .warm_start(grid.clone())
        .run()
        .unwrap();
    assert_eq!(mismatched.iterations, 3, "mismatched-m strat refreshes to uniform");

    // A bare Bins file (the pre-GridState, pre-Checkpoint schema)
    // loads as both a GridState and a fresh-start Checkpoint.
    let bins = Bins::uniform(5, 50);
    let path = std::env::temp_dir().join("mcubes_api_legacy_bins.json");
    bins.save(&path).unwrap();
    let as_grid = GridState::load(&path).unwrap();
    assert!(as_grid.strat().is_none());
    let as_checkpoint = Checkpoint::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(as_checkpoint.iteration(), 0);
    assert_eq!(as_checkpoint.calls_used(), 0);
    assert_eq!(as_checkpoint.estimator(), EstimatorState::default());

    // A checkpoint works anywhere a grid warm start does: resuming the
    // fresh checkpoint equals running with the donor grid directly.
    let from_ckpt = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(4096)
        .tolerance(1e-12)
        .plan(RunPlan::classic(4, 2, 0))
        .seed(77)
        .resume_session(&Checkpoint::from_grid(grid.clone()))
        .unwrap()
        .finish()
        .unwrap()
        .output;
    let from_grid = Integrator::from_registry("f4", 5)
        .unwrap()
        .maxcalls(4096)
        .tolerance(1e-12)
        .plan(RunPlan::classic(4, 2, 0))
        .seed(77)
        .warm_start(grid)
        .run()
        .unwrap();
    assert_eq!(from_ckpt.integral.to_bits(), from_grid.integral.to_bits());
}

/// The legacy string-keyed flow still works through IntegrandSpec.
#[test]
fn integrand_spec_drives_the_facade() {
    let out = Integrator::from_spec(IntegrandSpec::registry("f5", 4))
        .maxcalls(1 << 13)
        .tolerance(1e-3)
        .seed(2)
        .run()
        .unwrap();
    assert!(out.converged);

    let custom = IntegrandSpec::custom(
        FnIntegrand::unit(2, |x: &[f64]| (x[0] + x[1]).sin())
            .named("sinsum")
            .into_ref(),
    );
    let out = Integrator::from_spec(custom)
        .maxcalls(1 << 13)
        .tolerance(1e-3)
        .seed(2)
        .run()
        .unwrap();
    // ∫∫ sin(x+y) over [0,1]² = 2 sin(1) (1 - cos(1)) ≈ 0.7736445
    let truth = 2.0 * 1.0f64.sin() * (1.0 - 1.0f64.cos());
    assert!(
        ((out.integral - truth) / truth).abs() < 5e-3,
        "I = {} truth = {truth}",
        out.integral
    );
}
