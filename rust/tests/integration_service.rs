//! Service-level integration tests: batched jobs, mixed workloads,
//! failure isolation, closure jobs, warm starts, and metric sanity.

use mcubes::api::FnIntegrand;
use mcubes::coordinator::{IntegrationService, JobConfig, JobRequest};

fn quick(seed: u32) -> JobConfig {
    JobConfig {
        maxcalls: 1 << 12,
        itmax: 10,
        ita: 7,
        skip: 1,
        tau_rel: 5e-3,
        seed,
        ..Default::default()
    }
}

#[test]
fn mixed_suite_batch() {
    let suite = [
        ("f2", 6),
        ("f3", 3),
        ("f4", 5),
        ("f5", 8),
        ("f6", 6),
        ("cosmo", 6),
    ];
    let mut svc = IntegrationService::new(4);
    let n = 18;
    for i in 0..n {
        let (name, d) = suite[i % suite.len()];
        svc.submit(JobRequest::registry(
            i as u64,
            name,
            d,
            quick(500 + i as u32),
        ));
    }
    let (results, metrics) = svc.drain().unwrap();
    assert_eq!(metrics.jobs, n);
    assert_eq!(metrics.failures, 0);
    for r in &results {
        let out = r.outcome.as_ref().unwrap();
        assert!(out.integral.is_finite());
        assert!(out.sigma.is_finite());
        assert!(r.grid.is_some());
    }
}

#[test]
fn throughput_scales_with_workers() {
    // 1 worker vs 4 workers on the same 12-job batch: wall time must
    // drop meaningfully (not necessarily 4x on CI machines).
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores < 2 {
        eprintln!("SKIP: single-core machine, no parallel speedup possible");
        return;
    }
    let make_batch = |svc: &mut IntegrationService| {
        for i in 0..12u64 {
            svc.submit(JobRequest::registry(
                i,
                "f5",
                6,
                JobConfig {
                    maxcalls: 1 << 17,
                    itmax: 6,
                    ita: 4,
                    skip: 1,
                    tau_rel: 1e-12, // run all iterations: fixed work
                    seed: 40 + i as u32,
                    ..Default::default()
                },
            ));
        }
    };
    let mut s1 = IntegrationService::new(1);
    make_batch(&mut s1);
    let (_, m1) = s1.drain().unwrap();
    let mut s4 = IntegrationService::new(4);
    make_batch(&mut s4);
    let (_, m4) = s4.drain().unwrap();
    assert!(
        m4.wall_time < m1.wall_time * 0.7,
        "1w {:.3}s vs 4w {:.3}s",
        m1.wall_time,
        m4.wall_time
    );
}

#[test]
fn failures_are_isolated() {
    let mut svc = IntegrationService::new(3);
    for i in 0..9u64 {
        let name = if i % 3 == 0 { "doesnotexist" } else { "f3" };
        svc.submit(JobRequest::registry(i, name, 3, quick(i as u32)));
    }
    let (results, metrics) = svc.drain().unwrap();
    assert_eq!(metrics.failures, 3);
    for r in results {
        if r.integrand == "doesnotexist" {
            assert!(r.outcome.is_err());
        } else {
            assert!(r.outcome.is_ok());
        }
    }
}

#[test]
fn queue_time_reflects_backlog() {
    // With one worker and several jobs, later jobs must wait.
    let mut svc = IntegrationService::new(1);
    for i in 0..6u64 {
        svc.submit(JobRequest::registry(i, "f4", 5, quick(i as u32)));
    }
    let (results, metrics) = svc.drain().unwrap();
    let first = results.iter().find(|r| r.id == 0).unwrap();
    let last = results.iter().find(|r| r.id == 5).unwrap();
    assert!(last.queue_time >= first.queue_time);
    assert!(metrics.mean_queue_time > 0.0);
}

#[test]
fn closure_jobs_mix_with_registry_jobs() {
    let mut svc = IntegrationService::new(3);
    svc.submit(JobRequest::registry(0, "f3", 3, quick(1)));
    svc.submit(JobRequest::custom(
        1,
        FnIntegrand::unit(2, |x: &[f64]| 4.0 * x[0] * x[1])
            .named("4xy")
            .with_true_value(1.0)
            .into_ref(),
        quick(2),
    ));
    svc.submit(JobRequest::registry(2, "f5", 4, quick(3)));
    let (results, metrics) = svc.drain().unwrap();
    assert_eq!(metrics.failures, 0);
    assert_eq!(results[1].integrand, "4xy");
    let out = results[1].outcome.as_ref().unwrap();
    assert!((out.integral - 1.0).abs() < 0.05, "I = {}", out.integral);
}

#[test]
fn warm_start_round_trips_through_service() {
    // Grid exported by one batch warm-starts the next; warm jobs skip
    // the adjust phase and still converge.
    let mut svc = IntegrationService::new(2);
    svc.submit(JobRequest::registry(
        0,
        "f4",
        5,
        JobConfig {
            maxcalls: 1 << 13,
            itmax: 20,
            ita: 12,
            skip: 2,
            tau_rel: 5e-3,
            seed: 7,
            ..Default::default()
        },
    ));
    let (results, _) = svc.drain().unwrap();
    let grid = results[0].grid.clone().expect("donor grid");

    let mut svc = IntegrationService::new(2);
    for i in 0..3u64 {
        svc.submit(
            JobRequest::registry(
                i,
                "f4",
                5,
                JobConfig {
                    maxcalls: 1 << 13,
                    itmax: 20,
                    ita: 0,
                    skip: 0,
                    tau_rel: 5e-3,
                    seed: 70 + i as u32,
                    ..Default::default()
                },
            )
            .with_warm_start(grid.clone()),
        );
    }
    let (warm_results, metrics) = svc.drain().unwrap();
    assert_eq!(metrics.failures, 0);
    for r in &warm_results {
        let out = r.outcome.as_ref().unwrap();
        assert!(out.converged, "warm job {} did not converge: {out:?}", r.id);
    }
}
