//! Scheduler-level integration tests: batched jobs, mixed workloads,
//! time-slicing, priorities, failure isolation, closure jobs, warm
//! starts, and metric sanity.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use mcubes::api::{FnIntegrand, RunPlan};
use mcubes::coordinator::{JobConfig, JobRequest, Scheduler};

fn quick(seed: u32) -> JobConfig {
    JobConfig::default()
        .with_maxcalls(1 << 12)
        .with_plan(RunPlan::classic(10, 7, 1))
        .with_tolerance(5e-3)
        .with_seed(seed)
}

#[test]
fn mixed_suite_batch() {
    let suite = [
        ("f2", 6),
        ("f3", 3),
        ("f4", 5),
        ("f5", 8),
        ("f6", 6),
        ("cosmo", 6),
    ];
    let mut svc = Scheduler::new(4);
    let n = 18;
    for i in 0..n {
        let (name, d) = suite[i % suite.len()];
        svc.submit(JobRequest::registry(
            i as u64,
            name,
            d,
            quick(500 + i as u32),
        ));
    }
    let (results, metrics) = svc.drain().unwrap();
    assert_eq!(metrics.jobs, n);
    assert_eq!(metrics.failures, 0);
    assert!(metrics.total_calls > 0);
    for r in &results {
        let out = r.outcome.as_ref().unwrap();
        assert!(out.integral.is_finite());
        assert!(out.sigma.is_finite());
        assert!(r.grid.is_some());
        assert!(r.stop.is_some());
    }
}

#[test]
fn throughput_scales_with_workers() {
    // 1 worker vs 4 workers on the same 12-job batch: wall time must
    // drop meaningfully (not necessarily 4x on CI machines).
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores < 2 {
        eprintln!("SKIP: single-core machine, no parallel speedup possible");
        return;
    }
    let make_batch = |svc: &mut Scheduler| {
        for i in 0..12u64 {
            svc.submit(JobRequest::registry(
                i,
                "f5",
                6,
                JobConfig::default()
                    .with_maxcalls(1 << 17)
                    .with_plan(RunPlan::classic(6, 4, 1))
                    .with_tolerance(1e-12) // run all iterations: fixed work
                    .with_seed(40 + i as u32),
            ));
        }
    };
    let mut s1 = Scheduler::new(1);
    make_batch(&mut s1);
    let (_, m1) = s1.drain().unwrap();
    let mut s4 = Scheduler::new(4);
    make_batch(&mut s4);
    let (_, m4) = s4.drain().unwrap();
    assert!(
        m4.wall_time < m1.wall_time * 0.7,
        "1w {:.3}s vs 4w {:.3}s",
        m1.wall_time,
        m4.wall_time
    );
}

#[test]
fn time_sliced_schedule_is_bitwise_equal_to_unsliced() {
    // The scheduler's round-robin slicing must never change numbers:
    // run the same mixed batch with a huge quantum (run-to-completion)
    // and a one-iteration quantum (maximum interleaving) and compare
    // every output bit for bit.
    let batch = |svc: &mut Scheduler| {
        for i in 0..6u64 {
            let name = if i % 2 == 0 { "f4" } else { "f5" };
            svc.submit(JobRequest::registry(
                i,
                name,
                5,
                quick(900 + i as u32).with_tolerance(1e-12),
            ));
        }
    };
    let mut coarse = Scheduler::new(2);
    coarse.calls_budget(usize::MAX);
    batch(&mut coarse);
    let (a, _) = coarse.drain().unwrap();

    let mut fine = Scheduler::new(2);
    fine.calls_budget(1);
    batch(&mut fine);
    let (b, _) = fine.drain().unwrap();

    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.id, rb.id);
        let (oa, ob) = (ra.outcome.as_ref().unwrap(), rb.outcome.as_ref().unwrap());
        assert_eq!(oa.integral.to_bits(), ob.integral.to_bits(), "job {}", ra.id);
        assert_eq!(oa.sigma.to_bits(), ob.sigma.to_bits(), "job {}", ra.id);
        assert_eq!(oa.iterations, ob.iterations);
        assert!(rb.slices >= oa.iterations, "one-call quantum slices per iteration");
    }
}

#[test]
fn failures_are_isolated() {
    let mut svc = Scheduler::new(3);
    for i in 0..9u64 {
        let name = if i % 3 == 0 { "doesnotexist" } else { "f3" };
        svc.submit(JobRequest::registry(i, name, 3, quick(i as u32)));
    }
    let (results, metrics) = svc.drain().unwrap();
    assert_eq!(metrics.failures, 3);
    for r in results {
        if r.integrand == "doesnotexist" {
            assert!(r.outcome.is_err());
        } else {
            assert!(r.outcome.is_ok());
        }
    }
}

#[test]
fn queue_time_reflects_backlog() {
    // With one worker and several jobs, later jobs must wait.
    let mut svc = Scheduler::new(1);
    for i in 0..6u64 {
        svc.submit(JobRequest::registry(i, "f4", 5, quick(i as u32)));
    }
    let (results, metrics) = svc.drain().unwrap();
    let first = results.iter().find(|r| r.id == 0).unwrap();
    let last = results.iter().find(|r| r.id == 5).unwrap();
    assert!(last.queue_time >= first.queue_time);
    assert!(metrics.mean_queue_time > 0.0);
}

#[test]
fn closure_jobs_mix_with_registry_jobs() {
    let mut svc = Scheduler::new(3);
    svc.submit(JobRequest::registry(0, "f3", 3, quick(1)));
    svc.submit(JobRequest::custom(
        1,
        FnIntegrand::unit(2, |x: &[f64]| 4.0 * x[0] * x[1])
            .named("4xy")
            .with_true_value(1.0)
            .into_ref(),
        quick(2),
    ));
    svc.submit(JobRequest::registry(2, "f5", 4, quick(3)));
    let (results, metrics) = svc.drain().unwrap();
    assert_eq!(metrics.failures, 0);
    assert_eq!(results[1].integrand, "4xy");
    let out = results[1].outcome.as_ref().unwrap();
    assert!((out.integral - 1.0).abs() < 0.05, "I = {}", out.integral);
}

#[test]
fn results_stream_in_completion_order() {
    // High-priority short jobs behind one long blocker on a single
    // worker: the stream must yield them as they finish, not in
    // submission order.
    let mut svc = Scheduler::new(1);
    svc.submit(JobRequest::registry(
        0,
        "f5",
        6,
        JobConfig::default()
            .with_maxcalls(1 << 16)
            .with_plan(RunPlan::classic(8, 5, 0))
            .with_tolerance(1e-12),
    ));
    for i in 1..4u64 {
        svc.submit(JobRequest::registry(i, "f3", 3, quick(i as u32)).with_priority(i as i32));
    }
    let stream = svc.stream();
    assert_eq!(stream.total(), 4);
    let ids: Vec<u64> = stream.map(|r| r.id).collect();
    assert_eq!(ids.len(), 4);
    // The blocker (id 0) was picked up first on the lone worker, but
    // among the queued rest, priority order (3, 2, 1) must hold.
    let pos = |id: u64| ids.iter().position(|&x| x == id).unwrap();
    assert!(pos(3) < pos(2), "{ids:?}");
    assert!(pos(2) < pos(1), "{ids:?}");
}

#[test]
fn warm_start_round_trips_through_scheduler() {
    // Grid exported by one batch warm-starts the next; warm jobs skip
    // the adjust phase and still converge.
    let mut svc = Scheduler::new(2);
    svc.submit(JobRequest::registry(
        0,
        "f4",
        5,
        JobConfig::default()
            .with_maxcalls(1 << 13)
            .with_plan(RunPlan::classic(20, 12, 2))
            .with_tolerance(5e-3)
            .with_seed(7),
    ));
    let (results, _) = svc.drain().unwrap();
    let grid = results[0].grid.clone().expect("donor grid");

    let mut svc = Scheduler::new(2);
    for i in 0..3u64 {
        svc.submit(
            JobRequest::registry(
                i,
                "f4",
                5,
                JobConfig::default()
                    .with_maxcalls(1 << 13)
                    .with_plan(RunPlan::classic(20, 0, 0))
                    .with_tolerance(5e-3)
                    .with_seed(70 + i as u32),
            )
            .with_warm_start(grid.clone()),
        );
    }
    let (warm_results, metrics) = svc.drain().unwrap();
    assert_eq!(metrics.failures, 0);
    for r in &warm_results {
        let out = r.outcome.as_ref().unwrap();
        assert!(out.converged, "warm job {} did not converge: {out:?}", r.id);
    }
}
