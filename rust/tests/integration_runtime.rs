//! PJRT-runtime integration tests: the AOT Pallas artifacts against the
//! Python-generated goldens and the native engine.
//!
//! Requires `make artifacts` to have produced `artifacts/`; all tests
//! skip politely if the directory is missing (e.g. plain `cargo test`
//! in a fresh checkout).

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use mcubes::api::{BackendSpec, Integrator, RunPlan};
use mcubes::coordinator::{drive, JobConfig, PjrtBackend, VSampleBackend};
use mcubes::grid::{Bins, GridMode};
use mcubes::integrands::by_name;
use mcubes::rng::philox4x32;
use mcubes::runtime::{PjrtRuntime, Registry};
use mcubes::strat::Bounds;
use mcubes::util::json::parse;
use std::path::Path;

fn artifacts_dir() -> Option<&'static str> {
    for dir in ["artifacts", "../artifacts"] {
        if Path::new(dir).join("manifest.json").exists() {
            return Some(dir);
        }
    }
    eprintln!("SKIP: artifacts/ missing (run `make artifacts`)");
    None
}

#[test]
fn manifest_loads_and_layouts_verify() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    assert!(reg.all().len() >= 20, "expected the full test set");
    for meta in reg.all() {
        meta.verify_layout().unwrap();
        assert!(reg.hlo_path(meta).exists(), "{} missing", meta.file);
    }
}

#[test]
fn philox_golden_vectors() {
    let Some(dir) = artifacts_dir() else { return };
    let text = std::fs::read_to_string(Path::new(dir).join("golden_philox.json")).unwrap();
    let root = parse(&text).unwrap();
    for case in root.req("kat").unwrap().as_arr().unwrap() {
        let ctr: Vec<u32> = case
            .req("ctr")
            .unwrap()
            .as_f64_vec()
            .unwrap()
            .iter()
            .map(|&x| x as u32)
            .collect();
        let key: Vec<u32> = case
            .req("key")
            .unwrap()
            .as_f64_vec()
            .unwrap()
            .iter()
            .map(|&x| x as u32)
            .collect();
        let want: Vec<u32> = case
            .req("out")
            .unwrap()
            .as_f64_vec()
            .unwrap()
            .iter()
            .map(|&x| x as u32)
            .collect();
        let got = philox4x32([ctr[0], ctr[1], ctr[2], ctr[3]], [key[0], key[1]]);
        assert_eq!(got.to_vec(), want, "ctr={ctr:?}");
    }
    // The uniform stream segment drawn exactly like the kernel does.
    let uni = root.req("uniforms").unwrap();
    let seed = uni.req("seed").unwrap().as_usize().unwrap() as u32;
    let it = uni.req("iteration").unwrap().as_usize().unwrap() as u32;
    let ndim = uni.req("ndim").unwrap().as_usize().unwrap();
    let n = uni.req("n").unwrap().as_usize().unwrap();
    let vals = uni.req("values").unwrap().as_f64_vec().unwrap();
    assert_eq!(vals.len(), n * ndim);
    let mut buf = vec![0.0; ndim];
    for s in 0..n {
        mcubes::rng::uniforms_into(s as u64, it, seed, &mut buf);
        for d in 0..ndim {
            assert_eq!(
                buf[d],
                vals[s * ndim + d],
                "sample {s} dim {d}: rust {} vs python {}",
                buf[d],
                vals[s * ndim + d]
            );
        }
    }
}

/// The native engine must reproduce the Python oracle's V-Sample
/// outputs (golden_vsample.json) bit-tight.
#[test]
fn native_engine_matches_python_goldens() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    let text = std::fs::read_to_string(Path::new(dir).join("golden_vsample.json")).unwrap();
    let root = parse(&text).unwrap();
    let engine = mcubes::engine::NativeEngine;
    for case in root.as_arr().unwrap() {
        let art = case.req("artifact").unwrap().as_str().unwrap();
        let meta = reg.by_name(art).unwrap();
        let layout = meta.layout();
        let bins = match case.req("bins").unwrap().as_str().unwrap() {
            "uniform" => Bins::uniform(layout.d, layout.nb),
            "skewed" => {
                // Same construction as aot.skewed_bins (gamma = 1.7).
                let mut edges = Vec::with_capacity(layout.d * layout.nb);
                for _ in 0..layout.d {
                    for b in 1..=layout.nb {
                        let e = (b as f64 / layout.nb as f64).powf(1.7);
                        edges.push(if b == layout.nb { 1.0 } else { e });
                    }
                }
                Bins::from_edges(layout.d, layout.nb, edges, GridMode::PerAxis).unwrap()
            }
            other => panic!("unknown bins kind {other}"),
        };
        let f = by_name(&meta.integrand, meta.dim).unwrap();
        let opts = mcubes::engine::VSampleOpts {
            seed: case.req("seed").unwrap().as_usize().unwrap() as u32,
            iteration: case.req("iteration").unwrap().as_usize().unwrap() as u32,
            adjust: true,
            threads: 4,
        };
        let (r, contrib) = engine.vsample(&*f, &layout, &bins, &opts);
        let want_i = case.req("integral").unwrap().as_f64().unwrap();
        let want_v = case.req("variance").unwrap().as_f64().unwrap();
        assert!(
            ((r.integral - want_i) / want_i).abs() < 1e-11,
            "{art}: I {} vs golden {want_i}",
            r.integral
        );
        assert!(
            ((r.variance - want_v) / want_v).abs() < 1e-9,
            "{art}: Var {} vs golden {want_v}",
            r.variance
        );
        let contrib = contrib.unwrap();
        let sums = case.req("c_axis_sums").unwrap().as_f64_vec().unwrap();
        for (axis, want) in sums.iter().enumerate() {
            let got: f64 = contrib[axis * layout.nb..(axis + 1) * layout.nb].iter().sum();
            assert!(
                ((got - want) / want).abs() < 1e-10,
                "{art} axis {axis}: {got} vs {want}"
            );
        }
        // Full histogram where provided (f4 cases).
        if let Some(full) = case.get("c_full").filter(|v| v.as_arr().is_some()) {
            let rows = full.as_arr().unwrap();
            for (axis, row) in rows.iter().enumerate() {
                let want_row = row.as_f64_vec().unwrap();
                for (b, want) in want_row.iter().enumerate() {
                    let got = contrib[axis * layout.nb + b];
                    let tol = 1e-9 * want.abs().max(1e-30);
                    assert!(
                        (got - want).abs() <= tol,
                        "{art} C[{axis}][{b}]: {got} vs {want}"
                    );
                }
            }
        }
    }
}

/// The PJRT artifact and native engine agree iteration-by-iteration
/// through a full adaptive run (grid feedback included), both driven
/// through the `Integrator` facade.
#[test]
fn pjrt_vs_native_full_driver() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    for name in ["f4", "f2", "cosmo"] {
        let meta = reg.select(name, true, 4).unwrap().clone();
        let run = |backend: BackendSpec| {
            Integrator::from_registry(&meta.integrand, meta.dim)
                .unwrap()
                .backend(backend)
                .config(
                    JobConfig::default()
                        .with_maxcalls(meta.maxcalls)
                        .with_bins(meta.nb)
                        .with_blocks(meta.nblocks)
                        .with_plan(RunPlan::classic(4, 3, 0))
                        .with_tolerance(1e-14) // force all iterations
                        .with_seed(555),
                )
                .run()
                .unwrap()
        };
        let pjrt = run(BackendSpec::Pjrt {
            artifacts_dir: dir.to_string(),
        });
        let native = run(BackendSpec::Native);
        let rel = ((pjrt.integral - native.integral) / native.integral).abs();
        assert!(rel < 1e-9, "{name}: pjrt vs native rel {rel:.2e}");
        let rel_s = ((pjrt.sigma - native.sigma) / native.sigma).abs();
        assert!(rel_s < 1e-6, "{name}: sigma rel {rel_s:.2e}");
    }
}

/// `drive` on a raw PJRT backend still works for low-level callers.
#[test]
fn drive_runs_raw_pjrt_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let mut backend = PjrtBackend::load(&runtime, &reg, "f4", 0).unwrap();
    let meta = backend.meta().clone();
    let cfg = JobConfig::default()
        .with_maxcalls(meta.maxcalls)
        .with_bins(meta.nb)
        .with_blocks(meta.nblocks)
        .with_plan(RunPlan::classic(2, 1, 0))
        .with_tolerance(1e-14)
        .with_seed(1);
    let outcome = drive(&mut backend, &cfg, None, None).unwrap();
    assert_eq!(outcome.output.iterations, 2);
    assert_eq!(outcome.grid.d(), meta.dim);
}

/// The no-adjust artifact returns the same estimates as the adjust one
/// (only the histogram work differs).
#[test]
fn na_artifact_matches_adjust_estimates() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let adj = runtime
        .load(&reg, reg.select("f5", true, 0).unwrap())
        .unwrap();
    let na = runtime
        .load(&reg, reg.select("f5", false, 0).unwrap())
        .unwrap();
    let layout = adj.meta().layout();
    let bins = Bins::uniform(layout.d, layout.nb);
    let (ra, ca) = adj.vsample(&bins, 9, 4).unwrap();
    let (rn, cn) = na.vsample(&bins, 9, 4).unwrap();
    assert!(ca.is_some());
    assert!(cn.is_none());
    assert!(((ra.integral - rn.integral) / ra.integral).abs() < 1e-12);
    assert!(((ra.variance - rn.variance) / ra.variance).abs() < 1e-12);
}

/// The one-hot (MXU-shaped) histogram ablation artifact matches the
/// scatter artifact exactly.
#[test]
fn onehot_artifact_matches_scatter() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    let Ok(onehot_meta) = reg.by_name("f4_d5_c16384_adj_onehot") else {
        eprintln!("SKIP: onehot ablation artifact missing");
        return;
    };
    let runtime = PjrtRuntime::cpu().unwrap();
    let scatter = runtime.load(&reg, reg.by_name("f4_d5_c16384_adj").unwrap()).unwrap();
    let onehot = runtime.load(&reg, onehot_meta).unwrap();
    let layout = scatter.meta().layout();
    let bins = Bins::uniform(layout.d, layout.nb);
    let (rs, cs) = scatter.vsample(&bins, 31, 2).unwrap();
    let (ro, co) = onehot.vsample(&bins, 31, 2).unwrap();
    assert!(((rs.integral - ro.integral) / rs.integral).abs() < 1e-12);
    let (cs, co) = (cs.unwrap(), co.unwrap());
    for (a, b) in cs.iter().zip(&co) {
        assert!((a - b).abs() <= 1e-9 * a.abs().max(1e-30), "{a} vs {b}");
    }
}

/// Executables are cached: loading twice returns the same Arc.
#[test]
fn runtime_caches_executables() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let meta = reg.select("f3", true, 0).unwrap();
    let a = runtime.load(&reg, meta).unwrap();
    let b = runtime.load(&reg, meta).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

/// Mismatched bins shape is rejected cleanly, not a crash.
#[test]
fn bins_shape_mismatch_is_config_error() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let exe = runtime
        .load(&reg, reg.select("f4", true, 0).unwrap())
        .unwrap();
    let wrong = Bins::uniform(3, 10);
    assert!(exe.vsample(&wrong, 1, 0).is_err());
}

/// Backend trait sanity on the PJRT side.
#[test]
fn pjrt_backend_reports_meta() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::load(dir).unwrap();
    let runtime = PjrtRuntime::cpu().unwrap();
    let backend = PjrtBackend::load(&runtime, &reg, "fB", 0).unwrap();
    assert_eq!(backend.layout().d, 9);
    assert_eq!(backend.bounds(), Bounds::uniform(9, -1.0, 1.0));
    assert_eq!(backend.name(), "pjrt");
}
