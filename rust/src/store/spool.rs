//! The daemon's inbox and outbox.
//!
//! Submissions are `spool/<job_id>.json` — a **plain** (unsealed)
//! [`JobManifest`], deliberately hand-writable: any client that can
//! emit JSON and rename a file can submit work (writers should still
//! write-then-rename; [`Spool::submit`] does). Results leave through
//! `outbox/<job_id>.json` as **sealed** [`ResultManifest`]s — those
//! are store-authored, so they get the full integrity treatment.
//!
//! A spool file is removed ([`Spool::complete`]) only *after* the
//! job's result is durably published, so every crash point leaves
//! either the submission or the result (or, briefly, both — the
//! restart re-scan then answers the leftover submission from the
//! result cache). Removal is idempotent for exactly that reason.

use super::{read_sealed, seal, write_atomic, JobManifest, ResultManifest, StoreError, StoreResult};
use crate::util::json;
use std::path::{Path, PathBuf};

/// The inbox/outbox half of a [`super::ServiceStore`].
pub struct Spool {
    inbox: PathBuf,
    outbox: PathBuf,
}

impl Spool {
    /// Open (creating if needed) the spool and outbox directories.
    pub fn open(inbox: impl AsRef<Path>, outbox: impl AsRef<Path>) -> StoreResult<Spool> {
        let inbox = inbox.as_ref().to_path_buf();
        let outbox = outbox.as_ref().to_path_buf();
        for dir in [&inbox, &outbox] {
            std::fs::create_dir_all(dir).map_err(|e| StoreError::Io {
                path: dir.clone(),
                source: e,
            })?;
        }
        Ok(Spool { inbox, outbox })
    }

    /// The inbox directory (watched by the daemon).
    pub fn inbox_dir(&self) -> &Path {
        &self.inbox
    }

    /// The outbox directory (read by clients).
    pub fn outbox_dir(&self) -> &Path {
        &self.outbox
    }

    /// Validate and atomically drop a submission into the inbox.
    /// Returns the spool file path.
    pub fn submit(&self, job: &JobManifest) -> StoreResult<PathBuf> {
        job.validate().map_err(|e| StoreError::BadKey {
            key: job.job_id.clone(),
            detail: e.to_string(),
        })?;
        let path = self.inbox.join(format!("{}.json", job.job_id));
        write_atomic(&path, &job.to_json().to_json())?;
        Ok(path)
    }

    /// Pending submission files, sorted by file name (the daemon
    /// re-sorts by priority after loading; this order is just a
    /// deterministic scan).
    pub fn pending(&self) -> StoreResult<Vec<PathBuf>> {
        super::list_json_sorted(&self.inbox)
    }

    /// Parse one submission. Unreadable or invalid submissions are
    /// typed errors — the daemon answers those with an error result
    /// rather than retrying forever.
    pub fn load(&self, path: &Path) -> StoreResult<JobManifest> {
        let corrupt = |detail: String| StoreError::Corrupt {
            path: path.to_path_buf(),
            detail,
        };
        let text = std::fs::read_to_string(path).map_err(|e| StoreError::Io {
            path: path.to_path_buf(),
            source: e,
        })?;
        let v = json::parse(&text).map_err(|e| corrupt(e.to_string()))?;
        let job = JobManifest::from_json(&v).map_err(|e| corrupt(e.to_string()))?;
        job.validate().map_err(|e| corrupt(e.to_string()))?;
        // The file stem is the service-side identity; a manifest
        // claiming a different id would publish under a name the
        // submitter never watches.
        let stem = path.file_stem().and_then(std::ffi::OsStr::to_str);
        if stem != Some(job.job_id.as_str()) {
            return Err(corrupt(format!(
                "job_id `{}` does not match spool file name",
                job.job_id
            )));
        }
        Ok(job)
    }

    /// Remove a consumed submission (idempotent — see module docs).
    pub fn complete(&self, path: &Path) -> StoreResult<()> {
        match std::fs::remove_file(path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(StoreError::Io {
                path: path.to_path_buf(),
                source: e,
            }),
        }
    }

    /// Durably publish a result to the outbox (sealed; replaces any
    /// previous result for the job id). Returns the outbox path.
    pub fn publish(&self, result: &ResultManifest) -> StoreResult<PathBuf> {
        super::check_job_key(&result.job_id)?;
        let path = self.outbox.join(format!("{}.json", result.job_id));
        write_atomic(&path, &seal(result.to_json()).to_json())?;
        Ok(path)
    }

    /// Read back a published result by job id (`Ok(None)` if absent).
    pub fn result(&self, job_id: &str) -> StoreResult<Option<ResultManifest>> {
        super::check_job_key(job_id)?;
        let path = self.outbox.join(format!("{job_id}.json"));
        let Some(body) = read_sealed(&path, super::manifest::RESULT_MANIFEST_SCHEMA)? else {
            return Ok(None);
        };
        let result = ResultManifest::from_json(&body).map_err(|e| StoreError::Corrupt {
            path,
            detail: format!("outbox payload: {e}"),
        })?;
        Ok(Some(result))
    }

    /// All published results, sorted by job id.
    pub fn results(&self) -> StoreResult<Vec<ResultManifest>> {
        let mut out = Vec::new();
        for path in super::list_json_sorted(&self.outbox)? {
            if let Some(stem) = path.file_stem().and_then(std::ffi::OsStr::to_str) {
                if super::check_job_key(stem).is_ok() {
                    if let Some(r) = self.result(stem)? {
                        out.push(r);
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::JobConfig;

    fn scratch(tag: &str) -> Spool {
        let p = std::env::temp_dir().join(format!(
            "mcubes-store-spool-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        Spool::open(p.join("spool"), p.join("outbox")).unwrap()
    }

    #[test]
    fn submit_load_complete_cycle() {
        let spool = scratch("cycle");
        let job = JobManifest::new("alpha", "f3", 3, JobConfig::default());
        let path = spool.submit(&job).unwrap();
        assert_eq!(spool.pending().unwrap(), vec![path.clone()]);
        let back = spool.load(&path).unwrap();
        assert_eq!(back.to_json().to_json(), job.to_json().to_json());
        spool.complete(&path).unwrap();
        spool.complete(&path).unwrap(); // idempotent
        assert!(spool.pending().unwrap().is_empty());
    }

    #[test]
    fn hand_written_submissions_are_accepted() {
        let spool = scratch("handwritten");
        // Minimal unsealed manifest, fields in arbitrary order — what
        // a shell script might drop in.
        let path = spool.inbox_dir().join("manual.json");
        std::fs::write(
            &path,
            r#"{"dim": 3, "integrand": "f3", "job_id": "manual",
               "$schema": "mcubes/job-manifest/v1", "seed": 5}"#,
        )
        .unwrap();
        let job = spool.load(&path).unwrap();
        assert_eq!(job.job_id, "manual");
        assert_eq!(job.config.seed, 5);
    }

    #[test]
    fn mismatched_or_garbage_submissions_are_typed_errors() {
        let spool = scratch("garbage");
        let bad = spool.inbox_dir().join("bad.json");
        std::fs::write(&bad, "{not json").unwrap();
        assert!(matches!(spool.load(&bad), Err(StoreError::Corrupt { .. })));
        // job_id / file-name mismatch
        let sneaky = spool.inbox_dir().join("sneaky.json");
        let job = JobManifest::new("other-name", "f3", 3, JobConfig::default());
        std::fs::write(&sneaky, job.to_json().to_json()).unwrap();
        assert!(matches!(
            spool.load(&sneaky),
            Err(StoreError::Corrupt { .. })
        ));
    }

    #[test]
    fn publish_and_read_back() {
        let spool = scratch("publish");
        let r = ResultManifest::failure("job-9", "f3", 3, "unknown integrand");
        let path = spool.publish(&r).unwrap();
        assert!(path.ends_with("job-9.json"));
        let back = spool.result("job-9").unwrap().unwrap();
        assert_eq!(back.outcome, r.outcome);
        assert!(spool.result("absent").unwrap().is_none());
        assert_eq!(spool.results().unwrap().len(), 1);
    }
}
