//! Durable persistence for the integration service.
//!
//! The paper frames m-Cubes as a component for "complicated pipelines
//! with easy to define stateful integrals"; this module is where that
//! state becomes *durable*. It turns the bitwise-resumable
//! [`crate::api::Checkpoint`] into a crash-safe on-disk product with
//! four parts (see docs/service.md for schemas and the full
//! crash-recovery state machine):
//!
//! * [`manifest`] — `$schema`-versioned job/result manifests
//!   ([`JobManifest`], [`ResultManifest`]) plus the canonical
//!   content-address digest of a job's *semantic* fields.
//! * [`checkpoint_store`] — mid-run [`crate::api::Checkpoint`]s keyed
//!   by job digest; a killed run resumes bitwise from the last durable
//!   iteration.
//! * [`result_cache`] — completed results keyed by the same digest; a
//!   re-submitted identical job is answered with **zero** new
//!   integrand evaluations.
//! * [`spool`] — the daemon's inbox/outbox directories
//!   (`spool/*.json` in, `outbox/*.json` out).
//!
//! Every write follows the same crash-safety discipline: serialize,
//! write to `<final>.tmp` through a `BufWriter`, `flush` + `sync_all`,
//! then atomically `rename` over the final path (and fsync the parent
//! directory on unix). A reader therefore sees either the previous
//! durable file or the complete new one — never a torn mix. Store-own
//! files additionally carry a `sha256` seal over their canonical JSON
//! (`util::json::to_canonical_json`), so even a corrupted-in-place
//! file is detected and surfaced as a typed [`StoreError`], never a
//! panic or a half-read checkpoint.
//!
//! Determinism: this module is in the MC003 lint scope (`cargo xtask
//! lint`) — no wall clocks and no ambient randomness. Digests are pure
//! functions of manifest bytes, temp-file names are derived from final
//! names, and directory listings are sorted before use.

pub mod checkpoint_store;
pub mod manifest;
pub mod result_cache;
pub mod spool;

pub use checkpoint_store::CheckpointStore;
pub use manifest::{JobManifest, ResultManifest, ResultNumbers};
pub use result_cache::ResultCache;
pub use spool::Spool;

use crate::util::digest::sha256_hex;
use crate::util::json::{self, to_canonical_json, Value};
use std::fmt;
use std::fs::File;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Typed failure of a store operation. The durability contract of the
/// torn-write test suite: every malformed on-disk state maps to one of
/// these variants (or to the previous durable state) — never a panic.
#[derive(Debug)]
pub enum StoreError {
    /// Underlying filesystem failure at `path` (including undecodable
    /// non-UTF-8 file contents).
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
    /// The file exists but cannot be trusted: JSON syntax error,
    /// checksum mismatch, or a payload that fails validation.
    Corrupt { path: PathBuf, detail: String },
    /// The file is well-formed but declares a `$schema` this build
    /// does not speak (typically: written by a newer version).
    UnsupportedSchema {
        path: PathBuf,
        found: String,
        expected: &'static str,
    },
    /// A store key (job id or digest) violates the naming rules, or a
    /// manifest refused an operation (e.g. caching a failed result).
    BadKey { key: String, detail: String },
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io { path, source } => {
                write!(f, "io failure at {}: {source}", path.display())
            }
            StoreError::Corrupt { path, detail } => {
                write!(f, "corrupt store file {}: {detail}", path.display())
            }
            StoreError::UnsupportedSchema {
                path,
                found,
                expected,
            } => write!(
                f,
                "unsupported schema in {}: found `{found}`, this build speaks `{expected}`",
                path.display()
            ),
            StoreError::BadKey { key, detail } => write!(f, "bad store key `{key}`: {detail}"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<StoreError> for crate::error::Error {
    fn from(e: StoreError) -> Self {
        crate::error::Error::Store(e)
    }
}

/// Store-local result alias.
pub type StoreResult<T> = std::result::Result<T, StoreError>;

/// One service store root: `spool/` + `outbox/` + `checkpoints/` +
/// `results/` under a single directory (created on open). This is the
/// layout `mcubes serve --store <root>` operates on.
pub struct ServiceStore {
    root: PathBuf,
    checkpoints: CheckpointStore,
    results: ResultCache,
    spool: Spool,
}

impl ServiceStore {
    /// Open (creating directories as needed) the store rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> StoreResult<ServiceStore> {
        let root = root.as_ref().to_path_buf();
        let checkpoints = CheckpointStore::open(root.join("checkpoints"))?;
        let results = ResultCache::open(root.join("results"))?;
        let spool = Spool::open(root.join("spool"), root.join("outbox"))?;
        Ok(ServiceStore {
            root,
            checkpoints,
            results,
            spool,
        })
    }

    /// The store root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The mid-run checkpoint store (keyed by job digest).
    pub fn checkpoints(&self) -> &CheckpointStore {
        &self.checkpoints
    }

    /// The content-addressed result cache (keyed by job digest).
    pub fn results(&self) -> &ResultCache {
        &self.results
    }

    /// The job spool and result outbox.
    pub fn spool(&self) -> &Spool {
        &self.spool
    }
}

/// Name of the integrity-seal field appended to store-own files.
pub(crate) const SEAL_FIELD: &str = "sha256";

/// Append the integrity seal: `sha256` over the canonical
/// serialization of the object *without* the seal field itself.
/// Verification re-derives exactly that (parse → strip seal →
/// canonicalize → hash), which is byte-stable because the canonical
/// number format round-trips f64 exactly.
pub(crate) fn seal(v: Value) -> Value {
    let hex = sha256_hex(to_canonical_json(&v).as_bytes());
    match v {
        Value::Obj(mut fields) => {
            fields.push((SEAL_FIELD.to_string(), Value::Str(hex)));
            Value::Obj(fields)
        }
        other => other,
    }
}

/// Read, parse, checksum-verify, and schema-check a sealed store file.
/// `Ok(None)` when the file does not exist; the returned value has the
/// seal field stripped.
pub(crate) fn read_sealed(
    path: &Path,
    expected_schema: &'static str,
) -> StoreResult<Option<Value>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(StoreError::Io {
                path: path.to_path_buf(),
                source: e,
            })
        }
    };
    let corrupt = |detail: String| StoreError::Corrupt {
        path: path.to_path_buf(),
        detail,
    };
    let v = json::parse(&text).map_err(|e| corrupt(format!("{e}")))?;
    let Value::Obj(fields) = v else {
        return Err(corrupt("top level is not a json object".to_string()));
    };
    let mut body = Vec::with_capacity(fields.len());
    let mut recorded = None;
    for (k, val) in fields {
        if k == SEAL_FIELD {
            match val.as_str() {
                Some(s) => recorded = Some(s.to_string()),
                None => return Err(corrupt("sha256 seal is not a string".to_string())),
            }
        } else {
            body.push((k, val));
        }
    }
    let Some(recorded) = recorded else {
        return Err(corrupt("missing sha256 seal".to_string()));
    };
    let body = Value::Obj(body);
    let computed = sha256_hex(to_canonical_json(&body).as_bytes());
    if computed != recorded {
        return Err(corrupt(format!(
            "checksum mismatch (recorded {recorded}, computed {computed})"
        )));
    }
    match body.get("$schema").and_then(Value::as_str) {
        Some(found) if found == expected_schema => Ok(Some(body)),
        Some(found) => Err(StoreError::UnsupportedSchema {
            path: path.to_path_buf(),
            found: found.to_string(),
            expected: expected_schema,
        }),
        None => Err(corrupt("missing $schema".to_string())),
    }
}

/// Crash-safe file replacement: write `<path>.tmp` through a
/// `BufWriter`, flush + fsync, atomically rename over `path`, then
/// fsync the parent directory (unix). The temp name is derived from
/// the final name — deterministic, and a crashed leftover is simply
/// overwritten by the next attempt (readers never look at `.tmp`).
pub(crate) fn write_atomic(path: &Path, contents: &str) -> StoreResult<()> {
    let tmp = tmp_path(path);
    {
        let file = File::create(&tmp).map_err(|e| StoreError::Io {
            path: tmp.clone(),
            source: e,
        })?;
        let mut w = std::io::BufWriter::new(file);
        w.write_all(contents.as_bytes())
            .and_then(|()| w.flush())
            .and_then(|()| w.get_ref().sync_all())
            .map_err(|e| StoreError::Io {
                path: tmp.clone(),
                source: e,
            })?;
    }
    std::fs::rename(&tmp, path).map_err(|e| StoreError::Io {
        path: path.to_path_buf(),
        source: e,
    })?;
    #[cfg(unix)]
    if let Some(dir) = path.parent() {
        // Make the rename itself durable. Failure here is not fatal to
        // correctness (the rename is atomic either way), so errors are
        // deliberately ignored.
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// The deterministic temp-file twin of `path` (`<name>.tmp`).
pub(crate) fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path
        .file_name()
        .map(std::ffi::OsStr::to_os_string)
        .unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Validate a content-address digest key: exactly 64 lowercase hex
/// characters (what `sha256_hex` produces).
pub(crate) fn check_digest_key(digest: &str) -> StoreResult<()> {
    let ok = digest.len() == 64
        && digest
            .bytes()
            .all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b));
    if ok {
        Ok(())
    } else {
        Err(StoreError::BadKey {
            key: digest.to_string(),
            detail: "digest keys are 64 lowercase hex chars".to_string(),
        })
    }
}

/// Validate a job id used as a spool/outbox file stem: 1–100 chars of
/// `[A-Za-z0-9._-]`, not starting with `.` (no hidden files, no path
/// separators, portable across filesystems).
pub(crate) fn check_job_key(job_id: &str) -> StoreResult<()> {
    let ok = !job_id.is_empty()
        && job_id.len() <= 100
        && !job_id.starts_with('.')
        && job_id
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-');
    if ok {
        Ok(())
    } else {
        Err(StoreError::BadKey {
            key: job_id.to_string(),
            detail: "job ids are 1-100 chars of [A-Za-z0-9._-], not starting with `.`".to_string(),
        })
    }
}

/// Sorted `*.json` files directly under `dir` (deterministic listing
/// order; `.tmp` leftovers and subdirectories are ignored).
pub(crate) fn list_json_sorted(dir: &Path) -> StoreResult<Vec<PathBuf>> {
    let io_err = |e: std::io::Error| StoreError::Io {
        path: dir.to_path_buf(),
        source: e,
    };
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir).map_err(io_err)? {
        let entry = entry.map_err(io_err)?;
        let path = entry.path();
        if path.extension().and_then(std::ffi::OsStr::to_str) == Some("json") {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::ObjBuilder;

    fn scratch(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("mcubes-store-mod-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn seal_roundtrip_and_tamper_detection() {
        let dir = scratch("seal");
        let path = dir.join("x.json");
        let doc = ObjBuilder::new()
            .field("$schema", "mcubes/test/v1")
            .field("value", 0.5)
            .build();
        write_atomic(&path, &seal(doc).to_json()).unwrap();
        let back = read_sealed(&path, "mcubes/test/v1").unwrap().unwrap();
        assert_eq!(back.get("value").and_then(Value::as_f64), Some(0.5));
        // Wrong expected schema is a typed error.
        assert!(matches!(
            read_sealed(&path, "mcubes/test/v2"),
            Err(StoreError::UnsupportedSchema { .. })
        ));
        // Tamper with the payload: checksum catches it.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("5.00000000000000000e-1", "2.5")).unwrap();
        assert!(matches!(
            read_sealed(&path, "mcubes/test/v1"),
            Err(StoreError::Corrupt { .. })
        ));
        // Missing file is None, not an error.
        assert!(read_sealed(&dir.join("absent.json"), "mcubes/test/v1")
            .unwrap()
            .is_none());
    }

    #[test]
    fn tmp_leftover_is_invisible_to_listings() {
        let dir = scratch("tmp");
        std::fs::write(dir.join("a.json"), "{}").unwrap();
        std::fs::write(dir.join("b.json.tmp"), "garbage").unwrap();
        std::fs::write(dir.join("c.json"), "{}").unwrap();
        let listed = list_json_sorted(&dir).unwrap();
        let names: Vec<_> = listed
            .iter()
            .map(|p| p.file_name().unwrap().to_str().unwrap().to_string())
            .collect();
        assert_eq!(names, vec!["a.json", "c.json"]);
    }

    #[test]
    fn key_validation() {
        assert!(check_digest_key(&"a".repeat(64)).is_ok());
        assert!(check_digest_key("xyz").is_err());
        assert!(check_digest_key(&"A".repeat(64)).is_err());
        assert!(check_job_key("nightly-f4_01.a").is_ok());
        assert!(check_job_key("").is_err());
        assert!(check_job_key(".hidden").is_err());
        assert!(check_job_key("a/b").is_err());
        assert!(check_job_key(&"x".repeat(101)).is_err());
    }

    #[test]
    fn error_display_and_conversion() {
        let e = StoreError::BadKey {
            key: "k".into(),
            detail: "d".into(),
        };
        assert!(e.to_string().contains("bad store key"));
        let lib: crate::Error = e.into();
        assert!(lib.to_string().contains("store error"));
        assert!(std::error::Error::source(&lib).is_some());
    }
}
