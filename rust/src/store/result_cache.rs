//! Content-addressed cache of completed results.
//!
//! One file per distinct job content: `<dir>/<digest>.json`, the
//! sealed JSON of a successful [`ResultManifest`]. The key is
//! [`super::JobManifest::digest`] — a hash of the job's *semantic*
//! fields only — so any re-submission that would compute the same
//! numbers (regardless of job id, priority, checkpoint interval, or
//! thread count) is answered from here with zero new integrand
//! evaluations. Only successes are cached: failures depend on
//! transient conditions (unknown integrand names get registered,
//! resolvers change) and must re-run.

use super::{read_sealed, seal, write_atomic, ResultManifest, StoreError, StoreResult};
use std::path::{Path, PathBuf};

/// `$schema` tag of cache entries — the result-manifest schema itself
/// (a cache entry *is* a sealed result manifest).
pub use super::manifest::RESULT_MANIFEST_SCHEMA;

/// The result-cache half of a [`super::ServiceStore`] (usable
/// standalone: any directory works as a root).
pub struct ResultCache {
    dir: PathBuf,
}

impl ResultCache {
    /// Open (creating if needed) the cache directory.
    pub fn open(dir: impl AsRef<Path>) -> StoreResult<ResultCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).map_err(|e| StoreError::Io {
            path: dir.clone(),
            source: e,
        })?;
        Ok(ResultCache { dir })
    }

    /// The directory this cache persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_for(&self, digest: &str) -> StoreResult<PathBuf> {
        super::check_digest_key(digest)?;
        Ok(self.dir.join(format!("{digest}.json")))
    }

    /// Durably cache a *successful* result under its digest. Failed
    /// results are refused ([`StoreError::BadKey`]): a cache must
    /// never pin an error. The manifest's own `digest` field must
    /// match the key.
    pub fn put(&self, digest: &str, result: &ResultManifest) -> StoreResult<()> {
        let path = self.path_for(digest)?;
        if result.outcome.is_err() {
            return Err(StoreError::BadKey {
                key: digest.to_string(),
                detail: "refusing to cache a failed result".to_string(),
            });
        }
        if result.digest != digest {
            return Err(StoreError::BadKey {
                key: digest.to_string(),
                detail: format!("manifest digest {} does not match key", result.digest),
            });
        }
        write_atomic(&path, &seal(result.to_json()).to_json())
    }

    /// Look up a cached result. `Ok(None)` on a miss; a hit returns
    /// the stored manifest verbatim (the caller re-stamps `job_id` and
    /// the `cached` flag when answering a new submission). A renamed
    /// or cross-copied entry is rejected as corrupt via the embedded
    /// digest, mirroring the checkpoint store.
    pub fn get(&self, digest: &str) -> StoreResult<Option<ResultManifest>> {
        let path = self.path_for(digest)?;
        let Some(body) = read_sealed(&path, RESULT_MANIFEST_SCHEMA)? else {
            return Ok(None);
        };
        let corrupt = |detail: String| StoreError::Corrupt {
            path: path.clone(),
            detail,
        };
        let result = ResultManifest::from_json(&body)
            .map_err(|e| corrupt(format!("cache payload: {e}")))?;
        if result.digest != digest {
            return Err(corrupt(format!(
                "entry digest {} does not match key {digest}",
                result.digest
            )));
        }
        if result.outcome.is_err() {
            return Err(corrupt("cache entry holds a failed result".to_string()));
        }
        Ok(Some(result))
    }

    /// Cached digests, sorted (deterministic listing order).
    pub fn digests(&self) -> StoreResult<Vec<String>> {
        let mut out = Vec::new();
        for path in super::list_json_sorted(&self.dir)? {
            if let Some(stem) = path.file_stem().and_then(std::ffi::OsStr::to_str) {
                if super::check_digest_key(stem).is_ok() {
                    out.push(stem.to_string());
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::StopReason;
    use crate::coordinator::JobConfig;
    use crate::store::{JobManifest, ResultNumbers};

    fn scratch(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "mcubes-store-cache-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn demo_result() -> (String, ResultManifest) {
        let job = JobManifest::new("cache-test", "f3", 3, JobConfig::default());
        let digest = job.digest();
        let numbers = ResultNumbers {
            integral: 1.5,
            sigma: 1e-4,
            chi2_dof: 1.1,
            rel_err: 6.7e-5,
            iterations: 10,
            converged: true,
            calls_used: 123_456,
            stop: StopReason::Converged,
        };
        let result = ResultManifest::success(&job, digest.clone(), numbers);
        (digest, result)
    }

    #[test]
    fn put_get_roundtrip() {
        let cache = ResultCache::open(scratch("roundtrip")).unwrap();
        let (digest, result) = demo_result();
        assert!(cache.get(&digest).unwrap().is_none());
        cache.put(&digest, &result).unwrap();
        let hit = cache.get(&digest).unwrap().unwrap();
        assert_eq!(hit.to_json().to_json(), result.to_json().to_json());
        assert_eq!(cache.digests().unwrap(), vec![digest]);
    }

    #[test]
    fn failed_results_are_refused() {
        let cache = ResultCache::open(scratch("refuse")).unwrap();
        let (digest, _) = demo_result();
        let failed = ResultManifest::failure("x", "f3", 3, "boom");
        assert!(matches!(
            cache.put(&digest, &failed),
            Err(StoreError::BadKey { .. })
        ));
    }

    #[test]
    fn digest_mismatch_is_refused_and_detected() {
        let cache = ResultCache::open(scratch("mismatch")).unwrap();
        let (digest, result) = demo_result();
        let wrong_key = "b".repeat(64);
        // put under a key that doesn't match the manifest's digest
        assert!(matches!(
            cache.put(&wrong_key, &result),
            Err(StoreError::BadKey { .. })
        ));
        // a cross-copied entry fails get() despite an intact seal
        cache.put(&digest, &result).unwrap();
        std::fs::copy(
            cache.dir().join(format!("{digest}.json")),
            cache.dir().join(format!("{wrong_key}.json")),
        )
        .unwrap();
        assert!(matches!(
            cache.get(&wrong_key),
            Err(StoreError::Corrupt { .. })
        ));
    }
}
