//! Durable mid-run checkpoints, keyed by job digest.
//!
//! One file per in-flight job: `<dir>/<digest>.json`, a sealed
//! envelope (`$schema = mcubes/checkpoint-file/v1`) wrapping the
//! [`Checkpoint`]'s own JSON. The daemon flushes here every
//! `checkpoint_interval` iterations; after a crash, [`CheckpointStore::load`]
//! hands back the last durable iteration and `Session::resume`
//! continues bitwise. The envelope echoes the digest so a file that
//! was renamed (or copied under the wrong key) is rejected as corrupt
//! instead of silently resuming the wrong job.

use super::{read_sealed, seal, write_atomic, StoreError, StoreResult};
use crate::api::Checkpoint;
use crate::util::json::{ObjBuilder, Value};
use std::path::{Path, PathBuf};

/// `$schema` tag of the sealed checkpoint envelope.
pub const CHECKPOINT_FILE_SCHEMA: &str = "mcubes/checkpoint-file/v1";

/// The checkpoint half of a [`super::ServiceStore`] (usable
/// standalone: any directory works as a root).
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    /// Open (creating if needed) the checkpoint directory.
    pub fn open(dir: impl AsRef<Path>) -> StoreResult<CheckpointStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).map_err(|e| StoreError::Io {
            path: dir.clone(),
            source: e,
        })?;
        Ok(CheckpointStore { dir })
    }

    /// The directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_for(&self, digest: &str) -> StoreResult<PathBuf> {
        super::check_digest_key(digest)?;
        Ok(self.dir.join(format!("{digest}.json")))
    }

    /// Durably persist `cp` under `digest` (write-temp + fsync +
    /// atomic rename; replaces any previous checkpoint for the key).
    /// On return the checkpoint has reached disk: a crash at any later
    /// point resumes from *at least* this iteration.
    pub fn save(&self, digest: &str, cp: &Checkpoint) -> StoreResult<()> {
        let path = self.path_for(digest)?;
        let envelope = ObjBuilder::new()
            .field("$schema", CHECKPOINT_FILE_SCHEMA)
            .field("digest", digest)
            .field("checkpoint", cp.to_json())
            .build();
        write_atomic(&path, &seal(envelope).to_json())
    }

    /// Load the durable checkpoint for `digest`, if one exists.
    /// `Ok(None)` means "no checkpoint" (cold start); every malformed
    /// on-disk state is a typed [`StoreError`].
    pub fn load(&self, digest: &str) -> StoreResult<Option<Checkpoint>> {
        let path = self.path_for(digest)?;
        let Some(body) = read_sealed(&path, CHECKPOINT_FILE_SCHEMA)? else {
            return Ok(None);
        };
        let corrupt = |detail: String| StoreError::Corrupt {
            path: path.clone(),
            detail,
        };
        match body.get("digest").and_then(Value::as_str) {
            Some(found) if found == digest => {}
            Some(found) => {
                return Err(corrupt(format!(
                    "envelope digest {found} does not match key {digest}"
                )))
            }
            None => return Err(corrupt("missing envelope digest".to_string())),
        }
        let cp_json = body
            .get("checkpoint")
            .ok_or_else(|| corrupt("missing checkpoint payload".to_string()))?;
        let cp = Checkpoint::from_json(cp_json)
            .map_err(|e| corrupt(format!("checkpoint payload: {e}")))?;
        Ok(Some(cp))
    }

    /// Delete the checkpoint for `digest` (idempotent: deleting a
    /// missing key is `Ok` — the daemon calls this after publishing a
    /// result, and a crash between publish and delete must not wedge
    /// the restart).
    pub fn remove(&self, digest: &str) -> StoreResult<()> {
        let path = self.path_for(digest)?;
        match std::fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(StoreError::Io { path, source: e }),
        }
    }

    /// Digests with a durable checkpoint, sorted (deterministic
    /// startup scan order).
    pub fn digests(&self) -> StoreResult<Vec<String>> {
        let mut out = Vec::new();
        for path in super::list_json_sorted(&self.dir)? {
            if let Some(stem) = path.file_stem().and_then(std::ffi::OsStr::to_str) {
                if super::check_digest_key(stem).is_ok() {
                    out.push(stem.to_string());
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{RunPlan, Session};
    use crate::coordinator::JobConfig;

    fn scratch(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "mcubes-store-ckpt-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn digest_key(fill: char) -> String {
        fill.to_string().repeat(64)
    }

    fn suspended_checkpoint() -> Checkpoint {
        let f = crate::integrands::by_name("f3", 3).unwrap();
        let mut cfg = JobConfig::default();
        cfg.maxcalls = 1 << 12;
        cfg.plan = RunPlan::classic(6, 4, 1);
        cfg.seed = 9;
        let mut s = Session::new(f, cfg).unwrap();
        for _ in 0..3 {
            s.step().unwrap();
        }
        s.suspend()
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let store = CheckpointStore::open(scratch("roundtrip")).unwrap();
        let cp = suspended_checkpoint();
        let key = digest_key('a');
        assert!(store.load(&key).unwrap().is_none());
        store.save(&key, &cp).unwrap();
        let back = store.load(&key).unwrap().unwrap();
        assert_eq!(back, cp);
        assert_eq!(store.digests().unwrap(), vec![key.clone()]);
        // Overwrite with a later checkpoint replaces, not appends.
        store.save(&key, &cp).unwrap();
        assert_eq!(store.digests().unwrap().len(), 1);
        store.remove(&key).unwrap();
        store.remove(&key).unwrap(); // idempotent
        assert!(store.load(&key).unwrap().is_none());
    }

    #[test]
    fn renamed_file_is_rejected() {
        let store = CheckpointStore::open(scratch("renamed")).unwrap();
        let cp = suspended_checkpoint();
        let (a, b) = (digest_key('a'), digest_key('b'));
        store.save(&a, &cp).unwrap();
        std::fs::rename(
            store.dir().join(format!("{a}.json")),
            store.dir().join(format!("{b}.json")),
        )
        .unwrap();
        // The seal still verifies (the bytes are intact), but the
        // envelope digest exposes the mismatch.
        assert!(matches!(
            store.load(&b),
            Err(StoreError::Corrupt { .. })
        ));
    }

    #[test]
    fn bad_keys_are_typed_errors() {
        let store = CheckpointStore::open(scratch("badkey")).unwrap();
        assert!(matches!(
            store.load("not-a-digest"),
            Err(StoreError::BadKey { .. })
        ));
        assert!(matches!(
            store.save("UPPER", &suspended_checkpoint()),
            Err(StoreError::BadKey { .. })
        ));
    }
}
