//! `$schema`-versioned job and result manifests, and the canonical
//! content-address digest of a job.
//!
//! A [`JobManifest`] is what a client drops into the spool: integrand
//! name + the semantic [`JobConfig`] fields + service metadata
//! (checkpoint interval, priority). A [`ResultManifest`] is what the
//! daemon publishes to the outbox and stores in the result cache.
//! Both carry an explicit `$schema` tag (`mcubes/job-manifest/v1`,
//! `mcubes/result-manifest/v1`) and are read by *tolerant* readers:
//! unknown fields are ignored, optional fields default — the frozen v1
//! fixture strings in this module's tests must load forever.
//!
//! [`JobManifest::digest`] is the store's content address: SHA-256
//! over the canonical JSON (`util::json::to_canonical_json` — sorted
//! keys, fixed float format) of the fields that determine the
//! *numbers* — integrand, dim, seed, budgets, tolerance, grid mode,
//! sampling, plan. Service metadata (job id, priority, checkpoint
//! interval) and the execution knobs — thread count, exec schedule,
//! shard count, shard spool directory (results are bitwise invariant
//! to all of them) — are deliberately excluded: two submissions that
//! would compute the same answer share one digest, one checkpoint,
//! and one cache entry.

use crate::api::{RunPlan, Stage, StopReason};
use crate::coordinator::{IntegrationOutput, JobConfig};
use crate::error::{Error, Result};
use crate::grid::GridMode;
use crate::strat::Sampling;
use crate::util::digest::sha256_hex;
use crate::util::json::{to_canonical_json, ObjBuilder, Value};

/// `$schema` tag written by [`JobManifest::to_json`].
pub const JOB_MANIFEST_SCHEMA: &str = "mcubes/job-manifest/v1";
/// `$schema` tag written by [`ResultManifest::to_json`].
pub const RESULT_MANIFEST_SCHEMA: &str = "mcubes/result-manifest/v1";
/// `$schema` tag of the digest input document (versioning the digest
/// rules themselves: changing what the digest covers bumps this and
/// thereby invalidates — rather than silently aliasing — old cache
/// entries).
pub const JOB_DIGEST_SCHEMA: &str = "mcubes/job-digest/v1";

/// A job submission: *what* to integrate plus service metadata.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct JobManifest {
    /// Client-chosen id; names the spool and outbox files. Validated
    /// by the store: 1–100 chars of `[A-Za-z0-9._-]`.
    pub job_id: String,
    /// Registry integrand name (or a name the daemon's resolver
    /// understands — see `coordinator::Daemon::with_resolver`).
    pub integrand: String,
    /// Integrand dimension.
    pub dim: usize,
    /// The run configuration. The execution knobs (`threads`,
    /// `shards`, `shard_dir`, `exec`) are ignored on submission — the
    /// daemon decides; results are invariant to all of them.
    pub config: JobConfig,
    /// Iterations between durable checkpoint flushes (>= 1).
    pub checkpoint_interval: usize,
    /// Spool ordering hint: higher runs first (ties break by job id).
    pub priority: i64,
}

impl JobManifest {
    /// A manifest with service defaults (checkpoint every iteration,
    /// priority 0).
    pub fn new(
        job_id: impl Into<String>,
        integrand: impl Into<String>,
        dim: usize,
        config: JobConfig,
    ) -> JobManifest {
        JobManifest {
            job_id: job_id.into(),
            integrand: integrand.into(),
            dim,
            config,
            checkpoint_interval: 1,
            priority: 0,
        }
    }

    /// Set the checkpoint flush interval (iterations, >= 1).
    pub fn with_checkpoint_interval(mut self, iters: usize) -> JobManifest {
        self.checkpoint_interval = iters;
        self
    }

    /// Set the spool priority.
    pub fn with_priority(mut self, priority: i64) -> JobManifest {
        self.priority = priority;
        self
    }

    /// Validate the manifest (id naming rules, config invariants,
    /// interval >= 1).
    pub fn validate(&self) -> Result<()> {
        super::check_job_key(&self.job_id).map_err(|e| Error::Manifest(e.to_string()))?;
        if self.integrand.is_empty() {
            return Err(Error::Manifest("job manifest: empty integrand name".into()));
        }
        if self.dim == 0 {
            return Err(Error::Manifest("job manifest: dim must be >= 1".into()));
        }
        if self.checkpoint_interval == 0 {
            return Err(Error::Manifest(
                "job manifest: checkpoint_interval must be >= 1".into(),
            ));
        }
        self.config.validate()
    }

    /// The run configuration this job executes under: the manifest's
    /// semantic fields with the daemon-chosen thread count.
    pub fn to_config(&self, threads: usize) -> JobConfig {
        let mut cfg = self.config.clone();
        cfg.threads = threads.max(1);
        cfg
    }

    /// The content-address of this job: SHA-256 (hex) of the canonical
    /// JSON of its semantic fields. See the module docs for what is —
    /// and deliberately is not — covered.
    pub fn digest(&self) -> String {
        let doc = ObjBuilder::new()
            .field("$schema", JOB_DIGEST_SCHEMA)
            .field("integrand", self.integrand.as_str())
            .field("dim", self.dim)
            .field("seed", i64::from(self.config.seed))
            .field("maxcalls", self.config.maxcalls)
            .field("nb", self.config.nb)
            .field("nblocks", self.config.nblocks)
            .field("tau_rel", self.config.tau_rel)
            .field("max_total_calls", opt_usize(self.config.max_total_calls))
            .field("reset_on_inconsistency", self.config.reset_on_inconsistency)
            .field("grid_mode", grid_mode_label(self.config.grid_mode))
            .field("sampling", sampling_to_json(&self.config.sampling))
            .field("plan", plan_to_json(&self.config.plan))
            .build();
        sha256_hex(to_canonical_json(&doc).as_bytes())
    }

    /// Serialize (v1 schema).
    pub fn to_json(&self) -> Value {
        ObjBuilder::new()
            .field("$schema", JOB_MANIFEST_SCHEMA)
            .field("job_id", self.job_id.as_str())
            .field("integrand", self.integrand.as_str())
            .field("dim", self.dim)
            .field("seed", i64::from(self.config.seed))
            .field("maxcalls", self.config.maxcalls)
            .field("nb", self.config.nb)
            .field("nblocks", self.config.nblocks)
            .field("tau_rel", self.config.tau_rel)
            .field("max_total_calls", opt_usize(self.config.max_total_calls))
            .field("reset_on_inconsistency", self.config.reset_on_inconsistency)
            .field("grid_mode", grid_mode_label(self.config.grid_mode))
            .field("sampling", sampling_to_json(&self.config.sampling))
            .field("plan", plan_to_json(&self.config.plan))
            .field("checkpoint_interval", self.checkpoint_interval)
            .field("priority", self.priority)
            .build()
    }

    /// Tolerant v1 reader: `$schema`, `job_id`, `integrand`, and `dim`
    /// are required; every other field defaults to
    /// [`JobConfig::default`] semantics; unknown fields are ignored
    /// (forward compatibility within v1).
    pub fn from_json(v: &Value) -> Result<JobManifest> {
        check_manifest_schema(v, JOB_MANIFEST_SCHEMA)?;
        let job_id = req_str(v, "job_id")?;
        let integrand = req_str(v, "integrand")?;
        let dim = req_usize(v, "dim")?;
        let defaults = JobConfig::default();
        let mut config = defaults.clone();
        config.seed = match v.get("seed") {
            None => defaults.seed,
            Some(s) => u32::try_from(s.as_i64().unwrap_or(-1))
                .map_err(|_| Error::Manifest("job manifest: seed must fit u32".into()))?,
        };
        config.maxcalls = opt_usize_field(v, "maxcalls")?.unwrap_or(defaults.maxcalls);
        config.nb = opt_usize_field(v, "nb")?.unwrap_or(defaults.nb);
        config.nblocks = opt_usize_field(v, "nblocks")?.unwrap_or(defaults.nblocks);
        if let Some(t) = v.get("tau_rel") {
            config.tau_rel = t
                .as_f64()
                .ok_or_else(|| Error::Manifest("job manifest: tau_rel must be a number".into()))?;
        }
        config.max_total_calls = match v.get("max_total_calls") {
            None | Some(Value::Null) => None,
            Some(n) => Some(n.as_usize().ok_or_else(|| {
                Error::Manifest(
                    "job manifest: max_total_calls must be a non-negative integer".into(),
                )
            })?),
        };
        if let Some(r) = v.get("reset_on_inconsistency") {
            config.reset_on_inconsistency = r.as_bool().ok_or_else(|| {
                Error::Manifest("job manifest: reset_on_inconsistency must be a bool".into())
            })?;
        }
        if let Some(g) = v.get("grid_mode") {
            config.grid_mode = grid_mode_from_json(g)?;
        }
        if let Some(s) = v.get("sampling") {
            config.sampling = sampling_from_json(s)?;
        }
        if let Some(p) = v.get("plan") {
            config.plan = plan_from_json(p)?;
        }
        let checkpoint_interval = opt_usize_field(v, "checkpoint_interval")?.unwrap_or(1);
        let priority = match v.get("priority") {
            None => 0,
            Some(p) => p.as_i64().ok_or_else(|| {
                Error::Manifest("job manifest: priority must be an integer".into())
            })?,
        };
        Ok(JobManifest {
            job_id,
            integrand,
            dim,
            config,
            checkpoint_interval,
            priority,
        })
    }
}

/// The reproducible numbers of a completed run — everything in
/// [`IntegrationOutput`] except the wall-clock timings, which are
/// deliberately excluded so result manifests (like everything else in
/// the store) are bit-reproducible.
#[derive(Debug, Clone, PartialEq)]
#[non_exhaustive]
pub struct ResultNumbers {
    pub integral: f64,
    pub sigma: f64,
    pub chi2_dof: f64,
    pub rel_err: f64,
    pub iterations: usize,
    pub converged: bool,
    pub calls_used: usize,
    pub stop: StopReason,
}

impl ResultNumbers {
    /// Extract the reproducible subset of a run outcome.
    pub fn from_output(o: &IntegrationOutput, stop: StopReason) -> ResultNumbers {
        ResultNumbers {
            integral: o.integral,
            sigma: o.sigma,
            chi2_dof: o.chi2_dof,
            rel_err: o.rel_err,
            iterations: o.iterations,
            converged: o.converged,
            calls_used: o.calls_used,
            stop,
        }
    }
}

/// What the daemon publishes to the outbox (and, for successes, the
/// result cache): the job's numbers or its error, plus provenance.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct ResultManifest {
    /// The job id this result answers.
    pub job_id: String,
    /// The content-address digest of the job (cache key).
    pub digest: String,
    /// Integrand name, echoed from the manifest.
    pub integrand: String,
    /// Dimension, echoed from the manifest.
    pub dim: usize,
    /// The numbers, or the job's error message.
    pub outcome: std::result::Result<ResultNumbers, String>,
    /// True when this result was served from the content-addressed
    /// cache (zero new integrand evaluations).
    pub cached: bool,
    /// Checkpoint iteration the run resumed from (0 = cold start).
    pub resumed_iteration: usize,
}

impl ResultManifest {
    /// A success result.
    pub fn success(
        job: &JobManifest,
        digest: impl Into<String>,
        numbers: ResultNumbers,
    ) -> ResultManifest {
        ResultManifest {
            job_id: job.job_id.clone(),
            digest: digest.into(),
            integrand: job.integrand.clone(),
            dim: job.dim,
            outcome: Ok(numbers),
            cached: false,
            resumed_iteration: 0,
        }
    }

    /// A failure result (also used for unreadable submissions, where
    /// only the spool file stem is known).
    pub fn failure(
        job_id: impl Into<String>,
        integrand: impl Into<String>,
        dim: usize,
        error: impl Into<String>,
    ) -> ResultManifest {
        ResultManifest {
            job_id: job_id.into(),
            digest: String::new(),
            integrand: integrand.into(),
            dim,
            outcome: Err(error.into()),
            cached: false,
            resumed_iteration: 0,
        }
    }

    /// Serialize (v1 schema). Note: no timings, by design — see
    /// [`ResultNumbers`].
    pub fn to_json(&self) -> Value {
        let mut b = ObjBuilder::new()
            .field("$schema", RESULT_MANIFEST_SCHEMA)
            .field("job_id", self.job_id.as_str())
            .field("digest", self.digest.as_str())
            .field("integrand", self.integrand.as_str())
            .field("dim", self.dim);
        match &self.outcome {
            Ok(n) => {
                b = b
                    .field("status", "ok")
                    .field("integral", n.integral)
                    .field("sigma", n.sigma)
                    .field("chi2_dof", n.chi2_dof)
                    .field("rel_err", n.rel_err)
                    .field("iterations", n.iterations)
                    .field("converged", n.converged)
                    .field("calls_used", n.calls_used)
                    .field("stop", n.stop.as_str());
            }
            Err(msg) => {
                b = b.field("status", "error").field("error", msg.as_str());
            }
        }
        b.field("cached", self.cached)
            .field("resumed_iteration", self.resumed_iteration)
            .build()
    }

    /// Tolerant v1 reader (mirror of [`ResultManifest::to_json`]).
    pub fn from_json(v: &Value) -> Result<ResultManifest> {
        check_manifest_schema(v, RESULT_MANIFEST_SCHEMA)?;
        let job_id = req_str(v, "job_id")?;
        let digest = req_str(v, "digest")?;
        let integrand = req_str(v, "integrand")?;
        let dim = req_usize(v, "dim")?;
        let status = req_str(v, "status")?;
        let outcome = match status.as_str() {
            "ok" => {
                let num = |key: &str| -> Result<f64> {
                    v.req(key)?.as_f64().ok_or_else(|| {
                        Error::Manifest(format!("result manifest: `{key}` must be a number"))
                    })
                };
                let stop_label = req_str(v, "stop")?;
                let stop = StopReason::from_label(&stop_label).ok_or_else(|| {
                    Error::Manifest(format!("result manifest: unknown stop `{stop_label}`"))
                })?;
                Ok(ResultNumbers {
                    integral: num("integral")?,
                    sigma: num("sigma")?,
                    chi2_dof: num("chi2_dof")?,
                    rel_err: num("rel_err")?,
                    iterations: req_usize(v, "iterations")?,
                    converged: v.req("converged")?.as_bool().ok_or_else(|| {
                        Error::Manifest("result manifest: `converged` must be a bool".into())
                    })?,
                    calls_used: req_usize(v, "calls_used")?,
                    stop,
                })
            }
            "error" => Err(req_str(v, "error")?),
            other => {
                return Err(Error::Manifest(format!(
                    "result manifest: unknown status `{other}`"
                )))
            }
        };
        let cached = v.get("cached").and_then(Value::as_bool).unwrap_or(false);
        let resumed_iteration = opt_usize_field(v, "resumed_iteration")?.unwrap_or(0);
        Ok(ResultManifest {
            job_id,
            digest,
            integrand,
            dim,
            outcome,
            cached,
            resumed_iteration,
        })
    }
}

// ---- JSON helpers for the config sub-schemas ------------------------

fn opt_usize(v: Option<usize>) -> Value {
    match v {
        Some(n) => Value::from(n),
        None => Value::Null,
    }
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    v.req(key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| Error::Manifest(format!("manifest field `{key}` must be a string")))
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    v.req(key)?.as_usize().ok_or_else(|| {
        Error::Manifest(format!(
            "manifest field `{key}` must be a non-negative integer"
        ))
    })
}

fn opt_usize_field(v: &Value, key: &str) -> Result<Option<usize>> {
    match v.get(key) {
        None => Ok(None),
        Some(n) => n.as_usize().map(Some).ok_or_else(|| {
            Error::Manifest(format!(
                "manifest field `{key}` must be a non-negative integer"
            ))
        }),
    }
}

/// Require `$schema` to be the expected v1 tag, with a distinct error
/// for a same-family-but-newer tag (forward-compat contract: v1
/// readers reject, never misread, v2 files).
fn check_manifest_schema(v: &Value, expected: &'static str) -> Result<()> {
    let found = v
        .get("$schema")
        .and_then(Value::as_str)
        .ok_or_else(|| Error::Manifest("manifest: missing $schema".into()))?;
    if found == expected {
        return Ok(());
    }
    let family = expected.rsplit_once('/').map_or(expected, |(fam, _)| fam);
    if found.starts_with(family) {
        return Err(Error::Manifest(format!(
            "manifest schema `{found}` is newer than supported `{expected}`"
        )));
    }
    Err(Error::Manifest(format!(
        "manifest: expected schema `{expected}`, found `{found}`"
    )))
}

fn grid_mode_label(m: GridMode) -> &'static str {
    match m {
        GridMode::PerAxis => "per_axis",
        GridMode::Shared1D => "shared_1d",
    }
}

fn grid_mode_from_json(v: &Value) -> Result<GridMode> {
    match v.as_str() {
        Some("per_axis") => Ok(GridMode::PerAxis),
        Some("shared_1d") => Ok(GridMode::Shared1D),
        _ => Err(Error::Manifest(format!(
            "manifest: grid_mode must be \"per_axis\" or \"shared_1d\", got {}",
            v.to_json()
        ))),
    }
}

fn sampling_to_json(s: &Sampling) -> Value {
    match s {
        Sampling::Uniform => ObjBuilder::new().field("kind", "uniform").build(),
        Sampling::VegasPlus { beta } => ObjBuilder::new()
            .field("kind", "vegas_plus")
            .field("beta", *beta)
            .build(),
    }
}

fn sampling_from_json(v: &Value) -> Result<Sampling> {
    match v.get("kind").and_then(Value::as_str) {
        Some("uniform") => Ok(Sampling::Uniform),
        Some("vegas_plus") => {
            let beta = match v.get("beta") {
                None => return Ok(Sampling::vegas_plus()),
                Some(b) => b.as_f64().ok_or_else(|| {
                    Error::Manifest("manifest: sampling beta must be a number".into())
                })?,
            };
            Ok(Sampling::VegasPlus { beta })
        }
        _ => Err(Error::Manifest(format!(
            "manifest: sampling kind must be \"uniform\" or \"vegas_plus\", got {}",
            v.to_json()
        ))),
    }
}

fn stage_to_json(s: &Stage) -> Value {
    let mut b = ObjBuilder::new()
        .field("iters", s.iters)
        .field("adapt", s.adapt)
        .field("discard", s.discard);
    if let Some(c) = s.calls {
        b = b.field("calls", c);
    }
    if let Some(sm) = &s.sampling {
        b = b.field("sampling", sampling_to_json(sm));
    }
    b.build()
}

fn stage_from_json(v: &Value) -> Result<Stage> {
    let iters = req_usize(v, "iters")?;
    let adapt = v
        .req("adapt")?
        .as_bool()
        .ok_or_else(|| Error::Manifest("manifest: stage adapt must be a bool".into()))?;
    let mut stage = if adapt {
        Stage::adapt(iters)
    } else {
        Stage::sample(iters)
    };
    if v.get("discard").and_then(Value::as_bool) == Some(true) {
        stage = stage.discarded();
    }
    match v.get("calls") {
        None | Some(Value::Null) => {}
        Some(c) => {
            stage = stage.with_calls(c.as_usize().ok_or_else(|| {
                Error::Manifest("manifest: stage calls must be a non-negative integer".into())
            })?);
        }
    }
    match v.get("sampling") {
        None | Some(Value::Null) => {}
        Some(sv) => stage = stage.with_sampling(sampling_from_json(sv)?),
    }
    Ok(stage)
}

fn plan_to_json(p: &RunPlan) -> Value {
    Value::Arr(p.stages().iter().map(stage_to_json).collect())
}

fn plan_from_json(v: &Value) -> Result<RunPlan> {
    let arr = v
        .as_arr()
        .ok_or_else(|| Error::Manifest("manifest: plan must be an array of stages".into()))?;
    let mut stages = Vec::with_capacity(arr.len());
    for s in arr {
        stages.push(stage_from_json(s)?);
    }
    Ok(RunPlan::new(stages))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::RunPlan;

    fn demo_manifest() -> JobManifest {
        let mut cfg = JobConfig::default()
            .with_maxcalls(1 << 14)
            .with_tolerance(1e-4)
            .with_seed(7)
            .with_sampling(Sampling::vegas_plus());
        cfg.plan = RunPlan::warmup_then_final(3, 1 << 10, 6);
        cfg.max_total_calls = Some(1 << 20);
        JobManifest::new("job-001", "f4", 5, cfg)
            .with_checkpoint_interval(2)
            .with_priority(5)
    }

    #[test]
    fn job_manifest_roundtrip_is_exact() {
        let m = demo_manifest();
        assert!(m.validate().is_ok());
        let round = JobManifest::from_json(&m.to_json()).unwrap();
        // Byte-identical re-serialization is the strongest equality we
        // can assert without PartialEq on JobConfig.
        assert_eq!(m.to_json().to_json(), round.to_json().to_json());
        assert_eq!(m.digest(), round.digest());
    }

    /// FROZEN v1 fixture — do not regenerate. v1 job manifests on disk
    /// must load forever, including ones with fields this build has
    /// never heard of.
    const JOB_FIXTURE_V1: &str = r#"{
        "$schema": "mcubes/job-manifest/v1",
        "job_id": "fixture-v1",
        "integrand": "f3",
        "dim": 3,
        "seed": 11,
        "maxcalls": 8192,
        "tau_rel": 1e-5,
        "grid_mode": "per_axis",
        "sampling": {"kind": "vegas_plus", "beta": 0.75},
        "plan": [
            {"iters": 4, "adapt": true, "discard": true, "calls": 1024},
            {"iters": 8, "adapt": false, "discard": false}
        ],
        "checkpoint_interval": 3,
        "future_field_from_v1_point_5": {"ignored": true}
    }"#;

    #[test]
    fn v1_fixture_loads_forever() {
        let v = crate::util::json::parse(JOB_FIXTURE_V1).unwrap();
        let m = JobManifest::from_json(&v).unwrap();
        assert_eq!(m.job_id, "fixture-v1");
        assert_eq!((m.integrand.as_str(), m.dim), ("f3", 3));
        assert_eq!(m.config.seed, 11);
        assert_eq!(m.config.maxcalls, 8192);
        assert_eq!(m.config.tau_rel, 1e-5);
        // Omitted fields take defaults.
        assert_eq!(m.config.nb, JobConfig::default().nb);
        assert_eq!(m.config.max_total_calls, None);
        assert_eq!(m.priority, 0);
        assert_eq!(m.checkpoint_interval, 3);
        assert!(matches!(m.config.sampling, Sampling::VegasPlus { beta } if beta == 0.75));
        assert_eq!(m.config.plan.stages().len(), 2);
        assert_eq!(m.config.plan.stages()[0].calls, Some(1024));
        assert!(m.validate().is_ok());
    }

    #[test]
    fn newer_schema_is_rejected_not_misread() {
        let v = crate::util::json::parse(
            r#"{"$schema": "mcubes/job-manifest/v2", "job_id": "x", "integrand": "f3", "dim": 3}"#,
        )
        .unwrap();
        let err = JobManifest::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("newer than supported"), "{err}");
        let v = crate::util::json::parse(r#"{"job_id": "x"}"#).unwrap();
        assert!(JobManifest::from_json(&v).is_err());
    }

    #[test]
    fn digest_covers_semantics_only() {
        let base = demo_manifest();
        let d = base.digest();
        assert_eq!(d.len(), 64);
        // Service metadata does not change the digest...
        let mut m = demo_manifest();
        m.job_id = "renamed".into();
        m.priority = -3;
        m.checkpoint_interval = 7;
        m.config.threads = 16;
        assert_eq!(m.digest(), d);
        // ...nor do the other execution knobs: an 8-shard spooled run
        // is bitwise the single-worker run, so it shares its cache
        // entry and checkpoint.
        let mut m = demo_manifest();
        m.config.shards = 8;
        m.config.shard_dir = Some("/tmp/spool".into());
        assert_eq!(m.digest(), d);
        // ...semantic fields do.
        let mut m = demo_manifest();
        m.config.seed = 8;
        assert_ne!(m.digest(), d);
        let mut m = demo_manifest();
        m.config.sampling = Sampling::Uniform;
        assert_ne!(m.digest(), d);
        let mut m = demo_manifest();
        m.config.plan = RunPlan::classic(9, 4, 1);
        assert_ne!(m.digest(), d);
        let mut m = demo_manifest();
        m.integrand = "f5".into();
        assert_ne!(m.digest(), d);
    }

    #[test]
    fn digest_is_stable_across_field_order() {
        // A hand-written manifest with fields in a scrambled order
        // digests identically to the writer's order: the canonical
        // form, not the file bytes, is hashed.
        let m = demo_manifest();
        let v = m.to_json();
        let Value::Obj(mut fields) = v else {
            panic!("manifest json is an object")
        };
        fields.reverse();
        let scrambled = JobManifest::from_json(&Value::Obj(fields)).unwrap();
        assert_eq!(scrambled.digest(), m.digest());
    }

    /// FROZEN v1 result fixture — do not regenerate.
    const RESULT_FIXTURE_V1: &str = r#"{
        "$schema": "mcubes/result-manifest/v1",
        "job_id": "fixture-v1",
        "digest": "0000000000000000000000000000000000000000000000000000000000000000",
        "integrand": "f3",
        "dim": 3,
        "status": "ok",
        "integral": 1.25,
        "sigma": 3.5e-4,
        "chi2_dof": 0.9,
        "rel_err": 2.8e-4,
        "iterations": 12,
        "converged": true,
        "calls_used": 98304,
        "stop": "converged",
        "cached": false,
        "resumed_iteration": 4
    }"#;

    #[test]
    fn result_manifest_fixture_and_roundtrip() {
        let v = crate::util::json::parse(RESULT_FIXTURE_V1).unwrap();
        let r = ResultManifest::from_json(&v).unwrap();
        let n = r.outcome.as_ref().unwrap();
        assert_eq!(n.integral, 1.25);
        assert_eq!(n.stop, StopReason::Converged);
        assert_eq!(r.resumed_iteration, 4);
        let round = ResultManifest::from_json(&r.to_json()).unwrap();
        assert_eq!(round.to_json().to_json(), r.to_json().to_json());

        // Error results round-trip too.
        let e = ResultManifest::failure("bad-job", "nope", 2, "unknown integrand: nope");
        let round = ResultManifest::from_json(&e.to_json()).unwrap();
        assert_eq!(round.outcome, e.outcome);
        assert!(!round.cached);
    }
}
