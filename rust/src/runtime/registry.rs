//! Artifact manifest: what `python -m compile.aot` emitted.

use crate::error::{Error, Result};
use crate::strat::Layout;
use crate::util::json::{parse, Value};
use std::fs;
use std::path::{Path, PathBuf};

/// Metadata for one AOT-lowered V-Sample executable.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub integrand: String,
    pub dim: usize,
    pub nb: usize,
    pub g: usize,
    pub m: usize,
    pub p: usize,
    pub nblocks: usize,
    pub cpb: usize,
    pub maxcalls: usize,
    pub calls: usize,
    pub adjust: bool,
    pub hist_mode: String,
    pub batch_size: usize,
    pub lo: f64,
    pub hi: f64,
    pub symmetric: bool,
    pub n_tables: usize,
    pub table_knots: usize,
    pub true_value: Option<f64>,
}

impl ArtifactMeta {
    fn from_json(v: &Value) -> Result<ArtifactMeta> {
        let s = |k: &str| -> Result<String> {
            Ok(v.req(k)?
                .as_str()
                .ok_or_else(|| Error::Manifest(format!("{k}: not a string")))?
                .to_string())
        };
        let u = |k: &str| -> Result<usize> {
            v.req(k)?
                .as_usize()
                .ok_or_else(|| Error::Manifest(format!("{k}: not a usize")))
        };
        let f = |k: &str| -> Result<f64> {
            v.req(k)?
                .as_f64()
                .ok_or_else(|| Error::Manifest(format!("{k}: not a number")))
        };
        let b = |k: &str| -> Result<bool> {
            v.req(k)?
                .as_bool()
                .ok_or_else(|| Error::Manifest(format!("{k}: not a bool")))
        };
        Ok(ArtifactMeta {
            name: s("name")?,
            file: s("file")?,
            integrand: s("integrand")?,
            dim: u("dim")?,
            nb: u("nb")?,
            g: u("g")?,
            m: u("m")?,
            p: u("p")?,
            nblocks: u("nblocks")?,
            cpb: u("cpb")?,
            maxcalls: u("maxcalls")?,
            calls: u("calls")?,
            adjust: b("adjust")?,
            hist_mode: s("hist_mode")?,
            batch_size: u("batch_size")?,
            lo: f("lo")?,
            hi: f("hi")?,
            symmetric: b("symmetric")?,
            n_tables: u("n_tables")?,
            table_knots: u("table_knots")?,
            true_value: v.get("true_value").and_then(|x| x.as_f64()),
        })
    }

    /// The stratification layout this artifact was compiled for.
    pub fn layout(&self) -> Layout {
        Layout {
            d: self.dim,
            nb: self.nb,
            g: self.g,
            m: self.m,
            p: self.p,
            nblocks: self.nblocks,
            cpb: self.cpb,
        }
    }

    /// Cross-check: the manifest numbers must reproduce under the Rust
    /// layout rule (guards Python/Rust drift).
    pub fn verify_layout(&self) -> Result<()> {
        let l = Layout::compute(self.dim, self.maxcalls, self.nb, self.nblocks)
            .map_err(|e| Error::Manifest(format!("{}: {e}", self.name)))?;
        if l != self.layout() {
            return Err(Error::Manifest(format!(
                "{}: layout drift python={:?} rust={:?}",
                self.name,
                self.layout(),
                l
            )));
        }
        // The Pallas kernel draws uint32 sample indices
        // (`python/compile/philox.py`); only the native engine carries
        // the 64-bit counter pipeline. Reject artifacts whose layouts
        // would wrap on device rather than integrate them silently
        // wrong (no compiled artifact comes close to this today).
        if (l.m as u128) * (l.p as u128) > u32::MAX as u128 {
            return Err(Error::Manifest(format!(
                "{}: {} calls per iteration exceeds the PJRT kernel's \
                 32-bit sample counter — run layouts past 2^32 calls on \
                 the native engine",
                self.name,
                l.m as u128 * l.p as u128
            )));
        }
        Ok(())
    }
}

/// The parsed artifacts directory.
#[derive(Debug, Clone)]
pub struct Registry {
    dir: PathBuf,
    artifacts: Vec<ArtifactMeta>,
}

impl Registry {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.json");
        let text = fs::read_to_string(&manifest).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest.display()
            ))
        })?;
        let root = parse(&text)?;
        let arts = root
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("artifacts: not an array".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let meta = ArtifactMeta::from_json(a)?;
            meta.verify_layout()?;
            artifacts.push(meta);
        }
        Ok(Registry { dir, artifacts })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn all(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    /// Find by artifact name.
    pub fn by_name(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Unknown {
                kind: "artifact",
                name: name.to_string(),
            })
    }

    /// Find the best artifact for (integrand, variant) with
    /// maxcalls >= `min_calls` (smallest adequate), falling back to the
    /// largest available.
    pub fn select(&self, integrand: &str, adjust: bool, min_calls: usize) -> Result<&ArtifactMeta> {
        let mut candidates: Vec<&ArtifactMeta> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.integrand == integrand && a.adjust == adjust && a.hist_mode == "scatter"
            })
            .collect();
        if candidates.is_empty() {
            return Err(Error::Unknown {
                kind: "artifact for integrand",
                name: format!("{integrand} (adjust={adjust})"),
            });
        }
        candidates.sort_by_key(|a| a.maxcalls);
        candidates
            .iter()
            .find(|a| a.maxcalls >= min_calls)
            .or_else(|| candidates.last())
            .copied()
            .ok_or_else(|| Error::Unknown {
                kind: "artifact for integrand",
                name: format!("{integrand} (adjust={adjust})"),
            })
    }

    /// Path to an artifact's HLO text.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Load the runtime interpolation tables for a stateful integrand
    /// from `tables.json` (row-major [n_tables][knots]).
    pub fn tables_for(&self, meta: &ArtifactMeta) -> Result<Option<Vec<f64>>> {
        if meta.n_tables == 0 {
            return Ok(None);
        }
        let text = fs::read_to_string(self.dir.join("tables.json"))?;
        let root = parse(&text)?;
        let entry = root.req(&meta.integrand)?;
        let values = entry
            .req("values")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("tables values: not an array".into()))?;
        let mut flat = Vec::with_capacity(meta.n_tables * meta.table_knots);
        for row in values {
            let r = row
                .as_f64_vec()
                .ok_or_else(|| Error::Manifest("table row: not numbers".into()))?;
            if r.len() != meta.table_knots {
                return Err(Error::Manifest(format!(
                    "table row len {} != knots {}",
                    r.len(),
                    meta.table_knots
                )));
            }
            flat.extend_from_slice(&r);
        }
        if flat.len() != meta.n_tables * meta.table_knots {
            return Err(Error::Manifest("table count mismatch".into()));
        }
        Ok(Some(flat))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "f4_d5_c16384_adj", "file": "f4_d5_c16384_adj.hlo.txt",
         "integrand": "f4", "dim": 5, "nb": 50, "g": 6, "m": 7776, "p": 2,
         "nblocks": 8, "cpb": 972, "maxcalls": 16384, "calls": 15552,
         "adjust": true, "hist_mode": "scatter", "batch_size": 1,
         "lo": 0.0, "hi": 1.0, "symmetric": true,
         "n_tables": 0, "table_knots": 0, "true_value": 1.79e-6,
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let root = parse(SAMPLE).unwrap();
        let meta =
            ArtifactMeta::from_json(&root.req("artifacts").unwrap().as_arr().unwrap()[0]).unwrap();
        assert_eq!(meta.name, "f4_d5_c16384_adj");
        assert_eq!(meta.m, 7776);
        assert!(meta.adjust);
        assert_eq!(meta.layout().d, 5);
    }

    #[test]
    fn verify_layout_catches_drift() {
        let root = parse(SAMPLE).unwrap();
        let mut meta =
            ArtifactMeta::from_json(&root.req("artifacts").unwrap().as_arr().unwrap()[0]).unwrap();
        // The real numbers for (5, 16384): g=6? python: (16384/2)^(1/5)=6.06 -> 6
        meta.verify_layout().expect("sample should be consistent");
        meta.g = 5;
        assert!(meta.verify_layout().is_err());
    }

    /// The device kernel draws uint32 sample indices; a manifest whose
    /// layout exceeds 2^32 calls must be refused, not wrapped.
    #[test]
    #[cfg(target_pointer_width = "64")]
    fn verify_layout_rejects_32bit_counter_overflow() {
        let root = parse(SAMPLE).unwrap();
        let mut meta =
            ArtifactMeta::from_json(&root.req("artifacts").unwrap().as_arr().unwrap()[0]).unwrap();
        // d=1 keeps the Rust layout rule consistent: g = maxcalls/2.
        meta.dim = 1;
        meta.maxcalls = 1 << 33;
        meta.g = 1 << 32;
        meta.m = 1 << 32;
        meta.p = 2;
        meta.nblocks = 8;
        meta.cpb = meta.m.div_ceil(8);
        let err = meta.verify_layout().unwrap_err();
        assert!(
            err.to_string().contains("32-bit sample counter"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn missing_field_is_error() {
        let root = parse(r#"{"name": "x"}"#).unwrap();
        assert!(ArtifactMeta::from_json(&root).is_err());
    }
}
