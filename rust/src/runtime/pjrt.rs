//! PJRT client wrapper: compile HLO-text artifacts once, execute many
//! times from the coordinator's hot loop.
//!
//! The real implementation follows the /opt/xla-example/load_hlo
//! pattern: text -> HloModuleProto -> XlaComputation ->
//! PjRtLoadedExecutable. The executable returns a tuple
//! (res[2][, C[d][nb]]), matching `model.py`'s output convention.
//!
//! Gating is two-stage so every feature combination *builds*:
//!
//! * the `pjrt` cargo feature opts into the runtime surface, but
//! * the real client also needs the vendored `xla` crate, which the
//!   offline registry does not carry — it is linked only when the
//!   build sets `--cfg xla_runtime` (e.g.
//!   `RUSTFLAGS="--cfg xla_runtime"` after vendoring).
//!
//! Any other combination (including `--features pjrt` alone and
//! `--all-features`, which CI's feature matrix builds) compiles a stub
//! with the identical public surface; `PjrtRuntime::cpu()` reports the
//! backend as unavailable and every caller falls back to the native
//! engine.

#[cfg(all(feature = "pjrt", xla_runtime))]
mod imp {
    use crate::error::{Error, Result};
    use crate::estimator::IterationResult;
    use crate::grid::Bins;
    use crate::runtime::registry::{ArtifactMeta, Registry};
    // BTreeMap rather than HashMap: the compile cache is only ever hit
    // by exact key, but a deterministic container keeps every
    // collection in the runtime iteration-order-stable by construction
    // (the MC002 determinism rule bans hashed iteration outright in the
    // core modules; the runtime follows the same discipline).
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};

    fn xerr(e: xla::Error) -> Error {
        Error::Runtime(e.to_string())
    }

    /// Owns the PJRT CPU client and a compile cache keyed by artifact name.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        cache: Mutex<BTreeMap<String, Arc<VSampleExecutable>>>,
    }

    impl PjrtRuntime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu().map_err(xerr)?;
            Ok(PjrtRuntime {
                client,
                cache: Mutex::new(BTreeMap::new()),
            })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }

        /// Load + compile an artifact (cached).
        pub fn load(
            &self,
            registry: &Registry,
            meta: &ArtifactMeta,
        ) -> Result<Arc<VSampleExecutable>> {
            if let Some(exe) = self.cache.lock().unwrap().get(&meta.name) {
                return Ok(Arc::clone(exe));
            }
            let path = registry.hlo_path(meta);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
            )
            .map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(xerr)?;
            let tables = registry.tables_for(meta)?;
            let built = Arc::new(VSampleExecutable {
                exe,
                meta: meta.clone(),
                tables,
            });
            self.cache
                .lock()
                .unwrap()
                .insert(meta.name.clone(), Arc::clone(&built));
            Ok(built)
        }
    }

    /// A compiled V-Sample pass for one (integrand, layout, variant).
    pub struct VSampleExecutable {
        exe: xla::PjRtLoadedExecutable,
        meta: ArtifactMeta,
        /// Runtime tables for stateful integrands (row-major), if any.
        tables: Option<Vec<f64>>,
    }

    impl VSampleExecutable {
        pub fn meta(&self) -> &ArtifactMeta {
            &self.meta
        }

        /// Execute one iteration. `bins` must match the artifact's (d, nb).
        ///
        /// Returns the iteration result and the bin-contribution histogram
        /// (row-major d*nb) for adjust-variant artifacts, `None` otherwise.
        pub fn vsample(
            &self,
            bins: &Bins,
            seed: u32,
            iteration: u32,
        ) -> Result<(IterationResult, Option<Vec<f64>>)> {
            let d = self.meta.dim;
            let nb = self.meta.nb;
            if bins.d() != d || bins.nb() != nb {
                return Err(Error::Config(format!(
                    "bins shape ({}, {}) != artifact ({d}, {nb})",
                    bins.d(),
                    bins.nb()
                )));
            }
            let bins_lit = xla::Literal::vec1(bins.flat())
                .reshape(&[d as i64, nb as i64])
                .map_err(xerr)?;
            let lo_lit = xla::Literal::vec1(&vec![self.meta.lo; d]);
            let hi_lit = xla::Literal::vec1(&vec![self.meta.hi; d]);
            let seed_lit = xla::Literal::vec1(&[seed, iteration]);

            let mut args = vec![bins_lit, lo_lit, hi_lit, seed_lit];
            if let Some(t) = &self.tables {
                args.push(
                    xla::Literal::vec1(t)
                        .reshape(&[self.meta.n_tables as i64, self.meta.table_knots as i64])
                        .map_err(xerr)?,
                );
            }

            let result = self.exe.execute::<xla::Literal>(&args).map_err(xerr)?[0][0]
                .to_literal_sync()
                .map_err(xerr)?;
            let parts = result.to_tuple().map_err(xerr)?;
            if parts.is_empty() {
                return Err(Error::Runtime("empty result tuple".into()));
            }
            let res = parts[0].to_vec::<f64>().map_err(xerr)?;
            if res.len() != 2 {
                return Err(Error::Runtime(format!("res len {} != 2", res.len())));
            }
            let contrib = if self.meta.adjust {
                let c = parts
                    .get(1)
                    .ok_or_else(|| Error::Runtime("missing contrib output".into()))?
                    .to_vec::<f64>()
                    .map_err(xerr)?;
                if c.len() != d * nb {
                    return Err(Error::Runtime(format!(
                        "contrib len {} != {}",
                        c.len(),
                        d * nb
                    )));
                }
                Some(c)
            } else {
                None
            };
            Ok((
                IterationResult {
                    integral: res[0],
                    variance: res[1],
                },
                contrib,
            ))
        }
    }
}

#[cfg(not(all(feature = "pjrt", xla_runtime)))]
mod imp {
    use crate::error::{Error, Result};
    use crate::estimator::IterationResult;
    use crate::grid::Bins;
    use crate::runtime::registry::{ArtifactMeta, Registry};
    use std::sync::Arc;

    fn unavailable() -> Error {
        Error::Runtime(
            "PJRT backend not compiled in: rebuild with `--features pjrt`, \
             a vendored `xla` crate, and RUSTFLAGS=\"--cfg xla_runtime\" \
             (the native engine serves every workload without it)"
                .into(),
        )
    }

    /// Offline stub: same surface as the real runtime, always
    /// unavailable.
    pub struct PjrtRuntime {
        _priv: (),
    }

    impl PjrtRuntime {
        /// Always fails in the stub build; callers fall back to native.
        pub fn cpu() -> Result<PjrtRuntime> {
            Err(unavailable())
        }

        pub fn platform_name(&self) -> String {
            "unavailable".to_string()
        }

        pub fn device_count(&self) -> usize {
            0
        }

        pub fn load(
            &self,
            _registry: &Registry,
            _meta: &ArtifactMeta,
        ) -> Result<Arc<VSampleExecutable>> {
            Err(unavailable())
        }
    }

    /// Stub executable — never constructed (loading always fails), but
    /// the type must exist so signatures match the real runtime.
    pub struct VSampleExecutable {
        meta: ArtifactMeta,
    }

    impl VSampleExecutable {
        pub fn meta(&self) -> &ArtifactMeta {
            &self.meta
        }

        pub fn vsample(
            &self,
            _bins: &Bins,
            _seed: u32,
            _iteration: u32,
        ) -> Result<(IterationResult, Option<Vec<f64>>)> {
            Err(unavailable())
        }
    }
}

pub use imp::{PjrtRuntime, VSampleExecutable};

#[cfg(all(test, not(all(feature = "pjrt", xla_runtime))))]
mod tests {
    use super::PjrtRuntime;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjrtRuntime::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT backend not compiled in"));
    }
}
