//! PJRT runtime: load AOT artifacts (HLO text + manifest) and execute
//! them from the Rust request path. Python is never involved here.

mod pjrt;
mod registry;

pub use pjrt::{PjrtRuntime, VSampleExecutable};
pub use registry::{ArtifactMeta, Registry};

/// Default artifacts directory (relative to the repo root / cwd).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
