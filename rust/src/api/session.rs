//! Pull-based, resumable integration runs.
//!
//! A [`Session`] is one in-flight m-Cubes run turned inside out:
//! instead of handing the driver a callback and blocking until it
//! finishes, the caller *pulls* — [`Session::step`] advances exactly
//! one iteration and returns a typed [`Iteration`] snapshot, and
//! [`Session::finish`] drains whatever is left. Between steps the
//! caller may inspect the running estimate, abort, interleave other
//! sessions (the scheduler does exactly that), or [`Session::suspend`]
//! the run into a [`Checkpoint`] — a superset of `GridState` carrying
//! the importance grid, the VEGAS+ stratification snapshot, the
//! weighted-estimator sums, and the RNG cursor — which
//! [`Session::resume`] restores **bitwise**: a suspended-and-resumed
//! run produces exactly the estimates the uninterrupted run would
//! have (property-tested on both engines).
//!
//! ```
//! use mcubes::prelude::*;
//!
//! let f = mcubes::integrands::by_name("f3", 3)?;
//! let mut cfg = JobConfig::default();
//! cfg.maxcalls = 1 << 12;
//! cfg.plan = RunPlan::classic(8, 5, 1);
//! cfg.seed = 7;
//!
//! let mut session = Session::new(f, cfg)?;
//! while let Some(it) = session.step()? {
//!     // Inspect (or persist) mid-run state between iterations.
//!     if it.index == 2 {
//!         let checkpoint = session.suspend();
//!         assert_eq!(checkpoint.iteration(), 3); // 3 iterations done
//!     }
//! }
//! let outcome = session.finish()?;
//! assert!(outcome.output.calls_used > 0);
//! # Ok::<(), mcubes::Error>(())
//! ```

use super::grid_state::{GridState, StratSnapshot};
use super::observer::IterationEvent;
use crate::coordinator::{
    DriveOutcome, EngineBackend, JobConfig, SessionCore, StepRecord, VSampleBackend,
};
use crate::error::{Error, Result};
use crate::estimator::{EstimatorState, IterationResult};
use crate::integrands::IntegrandRef;
use crate::shard::{ShardStats, ShardedBackend, SpoolOptions, SpoolTransport};
use crate::strat::{AllocStats, Layout, Sampling};
use crate::util::json::{ObjBuilder, Value};
use std::path::Path;
use std::time::Instant;

/// Why a run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StopReason {
    /// The convergence policy (tau target + chi^2 guard) was met.
    Converged,
    /// The run plan ran out of iterations before converging.
    Exhausted,
    /// `JobConfig::max_total_calls` was reached.
    TargetCallsReached,
    /// An observer returned `ObserverControl::Abort` (or the session
    /// was aborted between steps).
    ObserverAbort,
}

impl StopReason {
    /// Stable label (used in checkpoint JSON and reports).
    pub fn as_str(self) -> &'static str {
        match self {
            StopReason::Converged => "converged",
            StopReason::Exhausted => "exhausted",
            StopReason::TargetCallsReached => "target_calls_reached",
            StopReason::ObserverAbort => "observer_abort",
        }
    }

    /// Inverse of [`StopReason::as_str`] (manifest/checkpoint readers).
    pub(crate) fn from_label(s: &str) -> Option<StopReason> {
        Some(match s {
            "converged" => StopReason::Converged,
            "exhausted" => StopReason::Exhausted,
            "target_calls_reached" => StopReason::TargetCallsReached,
            "observer_abort" => StopReason::ObserverAbort,
            _ => return None,
        })
    }
}

/// Owned snapshot of one completed session iteration — what
/// [`Session::step`] returns. The borrowing twin delivered to
/// observers is `api::IterationEvent`.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct Iteration {
    /// 0-based global iteration index (also the RNG stream cursor).
    pub index: usize,
    /// Index of the run-plan stage this iteration belongs to.
    pub stage: usize,
    /// Label of that stage ("adapt", "sample", "+discard" suffix).
    pub stage_label: String,
    /// Whether the importance grid was adjusted this iteration.
    pub adjusting: bool,
    /// Whether this iteration was excluded from the weighted estimate.
    pub discarded: bool,
    /// Raw estimate of this iteration alone.
    pub estimate: IterationResult,
    /// Running weighted integral (empty-estimator sentinel 0.0 during
    /// discarded warm-up).
    pub integral: f64,
    /// Running combined sigma (infinite until the first fold).
    pub sigma: f64,
    /// Running chi^2 per degree of freedom.
    pub chi2_dof: f64,
    /// Running relative error (infinite until the first fold).
    pub rel_err: f64,
    /// Total integrand evaluations consumed so far.
    pub calls_used: usize,
    /// The chi^2 guard reset the estimator this iteration.
    pub estimator_reset: bool,
    /// Per-cube allocation stats (VEGAS+ stages only).
    pub alloc: Option<AllocStats>,
    /// `Some` when this was the final iteration.
    pub stop: Option<StopReason>,
}

impl Iteration {
    /// Convergence was declared on this iteration.
    pub fn converged(&self) -> bool {
        self.stop == Some(StopReason::Converged)
    }
}

/// A suspended run: everything needed to continue bit-identically —
/// the adapted importance grid, the VEGAS+ stratification snapshot
/// (when present), the weighted-estimator sums, and the plan/RNG
/// cursor. Serializes as a superset of the `GridState` JSON schema,
/// so plain grid files (including pre-checkpoint ones) load as
/// fresh-start checkpoints and a checkpoint file still works anywhere
/// a grid warm start is accepted.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    grid: GridState,
    est: EstimatorState,
    iteration: usize,
    stage: usize,
    stage_iter: usize,
    calls_used: usize,
    /// `Some` when the session had already ended when it was
    /// suspended — resuming restores the finished state instead of
    /// silently un-finishing the run.
    stop: Option<StopReason>,
}

impl Checkpoint {
    /// Newest checkpoint JSON schema this build writes and reads.
    /// Files carry it as a top-level `"schema_version"` field; files
    /// without one (written before the field existed) are read as
    /// version 1, whose layout is frozen — see the
    /// `checkpoint_v1_fixture_loads_forever` test.
    pub const SCHEMA_VERSION: usize = 1;

    /// A fresh-start checkpoint from a bare grid — this is exactly how
    /// grid warm starts are represented internally.
    pub fn from_grid(grid: GridState) -> Checkpoint {
        Checkpoint {
            grid,
            est: EstimatorState::default(),
            iteration: 0,
            stage: 0,
            stage_iter: 0,
            calls_used: 0,
            stop: None,
        }
    }

    /// Why the run had ended at suspension time, if it had.
    pub fn stop(&self) -> Option<StopReason> {
        self.stop
    }

    /// The importance grid (plus VEGAS+ snapshot, when present).
    pub fn grid(&self) -> &GridState {
        &self.grid
    }

    /// The weighted-estimator sums at suspension time.
    pub fn estimator(&self) -> EstimatorState {
        self.est
    }

    /// Completed iterations (equals the next RNG stream index).
    pub fn iteration(&self) -> usize {
        self.iteration
    }

    /// Run-plan stage the cursor sits in.
    pub fn stage(&self) -> usize {
        self.stage
    }

    /// Completed iterations within that stage.
    pub fn stage_iter(&self) -> usize {
        self.stage_iter
    }

    /// Total integrand evaluations consumed so far.
    pub fn calls_used(&self) -> usize {
        self.calls_used
    }

    /// Serialize (JSON value): the `GridState` schema plus a
    /// `"session"` object with the cursor and estimator sums.
    pub fn to_json(&self) -> Value {
        let mut v = self.grid.to_json();
        if let Value::Obj(fields) = &mut v {
            fields.insert(
                0,
                (
                    "schema_version".to_string(),
                    Value::from(Checkpoint::SCHEMA_VERSION),
                ),
            );
            let est = ObjBuilder::new()
                .field("sum_w", self.est.sum_w)
                .field("sum_wi", self.est.sum_wi)
                .field("sum_wi2", self.est.sum_wi2)
                .field("n", self.est.n)
                .build();
            let mut session = ObjBuilder::new()
                .field("iteration", self.iteration)
                .field("stage", self.stage)
                .field("stage_iter", self.stage_iter)
                .field("calls_used", self.calls_used)
                .field("estimator", est);
            if let Some(stop) = self.stop {
                session = session.field("stop", stop.as_str());
            }
            fields.push(("session".to_string(), session.build()));
        }
        v
    }

    /// Restore from `to_json` output. A value without a `"session"`
    /// field (any grid file, old or new) loads as a fresh-start
    /// checkpoint.
    pub fn from_json(v: &Value) -> Result<Checkpoint> {
        // Version gate first: reject files from a future layout before
        // touching any field (a v2 writer may have changed all of
        // them). Absent field = version 1, so every pre-field file —
        // and every bare grid file — keeps loading.
        if let Some(ver) = v.get("schema_version") {
            let ver = ver.as_usize().ok_or_else(|| {
                Error::Manifest("checkpoint schema_version must be a non-negative integer".into())
            })?;
            if ver > Checkpoint::SCHEMA_VERSION {
                return Err(Error::Manifest(format!(
                    "checkpoint schema_version {ver} is newer than supported {}",
                    Checkpoint::SCHEMA_VERSION
                )));
            }
        }
        let grid = GridState::from_json(v)?;
        let Some(session) = v.get("session") else {
            return Ok(Checkpoint::from_grid(grid));
        };
        let usz = |key: &str| -> Result<usize> {
            session
                .req(key)?
                .as_usize()
                .ok_or_else(|| Error::Manifest(format!("checkpoint session field `{key}`")))
        };
        let est_v = session.req("estimator")?;
        let num = |key: &str| -> Result<f64> {
            est_v
                .req(key)?
                .as_f64()
                .ok_or_else(|| Error::Manifest(format!("checkpoint estimator field `{key}`")))
        };
        let est = EstimatorState {
            sum_w: num("sum_w")?,
            sum_wi: num("sum_wi")?,
            sum_wi2: num("sum_wi2")?,
            n: est_v
                .req("n")?
                .as_usize()
                .ok_or_else(|| Error::Manifest("checkpoint estimator field `n`".into()))?,
        };
        est.validate().map_err(|e| {
            Error::Manifest(format!("checkpoint estimator: {e}"))
        })?;
        let stop = match session.get("stop") {
            None => None,
            Some(v) => {
                let label = v
                    .as_str()
                    .ok_or_else(|| Error::Manifest("checkpoint stop label".into()))?;
                Some(StopReason::from_label(label).ok_or_else(|| {
                    Error::Manifest(format!("unknown checkpoint stop reason `{label}`"))
                })?)
            }
        };
        Ok(Checkpoint {
            grid,
            est,
            iteration: usz("iteration")?,
            stage: usz("stage")?,
            stage_iter: usz("stage_iter")?,
            calls_used: usz("calls_used")?,
            stop,
        })
    }

    /// Save to a JSON file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_json())?;
        Ok(())
    }

    /// Load from a file written by `save` (or any grid file).
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)?;
        Checkpoint::from_json(&crate::util::json::parse(&text)?)
    }
}

/// A resumable native-engine integration run (see the module docs).
///
/// Sessions are `Send`: the scheduler moves paused sessions between
/// worker threads, and because the engine's reduction is bitwise
/// thread-count-invariant, *where* a session is stepped never changes
/// its numbers.
pub struct Session {
    f: IntegrandRef,
    cfg: JobConfig,
    /// Per-stage layouts, resolved and validated at construction.
    layouts: Vec<Layout>,
    core: SessionCore,
    /// The backend serving the current stage; rebuilt lazily after
    /// stage boundaries (per-stage calls/sampling may re-layout).
    backend: Option<Box<dyn VSampleBackend + Send>>,
    backend_label: &'static str,
    /// Stratification state carried across stage boundaries and
    /// checkpoint restores, consumed by the next VEGAS+ backend build.
    pending_strat: Option<StratSnapshot>,
    /// Shard accounting folded from backends retired at stage
    /// boundaries (`Session::shard_stats` adds the live backend's).
    shard_stats_acc: ShardStats,
    /// Accumulated wall time actually spent inside `step` (seconds).
    active_time: f64,
}

impl Session {
    /// Start a fresh run of `f` under `cfg` (validated eagerly).
    pub fn new(f: IntegrandRef, cfg: JobConfig) -> Result<Session> {
        let core = SessionCore::new(&cfg, f.dim(), cfg.nb, None)?;
        Session::build(f, cfg, core, None)
    }

    /// Restore a suspended run. For bitwise continuation the caller
    /// must pass the same integrand and config the suspended session
    /// ran with; the grid/plan shape is validated, the integrand's
    /// math is trusted.
    pub fn resume(f: IntegrandRef, cfg: JobConfig, checkpoint: &Checkpoint) -> Result<Session> {
        let core = SessionCore::restore(
            &cfg,
            f.dim(),
            cfg.nb,
            checkpoint.grid(),
            checkpoint.estimator(),
            checkpoint.stage(),
            checkpoint.stage_iter(),
            checkpoint.iteration(),
            checkpoint.calls_used(),
            checkpoint.stop(),
        )?;
        Session::build(f, cfg, core, checkpoint.grid().strat().cloned())
    }

    fn build(
        f: IntegrandRef,
        cfg: JobConfig,
        core: SessionCore,
        pending_strat: Option<StratSnapshot>,
    ) -> Result<Session> {
        // Resolve every stage's layout now so a bad per-stage calls
        // override fails at construction, not three stages in.
        let mut layouts = Vec::with_capacity(core.stages().len());
        for stage in core.stages() {
            layouts.push(Layout::compute(f.dim(), stage.calls, cfg.nb, cfg.nblocks)?);
        }
        Ok(Session {
            f,
            cfg,
            layouts,
            core,
            backend: None,
            backend_label: "native",
            pending_strat,
            shard_stats_acc: ShardStats::default(),
            active_time: 0.0,
        })
    }

    /// Build (or rebuild) the backend for the current stage.
    fn ensure_backend(&mut self) -> Result<()> {
        if self.backend.is_some() {
            return Ok(());
        }
        let idx = self.core.stage_idx();
        let stage = &self.core.stages()[idx];
        let layout = self.layouts[idx];
        let backend: Box<dyn VSampleBackend + Send> = if self.cfg.shards > 1 {
            // Sharded execution covers both sampling modes with one
            // backend; its merge is bitwise equal to the single-worker
            // backends below (see crate::shard).
            let mut b = ShardedBackend::new(
                self.f.clone(),
                layout,
                self.cfg.shards,
                self.cfg.threads,
                stage.sampling,
                self.pending_strat.as_ref(),
            )?;
            if let Some(dir) = &self.cfg.shard_dir {
                b = b.with_spool(SpoolTransport::open(dir, SpoolOptions::default())?);
            }
            Box::new(b)
        } else {
            match stage.sampling {
                Sampling::Uniform => Box::new(
                    EngineBackend::uniform(self.f.clone(), layout, self.cfg.threads)
                        .with_exec(self.cfg.exec),
                ),
                Sampling::VegasPlus { beta } => Box::new(
                    EngineBackend::vegas_plus(
                        self.f.clone(),
                        layout,
                        self.cfg.threads,
                        beta,
                        self.pending_strat.as_ref(),
                    )?
                    .with_exec(self.cfg.exec),
                ),
            }
        };
        self.backend_label = backend.name();
        self.backend = Some(backend);
        Ok(())
    }

    /// Advance exactly one iteration. Returns the iteration snapshot,
    /// or `None` once the run has ended (check [`Session::stop_reason`]).
    pub fn step(&mut self) -> Result<Option<Iteration>> {
        if self.core.finished() {
            return Ok(None);
        }
        let t0 = Instant::now();
        self.ensure_backend()?;
        let rec = {
            // lint:allow(MC005, ensure_backend() on the previous line guarantees Some)
            let backend = self.backend.as_deref_mut().expect("backend just ensured");
            self.core.step(backend, &self.cfg)?
        };
        if rec.stage_changed {
            // Stage boundary: retire the backend, carrying its
            // stratification state into the next build.
            if let Some(retired) = self.backend.take() {
                if let Some(snap) = retired.strat_export() {
                    self.pending_strat = Some(snap);
                }
                if let Some(stats) = retired.shard_stats() {
                    self.shard_stats_acc.absorb(stats);
                }
            }
        }
        self.active_time += t0.elapsed().as_secs_f64();
        Ok(Some(self.iteration_from(&rec)))
    }

    fn iteration_from(&self, rec: &StepRecord) -> Iteration {
        Iteration {
            index: rec.index,
            stage: rec.stage,
            stage_label: self.core.stages()[rec.stage].label.clone(),
            adjusting: rec.adapting,
            discarded: rec.discarded,
            estimate: rec.estimate,
            integral: rec.integral,
            sigma: rec.sigma,
            chi2_dof: rec.chi2_dof,
            rel_err: rec.rel_err,
            calls_used: rec.calls_used,
            estimator_reset: rec.estimator_reset,
            alloc: rec.alloc,
            stop: rec.stop,
        }
    }

    /// The borrowing observer event for an iteration this session just
    /// produced (used by the facade's observer fan-out).
    pub(crate) fn event<'s>(&'s self, it: &'s Iteration) -> IterationEvent<'s> {
        IterationEvent {
            iteration: it.index,
            stage: it.stage,
            stage_label: &it.stage_label,
            adjusting: it.adjusting,
            discarded: it.discarded,
            estimate: it.estimate,
            integral: it.integral,
            sigma: it.sigma,
            chi2_dof: it.chi2_dof,
            rel_err: it.rel_err,
            calls_used: it.calls_used,
            estimator_reset: it.estimator_reset,
            converged: it.converged(),
            stop: it.stop,
            alloc: it.alloc,
            grid: self.core.bins(),
        }
    }

    /// Drain any remaining iterations and assemble the final outcome.
    pub fn finish(mut self) -> Result<DriveOutcome> {
        while self.step()?.is_some() {}
        let strat = self.current_strat();
        Ok(self
            .core
            .into_outcome(self.backend_label, strat, self.active_time))
    }

    /// Export the complete run state for a later [`Session::resume`].
    /// Valid at any point: before the first step it degenerates to a
    /// grid warm start, and after the run has ended the checkpoint
    /// remembers the [`StopReason`] (resuming restores the finished
    /// state instead of running extra iterations).
    pub fn suspend(&self) -> Checkpoint {
        Checkpoint {
            grid: self.grid(),
            est: self.core.estimator_state(),
            iteration: self.core.iteration(),
            stage: self.core.stage_idx(),
            stage_iter: self.core.stage_iter(),
            calls_used: self.core.calls_used(),
            stop: self.core.stop(),
        }
    }

    fn current_strat(&self) -> Option<StratSnapshot> {
        self.backend
            .as_ref()
            .and_then(|b| b.strat_export())
            .or_else(|| self.pending_strat.clone())
    }

    /// Cumulative shard-execution accounting (zeroed default when the
    /// run is not sharded): stage-retired backends plus the live one.
    pub fn shard_stats(&self) -> ShardStats {
        let mut stats = self.shard_stats_acc;
        if let Some(live) = self.backend.as_ref().and_then(|b| b.shard_stats()) {
            stats.absorb(live);
        }
        stats
    }

    /// End the run after the last completed iteration
    /// ([`StopReason::ObserverAbort`]); no-op if already finished.
    pub fn abort(&mut self) {
        self.core.abort();
    }

    /// True once the run has ended (step will return `None`).
    pub fn is_finished(&self) -> bool {
        self.core.finished()
    }

    /// Why the run ended, once it has.
    pub fn stop_reason(&self) -> Option<StopReason> {
        self.core.stop()
    }

    /// Completed iterations so far.
    pub fn iterations(&self) -> usize {
        self.core.iteration()
    }

    /// Total integrand evaluations consumed so far.
    pub fn calls_used(&self) -> usize {
        self.core.calls_used()
    }

    /// Running weighted integral estimate.
    pub fn integral(&self) -> f64 {
        self.core.estimator().integral()
    }

    /// Running combined sigma.
    pub fn sigma(&self) -> f64 {
        self.core.estimator().sigma()
    }

    /// Running chi^2 per degree of freedom.
    pub fn chi2_dof(&self) -> f64 {
        self.core.estimator().chi2_dof()
    }

    /// Running relative error.
    pub fn rel_err(&self) -> f64 {
        self.core.estimator().rel_err()
    }

    /// The current adapted grid (with VEGAS+ snapshot when present) —
    /// the same grid [`Session::suspend`] embeds.
    pub fn grid(&self) -> GridState {
        let mut grid = GridState::from_bins(self.core.bins().clone());
        if let Some(s) = self.current_strat() {
            grid = grid.with_strat(s);
        }
        grid
    }

    /// The configuration this session runs under.
    pub fn config(&self) -> &JobConfig {
        &self.cfg
    }
}
