//! Exportable importance-grid state — the warm-start currency of the
//! `Integrator` facade.
//!
//! A `GridState` captures the adapted VEGAS bin boundaries after a run.
//! Re-importing it into a later run (same dimension and bin count; the
//! call budget may differ) skips the adjust phase's warm-up cost — the
//! serving win for repeated similar integrals, escalation ladders, and
//! service jobs.

use crate::error::{Error, Result};
use crate::grid::{Bins, GridMode};
use crate::util::json::Value;
use std::path::Path;

/// An adapted (or uniform) importance grid, detached from any driver.
#[derive(Debug, Clone, PartialEq)]
pub struct GridState {
    bins: Bins,
}

impl GridState {
    /// Capture a grid from raw bin boundaries.
    pub fn from_bins(bins: Bins) -> GridState {
        GridState { bins }
    }

    /// A fresh uniform grid (what a cold start uses internally).
    pub fn uniform(d: usize, nb: usize, mode: GridMode) -> GridState {
        GridState {
            bins: Bins::uniform_mode(d, nb, mode),
        }
    }

    /// Borrow the underlying bin boundaries.
    pub fn bins(&self) -> &Bins {
        &self.bins
    }

    /// Unwrap into the underlying bin boundaries.
    pub fn into_bins(self) -> Bins {
        self.bins
    }

    /// Dimension of the grid.
    pub fn d(&self) -> usize {
        self.bins.d()
    }

    /// Importance bins per axis.
    pub fn nb(&self) -> usize {
        self.bins.nb()
    }

    /// Grid mode the donor run used.
    pub fn mode(&self) -> GridMode {
        self.bins.mode()
    }

    /// Check this grid can seed a job with layout `(d, nb)`.
    pub fn compatible(&self, d: usize, nb: usize) -> Result<()> {
        if self.d() != d || self.nb() != nb {
            return Err(Error::Config(format!(
                "warm-start grid shape (d={}, nb={}) != job layout (d={d}, nb={nb})",
                self.d(),
                self.nb()
            )));
        }
        Ok(())
    }

    /// Serialize (JSON value) — same schema as `Bins::to_json`.
    pub fn to_json(&self) -> Value {
        self.bins.to_json()
    }

    /// Restore from `to_json` output (validates grid invariants).
    pub fn from_json(v: &Value) -> Result<GridState> {
        Ok(GridState {
            bins: Bins::from_json(v)?,
        })
    }

    /// Save to a JSON file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        self.bins.save(path)
    }

    /// Load from a file written by `save`.
    pub fn load(path: impl AsRef<Path>) -> Result<GridState> {
        Ok(GridState {
            bins: Bins::load(path)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_preserves_grid() {
        let mut bins = Bins::uniform(3, 12);
        let mut contrib = vec![1.0; 36];
        contrib[2] = 50.0;
        bins.adjust(&contrib);
        let gs = GridState::from_bins(bins);
        let back = GridState::from_json(&gs.to_json()).unwrap();
        assert_eq!(back, gs);
        assert_eq!(back.d(), 3);
        assert_eq!(back.nb(), 12);
    }

    #[test]
    fn compatibility_is_checked() {
        let gs = GridState::uniform(4, 50, GridMode::PerAxis);
        assert!(gs.compatible(4, 50).is_ok());
        assert!(gs.compatible(4, 32).is_err());
        assert!(gs.compatible(3, 50).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let gs = GridState::uniform(2, 8, GridMode::Shared1D);
        let path = std::env::temp_dir().join("mcubes_grid_state_test.json");
        gs.save(&path).unwrap();
        let back = GridState::load(&path).unwrap();
        assert_eq!(back, gs);
        assert_eq!(back.mode(), GridMode::Shared1D);
        let _ = std::fs::remove_file(path);
    }
}
