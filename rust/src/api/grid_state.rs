//! Exportable importance-grid + stratification state — the warm-start
//! currency of the `Integrator` facade.
//!
//! A `GridState` captures the adapted VEGAS bin boundaries after a run
//! and, for `Sampling::VegasPlus` runs, a [`StratSnapshot`] of the
//! per-cube sample allocation (counts + damped variance accumulator).
//! Re-importing it into a later run (same dimension and bin count; the
//! call budget may differ) skips the adjust phase's warm-up — the
//! serving win for repeated similar integrals, escalation ladders, and
//! service jobs. A matching-layout VEGAS+ run additionally resumes the
//! adaptive allocation instead of re-learning it from uniform counts.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use crate::error::{Error, Result};
use crate::grid::{Bins, GridMode};
use crate::strat::Allocation;
use crate::util::json::{ObjBuilder, Value};
use std::path::Path;

/// Snapshot of a VEGAS+ run's per-cube allocation state, carried
/// alongside the importance grid so warm starts resume the adaptive
/// stratification (see `crate::strat::Allocation`).
///
/// The snapshot is layout-specific: `counts.len()` is the donor
/// layout's cube count `m`. A warm-started run whose layout has a
/// different `m` (different `maxcalls`, e.g. an escalation level)
/// keeps the grid but starts from a fresh uniform allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct StratSnapshot {
    /// Redistribution exponent the donor ran with.
    pub beta: f64,
    /// Per-cube sample counts of the donor's final allocation.
    pub counts: Vec<u32>,
    /// Damped per-cube variance accumulator (`d_k`).
    pub damped: Vec<f64>,
}

impl StratSnapshot {
    fn to_json(&self) -> Value {
        ObjBuilder::new()
            .field("beta", self.beta)
            .field(
                "counts",
                self.counts.iter().map(|&c| c as usize).collect::<Vec<_>>(),
            )
            .field("damped", self.damped.clone())
            .build()
    }

    fn from_json(v: &Value) -> Result<StratSnapshot> {
        let beta = v
            .req("beta")?
            .as_f64()
            .ok_or_else(|| Error::Manifest("strat beta".into()))?;
        // Mirror `Sampling::validate`: a grid file must not smuggle in
        // a beta the config layer would reject.
        if !beta.is_finite() || !(0.0..=1.0).contains(&beta) {
            return Err(Error::Manifest(format!(
                "strat beta must lie in [0, 1], got {beta}"
            )));
        }
        let counts_raw = v
            .req("counts")?
            .as_f64_vec()
            .ok_or_else(|| Error::Manifest("strat counts".into()))?;
        let mut counts = Vec::with_capacity(counts_raw.len());
        for c in counts_raw {
            // JSON-level shape only (integral, fits u32); the
            // allocation invariants are checked once, below.
            if c.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&c) {
                return Err(Error::Manifest(format!("bad strat count {c}")));
            }
            counts.push(c as u32);
        }
        let damped = v
            .req("damped")?
            .as_f64_vec()
            .ok_or_else(|| Error::Manifest("strat damped".into()))?;
        // Single source of truth for the allocation invariants (shape,
        // per-cube floor, finite non-negative accumulator).
        let alloc = Allocation::from_parts(counts, damped)
            .map_err(|e| Error::Manifest(format!("strat snapshot: {e}")))?;
        Ok(StratSnapshot {
            beta,
            counts: alloc.counts().to_vec(),
            damped: alloc.damped().to_vec(),
        })
    }
}

/// An adapted (or uniform) importance grid, detached from any driver,
/// optionally carrying VEGAS+ stratification state.
#[derive(Debug, Clone, PartialEq)]
pub struct GridState {
    bins: Bins,
    strat: Option<StratSnapshot>,
}

impl GridState {
    /// Capture a grid from raw bin boundaries (no stratification
    /// state).
    pub fn from_bins(bins: Bins) -> GridState {
        GridState { bins, strat: None }
    }

    /// A fresh uniform grid (what a cold start uses internally).
    pub fn uniform(d: usize, nb: usize, mode: GridMode) -> GridState {
        GridState {
            bins: Bins::uniform_mode(d, nb, mode),
            strat: None,
        }
    }

    /// Attach a VEGAS+ stratification snapshot (builder style).
    pub fn with_strat(mut self, strat: StratSnapshot) -> GridState {
        self.strat = Some(strat);
        self
    }

    /// The VEGAS+ stratification snapshot, when the donor ran with
    /// `Sampling::VegasPlus`.
    pub fn strat(&self) -> Option<&StratSnapshot> {
        self.strat.as_ref()
    }

    /// Drop the stratification snapshot, keeping only the grid.
    pub fn without_strat(mut self) -> GridState {
        self.strat = None;
        self
    }

    /// Borrow the underlying bin boundaries.
    pub fn bins(&self) -> &Bins {
        &self.bins
    }

    /// Unwrap into the underlying bin boundaries.
    pub fn into_bins(self) -> Bins {
        self.bins
    }

    /// Dimension of the grid.
    pub fn d(&self) -> usize {
        self.bins.d()
    }

    /// Importance bins per axis.
    pub fn nb(&self) -> usize {
        self.bins.nb()
    }

    /// Grid mode the donor run used.
    pub fn mode(&self) -> GridMode {
        self.bins.mode()
    }

    /// Check this grid can seed a job with layout `(d, nb)`.
    pub fn compatible(&self, d: usize, nb: usize) -> Result<()> {
        if self.d() != d || self.nb() != nb {
            return Err(Error::Config(format!(
                "warm-start grid shape (d={}, nb={}) != job layout (d={d}, nb={nb})",
                self.d(),
                self.nb()
            )));
        }
        Ok(())
    }

    /// Serialize (JSON value) — the `Bins::to_json` schema plus an
    /// optional `strat` object, so grids saved before the VEGAS+
    /// extension still load.
    pub fn to_json(&self) -> Value {
        let mut v = self.bins.to_json();
        if let (Value::Obj(fields), Some(s)) = (&mut v, &self.strat) {
            fields.push(("strat".to_string(), s.to_json()));
        }
        v
    }

    /// Restore from `to_json` output (validates grid + strat
    /// invariants; the `strat` field is optional).
    pub fn from_json(v: &Value) -> Result<GridState> {
        let bins = Bins::from_json(v)?;
        let strat = match v.get("strat") {
            Some(sv) => Some(StratSnapshot::from_json(sv)?),
            None => None,
        };
        Ok(GridState { bins, strat })
    }

    /// Save to a JSON file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_json())?;
        Ok(())
    }

    /// Load from a file written by `save` (or a bare `Bins` file).
    pub fn load(path: impl AsRef<Path>) -> Result<GridState> {
        let text = std::fs::read_to_string(path)?;
        GridState::from_json(&crate::util::json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_preserves_grid() {
        let mut bins = Bins::uniform(3, 12);
        let mut contrib = vec![1.0; 36];
        contrib[2] = 50.0;
        bins.adjust(&contrib);
        let gs = GridState::from_bins(bins);
        let back = GridState::from_json(&gs.to_json()).unwrap();
        assert_eq!(back, gs);
        assert_eq!(back.d(), 3);
        assert_eq!(back.nb(), 12);
        assert!(back.strat().is_none());
    }

    #[test]
    fn json_roundtrip_preserves_strat_snapshot() {
        let gs = GridState::uniform(2, 8, GridMode::PerAxis).with_strat(StratSnapshot {
            beta: 0.75,
            counts: vec![2, 7, 3, 4],
            damped: vec![0.0, 1.5, 0.25, 1e-9],
        });
        let back = GridState::from_json(&gs.to_json()).unwrap();
        assert_eq!(back, gs);
        let s = back.strat().unwrap();
        assert_eq!(s.beta, 0.75);
        assert_eq!(s.counts, vec![2, 7, 3, 4]);
        assert_eq!(back.clone().without_strat().strat(), None);
    }

    #[test]
    fn strat_snapshot_rejects_corrupt_fields() {
        let bad = [
            // count below the floor
            r#"{"beta": 0.75, "counts": [1, 4], "damped": [0.0, 0.0]}"#,
            // fractional count
            r#"{"beta": 0.75, "counts": [2.5, 4], "damped": [0.0, 0.0]}"#,
            // shape mismatch
            r#"{"beta": 0.75, "counts": [2, 4], "damped": [0.0]}"#,
            // negative accumulator
            r#"{"beta": 0.75, "counts": [2, 4], "damped": [0.0, -1.0]}"#,
            // beta outside [0, 1] / non-finite (JSON null)
            r#"{"beta": 1.5, "counts": [2, 4], "damped": [0.0, 0.0]}"#,
            r#"{"beta": -0.25, "counts": [2, 4], "damped": [0.0, 0.0]}"#,
            r#"{"beta": null, "counts": [2, 4], "damped": [0.0, 0.0]}"#,
        ];
        for s in bad {
            let v = crate::util::json::parse(s).unwrap();
            assert!(StratSnapshot::from_json(&v).is_err(), "{s}");
        }
    }

    #[test]
    fn compatibility_is_checked() {
        let gs = GridState::uniform(4, 50, GridMode::PerAxis);
        assert!(gs.compatible(4, 50).is_ok());
        assert!(gs.compatible(4, 32).is_err());
        assert!(gs.compatible(3, 50).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let gs = GridState::uniform(2, 8, GridMode::Shared1D).with_strat(StratSnapshot {
            beta: 0.5,
            counts: vec![3, 2],
            damped: vec![0.125, 0.0],
        });
        let path = std::env::temp_dir().join("mcubes_grid_state_test.json");
        gs.save(&path).unwrap();
        let back = GridState::load(&path).unwrap();
        assert_eq!(back, gs);
        assert_eq!(back.mode(), GridMode::Shared1D);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn loads_pre_strat_grid_files() {
        // A file written by the pre-VEGAS+ GridState (bare Bins
        // schema) must still load, with no stratification state.
        let bins = Bins::uniform(2, 4);
        let path = std::env::temp_dir().join("mcubes_grid_state_legacy.json");
        bins.save(&path).unwrap();
        let back = GridState::load(&path).unwrap();
        assert_eq!(back.bins(), &bins);
        assert!(back.strat().is_none());
        let _ = std::fs::remove_file(path);
    }
}
