//! The unified integration facade.
//!
//! One entry point — [`Integrator`] — subsumes the seed's scattered
//! free functions (`integrate_native`, `integrate_native_adaptive`,
//! `run_driver`, `run_driver_traced`), which survive only as deprecated
//! shims. The facade adds what they couldn't express:
//!
//! * **Closure integrands** — [`FnIntegrand`] adapts any
//!   `Fn(&[f64]) -> f64` into the [`crate::integrands::Integrand`]
//!   trait; no registry entry needed.
//! * **Per-axis bounds** — [`crate::strat::Bounds`] generalizes the
//!   uniform `[lo, hi]^d` box to an arbitrary axis-aligned box, mapped
//!   affinely from the unit hypercube inside the engine hot loop.
//! * **Grid warm-start** — [`GridState`] exports the adapted VEGAS
//!   importance grid from one run and seeds the next (runs, escalation
//!   levels, service jobs), skipping the adjust phase's warm-up.
//! * **Observer hooks** — [`IterationEvent`] callbacks replace the
//!   ad-hoc `DriverOutput` trace with structured per-iteration
//!   telemetry.
//! * **Backend selection** — [`BackendSpec`] picks the native engine
//!   or the AOT-Pallas/PJRT artifact runtime behind the same builder.

mod grid_state;
mod integrand;
mod integrator;
mod observer;

pub use grid_state::GridState;
pub use integrand::{FnIntegrand, IntegrandSpec};
pub use integrator::{BackendSpec, Integrator};
pub use observer::IterationEvent;

// Re-export the bounds type here too: it is the facade's vocabulary for
// "where to integrate", even though it lives with the layout math.
pub use crate::strat::Bounds;
