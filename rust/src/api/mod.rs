//! The unified integration facade.
//!
//! One entry point — [`Integrator`] — subsumes the seed's scattered
//! free functions (`integrate_native`, `integrate_native_adaptive`,
//! `run_driver`, `run_driver_traced`), which have now been removed
//! (see the migration table below). The facade adds what they
//! couldn't express:
//!
//! * **Closure integrands** — [`FnIntegrand`] adapts any
//!   `Fn(&[f64]) -> f64` into the [`crate::integrands::Integrand`]
//!   trait; no registry entry needed.
//! * **Per-axis bounds** — [`crate::strat::Bounds`] generalizes the
//!   uniform `[lo, hi]^d` box to an arbitrary axis-aligned box, mapped
//!   affinely from the unit hypercube inside the engine hot loop.
//! * **Grid warm-start** — [`GridState`] exports the adapted VEGAS
//!   importance grid from one run and seeds the next (runs, escalation
//!   levels, service jobs), skipping the adjust phase's warm-up.
//! * **Observer hooks** — [`IterationEvent`] callbacks replace the
//!   ad-hoc `DriverOutput` trace with structured per-iteration
//!   telemetry.
//! * **Backend selection** — [`BackendSpec`] picks the native engine
//!   or the AOT-Pallas/PJRT artifact runtime behind the same builder.
//! * **Batch evaluation** — [`FnBatchIntegrand`] /
//!   [`Integrator::custom_batch`] accept closures over whole
//!   structure-of-arrays [`PointBlock`]s, the same
//!   one-virtual-call-per-block hot path the registry integrands use.
//! * **Sampling strategy** — [`Integrator::sampling`] switches between
//!   the paper's uniform per-cube allocation and VEGAS+ adaptive
//!   stratification ([`Sampling::VegasPlus`]); VEGAS+ runs export
//!   their allocation in [`GridState`] (as a [`StratSnapshot`]) and
//!   report per-iteration [`AllocStats`] through observers.
//! * **Resumable sessions** — [`Session`] turns a run inside out:
//!   [`Session::step`] pulls one iteration at a time ([`Iteration`]
//!   snapshots), [`Session::suspend`] exports a bitwise-resumable
//!   [`Checkpoint`], and every run ends with a typed [`StopReason`].
//! * **Composable plans** — [`RunPlan`] stages ([`Stage`]) replace the
//!   flat `itmax`/`ita`/`skip` knobs; [`RunPlan::classic`] reproduces
//!   them bitwise, [`RunPlan::warmup_then_final`] states the paper's
//!   two-phase workflow directly, and stages may override the call
//!   budget or sampling strategy mid-run (native engine).
//!
//! ## Migration table
//!
//! The deprecated seed-era APIs (last shipped behind the since-removed
//! `legacy-api` cargo feature) are gone. Each maps onto a current call
//! like so:
//!
//! | Removed API | Use instead |
//! |---|---|
//! | `integrate_native(&f, &cfg)` | `Integrator::new(f).config(cfg).run()` (or `Integrator::custom_batch(d, bounds, \|blk, out\| …)?.config(cfg).run()` for the fastest custom-integrand path) |
//! | `integrate_native_adaptive(&f, &cfg, l, k)` | `Integrator::new(f).config(cfg).escalate(l, k).run()` |
//! | `run_driver(&backend, &cfg)` | `coordinator::drive(&mut backend, &cfg, None, None)` |
//! | `run_driver_traced(&backend, &cfg)` | `drive(.., Some(&mut observer))` or `Integrator::observe(..)` |
//! | `DriverOutput` trace rows | [`IterationEvent`] observer callbacks / [`Session::step`] [`Iteration`] snapshots |
//! | `IntegrationService` (alias) | `coordinator::Scheduler` (same type, its real name) |
//! | `engine::vsample_with_fill(..)` | `engine::NativeEngine.vsample_exec(f, &layout, &bins, &opts, fill, exec)` — or build a `crate::engine::UniformEngine` and call `Engine::vsample` |
//! | `engine::vsample_stratified_with_fill(..)` | `crate::engine::VegasPlusEngine` + `Engine::vsample` (one pass incl. reallocation), or `engine::vsample_stratified(..)` for a pass over a caller-owned `Allocation` |
//!
//! Engine construction now goes through the [`crate::engine::Engine`]
//! trait: `EngineBackend::uniform` / `EngineBackend::vegas_plus` (or
//! `EngineBackend::new` over any custom engine) replace the historical
//! `NativeBackend` / `StratifiedBackend` pair.
//!
//! ## `PointBlock` SoA layout contract
//!
//! Batch closures receive points **column-major**: `block.axis(i)` is
//! the contiguous slice of axis-`i` coordinates for all `block.len()`
//! points (there is no per-point row). Write `out[k]` for every point
//! `k`; never apply `block.jacobians()` yourself — the engine folds the
//! VEGAS/box weight in during reduction. See [`crate::engine::block`]
//! for the full contract.

mod grid_state;
mod integrand;
mod integrator;
mod observer;
mod plan;
mod session;

pub use grid_state::{GridState, StratSnapshot};
pub use integrand::{FnBatchIntegrand, FnIntegrand, IntegrandSpec};
pub use integrator::{BackendSpec, Integrator};
pub use observer::{IterationEvent, ObserverControl};
pub use plan::{RunPlan, Stage};
pub use session::{Checkpoint, Iteration, Session, StopReason};

// Re-export the bounds type here too: it is the facade's vocabulary for
// "where to integrate", even though it lives with the layout math.
pub use crate::strat::Bounds;

// Sampling strategy + allocation stats are facade vocabulary as well:
// the builder's `sampling(..)` takes one, observers receive the other.
pub use crate::strat::{AllocStats, Sampling};

// The batch-evaluation vocabulary is part of the facade surface:
// `custom_batch` closures receive a `PointBlock`.
pub use crate::engine::block::PointBlock;
