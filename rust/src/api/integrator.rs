//! The unified `Integrator` facade — one entry point over the native
//! engine and the PJRT artifact runtime.
//!
//! ```no_run
//! use mcubes::prelude::*;
//!
//! // A closure over a non-uniform box: ∫ x·y over [0,2]×[1,3] = 8.
//! let bounds = Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)]).unwrap();
//! let out = Integrator::from_fn(2, bounds, |x| x[0] * x[1])
//!     .unwrap()
//!     .maxcalls(1 << 14)
//!     .tolerance(1e-3)
//!     .run()
//!     .unwrap();
//! println!("I = {} ± {}", out.integral, out.sigma);
//! ```
//!
//! Blocking `run()` is one of two execution styles. The pull-based
//! alternative — [`Integrator::session`] — returns a resumable
//! [`Session`] that advances one iteration per `step()` and can be
//! suspended to a [`Checkpoint`] and resumed bit-identically:
//!
//! ```no_run
//! use mcubes::prelude::*;
//!
//! let mut session = Integrator::from_registry("f4", 5)?
//!     .maxcalls(1 << 14)
//!     .plan(RunPlan::classic(15, 10, 2))
//!     .session()?;
//! while let Some(it) = session.step()? {
//!     eprintln!("it {}: rel {:.2e} [{}]", it.index, it.rel_err, it.stage_label);
//! }
//! let outcome = session.finish()?;
//! println!("I = {}", outcome.output.integral);
//! # Ok::<(), mcubes::Error>(())
//! ```

use super::grid_state::GridState;
use super::integrand::IntegrandSpec;
use super::observer::{IterationEvent, ObserverControl};
use super::plan::RunPlan;
use super::session::{Checkpoint, Session};
use crate::coordinator::{
    drive, escalate_native, integrate_native_core, DriveOutcome, IntegrationOutput, JobConfig,
    PjrtBackend,
};
use crate::engine::ExecPath;
use crate::error::{Error, Result};
use crate::grid::GridMode;
use crate::integrands::IntegrandRef;
use crate::runtime::{PjrtRuntime, Registry, DEFAULT_ARTIFACT_DIR};
use crate::strat::{Bounds, Sampling};

/// Which execution backend serves the job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendSpec {
    /// The native Rust engine (always available).
    Native,
    /// The AOT Pallas artifacts through PJRT. Only registry integrands
    /// are artifact-addressable; requires the `pjrt` cargo feature and
    /// `make artifacts`.
    Pjrt { artifacts_dir: String },
}

impl BackendSpec {
    /// PJRT with the conventional `artifacts/` directory.
    pub fn pjrt_default() -> BackendSpec {
        BackendSpec::Pjrt {
            artifacts_dir: DEFAULT_ARTIFACT_DIR.to_string(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Escalation {
    max_levels: usize,
    factor: usize,
}

/// Loaded-once PJRT state, reused across `run()` calls so repeated
/// runs (warm starts, benches) don't re-parse the manifest or rebuild
/// the client; the runtime's own compile cache then makes artifact
/// compilation once-per-name.
struct PjrtState {
    artifacts_dir: String,
    registry: Registry,
    runtime: PjrtRuntime,
}

type ObserverBox = Box<dyn FnMut(&IterationEvent) -> ObserverControl + Send>;

/// Builder-style facade over the whole integration stack.
///
/// Construct from a registry name, an `IntegrandRef`, or a closure;
/// chain configuration; `run()` (or pull iterations through
/// [`Integrator::session`]). The adapted importance grid of the last
/// run is exportable via [`Integrator::export_grid`] and feeds back in
/// through [`Integrator::warm_start`].
pub struct Integrator {
    spec: IntegrandSpec,
    cfg: JobConfig,
    backend: BackendSpec,
    escalation: Option<Escalation>,
    warm: Option<GridState>,
    observers: Vec<ObserverBox>,
    last_grid: Option<GridState>,
    pjrt: Option<PjrtState>,
    /// Shadow triple backing the deprecated flat-knob shims
    /// (`max_iterations`/`adjust_iterations`/`skip_iterations`), which
    /// rebuild a classic plan on every call.
    classic: (usize, usize, usize),
}

impl Integrator {
    /// Integrate a user-supplied integrand handle.
    pub fn new(f: IntegrandRef) -> Integrator {
        Integrator::from_spec(IntegrandSpec::custom(f))
    }

    /// Integrate a closure over per-axis `bounds`.
    pub fn from_fn<F>(dim: usize, bounds: Bounds, f: F) -> Result<Integrator>
    where
        F: Fn(&[f64]) -> f64 + Send + Sync + 'static,
    {
        let wrapped = super::integrand::FnIntegrand::new(dim, bounds, f)?;
        Ok(Integrator::new(wrapped.into_ref()))
    }

    /// Integrate a *batch* closure over per-axis `bounds` — the closure
    /// receives a structure-of-arrays [`crate::engine::PointBlock`] and
    /// writes one raw integrand value per point:
    ///
    /// ```no_run
    /// use mcubes::prelude::*;
    ///
    /// let out = Integrator::custom_batch(2, Bounds::unit(2), |block, out| {
    ///     let (x, y) = (block.axis(0), block.axis(1));
    ///     for (k, o) in out.iter_mut().enumerate() {
    ///         *o = x[k] * y[k];
    ///     }
    /// })?
    /// .tolerance(1e-3)
    /// .run()?;
    /// println!("I = {} ± {}", out.integral, out.sigma);
    /// # Ok::<(), mcubes::Error>(())
    /// ```
    ///
    /// This is the user-integrand twin of the registry's hand-batched
    /// evaluators: one virtual call per block instead of one per point,
    /// with contiguous per-axis columns the compiler can vectorize.
    pub fn custom_batch<F>(dim: usize, bounds: Bounds, f: F) -> Result<Integrator>
    where
        F: Fn(&crate::engine::PointBlock, &mut [f64]) + Send + Sync + 'static,
    {
        let wrapped = super::integrand::FnBatchIntegrand::new(dim, bounds, f)?;
        Ok(Integrator::new(wrapped.into_ref()))
    }

    /// Integrate a registry integrand (name checked eagerly).
    pub fn from_registry(name: &str, dim: usize) -> Result<Integrator> {
        // Resolve once now so typos fail at build, not run, time.
        crate::integrands::by_name(name, dim)?;
        Ok(Integrator::from_spec(IntegrandSpec::registry(name, dim)))
    }

    /// Integrate an explicit spec (what the scheduler queues).
    pub fn from_spec(spec: IntegrandSpec) -> Integrator {
        Integrator {
            spec,
            cfg: JobConfig::default(),
            backend: BackendSpec::Native,
            escalation: None,
            warm: None,
            observers: Vec::new(),
            last_grid: None,
            pjrt: None,
            classic: (15, 10, 2),
        }
    }

    /// Evaluation budget per iteration.
    pub fn maxcalls(mut self, calls: usize) -> Self {
        self.cfg.maxcalls = calls;
        self
    }

    /// Target relative error tau_rel.
    pub fn tolerance(mut self, tau_rel: f64) -> Self {
        self.cfg.tau_rel = tau_rel;
        self
    }

    /// The iteration schedule (see [`RunPlan`]). [`RunPlan::classic`]
    /// reproduces the old `itmax`/`ita`/`skip` triple bitwise;
    /// [`RunPlan::warmup_then_final`] states the paper's two-phase
    /// workflow directly.
    pub fn plan(mut self, plan: RunPlan) -> Self {
        self.cfg.plan = plan;
        self
    }

    /// Cap the total integrand evaluations across the whole run: the
    /// run ends with `StopReason::TargetCallsReached` once the budget
    /// is spent (spans escalation levels).
    pub fn call_budget(mut self, max_total_calls: usize) -> Self {
        self.cfg.max_total_calls = Some(max_total_calls);
        self
    }

    /// Total iteration cap.
    #[deprecated(
        since = "0.3.0",
        note = "use `.plan(RunPlan::classic(itmax, ita, skip))` — the flat \
                knobs are shims that rebuild a classic plan"
    )]
    pub fn max_iterations(mut self, itmax: usize) -> Self {
        self.classic.0 = itmax;
        self.cfg.plan = RunPlan::classic(self.classic.0, self.classic.1, self.classic.2);
        self
    }

    /// Iterations with importance-grid adjustment.
    #[deprecated(
        since = "0.3.0",
        note = "use `.plan(RunPlan::classic(itmax, ita, skip))` — the flat \
                knobs are shims that rebuild a classic plan"
    )]
    pub fn adjust_iterations(mut self, ita: usize) -> Self {
        self.classic.1 = ita;
        self.cfg.plan = RunPlan::classic(self.classic.0, self.classic.1, self.classic.2);
        self
    }

    /// Warm-up iterations excluded from the weighted estimate.
    #[deprecated(
        since = "0.3.0",
        note = "use `.plan(RunPlan::classic(itmax, ita, skip))` — the flat \
                knobs are shims that rebuild a classic plan"
    )]
    pub fn skip_iterations(mut self, skip: usize) -> Self {
        self.classic.2 = skip;
        self.cfg.plan = RunPlan::classic(self.classic.0, self.classic.1, self.classic.2);
        self
    }

    /// Importance bins per axis.
    pub fn bins_per_axis(mut self, nb: usize) -> Self {
        self.cfg.nb = nb;
        self
    }

    /// Grid programs / thread groups.
    pub fn blocks(mut self, nblocks: usize) -> Self {
        self.cfg.nblocks = nblocks;
        self
    }

    /// RNG seed (runs are deterministic per seed).
    pub fn seed(mut self, seed: u32) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Native-engine worker threads.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Shard workers the native engine splits each iteration across
    /// (default 1 = single worker). The N-shard merge is bitwise the
    /// single-worker run on both engines and both sampling modes, so —
    /// like [`Integrator::threads`] — this is purely an execution knob.
    pub fn shards(mut self, shards: usize) -> Self {
        self.cfg.shards = shards;
        self
    }

    /// Spool directory for sharded runs: scatter sealed task files for
    /// external `mcubes shard-worker` processes instead of the
    /// in-process pool (stragglers are recomputed locally). Only
    /// meaningful with [`Integrator::shards`] > 1.
    pub fn shard_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.shard_dir = Some(dir.into());
        self
    }

    /// Per-axis (m-Cubes) or shared (m-Cubes1D) importance grid.
    pub fn grid_mode(mut self, mode: GridMode) -> Self {
        self.cfg.grid_mode = mode;
        self
    }

    /// Per-cube sample allocation: the paper's uniform m-Cubes split
    /// (default) or VEGAS+ adaptive stratification, which re-apportions
    /// each iteration's budget toward high-variance sub-cubes (native
    /// backend only; `beta = 0` reproduces the uniform path bitwise).
    /// See `docs/sampling.md` for when each wins.
    ///
    /// ```no_run
    /// use mcubes::prelude::*;
    ///
    /// let out = Integrator::from_registry("f4", 8)?
    ///     .maxcalls(1 << 16)
    ///     .tolerance(1e-3)
    ///     .sampling(Sampling::VegasPlus { beta: 0.75 })
    ///     .observe(|ev| {
    ///         if let Some(a) = ev.alloc {
    ///             eprintln!(
    ///                 "it {}: samples/cube min {} mean {:.1} max {}",
    ///                 ev.iteration, a.min, a.mean, a.max
    ///             );
    ///         }
    ///     })
    ///     .run()?;
    /// println!("I = {} ± {}", out.integral, out.sigma);
    /// # Ok::<(), mcubes::Error>(())
    /// ```
    pub fn sampling(mut self, sampling: Sampling) -> Self {
        self.cfg.sampling = sampling;
        self
    }

    /// Native-engine execution schedule: the fused streaming tile loop
    /// ([`ExecPath::Streaming`], default) or the historical whole-block
    /// pipeline ([`ExecPath::Block`]). The two are bitwise identical
    /// (property-tested on both engines and both `Sampling` modes), so
    /// this is purely a performance knob — `Block` survives as the
    /// reference the equivalence suite and the microbench compare
    /// against.
    pub fn exec(mut self, exec: ExecPath) -> Self {
        self.cfg.exec = exec;
        self
    }

    /// Replace the whole job configuration at once.
    pub fn config(mut self, cfg: JobConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Select the execution backend (default: native).
    pub fn backend(mut self, backend: BackendSpec) -> Self {
        self.backend = backend;
        self
    }

    /// Escalate the per-iteration budget x`factor` up to `max_levels`
    /// times until the tolerance is met, carrying the adapted grid
    /// across levels (native backend only).
    pub fn escalate(mut self, max_levels: usize, factor: usize) -> Self {
        self.escalation = Some(Escalation { max_levels, factor });
        self
    }

    /// Seed the run with an adapted grid from a previous run — skips
    /// the importance-grid warm-up for repeated similar integrals.
    pub fn warm_start(mut self, grid: GridState) -> Self {
        self.warm = Some(grid);
        self
    }

    /// Register a per-iteration observer. Multiple observers fire in
    /// registration order.
    pub fn observe<F>(mut self, mut f: F) -> Self
    where
        F: FnMut(&IterationEvent) + Send + 'static,
    {
        self.observers.push(Box::new(move |ev: &IterationEvent| {
            f(ev);
            ObserverControl::Continue
        }));
        self
    }

    /// Register an observer that can end the run: returning
    /// [`ObserverControl::Abort`] stops after the current iteration
    /// with `StopReason::ObserverAbort`. If any observer aborts, the
    /// run aborts.
    pub fn observe_ctrl<F>(mut self, f: F) -> Self
    where
        F: FnMut(&IterationEvent) -> ObserverControl + Send + 'static,
    {
        self.observers.push(Box::new(f));
        self
    }

    /// The current job configuration.
    pub fn job_config(&self) -> &JobConfig {
        &self.cfg
    }

    /// The integrand spec this integrator runs.
    pub fn spec(&self) -> &IntegrandSpec {
        &self.spec
    }

    /// Open a resumable [`Session`] over the current configuration
    /// (native backend only; a configured `warm_start` grid seeds it).
    /// Observers registered on the builder do not transfer — the
    /// session caller *is* the observer.
    pub fn session(&self) -> Result<Session> {
        if !matches!(self.backend, BackendSpec::Native) {
            return Err(Error::Config(
                "sessions require the native backend (PJRT artifacts drive \
                 through the blocking `run()` path)"
                    .into(),
            ));
        }
        if self.escalation.is_some() {
            return Err(Error::Config(
                "escalation and sessions don't compose: express the budget \
                 ladder as RunPlan stages with per-stage `with_calls` \
                 overrides instead"
                    .into(),
            ));
        }
        let f = self.spec.resolve()?;
        match &self.warm {
            Some(grid) => Session::resume(f, self.cfg.clone(), &Checkpoint::from_grid(grid.clone())),
            None => Session::new(f, self.cfg.clone()),
        }
    }

    /// Restore a suspended [`Session`] from a [`Checkpoint`] under the
    /// current configuration. Bitwise continuation requires the same
    /// integrand, config, and plan the suspended session ran with.
    pub fn resume_session(&self, checkpoint: &Checkpoint) -> Result<Session> {
        if !matches!(self.backend, BackendSpec::Native) {
            return Err(Error::Config(
                "sessions require the native backend (PJRT artifacts drive \
                 through the blocking `run()` path)"
                    .into(),
            ));
        }
        let f = self.spec.resolve()?;
        Session::resume(f, self.cfg.clone(), checkpoint)
    }

    /// Run and return the integration output.
    pub fn run(&mut self) -> Result<IntegrationOutput> {
        self.run_outcome().map(|o| o.output)
    }

    /// Run and return the output, the adapted grid, and the typed
    /// [`crate::api::StopReason`].
    pub fn run_outcome(&mut self) -> Result<DriveOutcome> {
        self.cfg.validate()?;
        // Disjoint field borrows: the fan-out closure mutably borrows
        // `observers` in place (panic-safe — nothing is taken out of
        // self) while dispatch reads the other fields.
        let Integrator {
            spec,
            cfg,
            backend,
            escalation,
            warm,
            observers,
            last_grid,
            pjrt,
            classic: _,
        } = self;
        let mut fan;
        let obs: Option<&mut dyn FnMut(&IterationEvent) -> ObserverControl> =
            if observers.is_empty() {
                None
            } else {
                fan = |ev: &IterationEvent| {
                    let mut control = ObserverControl::Continue;
                    for o in observers.iter_mut() {
                        if o(ev) == ObserverControl::Abort {
                            control = ObserverControl::Abort;
                        }
                    }
                    control
                };
                Some(&mut fan)
            };
        let outcome = Self::dispatch(spec, cfg, backend, *escalation, warm.as_ref(), pjrt, obs)?;
        *last_grid = Some(outcome.grid.clone());
        Ok(outcome)
    }

    /// The adapted grid left by the most recent `run`.
    pub fn grid(&self) -> Option<&GridState> {
        self.last_grid.as_ref()
    }

    /// Clone out the adapted grid of the most recent `run` — feed it to
    /// another integrator's [`Integrator::warm_start`].
    pub fn export_grid(&self) -> Option<GridState> {
        self.last_grid.clone()
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        spec: &IntegrandSpec,
        cfg: &JobConfig,
        backend_spec: &BackendSpec,
        escalation: Option<Escalation>,
        warm: Option<&GridState>,
        pjrt: &mut Option<PjrtState>,
        observer: Option<&mut dyn FnMut(&IterationEvent) -> ObserverControl>,
    ) -> Result<DriveOutcome> {
        match backend_spec {
            BackendSpec::Native => {
                let f = spec.resolve()?;
                match escalation {
                    Some(esc) => {
                        escalate_native(&f, cfg, esc.max_levels, esc.factor, warm, observer)
                    }
                    None => integrate_native_core(&f, cfg, warm, observer),
                }
            }
            BackendSpec::Pjrt { artifacts_dir } => {
                if matches!(cfg.sampling, Sampling::VegasPlus { .. }) {
                    return Err(Error::Config(
                        "VEGAS+ adaptive stratification is native-only: the \
                         PJRT artifacts compile the uniform m-Cubes sample \
                         layout (drop `.sampling(..)` or use the native \
                         backend)"
                            .into(),
                    ));
                }
                if escalation.is_some() {
                    return Err(Error::Config(
                        "escalation is only supported on the native backend \
                         (PJRT artifacts have a fixed maxcalls)"
                            .into(),
                    ));
                }
                let name = spec.registry_name().ok_or_else(|| {
                    Error::Config(
                        "the PJRT backend requires a registry integrand \
                         (artifacts are compiled per registry name); use the \
                         native backend for closures"
                            .into(),
                    )
                })?;
                // Load the registry + PJRT client once per integrator;
                // the runtime's compile cache then makes repeated runs
                // (warm starts, benches) compile each artifact once.
                let stale = pjrt
                    .as_ref()
                    .map(|s| s.artifacts_dir != *artifacts_dir)
                    .unwrap_or(true);
                if stale {
                    *pjrt = Some(PjrtState {
                        artifacts_dir: artifacts_dir.clone(),
                        registry: Registry::load(artifacts_dir)?,
                        runtime: PjrtRuntime::cpu()?,
                    });
                }
                // lint:allow(MC005, the stale-check block directly above guarantees Some)
                let state = pjrt.as_ref().expect("pjrt state just ensured");
                let mut backend =
                    PjrtBackend::load(&state.runtime, &state.registry, name, cfg.maxcalls)?;
                // Adopt the artifact's compiled layout; the rest of the
                // config (tolerance, plan, seed) applies as-is.
                let meta = backend.meta();
                let mut run_cfg = cfg.clone();
                run_cfg.maxcalls = meta.maxcalls;
                run_cfg.nb = meta.nb;
                run_cfg.nblocks = meta.nblocks;
                drive(&mut backend, &run_cfg, warm, observer)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{FnIntegrand, StopReason};

    #[test]
    fn builder_round_trips_config() {
        let intg = Integrator::from_registry("f4", 5)
            .unwrap()
            .maxcalls(4096)
            .tolerance(5e-3)
            .plan(RunPlan::classic(9, 6, 1))
            .call_budget(1 << 20)
            .bins_per_axis(32)
            .blocks(4)
            .seed(7)
            .threads(2)
            .shards(4)
            .shard_dir("/tmp/shard-spool")
            .grid_mode(GridMode::Shared1D)
            .sampling(Sampling::vegas_plus())
            .exec(ExecPath::Block);
        let c = intg.job_config();
        assert_eq!(c.maxcalls, 4096);
        assert_eq!(c.tau_rel, 5e-3);
        assert_eq!(c.plan, RunPlan::classic(9, 6, 1));
        assert_eq!(c.plan.total_iters(), 9);
        assert_eq!(c.max_total_calls, Some(1 << 20));
        assert_eq!(c.nb, 32);
        assert_eq!(c.nblocks, 4);
        assert_eq!(c.seed, 7);
        assert_eq!(c.threads, 2);
        assert_eq!(c.shards, 4);
        assert_eq!(c.shard_dir.as_deref(), Some("/tmp/shard-spool"));
        assert_eq!(JobConfig::default().shards, 1);
        assert_eq!(c.grid_mode, GridMode::Shared1D);
        assert_eq!(c.sampling, Sampling::VegasPlus { beta: 0.75 });
        assert_eq!(c.exec, ExecPath::Block);
        assert_eq!(JobConfig::default().exec, ExecPath::Streaming);
        assert_eq!(intg.spec().label(), "f4");
    }

    /// The sanctioned use of the deprecated flat knobs: pin the shims
    /// to the classic plan they claim to build.
    #[test]
    #[allow(deprecated)]
    fn deprecated_flat_knobs_build_a_classic_plan() {
        let intg = Integrator::from_registry("f4", 5)
            .unwrap()
            .max_iterations(9)
            .adjust_iterations(6)
            .skip_iterations(1);
        assert_eq!(intg.job_config().plan, RunPlan::classic(9, 6, 1));
        // Order-independent: each shim call rebuilds from the triple.
        let intg = Integrator::from_registry("f4", 5)
            .unwrap()
            .skip_iterations(1)
            .max_iterations(9)
            .adjust_iterations(6);
        assert_eq!(intg.job_config().plan, RunPlan::classic(9, 6, 1));
    }

    #[test]
    fn unknown_registry_name_fails_at_build() {
        assert!(Integrator::from_registry("nope", 3).is_err());
    }

    #[test]
    fn runs_registry_integrand() {
        let out = Integrator::from_registry("f5", 4)
            .unwrap()
            .maxcalls(1 << 13)
            .tolerance(1e-3)
            .seed(11)
            .run()
            .unwrap();
        assert!(out.converged, "{out:?}");
        assert_eq!(out.backend, "native");
    }

    #[test]
    fn closure_on_pjrt_backend_is_rejected() {
        let f = FnIntegrand::unit(2, |x: &[f64]| x[0] + x[1]).into_ref();
        let err = Integrator::new(f)
            .backend(BackendSpec::pjrt_default())
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("registry integrand"), "{err}");
    }

    #[test]
    fn vegas_plus_on_pjrt_backend_is_rejected() {
        let err = Integrator::from_registry("f4", 5)
            .unwrap()
            .backend(BackendSpec::pjrt_default())
            .sampling(Sampling::vegas_plus())
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("native-only"), "{err}");
    }

    #[test]
    fn session_on_pjrt_backend_is_rejected() {
        let err = Integrator::from_registry("f4", 5)
            .unwrap()
            .backend(BackendSpec::pjrt_default())
            .session()
            .unwrap_err()
            .to_string();
        assert!(err.contains("native backend"), "{err}");
        let err = Integrator::from_registry("f4", 5)
            .unwrap()
            .escalate(2, 4)
            .session()
            .unwrap_err()
            .to_string();
        assert!(err.contains("escalation"), "{err}");
    }

    #[test]
    fn vegas_plus_runs_through_the_facade() {
        use std::sync::{Arc, Mutex};
        let sink: Arc<Mutex<Vec<(u32, u32, usize)>>> = Arc::new(Mutex::new(Vec::new()));
        let s2 = Arc::clone(&sink);
        let out = Integrator::from_registry("f4", 5)
            .unwrap()
            .maxcalls(4096)
            .tolerance(1e-12) // fixed work: run all iterations
            .plan(RunPlan::classic(5, 3, 0))
            .seed(3)
            .sampling(Sampling::vegas_plus())
            .observe(move |ev| {
                if let Some(a) = ev.alloc {
                    s2.lock().unwrap().push((a.min, a.max, a.total));
                }
            })
            .run()
            .unwrap();
        assert_eq!(out.backend, "native-vegas+");
        assert_eq!(out.iterations, 5);
        let spreads = sink.lock().unwrap();
        assert_eq!(spreads.len(), 5);
        // Iteration 0 is the uniform split (f4 d=5 @4096: p = 4
        // everywhere); every iteration keeps the full budget.
        assert_eq!(spreads[0].0, spreads[0].1);
        assert!(spreads.iter().all(|&(_, _, t)| t == 4096));
    }

    #[test]
    fn escalation_on_pjrt_backend_is_rejected() {
        let err = Integrator::from_registry("f4", 5)
            .unwrap()
            .backend(BackendSpec::pjrt_default())
            .escalate(2, 4)
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("escalation"), "{err}");
    }

    #[test]
    fn invalid_config_rejected_before_running() {
        let err = Integrator::from_registry("f4", 5)
            .unwrap()
            .maxcalls(0)
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("maxcalls"), "{err}");
        let err = Integrator::from_registry("f4", 5)
            .unwrap()
            .plan(RunPlan::new(vec![]))
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("no stages"), "{err}");
    }

    #[test]
    fn observers_fire_and_grid_exports() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        let mut intg = Integrator::from_registry("f3", 3)
            .unwrap()
            .maxcalls(1 << 12)
            .tolerance(1e-3)
            .observe(move |_ev| {
                c2.fetch_add(1, Ordering::Relaxed);
            });
        assert!(intg.grid().is_none());
        let out = intg.run().unwrap();
        assert_eq!(count.load(Ordering::Relaxed), out.iterations);
        let grid = intg.export_grid().expect("grid after run");
        assert_eq!(grid.d(), 3);
        assert_eq!(grid.nb(), intg.job_config().nb);
        // Observers survive across runs.
        let out2 = intg.run().unwrap();
        assert_eq!(
            count.load(Ordering::Relaxed),
            out.iterations + out2.iterations
        );
    }

    #[test]
    fn aborting_observer_ends_the_run() {
        let mut intg = Integrator::from_registry("f5", 4)
            .unwrap()
            .maxcalls(1 << 12)
            .tolerance(1e-12)
            .plan(RunPlan::classic(10, 6, 0))
            .observe_ctrl(|ev| {
                if ev.iteration >= 1 {
                    ObserverControl::Abort
                } else {
                    ObserverControl::Continue
                }
            });
        let outcome = intg.run_outcome().unwrap();
        assert_eq!(outcome.stop, StopReason::ObserverAbort);
        assert_eq!(outcome.output.iterations, 2);
    }

    #[test]
    fn session_matches_blocking_run_bitwise() {
        let builder = || {
            Integrator::from_registry("f3", 3)
                .unwrap()
                .maxcalls(1 << 12)
                .tolerance(1e-3)
                .plan(RunPlan::classic(10, 6, 1))
                .seed(13)
        };
        let blocking = builder().run().unwrap();
        let mut session = builder().session().unwrap();
        let mut steps = 0;
        while session.step().unwrap().is_some() {
            steps += 1;
        }
        let pulled = session.finish().unwrap().output;
        assert_eq!(steps, blocking.iterations);
        assert_eq!(blocking.integral.to_bits(), pulled.integral.to_bits());
        assert_eq!(blocking.sigma.to_bits(), pulled.sigma.to_bits());
        assert_eq!(blocking.chi2_dof.to_bits(), pulled.chi2_dof.to_bits());
    }
}
