//! Composable run plans — the typed replacement for the flat
//! `itmax`/`ita`/`skip` iteration knobs.
//!
//! Algorithm 2 is a two-phase loop: `ita` grid-adjustment iterations
//! followed by frozen-grid iterations, with the first `skip` iterations
//! excluded from the weighted estimate. [`RunPlan`] generalizes that to
//! an ordered list of [`Stage`]s, each with its own iteration count,
//! optional per-stage call budget, adjust/frozen switch, optional
//! sampling-strategy override, and a discard flag:
//!
//! * [`RunPlan::classic`] reproduces the seed's `itmax`/`ita`/`skip`
//!   behavior **bitwise** (it is also [`RunPlan::default`], so existing
//!   configs keep their exact semantics).
//! * [`RunPlan::warmup_then_final`] expresses the paper's
//!   cheap-adjustment-then-frozen-grid workflow directly: a discarded
//!   low-budget adapt stage, then full-budget frozen iterations.
//! * Arbitrary plans chain `Stage::adapt(..)` / `Stage::sample(..)`
//!   with per-stage `with_calls` / `with_sampling` overrides (native
//!   engine only — fixed-layout backends such as PJRT artifacts reject
//!   overrides).
//!
//! ```
//! use mcubes::api::{RunPlan, Stage};
//! use mcubes::strat::Sampling;
//!
//! // The default plan is exactly the seed's (15, 10, 2) triple.
//! assert_eq!(RunPlan::default(), RunPlan::classic(15, 10, 2));
//!
//! // Paper workflow: 5 cheap discarded adjustment iterations at 2^12
//! // calls, then 10 frozen-grid iterations at the configured budget.
//! let plan = RunPlan::warmup_then_final(5, 1 << 12, 10);
//! assert_eq!(plan.total_iters(), 15);
//!
//! // Fully custom: adapt uniformly, then refine with VEGAS+.
//! let plan = RunPlan::new(vec![
//!     Stage::adapt(4).discarded(),
//!     Stage::sample(8).with_sampling(Sampling::vegas_plus()),
//! ]);
//! assert!(plan.validate().is_ok());
//! ```

use crate::error::{Error, Result};
use crate::strat::Sampling;

/// One contiguous span of driver iterations sharing the same policy.
///
/// Construct via [`Stage::adapt`] (grid adjustment on) or
/// [`Stage::sample`] (frozen grid), then chain the `with_*`/`discarded`
/// builders. The struct is `#[non_exhaustive]`: future policy fields
/// will not be breaking changes.
#[derive(Debug, Clone, PartialEq)]
#[non_exhaustive]
pub struct Stage {
    /// Number of iterations this stage runs (must be >= 1).
    pub iters: usize,
    /// Per-iteration call budget override; `None` inherits the job's
    /// `maxcalls`. Native engine only.
    pub calls: Option<usize>,
    /// Whether iterations in this stage accumulate the v^2 histogram
    /// and adjust the importance grid (Algorithm 2's adjust phase).
    pub adapt: bool,
    /// Per-stage sampling-strategy override; `None` inherits the job's
    /// `sampling`. Native engine only.
    pub sampling: Option<Sampling>,
    /// Exclude this stage's iterations from the weighted estimate
    /// (the warm-up role of the classic `skip` knob).
    pub discard: bool,
}

impl Stage {
    /// A grid-adjusting stage of `iters` iterations.
    pub fn adapt(iters: usize) -> Stage {
        Stage {
            iters,
            calls: None,
            adapt: true,
            sampling: None,
            discard: false,
        }
    }

    /// A frozen-grid sampling stage of `iters` iterations.
    pub fn sample(iters: usize) -> Stage {
        Stage {
            adapt: false,
            ..Stage::adapt(iters)
        }
    }

    /// Override the per-iteration call budget for this stage.
    pub fn with_calls(mut self, calls: usize) -> Stage {
        self.calls = Some(calls);
        self
    }

    /// Override the sampling strategy for this stage.
    pub fn with_sampling(mut self, sampling: Sampling) -> Stage {
        self.sampling = Some(sampling);
        self
    }

    /// Exclude this stage's iterations from the weighted estimate.
    pub fn discarded(mut self) -> Stage {
        self.discard = true;
        self
    }

    /// Human-readable stage label ("adapt", "sample", "+discard"
    /// suffix when the stage is excluded from the estimate).
    pub fn label(&self) -> String {
        let base = if self.adapt { "adapt" } else { "sample" };
        if self.discard {
            format!("{base}+discard")
        } else {
            base.to_string()
        }
    }
}

/// An ordered list of [`Stage`]s describing one full run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunPlan {
    stages: Vec<Stage>,
}

impl Default for RunPlan {
    /// The seed's default `(itmax, ita, skip) = (15, 10, 2)` schedule.
    fn default() -> RunPlan {
        RunPlan::classic(15, 10, 2)
    }
}

impl RunPlan {
    /// A plan from explicit stages.
    pub fn new(stages: Vec<Stage>) -> RunPlan {
        RunPlan { stages }
    }

    /// Append a stage (builder style).
    pub fn then(mut self, stage: Stage) -> RunPlan {
        self.stages.push(stage);
        self
    }

    /// The seed's flat schedule, reproduced **bitwise**: `itmax` total
    /// iterations, grid adjustment on iterations `0..ita`, the first
    /// `skip` iterations excluded from the weighted estimate.
    ///
    /// `ita` and `skip` are clamped to `itmax` (adjusting or skipping
    /// past the iteration cap is meaningless). A schedule that discards
    /// everything (`skip >= itmax`) builds, but is rejected by
    /// [`RunPlan::validate`].
    pub fn classic(itmax: usize, ita: usize, skip: usize) -> RunPlan {
        let ita = ita.min(itmax);
        let skip = skip.min(itmax);
        let b1 = ita.min(skip);
        let b2 = ita.max(skip);
        let mut stages = Vec::with_capacity(3);
        if b1 > 0 {
            // Iterations [0, b1): both adjusting and discarded.
            stages.push(Stage::adapt(b1).discarded());
        }
        if b2 > b1 {
            // Iterations [b1, b2): whichever of the two knobs reaches
            // further — adjust-only (skip < ita) or discard-only.
            stages.push(if ita > skip {
                Stage::adapt(b2 - b1)
            } else {
                Stage::sample(b2 - b1).discarded()
            });
        }
        if itmax > b2 {
            // Iterations [b2, itmax): frozen grid, fully counted.
            stages.push(Stage::sample(itmax - b2));
        }
        RunPlan { stages }
    }

    /// The paper's two-phase workflow stated directly: `warmup_iters`
    /// cheap grid-adjustment iterations at `warmup_calls` per
    /// iteration, discarded from the estimate, then `final_iters`
    /// frozen-grid iterations at the job's full `maxcalls` budget.
    pub fn warmup_then_final(
        warmup_iters: usize,
        warmup_calls: usize,
        final_iters: usize,
    ) -> RunPlan {
        RunPlan::new(vec![
            Stage::adapt(warmup_iters).with_calls(warmup_calls).discarded(),
            Stage::sample(final_iters),
        ])
    }

    /// The stages, in execution order.
    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    /// Total iterations across all stages (the plan's `itmax`).
    pub fn total_iters(&self) -> usize {
        self.stages.iter().map(|s| s.iters).sum()
    }

    /// True when any stage overrides the per-iteration call budget or
    /// the sampling strategy — such plans need a backend that can
    /// re-layout between stages (the native engine session path).
    pub fn has_overrides(&self) -> bool {
        self.stages
            .iter()
            .any(|s| s.calls.is_some() || s.sampling.is_some())
    }

    /// Check plan invariants: at least one stage, every stage runs at
    /// least one iteration, call-budget overrides are large enough to
    /// stratify, sampling overrides are valid, and at least one stage
    /// contributes to the estimate.
    pub fn validate(&self) -> Result<()> {
        if self.stages.is_empty() {
            return Err(Error::Config(
                "run plan has no stages (need at least one iteration)".into(),
            ));
        }
        for (i, stage) in self.stages.iter().enumerate() {
            if stage.iters == 0 {
                return Err(Error::Config(format!(
                    "run plan stage {i}: iters must be >= 1, got 0"
                )));
            }
            if let Some(calls) = stage.calls {
                if calls < 4 {
                    return Err(Error::Config(format!(
                        "run plan stage {i}: calls override must be >= 4 \
                         (the layout needs at least 2 samples in at least \
                         1 cube), got {calls}"
                    )));
                }
            }
            if let Some(sampling) = &stage.sampling {
                sampling.validate()?;
            }
        }
        if self.stages.iter().all(|s| s.discard) {
            return Err(Error::Config(
                "run plan discards every stage: the weighted estimate would \
                 be empty — add at least one non-discard stage"
                    .into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference decomposition: replay a plan iteration by iteration
    /// and compare (adapt, discard) flags against the classic triple.
    fn flags(plan: &RunPlan) -> Vec<(bool, bool)> {
        let mut out = Vec::new();
        for s in plan.stages() {
            for _ in 0..s.iters {
                out.push((s.adapt, s.discard));
            }
        }
        out
    }

    #[test]
    fn classic_reproduces_the_flat_triple() {
        for (itmax, ita, skip) in [
            (15, 10, 2),
            (6, 3, 0),
            (10, 0, 0),
            (10, 10, 2),
            (10, 2, 5),
            (1, 1, 0),
            (8, 8, 8), // discard-only: built, rejected by validate
        ] {
            let plan = RunPlan::classic(itmax, ita, skip);
            let got = flags(&plan);
            assert_eq!(got.len(), itmax, "({itmax},{ita},{skip})");
            for (it, &(adapt, discard)) in got.iter().enumerate() {
                assert_eq!(adapt, it < ita, "({itmax},{ita},{skip}) it {it}");
                assert_eq!(discard, it < skip, "({itmax},{ita},{skip}) it {it}");
            }
            assert_eq!(plan.total_iters(), itmax);
            assert!(!plan.has_overrides());
        }
    }

    #[test]
    fn classic_clamps_out_of_range_knobs() {
        let plan = RunPlan::classic(5, 99, 2);
        assert_eq!(plan.total_iters(), 5);
        assert_eq!(flags(&plan), flags(&RunPlan::classic(5, 5, 2)));
        assert!(plan.validate().is_ok());
    }

    #[test]
    fn default_is_the_seed_schedule() {
        assert_eq!(RunPlan::default(), RunPlan::classic(15, 10, 2));
        assert!(RunPlan::default().validate().is_ok());
    }

    #[test]
    fn warmup_then_final_shape() {
        let plan = RunPlan::warmup_then_final(5, 4096, 10);
        assert!(plan.validate().is_ok());
        assert_eq!(plan.total_iters(), 15);
        assert!(plan.has_overrides());
        let s = plan.stages();
        assert_eq!(s.len(), 2);
        assert!(s[0].adapt && s[0].discard);
        assert_eq!(s[0].calls, Some(4096));
        assert!(!s[1].adapt && !s[1].discard);
        assert_eq!(s[1].calls, None);
        assert_eq!(s[0].label(), "adapt+discard");
        assert_eq!(s[1].label(), "sample");
    }

    #[test]
    fn validate_rejects_empty_plan() {
        let err = RunPlan::new(vec![]).validate().unwrap_err().to_string();
        assert!(err.contains("no stages"), "{err}");
        // classic(0, ..) builds the empty plan too.
        assert!(RunPlan::classic(0, 0, 0).validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_iteration_stage() {
        let err = RunPlan::new(vec![Stage::adapt(0)])
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("iters must be >= 1"), "{err}");
    }

    #[test]
    fn validate_rejects_zero_calls_override() {
        let err = RunPlan::new(vec![Stage::sample(3).with_calls(0)])
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("calls override must be >= 4"), "{err}");
        assert!(RunPlan::new(vec![Stage::sample(3).with_calls(4)])
            .validate()
            .is_ok());
    }

    #[test]
    fn validate_rejects_discard_only_plan() {
        let err = RunPlan::new(vec![Stage::adapt(4).discarded(), Stage::sample(2).discarded()])
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("discards every stage"), "{err}");
        // classic with skip >= itmax hits the same rejection.
        assert!(RunPlan::classic(4, 2, 9).validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_sampling_override() {
        let plan = RunPlan::new(vec![
            Stage::adapt(2).with_sampling(Sampling::VegasPlus { beta: 7.0 })
        ]);
        let err = plan.validate().unwrap_err().to_string();
        assert!(err.contains("beta"), "{err}");
    }

    #[test]
    fn then_appends_stages() {
        let plan = RunPlan::new(vec![Stage::adapt(2)]).then(Stage::sample(3));
        assert_eq!(plan.stages().len(), 2);
        assert_eq!(plan.total_iters(), 5);
    }
}
