//! Per-iteration observer hooks — the structured replacement for the
//! seed's ad-hoc per-iteration trace rows.
//!
//! The driver invokes the observer once per iteration, after grid
//! adjustment and the stop decision, so the event shows both the raw
//! iteration estimate and the running weighted combination. Cheap by
//! construction: the event borrows the live grid instead of cloning it;
//! observers that want history copy what they need.
//!
//! Observers return an [`ObserverControl`]: `Continue` keeps the run
//! going, `Abort` ends it after the current iteration with
//! [`StopReason::ObserverAbort`]. Unit-returning closures registered
//! through `Integrator::observe` are wrapped to always continue;
//! `Integrator::observe_ctrl` exposes the abort channel.

use super::session::StopReason;
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::strat::AllocStats;

/// What an observer wants the run to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ObserverControl {
    /// Keep iterating.
    #[default]
    Continue,
    /// Stop after this iteration ([`StopReason::ObserverAbort`]).
    Abort,
}

/// Snapshot of one driver iteration, delivered to observers.
///
/// `#[non_exhaustive]`: construct only inside the crate; future
/// telemetry fields will not be breaking changes. For an owned
/// equivalent (no grid borrow) see `api::Iteration`, returned by
/// `Session::step`.
#[derive(Debug, Clone, Copy)]
#[non_exhaustive]
pub struct IterationEvent<'a> {
    /// 0-based iteration index. When escalation is active the index is
    /// cumulative across levels.
    pub iteration: usize,
    /// Index of the run-plan stage this iteration belongs to.
    pub stage: usize,
    /// Human-readable label of that stage ("adapt", "sample",
    /// "+discard" suffix for discarded stages).
    pub stage_label: &'a str,
    /// Whether this iteration accumulated the v^2 histogram and
    /// adjusted the grid (the two-phase split of Algorithm 2).
    pub adjusting: bool,
    /// Whether this iteration was excluded from the weighted estimate
    /// (a discarded warm-up stage).
    pub discarded: bool,
    /// Raw estimate of this iteration alone.
    pub estimate: IterationResult,
    /// Running weighted integral. While the estimator is empty — the
    /// discarded warm-up iterations, or right after a chi^2 reset — the
    /// running fields carry their empty-estimator sentinels:
    /// `integral` 0.0, `sigma`/`rel_err` infinity, `chi2_dof` 0.0.
    pub integral: f64,
    /// Running combined sigma (infinite until the first fold).
    pub sigma: f64,
    /// Running chi^2 per degree of freedom.
    pub chi2_dof: f64,
    /// Running relative error |sigma / integral| (infinite until the
    /// first fold).
    pub rel_err: f64,
    /// Total integrand evaluations consumed so far, this iteration
    /// included.
    pub calls_used: usize,
    /// The chi^2 guard fired and the estimator was reset this iteration.
    pub estimator_reset: bool,
    /// Convergence was declared on this iteration (it is the last one).
    pub converged: bool,
    /// Why the run stops, when this is the final iteration; `None`
    /// while the run continues. Exception: an
    /// [`StopReason::ObserverAbort`] ending is decided *while* the
    /// final event is being handled, so that event still carries
    /// `None` — the abort reason appears on the `DriveOutcome`.
    pub stop: Option<StopReason>,
    /// Per-cube sample-allocation summary (min/max/mean samples per
    /// cube) of this iteration — `Some` only under
    /// `Sampling::VegasPlus` (see `crate::strat::Sampling`), where the
    /// spread shows how hard the adaptive stratification is skewing
    /// the budget toward high-variance cubes.
    pub alloc: Option<AllocStats>,
    /// The importance grid after this iteration's adjustment.
    pub grid: &'a Bins,
}
