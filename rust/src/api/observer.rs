//! Per-iteration observer hooks — the structured replacement for the
//! ad-hoc `DriverOutput` trace.
//!
//! The driver invokes the observer once per iteration, after grid
//! adjustment and the convergence decision, so the event shows both the
//! raw iteration estimate and the running weighted combination. Cheap
//! by construction: the event borrows the live grid instead of cloning
//! it; observers that want history copy what they need.

use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::strat::AllocStats;

/// Snapshot of one driver iteration, delivered to observers.
#[derive(Debug, Clone, Copy)]
pub struct IterationEvent<'a> {
    /// 0-based iteration index. When escalation is active the index is
    /// cumulative across levels.
    pub iteration: usize,
    /// Whether this iteration accumulated the v^2 histogram and
    /// adjusted the grid (the two-phase split of Algorithm 2).
    pub adjusting: bool,
    /// Raw estimate of this iteration alone.
    pub estimate: IterationResult,
    /// Running weighted integral. While the estimator is empty — the
    /// `skip` warm-up iterations, or right after a chi^2 reset — the
    /// running fields carry their empty-estimator sentinels:
    /// `integral` 0.0, `sigma`/`rel_err` infinity, `chi2_dof` 0.0.
    pub integral: f64,
    /// Running combined sigma (infinite until the first fold).
    pub sigma: f64,
    /// Running chi^2 per degree of freedom.
    pub chi2_dof: f64,
    /// Running relative error |sigma / integral| (infinite until the
    /// first fold).
    pub rel_err: f64,
    /// The chi^2 guard fired and the estimator was reset this iteration.
    pub estimator_reset: bool,
    /// Convergence was declared on this iteration (it is the last one).
    pub converged: bool,
    /// Per-cube sample-allocation summary (min/max/mean samples per
    /// cube) of this iteration — `Some` only under
    /// `Sampling::VegasPlus` (see `crate::strat::Sampling`), where the
    /// spread shows how hard the adaptive stratification is skewing
    /// the budget toward high-variance cubes.
    pub alloc: Option<AllocStats>,
    /// The importance grid after this iteration's adjustment.
    pub grid: &'a Bins,
}
