//! Closure integrands and integrand specifications.
//!
//! `FnIntegrand` adapts any `Fn(&[f64]) -> f64` closure (or fn pointer)
//! into the `Integrand` trait, with arbitrary per-axis bounds — the
//! user-defined-integrand-first surface the paper's "easy to define
//! stateful integrals" pitch calls for. `FnBatchIntegrand` is its
//! batch-first twin: the closure receives a whole structure-of-arrays
//! [`PointBlock`] per call, so user integrands get the same
//! one-virtual-call-per-block hot path as the built-in registry.
//! `IntegrandSpec` is the serializable-ish handle the service and
//! `Integrator` share: either a registry name (resolvable,
//! artifact-addressable) or a custom `IntegrandRef` (scalar *or*
//! batch — both erase to the same handle).

use crate::engine::block::PointBlock;
use crate::error::Result;
use crate::integrands::{by_name, Integrand, IntegrandRef};
use crate::strat::Bounds;
use std::fmt;
use std::sync::Arc;

/// A closure adapted into the `Integrand` trait.
///
/// The closure receives points in *physical* coordinates (inside
/// `bounds`); the engine handles the unit-box map and Jacobian. The
/// engine, driver, and CPU baselines all sample through `bounds()`;
/// for non-uniform boxes the legacy `lo()/hi()` pair reports the
/// bounding hull and should not be used for sampling.
pub struct FnIntegrand<F> {
    f: F,
    dim: usize,
    bounds: Bounds,
    hull: (f64, f64),
    name: String,
    true_value: Option<f64>,
    symmetric: bool,
}

impl<F> FnIntegrand<F>
where
    F: Fn(&[f64]) -> f64 + Send + Sync,
{
    /// Wrap `f` over an arbitrary box. Fails if `bounds.dim() != dim`.
    pub fn new(dim: usize, bounds: Bounds, f: F) -> Result<FnIntegrand<F>> {
        if bounds.dim() != dim {
            return Err(crate::error::Error::Config(format!(
                "bounds dimension {} != integrand dimension {dim}",
                bounds.dim()
            )));
        }
        let hull = bounds.hull();
        Ok(FnIntegrand {
            f,
            dim,
            bounds,
            hull,
            name: "closure".to_string(),
            true_value: None,
            symmetric: false,
        })
    }

    /// Wrap `f` over the unit box `[0, 1]^dim`.
    pub fn unit(dim: usize, f: F) -> FnIntegrand<F> {
        // lint:allow(MC005, structurally infallible — Bounds::unit(dim) always has exactly dim axes)
        Self::new(dim, Bounds::unit(dim), f).expect("unit bounds always match")
    }

    /// Attach a display name (shows up in service results and reports).
    pub fn named(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    /// Attach a known reference value (enables accuracy reporting).
    pub fn with_true_value(mut self, v: f64) -> Self {
        self.true_value = Some(v);
        self
    }

    /// Declare the integrand symmetric across axes (m-Cubes1D valid).
    pub fn assume_symmetric(mut self) -> Self {
        self.symmetric = true;
        self
    }

    /// Erase into a shared `IntegrandRef` handle.
    pub fn into_ref(self) -> IntegrandRef
    where
        F: 'static,
    {
        Arc::new(self)
    }
}

impl<F> Integrand for FnIntegrand<F>
where
    F: Fn(&[f64]) -> f64 + Send + Sync,
{
    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn lo(&self) -> f64 {
        self.hull.0
    }

    fn hi(&self) -> f64 {
        self.hull.1
    }

    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        (self.f)(x)
    }

    fn true_value(&self) -> Option<f64> {
        self.true_value
    }

    fn symmetric(&self) -> bool {
        self.symmetric
    }

    fn bounds(&self) -> Bounds {
        self.bounds.clone()
    }
}

/// A batch closure adapted into the `Integrand` trait.
///
/// The closure receives a [`PointBlock`] (column-major SoA: axis `i`'s
/// coordinates are the contiguous slice `block.axis(i)`) and must write
/// `out[k]` for every `k < block.len()` — raw integrand values, **no**
/// Jacobian factor (the engine applies `block.jacobians()` during
/// reduction). The scalar [`Integrand::eval`] bridge builds a one-point
/// block, so anything that only needs single points (baselines with no
/// batch path, debugging) still works.
pub struct FnBatchIntegrand<F> {
    f: F,
    dim: usize,
    bounds: Bounds,
    hull: (f64, f64),
    name: String,
    true_value: Option<f64>,
    symmetric: bool,
}

impl<F> FnBatchIntegrand<F>
where
    F: Fn(&PointBlock, &mut [f64]) + Send + Sync,
{
    /// Wrap a batch closure over an arbitrary box. Fails if
    /// `bounds.dim() != dim`.
    pub fn new(dim: usize, bounds: Bounds, f: F) -> Result<FnBatchIntegrand<F>> {
        if bounds.dim() != dim {
            return Err(crate::error::Error::Config(format!(
                "bounds dimension {} != integrand dimension {dim}",
                bounds.dim()
            )));
        }
        let hull = bounds.hull();
        Ok(FnBatchIntegrand {
            f,
            dim,
            bounds,
            hull,
            name: "batch-closure".to_string(),
            true_value: None,
            symmetric: false,
        })
    }

    /// Wrap a batch closure over the unit box `[0, 1]^dim`.
    pub fn unit(dim: usize, f: F) -> FnBatchIntegrand<F> {
        // lint:allow(MC005, structurally infallible — Bounds::unit(dim) always has exactly dim axes)
        Self::new(dim, Bounds::unit(dim), f).expect("unit bounds always match")
    }

    /// Attach a display name (shows up in service results and reports).
    pub fn named(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    /// Attach a known reference value (enables accuracy reporting).
    pub fn with_true_value(mut self, v: f64) -> Self {
        self.true_value = Some(v);
        self
    }

    /// Declare the integrand symmetric across axes (m-Cubes1D valid).
    pub fn assume_symmetric(mut self) -> Self {
        self.symmetric = true;
        self
    }

    /// Erase into a shared `IntegrandRef` handle.
    pub fn into_ref(self) -> IntegrandRef
    where
        F: 'static,
    {
        Arc::new(self)
    }
}

impl<F> Integrand for FnBatchIntegrand<F>
where
    F: Fn(&PointBlock, &mut [f64]) + Send + Sync,
{
    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn lo(&self) -> f64 {
        self.hull.0
    }

    fn hi(&self) -> f64 {
        self.hull.1
    }

    fn eval(&self, x: &[f64]) -> f64 {
        // Scalar bridge: a one-point block through the batch closure.
        // Allocates two Vecs per call — fine for debugging and spot
        // checks, but hot loops must go through eval_batch (every
        // engine/baseline path does).
        let mut block = PointBlock::with_capacity(self.dim, 1);
        block.push_point(x, 1.0);
        let mut out = [0.0f64];
        (self.f)(&block, &mut out);
        out[0]
    }

    #[inline]
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        (self.f)(block, &mut out[..block.len()]);
    }

    fn true_value(&self) -> Option<f64> {
        self.true_value
    }

    fn symmetric(&self) -> bool {
        self.symmetric
    }

    fn bounds(&self) -> Bounds {
        self.bounds.clone()
    }
}

/// What to integrate: a registry name or a user-supplied integrand.
///
/// The registry form stays artifact-addressable (the PJRT backend
/// selects compiled kernels by registry name); the custom form carries
/// any `Integrand`, including `FnIntegrand` closures.
#[derive(Clone)]
pub enum IntegrandSpec {
    /// A named integrand from `integrands::by_name` at a dimension.
    Registry { name: String, dim: usize },
    /// A user-supplied integrand handle.
    Custom(IntegrandRef),
}

impl IntegrandSpec {
    /// Spec for a registry integrand.
    pub fn registry(name: impl Into<String>, dim: usize) -> IntegrandSpec {
        IntegrandSpec::Registry {
            name: name.into(),
            dim,
        }
    }

    /// Spec wrapping a custom integrand.
    pub fn custom(f: IntegrandRef) -> IntegrandSpec {
        IntegrandSpec::Custom(f)
    }

    /// Human-readable label (registry name or the integrand's name).
    pub fn label(&self) -> String {
        match self {
            IntegrandSpec::Registry { name, .. } => name.clone(),
            IntegrandSpec::Custom(f) => f.name().to_string(),
        }
    }

    /// Dimension of the integral.
    pub fn dim(&self) -> usize {
        match self {
            IntegrandSpec::Registry { dim, .. } => *dim,
            IntegrandSpec::Custom(f) => f.dim(),
        }
    }

    /// Registry name, when artifact-addressable.
    pub fn registry_name(&self) -> Option<&str> {
        match self {
            IntegrandSpec::Registry { name, .. } => Some(name),
            IntegrandSpec::Custom(_) => None,
        }
    }

    /// Resolve to a callable integrand handle.
    pub fn resolve(&self) -> Result<IntegrandRef> {
        match self {
            IntegrandSpec::Registry { name, dim } => by_name(name, *dim),
            IntegrandSpec::Custom(f) => Ok(Arc::clone(f)),
        }
    }
}

impl fmt::Debug for IntegrandSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IntegrandSpec::Registry { name, dim } => {
                write!(f, "IntegrandSpec::Registry({name}, d={dim})")
            }
            IntegrandSpec::Custom(g) => {
                write!(f, "IntegrandSpec::Custom({}, d={})", g.name(), g.dim())
            }
        }
    }
}

impl From<IntegrandRef> for IntegrandSpec {
    fn from(f: IntegrandRef) -> Self {
        IntegrandSpec::Custom(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_integrand_evaluates_closure() {
        let f = FnIntegrand::unit(2, |x: &[f64]| x[0] * x[1])
            .named("xy")
            .with_true_value(0.25);
        assert_eq!(f.name(), "xy");
        assert_eq!(f.dim(), 2);
        assert_eq!(f.eval(&[0.5, 0.4]), 0.2);
        assert_eq!(f.true_value(), Some(0.25));
        assert_eq!(f.bounds(), Bounds::unit(2));
    }

    #[test]
    fn fn_integrand_per_axis_hull() {
        let b = Bounds::per_axis(&[(0.0, 2.0), (-1.0, 1.0)]).unwrap();
        let f = FnIntegrand::new(2, b.clone(), |_: &[f64]| 1.0).unwrap();
        assert_eq!(f.bounds(), b);
        assert_eq!((f.lo(), f.hi()), (-1.0, 2.0));
    }

    #[test]
    fn fn_integrand_dim_mismatch_rejected() {
        assert!(FnIntegrand::new(3, Bounds::unit(2), |_: &[f64]| 0.0).is_err());
    }

    #[test]
    fn batch_integrand_builders_and_scalar_bridge() {
        let f = FnBatchIntegrand::unit(2, |block: &PointBlock, out: &mut [f64]| {
            let (x, y) = (block.axis(0), block.axis(1));
            for (k, o) in out.iter_mut().enumerate() {
                *o = x[k] * y[k];
            }
        })
        .named("xy-batch")
        .with_true_value(0.25)
        .assume_symmetric();
        assert_eq!(f.name(), "xy-batch");
        assert_eq!(f.dim(), 2);
        assert_eq!(f.true_value(), Some(0.25));
        assert!(f.symmetric());
        assert_eq!(f.bounds(), Bounds::unit(2));
        // Scalar bridge builds a one-point block.
        assert_eq!(f.eval(&[0.5, 0.4]), 0.2);
        // Batch path writes every slot.
        let mut block = PointBlock::with_capacity(2, 3);
        block.push_point(&[0.5, 0.4], 1.0);
        block.push_point(&[1.0, 0.25], 1.0);
        block.push_point(&[0.0, 0.9], 1.0);
        let mut out = [9.0f64; 3];
        f.eval_batch(&block, &mut out);
        assert_eq!(out, [0.2, 0.25, 0.0]);
    }

    #[test]
    fn batch_integrand_dim_mismatch_rejected() {
        assert!(
            FnBatchIntegrand::new(3, Bounds::unit(2), |_: &PointBlock, _: &mut [f64]| {}).is_err()
        );
    }

    #[test]
    fn batch_integrand_per_axis_hull() {
        let b = Bounds::per_axis(&[(0.0, 2.0), (-1.0, 1.0)]).unwrap();
        let f = FnBatchIntegrand::new(2, b.clone(), |_: &PointBlock, out: &mut [f64]| {
            out.fill(1.0)
        })
        .unwrap();
        assert_eq!(f.bounds(), b);
        assert_eq!((f.lo(), f.hi()), (-1.0, 2.0));
    }

    #[test]
    fn spec_resolution() {
        let reg = IntegrandSpec::registry("f4", 5);
        assert_eq!(reg.label(), "f4");
        assert_eq!(reg.dim(), 5);
        assert_eq!(reg.registry_name(), Some("f4"));
        assert!(reg.resolve().is_ok());

        let bad = IntegrandSpec::registry("nope", 3);
        assert!(bad.resolve().is_err());

        let custom =
            IntegrandSpec::custom(FnIntegrand::unit(1, |x: &[f64]| x[0]).named("id").into_ref());
        assert_eq!(custom.label(), "id");
        assert_eq!(custom.dim(), 1);
        assert_eq!(custom.registry_name(), None);
        assert!(custom.resolve().is_ok());
    }
}
