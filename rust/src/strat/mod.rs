//! Stratification layout — sub-cube decomposition of the unit hypercube.
//!
//! Mirrors `python/compile/layout.py` exactly; the manifest carries the
//! Python-computed numbers and `Layout::compute` must reproduce them
//! (checked by `runtime::registry` on load and by unit tests here).
//!
//! The [`alloc`] submodule carries the VEGAS+ side of stratification:
//! the per-cube sample [`Allocation`] with its damped-variance
//! accumulator, and the user-facing [`Sampling`] strategy switch
//! (uniform m-Cubes vs VEGAS+ adaptive counts). See
//! `docs/sampling.md` for the algorithm-level comparison.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

pub mod alloc;

pub use alloc::{AllocStats, Allocation, Sampling, DEFAULT_BETA, MIN_SAMPLES_PER_CUBE};

use crate::error::{Error, Result};

/// Per-axis integration bounds — the physical box the unit hypercube
/// is affinely mapped onto.
///
/// The seed implementation assumed the same `[lo, hi]` on every axis
/// (the `Integrand::lo()/hi()` uniform box); `Bounds` generalizes that
/// to an arbitrary axis-aligned box while keeping the uniform case
/// bit-identical: for axis `i`, `x_i = lo_i + z_i * (hi_i - lo_i)` with
/// `z` the unit-box sample, and the Jacobian is `volume()`.
#[derive(Debug, Clone, PartialEq)]
pub struct Bounds {
    lo: Vec<f64>,
    hi: Vec<f64>,
}

impl Bounds {
    /// The unit box `[0, 1]^d`.
    pub fn unit(d: usize) -> Bounds {
        Bounds::uniform(d, 0.0, 1.0)
    }

    /// The uniform box `[lo, hi]^d` (the legacy `lo()/hi()` contract).
    ///
    /// Panics on a degenerate box (`lo >= hi`) — this is a programmer
    /// error in an `Integrand` impl, surfaced loudly rather than as a
    /// silent zero-volume estimate. Use [`Bounds::per_axis`] for
    /// fallible validation of user-supplied bounds. (Inside the job
    /// service the panic is caught and reported as that job's error.)
    pub fn uniform(d: usize, lo: f64, hi: f64) -> Bounds {
        assert!(d >= 1, "dimension must be >= 1");
        assert!(lo < hi, "need lo < hi, got [{lo}, {hi}]");
        Bounds {
            lo: vec![lo; d],
            hi: vec![hi; d],
        }
    }

    /// Arbitrary per-axis `(lo, hi)` pairs. Validates each axis.
    pub fn per_axis(pairs: &[(f64, f64)]) -> Result<Bounds> {
        if pairs.is_empty() {
            return Err(Error::Config("bounds need at least one axis".into()));
        }
        for (i, &(lo, hi)) in pairs.iter().enumerate() {
            if !lo.is_finite() || !hi.is_finite() {
                return Err(Error::Config(format!(
                    "axis {i}: bounds must be finite, got [{lo}, {hi}]"
                )));
            }
            if !(lo < hi) {
                return Err(Error::Config(format!(
                    "axis {i}: need lo < hi, got [{lo}, {hi}]"
                )));
            }
        }
        Ok(Bounds {
            lo: pairs.iter().map(|p| p.0).collect(),
            hi: pairs.iter().map(|p| p.1).collect(),
        })
    }

    /// Number of axes.
    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    /// Lower bound of one axis.
    #[inline]
    pub fn lo(&self, axis: usize) -> f64 {
        self.lo[axis]
    }

    /// Upper bound of one axis.
    #[inline]
    pub fn hi(&self, axis: usize) -> f64 {
        self.hi[axis]
    }

    /// Width of one axis.
    #[inline]
    pub fn span(&self, axis: usize) -> f64 {
        self.hi[axis] - self.lo[axis]
    }

    /// Volume of the box (the global Jacobian of the unit-box map).
    pub fn volume(&self) -> f64 {
        (0..self.dim()).map(|i| self.span(i)).product()
    }

    /// `Some((lo, hi))` when every axis shares the same bounds — the
    /// case legacy `Integrand::lo()/hi()` callers can represent.
    pub fn as_uniform(&self) -> Option<(f64, f64)> {
        let (lo, hi) = (self.lo[0], self.hi[0]);
        if self.lo.iter().all(|&l| l == lo) && self.hi.iter().all(|&h| h == hi) {
            Some((lo, hi))
        } else {
            None
        }
    }

    /// Smallest uniform box containing this one (legacy hull).
    pub fn hull(&self) -> (f64, f64) {
        let lo = self.lo.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = self.hi.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (lo, hi)
    }

    /// Hot-loop setup: unpack per-axis `lo` and `span` into
    /// caller-provided arrays (first `dim()` slots) and return the box
    /// volume. One definition shared by every sampler (engine,
    /// stratified engine, gVegas-sim) so the affine map can't diverge.
    pub fn unpack(&self, lo_out: &mut [f64], span_out: &mut [f64]) -> f64 {
        let d = self.dim();
        assert!(lo_out.len() >= d && span_out.len() >= d, "unpack buffers too small");
        let mut vol = 1.0f64;
        for i in 0..d {
            lo_out[i] = self.lo(i);
            span_out[i] = self.span(i);
            vol *= span_out[i];
        }
        vol
    }

    /// Affine map of a unit-box point into this box.
    pub fn map_unit(&self, z: &[f64], out: &mut [f64]) {
        assert_eq!(z.len(), self.dim());
        assert_eq!(out.len(), self.dim());
        for i in 0..self.dim() {
            out[i] = self.lo[i] + z[i] * self.span(i);
        }
    }

    /// The per-axis `(lo, hi)` pairs.
    pub fn to_pairs(&self) -> Vec<(f64, f64)> {
        self.lo.iter().cloned().zip(self.hi.iter().cloned()).collect()
    }
}

/// The paper's Algorithm-2 derived quantities (lines 3-8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout {
    /// Dimensionality of the integral.
    pub d: usize,
    /// Importance bins per axis.
    pub nb: usize,
    /// Stratification intervals per axis.
    pub g: usize,
    /// Number of sub-cubes, `g^d`.
    pub m: usize,
    /// Samples per sub-cube (uniform across cubes — the m-Cubes
    /// workload-balance contribution).
    pub p: usize,
    /// Grid programs / thread groups.
    pub nblocks: usize,
    /// Cubes per block (last block may be padded).
    pub cpb: usize,
}

impl Layout {
    /// Compute the layout from (d, maxcalls) per Algorithm 2.
    pub fn compute(d: usize, maxcalls: usize, nb: usize, nblocks: usize) -> Result<Layout> {
        if d < 1 {
            return Err(Error::Config(format!("dimension must be >= 1, got {d}")));
        }
        if maxcalls < 4 {
            return Err(Error::Config(format!("maxcalls must be >= 4, got {maxcalls}")));
        }
        let mut g = ((maxcalls as f64 / 2.0).powf(1.0 / d as f64)).floor() as usize;
        g = g.max(1);
        // Guard fp rounding, same as the Python twin (checked_pow: an
        // overflowing candidate can never satisfy `<= maxcalls / 2`).
        while (g + 1)
            .checked_pow(d as u32)
            .is_some_and(|v| v <= maxcalls / 2)
        {
            g += 1;
        }
        let m = g.checked_pow(d as u32).ok_or_else(|| {
            Error::Config(format!("cube count g^d = {g}^{d} overflows usize"))
        })?;
        let p = (maxcalls / m).max(2);
        let nblocks = nblocks.clamp(1, m);
        let cpb = m.div_ceil(nblocks);
        // Shrink away fully-empty trailing blocks (cpb rounding can
        // leave grid programs with zero cubes).
        let nblocks = m.div_ceil(cpb);
        let layout = Layout {
            d,
            nb,
            g,
            m,
            p,
            nblocks,
            cpb,
        };
        // Total calls are bounded by the 64-bit Philox counter
        // capacity (2^56 sample indices); beyond that the stream would
        // wrap, so refuse loudly instead of sampling garbage.
        layout.validate()?;
        Ok(layout)
    }

    /// Validate a layout's invariants — the checks [`Layout::compute`]
    /// guarantees by construction, made explicit so hand-built layouts
    /// (the fields are public) can't smuggle degenerate shapes into
    /// the engines:
    ///
    /// * `d >= 1`, `g >= 1`, `m == g^d`;
    /// * `p >= 2` — the per-cube variance estimate divides by
    ///   `p - 1`, so a single-sample cube would turn the whole
    ///   estimate into NaN;
    /// * total calls `m * p` fit the 64-bit Philox counter capacity
    ///   ([`crate::rng::MAX_SAMPLE_INDEX`], 2^56) — sample indices are
    ///   64-bit end to end, so layouts beyond 2^32 calls integrate
    ///   correctly, and only the (astronomical) 2^56 cap is rejected.
    ///
    /// Both engines assert this on entry.
    pub fn validate(&self) -> Result<()> {
        if self.d < 1 {
            return Err(Error::Config(format!(
                "layout dimension must be >= 1, got {}",
                self.d
            )));
        }
        if self.g < 1 {
            return Err(Error::Config(format!(
                "layout needs g >= 1 stratification intervals, got {}",
                self.g
            )));
        }
        if self.g.checked_pow(self.d as u32) != Some(self.m) {
            return Err(Error::Config(format!(
                "layout cube count m = {} != g^d = {}^{}",
                self.m, self.g, self.d
            )));
        }
        if self.p < 2 {
            return Err(Error::Config(format!(
                "layout has p = {} samples per cube; the per-cube variance \
                 estimate divides by p - 1, so p >= 2 is required",
                self.p
            )));
        }
        let total = (self.m as u128) * (self.p as u128);
        if total > crate::rng::MAX_SAMPLE_INDEX as u128 {
            return Err(Error::Config(format!(
                "layout asks for {total} calls per iteration, beyond the \
                 2^56 Philox sample-counter capacity — shrink maxcalls"
            )));
        }
        Ok(())
    }

    /// Function evaluations per iteration.
    pub fn calls(&self) -> usize {
        self.m * self.p
    }

    /// Decode flat cube index -> lattice coordinates (digit i base g).
    /// Must match `sampling.cube_coords`.
    #[inline]
    pub fn cube_coords(&self, cube: usize, out: &mut [usize]) {
        let mut idx = cube;
        for slot in out.iter_mut().take(self.d) {
            *slot = idx % self.g;
            idx /= self.g;
        }
    }

    /// Re-encode lattice coordinates -> flat cube index.
    pub fn cube_index(&self, coords: &[usize]) -> usize {
        let mut idx = 0usize;
        for &c in coords.iter().rev() {
            idx = idx * self.g + c;
        }
        idx
    }
}

/// The paper's Set-Batch-Size heuristic (Algorithm 2 line 5): how many
/// sub-cubes one worker processes serially. Mirrors
/// `layout.batch_size_heuristic`.
pub fn batch_size_heuristic(maxcalls: usize) -> usize {
    if maxcalls <= (1 << 15) {
        1
    } else if maxcalls <= (1 << 20) {
        2
    } else if maxcalls <= (1 << 25) {
        4
    } else {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_uniform_roundtrip() {
        let b = Bounds::uniform(3, -1.0, 1.0);
        assert_eq!(b.dim(), 3);
        assert_eq!(b.as_uniform(), Some((-1.0, 1.0)));
        assert_eq!(b.volume(), 8.0);
        assert_eq!(b.hull(), (-1.0, 1.0));
        let mut out = [0.0; 3];
        b.map_unit(&[0.0, 0.5, 1.0], &mut out);
        assert_eq!(out, [-1.0, 0.0, 1.0]);
    }

    #[test]
    fn bounds_per_axis() {
        let b = Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)]).unwrap();
        assert_eq!(b.dim(), 2);
        assert_eq!(b.as_uniform(), None);
        assert_eq!(b.volume(), 4.0);
        assert_eq!(b.span(1), 2.0);
        assert_eq!(b.hull(), (0.0, 3.0));
        assert_eq!(b.to_pairs(), vec![(0.0, 2.0), (1.0, 3.0)]);
    }

    #[test]
    fn bounds_reject_bad_axes() {
        assert!(Bounds::per_axis(&[]).is_err());
        assert!(Bounds::per_axis(&[(1.0, 1.0)]).is_err());
        assert!(Bounds::per_axis(&[(2.0, 1.0)]).is_err());
        assert!(Bounds::per_axis(&[(0.0, f64::INFINITY)]).is_err());
        assert!(Bounds::per_axis(&[(f64::NAN, 1.0)]).is_err());
    }

    #[test]
    fn layout_matches_paper_rule() {
        let l = Layout::compute(5, 1 << 14, 50, 8).unwrap();
        assert_eq!(l.m, l.g.pow(5));
        assert!(l.p >= 2);
        assert_eq!(l.calls(), l.m * l.p);
        // g is maximal with g^d <= maxcalls/2
        assert!((l.g + 1).pow(5) > (1 << 14) / 2);
        assert!(l.g.pow(5) <= (1 << 14) / 2);
    }

    #[test]
    fn layout_matches_python_values() {
        // Values printed by python compute_layout(5, 4096, 20, 4):
        // g=4, m=1024, p=4, cpb=256
        let l = Layout::compute(5, 4096, 20, 4).unwrap();
        assert_eq!((l.g, l.m, l.p, l.cpb), (4, 1024, 4, 256));
        // compute_layout(6, 16384, 50, 8): g = floor(8192^(1/6)) = 4
        let l = Layout::compute(6, 16384, 50, 8).unwrap();
        assert_eq!(l.g, 4);
        assert_eq!(l.m, 4096);
        assert_eq!(l.p, 4);
    }

    #[test]
    fn blocks_cover_cubes() {
        for (d, mc, nbk) in [(3, 5000, 8), (6, 16384, 8), (2, 100, 16), (9, 16384, 8)] {
            let l = Layout::compute(d, mc, 50, nbk).unwrap();
            assert!(l.cpb * l.nblocks >= l.m, "{l:?}");
            assert!(l.cpb * (l.nblocks - 1) < l.m, "{l:?} wastes a block");
        }
    }

    #[test]
    fn cube_coords_roundtrip() {
        let l = Layout::compute(4, 10_000, 50, 8).unwrap();
        let mut buf = [0usize; 4];
        for cube in 0..l.m {
            l.cube_coords(cube, &mut buf);
            assert!(buf.iter().all(|&c| c < l.g));
            assert_eq!(l.cube_index(&buf), cube);
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Layout::compute(0, 100, 50, 8).is_err());
        assert!(Layout::compute(3, 2, 50, 8).is_err());
    }

    /// Regression for the sample-counter truncation bug: a layout
    /// straddling the 2^32-call boundary is valid (the sample-index
    /// pipeline is 64-bit) and reports its call count untruncated.
    #[test]
    #[cfg(target_pointer_width = "64")]
    fn layout_past_u32_calls_is_valid_and_untruncated() {
        let l = Layout::compute(1, 1usize << 33, 50, 8).unwrap();
        assert_eq!(l.calls(), 1usize << 33);
        assert!(l.calls() > u32::MAX as usize);
        assert!(l.validate().is_ok());
        // The old pipeline computed `(cube * p + k) as u32`; make sure
        // the layout arithmetic itself can't collapse below 2^32.
        assert_eq!((l.m as u64) * (l.p as u64), 1u64 << 33);
    }

    /// Beyond the 2^56 Philox counter capacity the layout is rejected
    /// with a clear message — never a silent wrap.
    #[test]
    #[cfg(target_pointer_width = "64")]
    fn layout_beyond_counter_capacity_is_rejected() {
        let err = Layout::compute(1, 1usize << 60, 50, 8).unwrap_err();
        assert!(
            err.to_string().contains("counter capacity"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn validate_rejects_single_sample_cubes() {
        let mut l = Layout::compute(3, 4096, 20, 4).unwrap();
        assert!(l.validate().is_ok());
        l.p = 1;
        let err = l.validate().unwrap_err();
        assert!(
            err.to_string().contains("p >= 2 is required"),
            "unexpected error: {err}"
        );
        l.p = 0;
        assert!(l.validate().is_err());
    }

    #[test]
    fn validate_rejects_inconsistent_cube_count() {
        let mut l = Layout::compute(3, 4096, 20, 4).unwrap();
        l.m += 1;
        assert!(l.validate().is_err());
        l.m = 0;
        l.g = 0;
        assert!(l.validate().is_err());
    }

    #[test]
    fn batch_size_ladder() {
        assert_eq!(batch_size_heuristic(1 << 14), 1);
        assert_eq!(batch_size_heuristic(1 << 18), 2);
        assert_eq!(batch_size_heuristic(1 << 22), 4);
        assert_eq!(batch_size_heuristic(1 << 28), 8);
    }
}
