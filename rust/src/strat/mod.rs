//! Stratification layout — sub-cube decomposition of the unit hypercube.
//!
//! Mirrors `python/compile/layout.py` exactly; the manifest carries the
//! Python-computed numbers and `Layout::compute` must reproduce them
//! (checked by `runtime::registry` on load and by unit tests here).

use crate::error::{Error, Result};

/// The paper's Algorithm-2 derived quantities (lines 3-8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout {
    /// Dimensionality of the integral.
    pub d: usize,
    /// Importance bins per axis.
    pub nb: usize,
    /// Stratification intervals per axis.
    pub g: usize,
    /// Number of sub-cubes, `g^d`.
    pub m: usize,
    /// Samples per sub-cube (uniform across cubes — the m-Cubes
    /// workload-balance contribution).
    pub p: usize,
    /// Grid programs / thread groups.
    pub nblocks: usize,
    /// Cubes per block (last block may be padded).
    pub cpb: usize,
}

impl Layout {
    /// Compute the layout from (d, maxcalls) per Algorithm 2.
    pub fn compute(d: usize, maxcalls: usize, nb: usize, nblocks: usize) -> Result<Layout> {
        if d < 1 {
            return Err(Error::Config(format!("dimension must be >= 1, got {d}")));
        }
        if maxcalls < 4 {
            return Err(Error::Config(format!("maxcalls must be >= 4, got {maxcalls}")));
        }
        let mut g = ((maxcalls as f64 / 2.0).powf(1.0 / d as f64)).floor() as usize;
        g = g.max(1);
        // Guard fp rounding, same as the Python twin.
        while (g + 1).pow(d as u32) <= maxcalls / 2 {
            g += 1;
        }
        let m = g.pow(d as u32);
        let p = (maxcalls / m).max(2);
        let nblocks = nblocks.clamp(1, m);
        let cpb = m.div_ceil(nblocks);
        // Shrink away fully-empty trailing blocks (cpb rounding can
        // leave grid programs with zero cubes).
        let nblocks = m.div_ceil(cpb);
        Ok(Layout {
            d,
            nb,
            g,
            m,
            p,
            nblocks,
            cpb,
        })
    }

    /// Function evaluations per iteration.
    pub fn calls(&self) -> usize {
        self.m * self.p
    }

    /// Decode flat cube index -> lattice coordinates (digit i base g).
    /// Must match `sampling.cube_coords`.
    #[inline]
    pub fn cube_coords(&self, cube: usize, out: &mut [usize]) {
        let mut idx = cube;
        for slot in out.iter_mut().take(self.d) {
            *slot = idx % self.g;
            idx /= self.g;
        }
    }

    /// Re-encode lattice coordinates -> flat cube index.
    pub fn cube_index(&self, coords: &[usize]) -> usize {
        let mut idx = 0usize;
        for &c in coords.iter().rev() {
            idx = idx * self.g + c;
        }
        idx
    }
}

/// The paper's Set-Batch-Size heuristic (Algorithm 2 line 5): how many
/// sub-cubes one worker processes serially. Mirrors
/// `layout.batch_size_heuristic`.
pub fn batch_size_heuristic(maxcalls: usize) -> usize {
    if maxcalls <= (1 << 15) {
        1
    } else if maxcalls <= (1 << 20) {
        2
    } else if maxcalls <= (1 << 25) {
        4
    } else {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_matches_paper_rule() {
        let l = Layout::compute(5, 1 << 14, 50, 8).unwrap();
        assert_eq!(l.m, l.g.pow(5));
        assert!(l.p >= 2);
        assert_eq!(l.calls(), l.m * l.p);
        // g is maximal with g^d <= maxcalls/2
        assert!((l.g + 1).pow(5) > (1 << 14) / 2);
        assert!(l.g.pow(5) <= (1 << 14) / 2);
    }

    #[test]
    fn layout_matches_python_values() {
        // Values printed by python compute_layout(5, 4096, 20, 4):
        // g=4, m=1024, p=4, cpb=256
        let l = Layout::compute(5, 4096, 20, 4).unwrap();
        assert_eq!((l.g, l.m, l.p, l.cpb), (4, 1024, 4, 256));
        // compute_layout(6, 16384, 50, 8): g = floor(8192^(1/6)) = 4
        let l = Layout::compute(6, 16384, 50, 8).unwrap();
        assert_eq!(l.g, 4);
        assert_eq!(l.m, 4096);
        assert_eq!(l.p, 4);
    }

    #[test]
    fn blocks_cover_cubes() {
        for (d, mc, nbk) in [(3, 5000, 8), (6, 16384, 8), (2, 100, 16), (9, 16384, 8)] {
            let l = Layout::compute(d, mc, 50, nbk).unwrap();
            assert!(l.cpb * l.nblocks >= l.m, "{l:?}");
            assert!(l.cpb * (l.nblocks - 1) < l.m, "{l:?} wastes a block");
        }
    }

    #[test]
    fn cube_coords_roundtrip() {
        let l = Layout::compute(4, 10_000, 50, 8).unwrap();
        let mut buf = [0usize; 4];
        for cube in 0..l.m {
            l.cube_coords(cube, &mut buf);
            assert!(buf.iter().all(|&c| c < l.g));
            assert_eq!(l.cube_index(&buf), cube);
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Layout::compute(0, 100, 50, 8).is_err());
        assert!(Layout::compute(3, 2, 50, 8).is_err());
    }

    #[test]
    fn batch_size_ladder() {
        assert_eq!(batch_size_heuristic(1 << 14), 1);
        assert_eq!(batch_size_heuristic(1 << 18), 2);
        assert_eq!(batch_size_heuristic(1 << 22), 4);
        assert_eq!(batch_size_heuristic(1 << 28), 8);
    }
}
