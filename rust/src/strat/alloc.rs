//! VEGAS+ adaptive sample allocation — per-cube budgets driven by
//! damped variance (`d_k^beta` weights).
//!
//! m-Cubes keeps the workload uniform: every sub-cube receives the same
//! `p` samples (the paper's GPU load-balance contribution). The VEGAS+
//! line (Lepage 2021, "VEGAS Enhanced"; cuVegas, arXiv:2408.09229)
//! instead *re-allocates* the per-iteration call budget across cubes by
//! how much each cube contributes to the total variance:
//!
//! ```text
//! d_k   <- (1 - DAMPING) * d_k + DAMPING * n_k * Var_k     (damped accumulator)
//! n_k'  =  floor + apportion(budget - m * floor; w_k = d_k^beta)
//! ```
//!
//! where `Var_k` is the sample variance of cube `k`'s estimate this
//! iteration, `beta` damps the redistribution (`beta = 0.75` is
//! Lepage's default; `beta = 0` recovers the exact uniform split), and
//! `floor = MIN_SAMPLES_PER_CUBE` keeps a variance estimate alive in
//! every cube. The integer apportionment uses largest-remainder
//! rounding with index order as the tie-break, so the allocation is a
//! deterministic function of the damped accumulator — a load-time
//! snapshot (see `api::GridState`) resumes bit-identically.
//!
//! [`Allocation`] owns the per-cube counts, their exclusive prefix sums
//! (the per-cube Philox stream offsets used by
//! `engine::stratified::vsample_stratified`), and the damped
//! accumulator. [`Sampling`] is the user-facing strategy switch carried
//! by `coordinator::JobConfig` and the `api::Integrator` builder.

// usize→u32 per-cube count casts are guarded by capacity asserts and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use crate::error::{Error, Result};
use crate::strat::Layout;

/// Minimum samples any cube receives, ever — below two samples a cube
/// has no variance estimate and can never re-earn budget.
pub const MIN_SAMPLES_PER_CUBE: u32 = 2;

/// Damping factor for the per-cube variance accumulator: the new
/// observation and the running value are averaged 50/50, so stale
/// variance decays geometrically instead of pinning the allocation.
pub const DAMPING: f64 = 0.5;

/// Lepage's default redistribution exponent.
pub const DEFAULT_BETA: f64 = 0.75;

/// Which per-cube sample allocation the engine uses.
///
/// ```
/// use mcubes::strat::Sampling;
///
/// assert_eq!(Sampling::default(), Sampling::Uniform);
/// assert_eq!(Sampling::vegas_plus(), Sampling::VegasPlus { beta: 0.75 });
/// assert!(Sampling::VegasPlus { beta: 0.75 }.validate().is_ok());
/// assert!(Sampling::VegasPlus { beta: 2.0 }.validate().is_err());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Sampling {
    /// The paper's uniform m-Cubes allocation: every sub-cube draws the
    /// same `p = maxcalls / m` samples each iteration.
    #[default]
    Uniform,
    /// VEGAS+ adaptive stratification: per-cube counts re-allocated
    /// each iteration proportionally to `d_k^beta` (damped per-cube
    /// variance). `beta = 0` reproduces the uniform split bitwise;
    /// `beta = 0.75` is the standard default (see
    /// [`Sampling::vegas_plus`]).
    VegasPlus {
        /// Redistribution exponent in `[0, 1]`.
        beta: f64,
    },
}

impl Sampling {
    /// VEGAS+ with the standard damping exponent ([`DEFAULT_BETA`]).
    pub fn vegas_plus() -> Sampling {
        Sampling::VegasPlus { beta: DEFAULT_BETA }
    }

    /// Check the strategy's parameters.
    pub fn validate(&self) -> Result<()> {
        if let Sampling::VegasPlus { beta } = *self {
            if !beta.is_finite() || !(0.0..=1.0).contains(&beta) {
                return Err(Error::Config(format!(
                    "VEGAS+ beta must lie in [0, 1] (0 = uniform split, \
                     0.75 = Lepage default), got {beta}"
                )));
            }
        }
        Ok(())
    }

    /// Short label for reports ("uniform" / "vegas+").
    pub fn label(&self) -> &'static str {
        match self {
            Sampling::Uniform => "uniform",
            Sampling::VegasPlus { .. } => "vegas+",
        }
    }
}

/// Per-iteration summary of an [`Allocation`], surfaced to observers
/// through `api::IterationEvent::alloc`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AllocStats {
    /// Smallest per-cube sample count.
    pub min: u32,
    /// Largest per-cube sample count.
    pub max: u32,
    /// Mean samples per cube (`total / m`).
    pub mean: f64,
    /// Total samples this iteration (the call budget).
    pub total: usize,
}

/// Per-cube sample allocation state for one stratification layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    /// Samples allocated to each cube this iteration.
    counts: Vec<u32>,
    /// Exclusive prefix sums of `counts` — the 64-bit Philox counter
    /// offset of each cube's first sample (the engine's sample-index
    /// pipeline is 64-bit, so budgets past 2^32 never wrap).
    offsets: Vec<u64>,
    /// Damped per-cube variance accumulator `d_k` driving reallocation.
    damped: Vec<f64>,
}

impl Allocation {
    /// The uniform m-Cubes allocation for `layout` (`p` samples per
    /// cube, zeroed accumulator).
    ///
    /// Panics when `layout.p < MIN_SAMPLES_PER_CUBE` — a cube with
    /// fewer than two samples has no variance estimate and would turn
    /// the per-cube reduction's `1 / (p - 1)` into NaN.
    /// `Layout::compute` never produces such a layout; hand-built ones
    /// must pass `Layout::validate()` first.
    pub fn uniform(layout: &Layout) -> Allocation {
        assert!(
            layout.p as u64 >= MIN_SAMPLES_PER_CUBE as u64,
            "layout has p = {} samples per cube; the per-cube variance \
             divides by p - 1, so p >= {MIN_SAMPLES_PER_CUBE} is required \
             (validate hand-built layouts with Layout::validate())",
            layout.p
        );
        // Per-cube counts are u32 (the engine's 64-bit sample space is
        // addressed via the u64 prefix-sum offsets); a single cube can
        // hold at most u32::MAX samples.
        assert!(
            layout.p <= u32::MAX as usize,
            "layout has p = {} samples per cube, beyond the u32 per-cube \
             count range — use more cubes (smaller p) for this budget",
            layout.p
        );
        let counts = vec![layout.p as u32; layout.m];
        let offsets = prefix_sums(&counts);
        Allocation {
            counts,
            offsets,
            damped: vec![0.0; layout.m],
        }
    }

    /// Rebuild an allocation from a snapshot (warm start). Validates
    /// shape and the per-cube floor; offsets are recomputed.
    pub fn from_parts(counts: Vec<u32>, damped: Vec<f64>) -> Result<Allocation> {
        if counts.is_empty() {
            return Err(Error::Config("allocation needs at least one cube".into()));
        }
        if counts.len() != damped.len() {
            return Err(Error::Config(format!(
                "allocation shape mismatch: {} counts vs {} damped entries",
                counts.len(),
                damped.len()
            )));
        }
        if let Some(c) = counts.iter().find(|&&c| c < MIN_SAMPLES_PER_CUBE) {
            return Err(Error::Config(format!(
                "allocation count {c} below the per-cube floor {MIN_SAMPLES_PER_CUBE}"
            )));
        }
        if let Some(d) = damped.iter().find(|&&d| !d.is_finite() || d < 0.0) {
            return Err(Error::Config(format!(
                "damped variance entries must be finite and >= 0, got {d}"
            )));
        }
        let offsets = prefix_sums(&counts);
        Ok(Allocation {
            counts,
            offsets,
            damped,
        })
    }

    /// Number of cubes this allocation covers.
    pub fn m(&self) -> usize {
        self.counts.len()
    }

    /// Per-cube sample counts.
    pub fn counts(&self) -> &[u32] {
        &self.counts
    }

    /// Per-cube 64-bit Philox stream offsets (exclusive prefix sums of
    /// [`Allocation::counts`]).
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Damped per-cube variance accumulator.
    pub fn damped(&self) -> &[f64] {
        &self.damped
    }

    /// Total samples this iteration.
    pub fn total(&self) -> usize {
        self.counts.iter().map(|&c| c as usize).sum()
    }

    /// Min/max/mean summary of the current counts.
    pub fn stats(&self) -> AllocStats {
        let total = self.total();
        let min = self.counts.iter().copied().min().unwrap_or(0);
        let max = self.counts.iter().copied().max().unwrap_or(0);
        AllocStats {
            min,
            max,
            mean: total as f64 / self.counts.len().max(1) as f64,
            total,
        }
    }

    /// Fold one cube's fresh variance observation (`n_k * Var_k`) into
    /// the damped accumulator.
    #[inline]
    pub fn absorb(&mut self, cube: usize, d_new: f64) {
        let d = &mut self.damped[cube];
        *d = (1.0 - DAMPING) * *d + DAMPING * d_new.max(0.0);
    }

    /// Fold a contiguous span of fresh variance observations, one per
    /// cube starting at `cube_lo` — the damped-accumulator merge the
    /// shard coordinator uses to absorb each shard's `d_new` slice.
    ///
    /// Within one iteration every cube is observed exactly once, and
    /// [`Allocation::absorb`] touches only `damped[cube]`; absorbing
    /// disjoint spans in *any* order therefore produces bitwise the
    /// same accumulator as the single-worker engine's interleaved
    /// per-cube absorbs (property-tested below).
    pub fn absorb_span(&mut self, cube_lo: usize, d_new: &[f64]) {
        for (i, &dn) in d_new.iter().enumerate() {
            self.absorb(cube_lo + i, dn);
        }
    }

    /// Re-apportion `budget` samples across cubes from the damped
    /// accumulator with weights `d_k^beta`.
    ///
    /// Invariants (property-tested):
    /// * every count >= [`MIN_SAMPLES_PER_CUBE`];
    /// * `total() == max(budget, MIN_SAMPLES_PER_CUBE * m)`;
    /// * `beta == 0` (or an all-zero accumulator) yields the exact
    ///   integer uniform split `budget / m` (+1 on the first
    ///   `budget % m` cubes) — for the m-Cubes budget `m * p` that is
    ///   exactly `p` per cube, so the Philox offsets and therefore the
    ///   whole iteration match the uniform engine bitwise.
    pub fn reallocate(&mut self, budget: usize, beta: f64) {
        let m = self.counts.len();
        // lint:allow(MC001, u32→usize widening — lossless on every supported (>=32-bit) target)
        let floor = MIN_SAMPLES_PER_CUBE as usize;
        // Per-cube counts are u32; the 64-bit sample space is reached
        // through the u64 prefix-sum offsets. A budget no cube split
        // can hold is a caller error — refuse it instead of letting
        // the `as u32` casts below wrap (the silent-truncation bug
        // class this crate rejects everywhere else).
        let ceil = u32::MAX as usize;
        assert!(
            (budget as u128) <= (m as u128) * (ceil as u128)
                && (budget as u128) <= crate::rng::MAX_SAMPLE_INDEX as u128,
            "budget {budget} exceeds the sample-count capacity of {m} \
             cubes (u32 per cube, 2^56 Philox counters total)"
        );
        let weights: Vec<f64> = self.damped.iter().map(|&d| d.max(0.0).powf(beta)).collect();
        let total_w: f64 = weights.iter().sum();
        if beta == 0.0 || !(total_w > 0.0) || !total_w.is_finite() {
            // Exact uniform split (also the fallback before any
            // variance has been observed, or if the accumulator
            // degenerated to zeros/non-finite values).
            let (q, r) = if budget >= floor * m {
                (budget / m, budget % m)
            } else {
                (floor, 0)
            };
            for (i, c) in self.counts.iter_mut().enumerate() {
                *c = (q + usize::from(i < r)) as u32;
            }
            self.offsets = prefix_sums(&self.counts);
            return;
        }

        let spendable = budget.saturating_sub(floor * m);
        let mut fracs = vec![0.0f64; m];
        let mut allocated = floor * m;
        for i in 0..m {
            let share = spendable as f64 * (weights[i] / total_w);
            let base = share.floor();
            fracs[i] = share - base;
            let base = (base as usize).min(spendable).min(ceil - floor);
            self.counts[i] = (floor + base) as u32;
            allocated += base;
        }
        // Largest-remainder rounding for the leftover samples; ties
        // break toward the lower cube index, so the result is a pure
        // function of the accumulator. (Uncapped shares leave at most
        // one unit per cube here, so the single pass reproduces the
        // historical cycling loop bit for bit.)
        if allocated < budget {
            let mut order: Vec<usize> = (0..m).collect();
            order.sort_by(|&a, &b| fracs[b].total_cmp(&fracs[a]).then(a.cmp(&b)));
            let mut left = budget - allocated;
            for &i in &order {
                if left == 0 {
                    break;
                }
                if (self.counts[i] as usize) < ceil {
                    self.counts[i] += 1;
                    left -= 1;
                }
            }
            // Anything still left means shares were clipped at the
            // u32 ceiling (cubes wanting > 2^32 samples): top cubes up
            // in index order, whole chunks — still deterministic.
            if left > 0 {
                for c in self.counts.iter_mut() {
                    if left == 0 {
                        break;
                    }
                    let grant = (ceil - *c as usize).min(left);
                    *c += grant as u32;
                    left -= grant;
                }
            }
        } else if allocated > budget {
            // Floating-point slop can only over-floor by a hair; shave
            // deterministically, never below the floor.
            let mut excess = allocated - budget;
            while excess > 0 {
                let mut progressed = false;
                for c in self.counts.iter_mut() {
                    if excess == 0 {
                        break;
                    }
                    if *c as usize > floor {
                        *c -= 1;
                        excess -= 1;
                        progressed = true;
                    }
                }
                if !progressed {
                    break;
                }
            }
        }
        self.offsets = prefix_sums(&self.counts);
    }
}

fn prefix_sums(counts: &[u32]) -> Vec<u64> {
    let mut offsets = Vec::with_capacity(counts.len());
    let mut acc = 0u64;
    for &c in counts {
        offsets.push(acc);
        acc += c as u64;
    }
    offsets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_matches_layout() {
        let layout = Layout::compute(4, 4096, 20, 1).unwrap();
        let a = Allocation::uniform(&layout);
        assert_eq!(a.m(), layout.m);
        assert_eq!(a.total(), layout.m * layout.p);
        assert_eq!(a.offsets()[0], 0);
        assert_eq!(a.offsets()[1], layout.p as u64);
        let s = a.stats();
        assert_eq!(s.min, layout.p as u32);
        assert_eq!(s.max, layout.p as u32);
        assert_eq!(s.total, layout.m * layout.p);
    }

    #[test]
    fn reallocate_preserves_budget_and_floor() {
        let layout = Layout::compute(3, 8000, 20, 1).unwrap();
        let mut a = Allocation::uniform(&layout);
        a.absorb(7, 1e4); // one hot cube
        for cube in 0..a.m() {
            if cube != 7 {
                a.absorb(cube, 1e-4);
            }
        }
        a.reallocate(8000, DEFAULT_BETA);
        assert_eq!(a.total(), 8000);
        assert!(a.counts().iter().all(|&c| c >= MIN_SAMPLES_PER_CUBE));
        assert!(
            a.counts()[7] > a.counts()[100],
            "hot cube must get more samples: {} vs {}",
            a.counts()[7],
            a.counts()[100]
        );
        for i in 1..a.m() {
            assert_eq!(
                a.offsets()[i],
                a.offsets()[i - 1] + a.counts()[i - 1] as u64
            );
        }
    }

    #[test]
    fn beta_zero_is_exact_uniform_split() {
        let layout = Layout::compute(5, 4096, 20, 1).unwrap();
        let mut a = Allocation::uniform(&layout);
        // Wildly skewed accumulator: beta = 0 must ignore it.
        for cube in 0..a.m() {
            a.absorb(cube, (cube as f64).powi(3));
        }
        a.reallocate(layout.m * layout.p, 0.0);
        assert!(a.counts().iter().all(|&c| c as usize == layout.p));
        let b = Allocation::uniform(&layout);
        assert_eq!(a.counts(), b.counts());
        assert_eq!(a.offsets(), b.offsets());
    }

    #[test]
    fn uniform_split_distributes_remainder_to_low_indices() {
        let layout = Layout::compute(2, 100, 8, 1).unwrap();
        let mut a = Allocation::uniform(&layout);
        let budget = layout.m * layout.p + 3;
        a.reallocate(budget, 0.0);
        assert_eq!(a.total(), budget);
        for i in 0..3 {
            assert_eq!(a.counts()[i] as usize, layout.p + 1);
        }
        assert_eq!(a.counts()[3] as usize, layout.p);
    }

    #[test]
    fn floor_dominates_tiny_budgets() {
        let layout = Layout::compute(3, 2000, 8, 1).unwrap();
        let mut a = Allocation::uniform(&layout);
        a.absorb(0, 5.0);
        a.reallocate(3, DEFAULT_BETA); // budget < 2m
        assert!(a.counts().iter().all(|&c| c == MIN_SAMPLES_PER_CUBE));
        assert_eq!(a.total(), 2 * a.m());
    }

    #[test]
    fn from_parts_validates() {
        assert!(Allocation::from_parts(vec![], vec![]).is_err());
        assert!(Allocation::from_parts(vec![2, 2], vec![0.0]).is_err());
        assert!(Allocation::from_parts(vec![2, 1], vec![0.0, 0.0]).is_err());
        assert!(Allocation::from_parts(vec![2, 2], vec![0.0, -1.0]).is_err());
        assert!(Allocation::from_parts(vec![2, 2], vec![0.0, f64::NAN]).is_err());
        let a = Allocation::from_parts(vec![2, 5], vec![0.1, 0.9]).unwrap();
        assert_eq!(a.offsets(), &[0, 2]);
        assert_eq!(a.total(), 7);
    }

    /// A hot cube whose share exceeds u32::MAX is clipped at the
    /// per-cube ceiling and the excess redistributed — never wrapped.
    #[test]
    #[cfg(target_pointer_width = "64")]
    fn reallocate_clips_shares_at_the_u32_ceiling() {
        let mut a = Allocation::from_parts(vec![2, 2, 2], vec![1e12, 1e-6, 1e-6]).unwrap();
        let budget = 5_000_000_000usize; // > u32::MAX, < 3 * u32::MAX
        a.reallocate(budget, 1.0);
        assert_eq!(a.total(), budget);
        assert!(a.counts().iter().all(|&c| c >= MIN_SAMPLES_PER_CUBE));
        // The hot cube saturates; the spill lands deterministically.
        assert_eq!(a.counts()[0], u32::MAX);
        let mut acc = 0u64;
        for (&o, &c) in a.offsets().iter().zip(a.counts()) {
            assert_eq!(o, acc);
            acc += c as u64;
        }
    }

    #[test]
    #[cfg(target_pointer_width = "64")]
    #[should_panic(expected = "sample-count capacity")]
    fn reallocate_rejects_budgets_beyond_count_capacity() {
        let mut a = Allocation::from_parts(vec![2, 2], vec![1.0, 1.0]).unwrap();
        a.reallocate(2 * u32::MAX as usize + 1, DEFAULT_BETA);
    }

    #[test]
    #[should_panic(expected = "p >= 2 is required")]
    fn uniform_rejects_sub_floor_layouts() {
        // A hand-built layout with p = 1 (Layout::compute never emits
        // one) must be refused before it can poison a reduction.
        let mut layout = Layout::compute(2, 64, 4, 1).unwrap();
        layout.p = 1;
        let _ = Allocation::uniform(&layout);
    }

    #[test]
    fn absorb_damps_geometrically() {
        let layout = Layout::compute(2, 64, 4, 1).unwrap();
        let mut a = Allocation::uniform(&layout);
        a.absorb(0, 8.0);
        assert_eq!(a.damped()[0], 4.0);
        a.absorb(0, 8.0);
        assert_eq!(a.damped()[0], 6.0);
        a.absorb(0, -3.0); // negative observations clamp to zero
        assert_eq!(a.damped()[0], 3.0);
    }

    /// Property: one observation per cube, delivered as disjoint spans
    /// in *any* span order, damps bitwise identically to the engine's
    /// interleaved per-cube absorbs — the coordinator's merge freedom.
    #[test]
    fn absorb_span_order_is_bitwise_neutral_across_disjoint_spans() {
        let layout = Layout::compute(3, 8000, 20, 1).unwrap();
        let obs: Vec<f64> = (0..layout.m)
            .map(|k| ((k * 2654435761usize % 997) as f64) * 0.013 + 1e-9)
            .collect();

        let mut reference = Allocation::uniform(&layout);
        reference.absorb(3, 42.0); // pre-existing accumulator state
        for (cube, &dn) in obs.iter().enumerate() {
            reference.absorb(cube, dn);
        }

        // Same observations as 5 uneven spans, absorbed back-to-front.
        let mut spans = Allocation::uniform(&layout);
        spans.absorb(3, 42.0);
        let cuts = [0, 7, layout.m / 3, layout.m / 2, layout.m - 1, layout.m];
        for w in cuts.windows(2).rev() {
            spans.absorb_span(w[0], &obs[w[0]..w[1]]);
        }

        for (a, b) in reference.damped().iter().zip(spans.damped()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // And the downstream reallocation is therefore identical too.
        reference.reallocate(8000, DEFAULT_BETA);
        spans.reallocate(8000, DEFAULT_BETA);
        assert_eq!(reference.counts(), spans.counts());
        assert_eq!(reference.offsets(), spans.offsets());
    }

    #[test]
    fn sampling_validates_beta() {
        assert!(Sampling::Uniform.validate().is_ok());
        assert!(Sampling::vegas_plus().validate().is_ok());
        assert!(Sampling::VegasPlus { beta: 0.0 }.validate().is_ok());
        assert!(Sampling::VegasPlus { beta: 1.0 }.validate().is_ok());
        assert!(Sampling::VegasPlus { beta: -0.1 }.validate().is_err());
        assert!(Sampling::VegasPlus { beta: 1.5 }.validate().is_err());
        assert!(Sampling::VegasPlus { beta: f64::NAN }.validate().is_err());
        assert_eq!(Sampling::Uniform.label(), "uniform");
        assert_eq!(Sampling::vegas_plus().label(), "vegas+");
    }
}
