//! Serial VEGAS — the single-threaded CPU baseline (CUBA-style).
//!
//! Algorithmically identical to the m-Cubes driver with the native
//! engine pinned to one thread; packaged separately so benches can
//! present it as the paper's "serial Vegas" comparator (§6.1) without
//! accidentally inheriting coordinator parallelism.

use super::BaselineResult;
use crate::api::RunPlan;
use crate::coordinator::{integrate_native_core, JobConfig};
use crate::integrands::IntegrandRef;

/// Run serial VEGAS to `tau_rel` with the given per-iteration budget.
///
/// Takes the shared [`IntegrandRef`] handle (what `by_name` and the
/// closure builders return) — the session core owns its integrand.
pub fn vegas_serial_integrate(
    f: &IntegrandRef,
    maxcalls: usize,
    tau_rel: f64,
    itmax: usize,
    seed: u32,
) -> BaselineResult {
    let cfg = JobConfig {
        maxcalls,
        tau_rel,
        plan: RunPlan::classic(
            itmax,
            (itmax * 2).div_ceil(3),
            if itmax > 4 { 2 } else { 0 },
        ),
        seed,
        threads: 1, // serial by definition
        ..Default::default()
    };
    match integrate_native_core(f, &cfg, None, None).map(|o| o.output) {
        Ok(o) => BaselineResult {
            integral: o.integral,
            sigma: o.sigma,
            calls_used: o.calls_used,
            iterations: o.iterations,
            total_time: o.total_time,
            converged: o.converged,
        },
        Err(_) => BaselineResult {
            integral: f64::NAN,
            sigma: f64::INFINITY,
            calls_used: 0,
            iterations: 0,
            total_time: 0.0,
            converged: false,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    #[test]
    fn serial_vegas_converges() {
        let f = by_name("f4", 5).unwrap();
        let r = vegas_serial_integrate(&f, 1 << 16, 1e-3, 25, 3);
        assert!(r.converged);
        let truth = f.true_value().unwrap();
        assert!(((r.integral - truth) / truth).abs() < 5e-3);
    }
}
