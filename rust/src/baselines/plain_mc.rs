//! Standard Monte Carlo: uniform sampling, sample-mean estimate.

use super::BaselineResult;
use crate::integrands::Integrand;
use crate::rng::uniforms_into;
use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct PlainMcConfig {
    pub calls: usize,
    pub seed: u32,
}

impl Default for PlainMcConfig {
    fn default() -> Self {
        PlainMcConfig {
            calls: 1 << 20,
            seed: 42,
        }
    }
}

/// One-shot plain MC estimate over the integrand's (per-axis) box.
pub fn plain_mc_integrate(f: &dyn Integrand, cfg: &PlainMcConfig) -> BaselineResult {
    let t0 = Instant::now();
    let d = f.dim();
    let bounds = f.bounds();
    let vol = bounds.volume();
    let mut x = vec![0.0f64; d];
    let mut u = vec![0.0f64; d];
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    for s in 0..cfg.calls {
        uniforms_into(s as u32, 0, cfg.seed, &mut u);
        bounds.map_unit(&u, &mut x);
        let v = f.eval(&x) * vol;
        s1 += v;
        s2 += v * v;
    }
    let n = cfg.calls as f64;
    let mean = s1 / n;
    let var = ((s2 / n - mean * mean).max(0.0)) / (n - 1.0);
    BaselineResult {
        integral: mean,
        sigma: var.sqrt(),
        calls_used: cfg.calls,
        iterations: 1,
        total_time: t0.elapsed().as_secs_f64(),
        converged: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    #[test]
    fn estimates_smooth_integral() {
        let f = by_name("f5", 3).unwrap();
        let r = plain_mc_integrate(
            &*f,
            &PlainMcConfig {
                calls: 200_000,
                seed: 7,
            },
        );
        let truth = f.true_value().unwrap();
        assert!(
            (r.integral - truth).abs() < 5.0 * r.sigma,
            "I={} truth={truth} sigma={}",
            r.integral,
            r.sigma
        );
    }

    #[test]
    fn sigma_shrinks_with_calls() {
        let f = by_name("f3", 3).unwrap();
        let a = plain_mc_integrate(&*f, &PlainMcConfig { calls: 10_000, seed: 1 });
        let b = plain_mc_integrate(&*f, &PlainMcConfig { calls: 160_000, seed: 1 });
        // 16x samples -> ~4x smaller sigma
        assert!(b.sigma < a.sigma / 2.0, "a={} b={}", a.sigma, b.sigma);
    }
}
