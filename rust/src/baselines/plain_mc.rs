//! Standard Monte Carlo: uniform sampling, sample-mean estimate.
//!
//! Sampling and evaluation go through the shared block evaluator
//! (`engine::accumulate_uniform_box`): same Philox stream, same affine
//! map, but one `eval_batch` call per block instead of one virtual
//! `eval` per point.

use super::BaselineResult;
use crate::engine::{accumulate_uniform_box, PointBlock, BLOCK_POINTS};
use crate::integrands::Integrand;
use std::time::Instant; // lint:allow(MC003, wall-clock timing of the baseline run for reports; never feeds sampling — Philox is the only entropy source)

#[derive(Debug, Clone, Copy)]
pub struct PlainMcConfig {
    pub calls: usize,
    pub seed: u32,
}

impl Default for PlainMcConfig {
    fn default() -> Self {
        PlainMcConfig {
            calls: 1 << 20,
            seed: 42,
        }
    }
}

/// One-shot plain MC estimate over the integrand's (per-axis) box.
pub fn plain_mc_integrate(f: &dyn Integrand, cfg: &PlainMcConfig) -> BaselineResult {
    let t0 = Instant::now();
    let d = f.dim();
    let bounds = f.bounds();
    let lo: Vec<f64> = (0..d).map(|i| bounds.lo(i)).collect();
    let hi: Vec<f64> = (0..d).map(|i| bounds.hi(i)).collect();
    let mut block = PointBlock::with_capacity(d, BLOCK_POINTS);
    let mut vals = Vec::new();
    let (s1, s2) = accumulate_uniform_box(
        f, &lo, &hi, cfg.seed, 0, 0, cfg.calls, &mut block, &mut vals,
    );
    let n = cfg.calls as f64;
    let mean = s1 / n;
    let var = ((s2 / n - mean * mean).max(0.0)) / (n - 1.0);
    BaselineResult {
        integral: mean,
        sigma: var.sqrt(),
        calls_used: cfg.calls,
        iterations: 1,
        total_time: t0.elapsed().as_secs_f64(),
        converged: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    #[test]
    fn estimates_smooth_integral() {
        let f = by_name("f5", 3).unwrap();
        let r = plain_mc_integrate(
            &*f,
            &PlainMcConfig {
                calls: 200_000,
                seed: 7,
            },
        );
        let truth = f.true_value().unwrap();
        assert!(
            (r.integral - truth).abs() < 5.0 * r.sigma,
            "I={} truth={truth} sigma={}",
            r.integral,
            r.sigma
        );
    }

    #[test]
    fn sigma_shrinks_with_calls() {
        let f = by_name("f3", 3).unwrap();
        let a = plain_mc_integrate(&*f, &PlainMcConfig { calls: 10_000, seed: 1 });
        let b = plain_mc_integrate(&*f, &PlainMcConfig { calls: 160_000, seed: 1 });
        // 16x samples -> ~4x smaller sigma
        assert!(b.sigma < a.sigma / 2.0, "a={} b={}", a.sigma, b.sigma);
    }
}
