//! The comparators the paper evaluates against (§5):
//!
//! * `vegas_serial` — single-threaded classic VEGAS (the CUBA/GSL-style
//!   CPU baseline used in the §6.1 cosmology comparison).
//! * `plain_mc` — standard Monte Carlo (GSL "PLAIN").
//! * `miser` — recursive stratified sampling (GSL MISER).
//! * `gvegas_sim` — reproduces gVegas's *design choices* (one sample set
//!   per cube per launch, every function evaluation staged through a
//!   host buffer, host-side histogram, per-launch sample cap) so the
//!   Fig. 2 comparison exercises the mechanism the paper blames for
//!   gVegas's slowdown.
//! * `zmc_sim` — ZMCintegral-style stratified sampling + heuristic tree
//!   search (Table 1 comparison).

mod gvegas_sim;
mod miser;
mod plain_mc;
mod vegas_serial;
mod zmc_sim;

pub use gvegas_sim::{gvegas_integrate, GvegasConfig, GvegasSimEngine};
pub use miser::{miser_integrate, MiserConfig};
pub use plain_mc::{plain_mc_integrate, PlainMcConfig};
pub use vegas_serial::vegas_serial_integrate;
pub use zmc_sim::{zmc_integrate, ZmcConfig};

/// Common result shape for all baselines.
#[derive(Debug, Clone)]
pub struct BaselineResult {
    pub integral: f64,
    pub sigma: f64,
    pub calls_used: usize,
    pub iterations: usize,
    pub total_time: f64,
    pub converged: bool,
}
