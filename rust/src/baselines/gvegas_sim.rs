//! gVegas simulator — reproduces the *design choices* the paper blames
//! for gVegas's slowdown (§2.3, §5.2), on our testbed:
//!
//! 1. **Every function evaluation is staged through a host buffer** —
//!    gVegas copies all evals from device to host each iteration; we
//!    materialize the full eval vector and then do the histogram /
//!    reduction from that buffer in a second pass (real memory traffic,
//!    no artificial sleeps).
//! 2. **Host-side importance histogram** — the bin contributions are
//!    accumulated on the "host pass" over the staged buffer, serially.
//! 3. **Per-launch sample cap from GPU memory** — gVegas could only fit
//!    a limited number of evaluations per launch because the buffer
//!    lives in device memory; when `maxcalls` exceeds the cap the
//!    iteration is split into multiple launches, each paying the
//!    staging + reduction overhead again.
//! 4. **One thread per sub-cube, no batching** — parallel work items
//!    are per-cube closures rather than contiguous batched loops
//!    (boxed-task dispatch overhead mirrors the poor occupancy).
//!
//! The VEGAS math itself is identical to the engine, so accuracy
//! matches m-Cubes; only the organization differs — exactly the paper's
//! claim.

// Narrowing casts (staged-buffer u16 bin indices, iteration counters)
// are audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::BaselineResult;
use crate::engine::{PointBlock, VegasMap, BLOCK_POINTS};
use crate::estimator::{Convergence, WeightedEstimator};
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::rng::uniforms_into;
use crate::strat::Layout;
use crate::util::threadpool::parallel_chunks;
use std::time::Instant; // lint:allow(MC003, wall-clock timing of the baseline run for reports; never feeds sampling — Philox is the only entropy source)

#[derive(Debug, Clone, Copy)]
pub struct GvegasConfig {
    pub maxcalls: usize,
    pub tau_rel: f64,
    pub itmax: usize,
    pub ita: usize,
    pub seed: u32,
    pub nb: usize,
    pub threads: usize,
    /// Per-launch evaluation cap (the simulated GPU-memory limit;
    /// gVegas allocated one slot per evaluation).
    pub launch_cap: usize,
}

impl Default for GvegasConfig {
    fn default() -> Self {
        GvegasConfig {
            maxcalls: 1 << 17,
            tau_rel: 1e-3,
            itmax: 15,
            ita: 10,
            seed: 42,
            nb: 50,
            threads: crate::util::threadpool::default_threads(),
            launch_cap: 1 << 16,
        }
    }
}

/// Staged evaluation record (what gVegas copies back per sample).
#[derive(Clone, Copy, Default)]
struct EvalRecord {
    v: f64,
    bins: [u16; 10], // up to 10 dims recorded, like gVegas's fixed dims
}

pub fn gvegas_integrate(f: &dyn Integrand, cfg: &GvegasConfig) -> BaselineResult {
    let t0 = Instant::now();
    let d = f.dim();
    assert!(d <= 10, "gvegas_sim supports d <= 10");
    // gVegas's per-iteration sample count is capped by device-memory
    // allocation (one buffer slot per evaluation) — the paper's §2.3
    // "number of possible samples is limited". The iteration layout is
    // therefore computed from the cap, and the iteration budget grows
    // so the *total* allowed calls matches the uncapped configuration.
    let per_iter_calls = cfg.maxcalls.min(cfg.launch_cap);
    // lint:allow(MC005, baseline bench harness — configs come from the bench drivers and a bad layout should fail fast, not propagate)
    let layout = Layout::compute(d, per_iter_calls, cfg.nb, 1).expect("layout");
    let nb = cfg.nb;

    let mut bins = Bins::uniform(d, nb);
    let mut est = WeightedEstimator::new();
    let conv = Convergence::with_tau(cfg.tau_rel);
    let mut calls_used = 0usize;
    let mut iterations = 0usize;
    let mut converged = false;

    let cap_cubes = (cfg.launch_cap / layout.p).max(1);
    // Memory-capped iterations are statistically weaker; allow the
    // iteration count to grow so the total call budget matches what the
    // uncapped driver would spend (the paper's gVegas runs many more
    // iterations than m-Cubes for the same target).
    let itmax = cfg
        .itmax
        .saturating_mul((cfg.maxcalls / per_iter_calls).max(1))
        .min(cfg.itmax * 16);
    let ita = cfg.ita.saturating_mul((cfg.maxcalls / per_iter_calls).max(1)).min(itmax);

    for it in 0..itmax {
        let mut i_iter = 0.0;
        let mut var_iter = 0.0;
        let mut contrib = vec![0.0f64; d * nb];
        // Shared VEGAS transform (identical to the engine's fill).
        let map = VegasMap::new(&layout, &bins, &f.bounds());

        // Split the iteration into launches bounded by the memory cap.
        let mut cube0 = 0usize;
        while cube0 < layout.m {
            let cube1 = (cube0 + cap_cubes).min(layout.m);
            let n_evals = (cube1 - cube0) * layout.p;
            // gVegas re-allocates its device buffers each iteration
            // (early-CUDA design); model that with a fresh allocation
            // per launch rather than a reused buffer.
            let mut staged: Vec<EvalRecord> = vec![EvalRecord::default(); n_evals];

            // "Device" phase: fill-block → eval_batch → stage. The
            // records still round-trip through the host buffer (the
            // design flaw under test). NOTE: VegasMap multiplies by a
            // precomputed 1/g where the old loop divided by g — up to
            // 1 ulp per coordinate — so gVegas samples are *not*
            // bitwise-reproducible against pre-batch versions (its
            // results are statistical, asserted at wide tolerances;
            // only the native engine carries a bitwise contract).
            let p = layout.p;
            let chunks = parallel_chunks(cube1 - cube0, cfg.threads, |a, b| {
                let mut local: Vec<(usize, EvalRecord)> = Vec::with_capacity((b - a) * p);
                let mut u = [0.0f64; 10];
                let mut coords = [0usize; 10];
                let cubes_per_block = (BLOCK_POINTS / p).max(1);
                let cap = cubes_per_block * p;
                let mut blk = PointBlock::with_capacity(d, cap);
                let mut vals = vec![0.0f64; cap];
                let mut bidx = vec![0usize; cap * d];
                let mut rel_cube = a;
                while rel_cube < b {
                    let ncubes = cubes_per_block.min(b - rel_cube);
                    let npts = ncubes * p;
                    blk.reset(npts);
                    for c in 0..ncubes {
                        let cube = cube0 + rel_cube + c;
                        layout.cube_coords(cube, &mut coords[..d]);
                        for k in 0..p {
                            let j = c * p + k;
                            let sidx = (cube * p + k) as u64;
                            uniforms_into(sidx, it as u32, cfg.seed, &mut u[..d]);
                            map.fill_point(&coords[..d], &u[..d], &mut blk, j, &mut bidx);
                        }
                    }
                    f.eval_batch(&blk, &mut vals[..npts]);
                    for j in 0..npts {
                        let mut rec = EvalRecord::default();
                        for i in 0..d {
                            // bidx holds i*nb + b; the record keeps b.
                            // lint:allow(MC001, bin index b < nb <= a few hundred — u16 staging mirrors gVegas's compact device records)
                            rec.bins[i] = (bidx[j * d + i] - i * nb) as u16;
                        }
                        rec.v = vals[j] * blk.jac(j);
                        // Staged slot: launch-relative cube index * p + k,
                        // i.e. (rel_cube + j/p)*p + j%p == rel_cube*p + j —
                        // kept in cube/sample form to mirror the staged
                        // buffer's (cube, k) addressing in the host pass.
                        local.push(((rel_cube + j / p) * p + j % p, rec));
                    }
                    // lint:allow(MC004, chunk-local integer cube cursor — not a floating-point accumulator)
                    rel_cube += ncubes;
                }
                local
            });
            // "Copy back": write the records into the staged buffer.
            for chunk in chunks {
                for (slot, rec) in chunk {
                    staged[slot] = rec;
                }
            }
            calls_used += n_evals;

            // "Host" phase: serial pass over the staged buffer for the
            // per-cube reduction AND the histogram (gVegas does
            // importance accounting on the CPU).
            let pf = layout.p as f64;
            let mf = layout.m as f64;
            for rel_cube in 0..(cube1 - cube0) {
                let base = rel_cube * layout.p;
                let mut s1 = 0.0;
                let mut s2 = 0.0;
                for k in 0..layout.p {
                    let rec = &staged[base + k];
                    s1 += rec.v;
                    s2 += rec.v * rec.v;
                    let v2 = rec.v * rec.v;
                    for i in 0..d {
                        contrib[i * nb + rec.bins[i] as usize] += v2;
                    }
                }
                let mean = s1 / pf;
                let var = ((s2 / pf - mean * mean).max(0.0)) / (pf - 1.0);
                i_iter += mean / mf;
                var_iter += var / (mf * mf);
            }
            cube0 = cube1;
        }

        iterations += 1;
        if it >= 2.min(itmax - 1) {
            est.push(crate::estimator::IterationResult {
                integral: i_iter,
                variance: var_iter,
            });
        }
        if it < ita {
            bins.adjust(&contrib);
            if est.iterations() >= 2 && est.chi2_dof() > conv.max_chi2_dof {
                est.reset();
            }
        }
        if conv.satisfied(&est) {
            converged = true;
            break;
        }
    }

    BaselineResult {
        integral: est.integral(),
        sigma: est.sigma(),
        calls_used,
        iterations,
        total_time: t0.elapsed().as_secs_f64(),
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    #[test]
    fn gvegas_sim_is_accurate() {
        // Same math as m-Cubes: must converge to the truth.
        let f = by_name("f4", 5).unwrap();
        let r = gvegas_integrate(
            &*f,
            &GvegasConfig {
                maxcalls: 1 << 16,
                tau_rel: 1e-3,
                itmax: 25,
                seed: 9,
                ..Default::default()
            },
        );
        assert!(r.converged, "{r:?}");
        let truth = f.true_value().unwrap();
        assert!(((r.integral - truth) / truth).abs() < 5e-3);
    }

    #[test]
    fn launch_cap_splits_launches() {
        let f = by_name("f5", 4).unwrap();
        let r = gvegas_integrate(
            &*f,
            &GvegasConfig {
                maxcalls: 1 << 14,
                launch_cap: 1 << 10, // force many launches
                tau_rel: 1e-3,
                itmax: 5,
                ita: 3,
                seed: 1,
                ..Default::default()
            },
        );
        assert!(r.calls_used > 0);
    }
}
