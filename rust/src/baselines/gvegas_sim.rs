//! gVegas simulator — reproduces the *design choices* the paper blames
//! for gVegas's slowdown (§2.3, §5.2), on our testbed:
//!
//! 1. **Every function evaluation is staged through a host buffer** —
//!    gVegas copies all evals from device to host each iteration; we
//!    materialize the full eval vector and then do the histogram /
//!    reduction from that buffer in a second pass (real memory traffic,
//!    no artificial sleeps).
//! 2. **Host-side importance histogram** — the bin contributions are
//!    accumulated on the "host pass" over the staged buffer, serially.
//! 3. **Per-launch sample cap from GPU memory** — gVegas could only fit
//!    a limited number of evaluations per launch because the buffer
//!    lives in device memory; when the span exceeds the cap it is
//!    split into multiple launches, each paying the staging +
//!    reduction overhead again.
//! 4. **One thread per sub-cube, no batching** — samples are filled one
//!    scalar point at a time (no SIMD span batching) and reduced
//!    serially from the staged records.
//!
//! The simulator is the third [`Engine`] impl: [`GvegasSimEngine`]
//! plugs into the same `sample_tasks` / `update` contract as the
//! uniform and VEGAS+ engines, so it runs under `EngineBackend`, the
//! shard coordinator, and `Box<dyn Engine>` dispatch unchanged — the
//! landing pad a future PAGANI engine would use. The VEGAS math itself
//! is identical to the engine, so accuracy matches m-Cubes; only the
//! organization differs — exactly the paper's claim. Unlike the native
//! engines its results carry **no bitwise contract** (scalar staging
//! reorders the accumulation), so its tests assert wide tolerances.

// Narrowing casts (staged-buffer u16 bin indices, iteration counters)
// are audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::BaselineResult;
use crate::engine::{
    reduction_tasks, reduction_task_span, Engine, ExecPath, FillPath, PointBlock, TaskPartial,
    VSampleOpts, VegasMap, BLOCK_POINTS,
};
use crate::estimator::{Convergence, WeightedEstimator};
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::rng::uniforms_into;
use crate::strat::Layout;
use crate::util::threadpool::parallel_chunks;
use std::time::Instant; // lint:allow(MC003, wall-clock timing of the baseline run for reports; never feeds sampling — Philox is the only entropy source)

#[derive(Debug, Clone, Copy)]
pub struct GvegasConfig {
    pub maxcalls: usize,
    pub tau_rel: f64,
    pub itmax: usize,
    pub ita: usize,
    pub seed: u32,
    pub nb: usize,
    pub threads: usize,
    /// Per-launch evaluation cap (the simulated GPU-memory limit;
    /// gVegas allocated one slot per evaluation).
    pub launch_cap: usize,
}

impl Default for GvegasConfig {
    fn default() -> Self {
        GvegasConfig {
            maxcalls: 1 << 17,
            tau_rel: 1e-3,
            itmax: 15,
            ita: 10,
            seed: 42,
            nb: 50,
            threads: crate::util::threadpool::default_threads(),
            launch_cap: 1 << 16,
        }
    }
}

/// Staged evaluation record (what gVegas copies back per sample).
#[derive(Clone, Copy, Default)]
struct EvalRecord {
    v: f64,
    bins: [u16; 10], // up to 10 dims recorded, like gVegas's fixed dims
}

/// The gVegas organization as an [`Engine`]: uniform per-cube sample
/// counts (like [`crate::engine::UniformEngine`]) but every evaluation
/// staged through a launch-capped host buffer with a serial host-side
/// reduce — the anti-pattern the paper measures. Stateless beyond the
/// layout ([`Engine::update`] is a no-op; no allocation state).
#[derive(Debug, Clone)]
pub struct GvegasSimEngine {
    layout: Layout,
    launch_cap: usize,
}

impl GvegasSimEngine {
    /// Build over `layout` with the simulated per-launch evaluation
    /// cap (gVegas's device-buffer size).
    pub fn new(layout: Layout, launch_cap: usize) -> GvegasSimEngine {
        assert!(layout.d <= 10, "gvegas_sim supports d <= 10");
        GvegasSimEngine {
            layout,
            launch_cap: launch_cap.max(1),
        }
    }
}

/// One reduction task's cubes, the gVegas way: launch-capped staging
/// into `EvalRecord`s ("device" phase with fresh per-launch buffers),
/// then a serial "host" pass over the staged buffer for the per-cube
/// reduction and the importance histogram.
#[allow(clippy::too_many_arguments)]
fn sample_task_staged(
    f: &dyn Integrand,
    layout: &Layout,
    map: &VegasMap,
    opts: &VSampleOpts,
    launch_cap: usize,
    task: usize,
    cube_lo: usize,
    cube_hi: usize,
) -> TaskPartial {
    let d = layout.d;
    let nb = layout.nb;
    let p = layout.p;
    let pf = p as f64;
    let mf = layout.m as f64;
    let mut integral = 0.0f64;
    let mut variance = 0.0f64;
    let mut contrib = if opts.adjust {
        Some(vec![0.0f64; d * nb])
    } else {
        None
    };
    let cap_cubes = (launch_cap / p).max(1);
    let mut u = [0.0f64; 10];
    let mut coords = [0usize; 10];
    let cubes_per_block = (BLOCK_POINTS / p).max(1);
    let cap = cubes_per_block * p;
    let mut blk = PointBlock::with_capacity(d, cap);
    let mut vals = vec![0.0f64; cap];
    let mut bidx = vec![0usize; cap * d];

    let mut cube0 = cube_lo;
    while cube0 < cube_hi {
        let cube1 = (cube0 + cap_cubes).min(cube_hi);
        let n_evals = (cube1 - cube0) * p;
        // gVegas re-allocates its device buffers each iteration
        // (early-CUDA design); model that with a fresh allocation per
        // launch rather than a reused buffer.
        let mut staged: Vec<EvalRecord> = vec![EvalRecord::default(); n_evals];

        // "Device" phase: scalar fill → eval_batch → stage. The
        // records round-trip through the host buffer (the design flaw
        // under test). NOTE: VegasMap multiplies by a precomputed 1/g
        // where the old loop divided by g — up to 1 ulp per coordinate
        // — so gVegas samples are *not* bitwise-reproducible against
        // pre-batch versions (its results are statistical, asserted at
        // wide tolerances; only the native engines carry a bitwise
        // contract).
        let mut rel_cube = 0usize;
        while rel_cube < cube1 - cube0 {
            let ncubes = cubes_per_block.min(cube1 - cube0 - rel_cube);
            let npts = ncubes * p;
            blk.reset(npts);
            for c in 0..ncubes {
                let cube = cube0 + rel_cube + c;
                layout.cube_coords(cube, &mut coords[..d]);
                for k in 0..p {
                    let j = c * p + k;
                    let sidx = (cube * p + k) as u64;
                    uniforms_into(sidx, opts.iteration, opts.seed, &mut u[..d]);
                    map.fill_point(&coords[..d], &u[..d], &mut blk, j, &mut bidx);
                }
            }
            f.eval_batch(&blk, &mut vals[..npts]);
            for j in 0..npts {
                let mut rec = EvalRecord::default();
                for i in 0..d {
                    // bidx holds i*nb + b; the record keeps b.
                    // lint:allow(MC001, bin index b < nb <= a few hundred — u16 staging mirrors gVegas's compact device records)
                    rec.bins[i] = (bidx[j * d + i] - i * nb) as u16;
                }
                rec.v = vals[j] * blk.jac(j);
                staged[rel_cube * p + j] = rec;
            }
            rel_cube += ncubes;
        }

        // "Host" phase: serial pass over the staged buffer for the
        // per-cube reduction AND the histogram (gVegas does importance
        // accounting on the CPU).
        for rel_cube in 0..(cube1 - cube0) {
            let base = rel_cube * p;
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            for k in 0..p {
                let rec = &staged[base + k];
                s1 += rec.v;
                s2 += rec.v * rec.v;
                if let Some(contrib) = contrib.as_mut() {
                    let v2 = rec.v * rec.v;
                    for i in 0..d {
                        contrib[i * nb + rec.bins[i] as usize] += v2;
                    }
                }
            }
            let mean = s1 / pf;
            let var = ((s2 / pf - mean * mean).max(0.0)) / (pf - 1.0);
            integral += mean / mf;
            variance += var / (mf * mf);
        }
        cube0 = cube1;
    }

    TaskPartial {
        task,
        cube_lo,
        cube_hi,
        integral,
        variance,
        contrib,
        d_new: Vec::new(),
    }
}

impl Engine for GvegasSimEngine {
    fn name(&self) -> &'static str {
        "gvegas-sim"
    }

    fn layout(&self) -> &Layout {
        &self.layout
    }

    /// `fill` and `exec` are accepted but ignored: the gVegas design
    /// predates both knobs (scalar staging, fixed launch granularity).
    fn sample_tasks(
        &self,
        f: &dyn Integrand,
        bins: &Bins,
        opts: &VSampleOpts,
        _fill: FillPath,
        _exec: ExecPath,
        task_lo: usize,
        task_hi: usize,
    ) -> Vec<TaskPartial> {
        let layout = &self.layout;
        assert_eq!(bins.d(), layout.d);
        assert_eq!(bins.nb(), layout.nb);
        let ntasks = reduction_tasks(layout.m);
        assert!(
            task_lo <= task_hi && task_hi <= ntasks,
            "task range [{task_lo}, {task_hi}) outside 0..{ntasks}"
        );
        let span = task_hi - task_lo;
        let launch_cap = self.launch_cap;
        let nested: Vec<Vec<TaskPartial>> = parallel_chunks(span, opts.threads, |u0, u1| {
            let map = VegasMap::new(layout, bins, &f.bounds());
            (u0..u1)
                .map(|u| {
                    let t = task_lo + u;
                    let (cube_lo, cube_hi) = reduction_task_span(layout.m, ntasks, t);
                    sample_task_staged(f, layout, &map, opts, launch_cap, t, cube_lo, cube_hi)
                })
                .collect()
        });
        nested.into_iter().flatten().collect()
    }

    fn update(&mut self, _partials: &[TaskPartial]) {}
}

pub fn gvegas_integrate(f: &dyn Integrand, cfg: &GvegasConfig) -> BaselineResult {
    let t0 = Instant::now();
    let d = f.dim();
    assert!(d <= 10, "gvegas_sim supports d <= 10");
    // gVegas's per-iteration sample count is capped by device-memory
    // allocation (one buffer slot per evaluation) — the paper's §2.3
    // "number of possible samples is limited". The iteration layout is
    // therefore computed from the cap, and the iteration budget grows
    // so the *total* allowed calls matches the uncapped configuration.
    let per_iter_calls = cfg.maxcalls.min(cfg.launch_cap);
    // lint:allow(MC005, baseline bench harness — configs come from the bench drivers and a bad layout should fail fast, not propagate)
    let layout = Layout::compute(d, per_iter_calls, cfg.nb, 1).expect("layout");
    let nb = cfg.nb;
    let per_iter_evals = layout.m * layout.p;

    let mut engine = GvegasSimEngine::new(layout, cfg.launch_cap);
    let mut bins = Bins::uniform(d, nb);
    let mut est = WeightedEstimator::new();
    let conv = Convergence::with_tau(cfg.tau_rel);
    let mut calls_used = 0usize;
    let mut iterations = 0usize;
    let mut converged = false;

    // Memory-capped iterations are statistically weaker; allow the
    // iteration count to grow so the total call budget matches what the
    // uncapped driver would spend (the paper's gVegas runs many more
    // iterations than m-Cubes for the same target).
    let itmax = cfg
        .itmax
        .saturating_mul((cfg.maxcalls / per_iter_calls).max(1))
        .min(cfg.itmax * 16);
    let ita = cfg.ita.saturating_mul((cfg.maxcalls / per_iter_calls).max(1)).min(itmax);

    for it in 0..itmax {
        let opts = VSampleOpts {
            seed: cfg.seed,
            // lint:allow(MC001, the scan crosses the field label; `it` is an iteration ordinal bounded by itmax, far below 2^32)
            iteration: it as u32,
            adjust: true,
            threads: cfg.threads,
        };
        let (r, contrib) = engine.vsample(&*f, &bins, &opts, FillPath::Simd, ExecPath::default());
        calls_used += per_iter_evals;

        iterations += 1;
        if it >= 2.min(itmax - 1) {
            est.push(r);
        }
        if it < ita {
            // lint:allow(MC005, opts.adjust is true above — vsample always returns the histogram on adjust passes)
            bins.adjust(&contrib.expect("adjust pass returns a histogram"));
            if est.iterations() >= 2 && est.chi2_dof() > conv.max_chi2_dof {
                est.reset();
            }
        }
        if conv.satisfied(&est) {
            converged = true;
            break;
        }
    }

    BaselineResult {
        integral: est.integral(),
        sigma: est.sigma(),
        calls_used,
        iterations,
        total_time: t0.elapsed().as_secs_f64(),
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    #[test]
    fn gvegas_sim_is_accurate() {
        // Same math as m-Cubes: must converge to the truth.
        let f = by_name("f4", 5).unwrap();
        let r = gvegas_integrate(
            &*f,
            &GvegasConfig {
                maxcalls: 1 << 16,
                tau_rel: 1e-3,
                itmax: 25,
                seed: 9,
                ..Default::default()
            },
        );
        assert!(r.converged, "{r:?}");
        let truth = f.true_value().unwrap();
        assert!(((r.integral - truth) / truth).abs() < 5e-3);
    }

    #[test]
    fn launch_cap_splits_launches() {
        let f = by_name("f5", 4).unwrap();
        let r = gvegas_integrate(
            &*f,
            &GvegasConfig {
                maxcalls: 1 << 14,
                launch_cap: 1 << 10, // force many launches
                tau_rel: 1e-3,
                itmax: 5,
                ita: 3,
                seed: 1,
                ..Default::default()
            },
        );
        assert!(r.calls_used > 0);
    }

    #[test]
    fn engine_surface_is_uniform_and_thread_invariant() {
        // The simulator plugs into the same trait contract as the
        // native engines: per-task partials are deterministic and
        // independent of the internal thread count, and the engine
        // carries no allocation state.
        let f = by_name("f4", 4).unwrap();
        let layout = Layout::compute(4, 2048, 12, 1).unwrap();
        let bins = Bins::uniform(4, 12);
        let engine = GvegasSimEngine::new(layout, 1 << 10);
        assert_eq!(engine.name(), "gvegas-sim");
        assert!(engine.allocation().is_none());
        assert!(engine.export().is_none());
        let ntasks = reduction_tasks(layout.m);
        let mk = |threads| VSampleOpts {
            seed: 5,
            iteration: 1,
            adjust: true,
            threads,
        };
        let a = engine.sample_tasks(
            &*f, &bins, &mk(1), FillPath::Simd, ExecPath::default(), 0, ntasks,
        );
        let b = engine.sample_tasks(
            &*f, &bins, &mk(4), FillPath::Simd, ExecPath::default(), 0, ntasks,
        );
        assert_eq!(a.len(), ntasks);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.task, y.task);
            assert_eq!(x.integral.to_bits(), y.integral.to_bits());
            assert_eq!(x.variance.to_bits(), y.variance.to_bits());
        }
        // Through Box<dyn Engine>, same bits.
        let mut boxed: Box<dyn Engine> = Box::new(engine.clone());
        let c = boxed.sample_tasks(
            &*f, &bins, &mk(2), FillPath::Simd, ExecPath::default(), 0, ntasks,
        );
        for (x, y) in a.iter().zip(c.iter()) {
            assert_eq!(x.integral.to_bits(), y.integral.to_bits());
        }
        // One full pass through the provided vsample is well-formed.
        let (r, contrib) = boxed.vsample(&*f, &bins, &mk(2), FillPath::Simd, ExecPath::default());
        assert!(r.integral.is_finite() && r.variance >= 0.0);
        assert_eq!(contrib.unwrap().len(), layout.d * layout.nb);
    }
}
