//! MISER: recursive stratified sampling (Press & Farrar; GSL variant).
//!
//! At each level, spend an exploration fraction of the budget to pick
//! the axis whose bisection minimizes combined variance, split the
//! remaining budget between the halves proportionally to their
//! estimated sigma, and recurse until the budget floor.
//!
//! Leaf/exploration sampling runs through the shared block evaluator
//! (`engine::accumulate_uniform_box`) — same Philox draws as the old
//! scalar loop, but batched `eval_batch` calls.

// Float→int budget-split casts are audited by `cargo xtask lint`
// (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::BaselineResult;
use crate::engine::{accumulate_uniform_box, PointBlock, BLOCK_POINTS};
use crate::integrands::Integrand;
use std::time::Instant; // lint:allow(MC003, wall-clock timing of the baseline run for reports; never feeds sampling — Philox is the only entropy source)

#[derive(Debug, Clone, Copy)]
pub struct MiserConfig {
    pub calls: usize,
    pub seed: u32,
    /// Fraction of each node's budget spent exploring the split.
    pub explore_frac: f64,
    /// Below this many calls a node falls back to plain MC.
    pub min_calls_leaf: usize,
}

impl Default for MiserConfig {
    fn default() -> Self {
        MiserConfig {
            calls: 1 << 20,
            seed: 42,
            explore_frac: 0.1,
            min_calls_leaf: 64,
        }
    }
}

struct MiserState<'a> {
    f: &'a dyn Integrand,
    seed: u32,
    counter: u64,
    calls_used: usize,
    /// Reused block-evaluation scratch (the recursion calls `plain`
    /// thousands of times; allocating per node would dominate).
    block: PointBlock,
    vals: Vec<f64>,
}

impl<'a> MiserState<'a> {
    /// Plain MC over [lo,hi] with n samples -> (mean, var_of_mean),
    /// through the shared block evaluator (Philox stream 1, sequential
    /// counters — the same draws as the old scalar loop).
    fn plain(&mut self, lo: &[f64], hi: &[f64], n: usize) -> (f64, f64) {
        let (s1, s2) = accumulate_uniform_box(
            self.f,
            lo,
            hi,
            self.seed,
            1,
            self.counter,
            n,
            &mut self.block,
            &mut self.vals,
        );
        self.counter += n as u64;
        self.calls_used += n;
        let nf = n as f64;
        let mean = s1 / nf;
        let var = ((s2 / nf - mean * mean).max(0.0)) / (nf - 1.0).max(1.0);
        (mean, var)
    }

    fn recurse(&mut self, lo: &mut [f64], hi: &mut [f64], budget: usize, cfg: &MiserConfig) -> (f64, f64) {
        let d = lo.len();
        if budget < cfg.min_calls_leaf * 2 {
            return self.plain(lo, hi, budget.max(2));
        }
        let explore = ((budget as f64 * cfg.explore_frac) as usize).max(4 * d).min(budget / 2);
        let per_side = (explore / (2 * d)).max(2);

        // Pick the split axis minimizing sigma_l + sigma_r (GSL uses
        // fractional exponents; the simple sum keeps the same ordering
        // for well-behaved integrands).
        let mut best_axis = 0usize;
        let mut best_score = f64::INFINITY;
        let mut best_sig = (1.0, 1.0);
        for axis in 0..d {
            let mid = 0.5 * (lo[axis] + hi[axis]);
            let keep_hi = hi[axis];
            let keep_lo = lo[axis];
            hi[axis] = mid;
            let (_, var_l) = self.plain(lo, hi, per_side);
            hi[axis] = keep_hi;
            lo[axis] = mid;
            let (_, var_r) = self.plain(lo, hi, per_side);
            lo[axis] = keep_lo;
            let (sig_l, sig_r) = (var_l.sqrt(), var_r.sqrt());
            let score = sig_l + sig_r;
            if score < best_score {
                best_score = score;
                best_axis = axis;
                best_sig = (sig_l, sig_r);
            }
        }

        let remaining = budget - explore.min(budget);
        if remaining < 2 * cfg.min_calls_leaf {
            return self.plain(lo, hi, remaining.max(2));
        }
        // Allocate budget proportionally to sigma (variance reduction).
        let (sl, sr) = best_sig;
        let frac_l = if sl + sr > 0.0 { sl / (sl + sr) } else { 0.5 };
        let n_l = ((remaining as f64 * frac_l) as usize)
            .clamp(cfg.min_calls_leaf, remaining - cfg.min_calls_leaf);
        let n_r = remaining - n_l;

        let mid = 0.5 * (lo[best_axis] + hi[best_axis]);
        let keep_hi = hi[best_axis];
        let keep_lo = lo[best_axis];
        hi[best_axis] = mid;
        let (i_l, v_l) = self.recurse(lo, hi, n_l, cfg);
        hi[best_axis] = keep_hi;
        lo[best_axis] = mid;
        let (i_r, v_r) = self.recurse(lo, hi, n_r, cfg);
        lo[best_axis] = keep_lo;
        (i_l + i_r, v_l + v_r)
    }
}

/// Run MISER over the integrand's (per-axis) box.
pub fn miser_integrate(f: &dyn Integrand, cfg: &MiserConfig) -> BaselineResult {
    let t0 = Instant::now();
    let d = f.dim();
    let bounds = f.bounds();
    let mut lo: Vec<f64> = (0..d).map(|i| bounds.lo(i)).collect();
    let mut hi: Vec<f64> = (0..d).map(|i| bounds.hi(i)).collect();
    let mut st = MiserState {
        f,
        seed: cfg.seed,
        counter: 0,
        calls_used: 0,
        block: PointBlock::with_capacity(d, BLOCK_POINTS),
        vals: Vec::new(),
    };
    let (integral, var) = st.recurse(&mut lo, &mut hi, cfg.calls, cfg);
    BaselineResult {
        integral,
        sigma: var.sqrt(),
        calls_used: st.calls_used,
        iterations: 1,
        total_time: t0.elapsed().as_secs_f64(),
        converged: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    #[test]
    fn miser_beats_plain_mc_on_corner_peak() {
        use crate::baselines::plain_mc::{plain_mc_integrate, PlainMcConfig};
        // Corner peak: recursive bisection isolates the hot corner, so
        // stratified allocation genuinely helps (a centered symmetric
        // peak is split evenly by every bisection and would not).
        let f = by_name("f3", 3).unwrap();
        let calls = 200_000;
        let m = miser_integrate(
            &*f,
            &MiserConfig {
                calls,
                seed: 5,
                ..Default::default()
            },
        );
        let p = plain_mc_integrate(&*f, &PlainMcConfig { calls, seed: 5 });
        let truth = f.true_value().unwrap();
        assert!(
            (m.integral - truth).abs() < 6.0 * m.sigma + 1e-12,
            "miser off: I={} truth={truth} sigma={}",
            m.integral,
            m.sigma
        );
        assert!(
            m.sigma < p.sigma,
            "miser {} vs plain {}",
            m.sigma,
            p.sigma
        );
    }

    #[test]
    fn budget_respected_roughly() {
        let f = by_name("f5", 4).unwrap();
        let cfg = MiserConfig {
            calls: 50_000,
            seed: 2,
            ..Default::default()
        };
        let r = miser_integrate(&*f, &cfg);
        assert!(r.calls_used <= 60_000, "used {}", r.calls_used);
        assert!(r.calls_used >= 25_000, "used {}", r.calls_used);
    }
}
