//! ZMCintegral-style integrator (Wu et al. [14]): stratified sampling
//! plus a heuristic tree search that re-samples the highest-variance
//! partitions ("important domains") for several depth levels.
//!
//! Algorithm (following the ZMCintegral paper's structure):
//!  1. Split the box into k^d blocks; run plain MC in each.
//!  2. Rank blocks by sample sigma; select the top `select_frac`.
//!  3. Recurse into the selected blocks (split again, re-sample) for
//!    `depth` levels; unselected blocks keep their estimates.
//!  4. Total = sum of block estimates; variance = sum of block variances.

//! Block sampling runs through the shared block evaluator
//! (`engine::accumulate_uniform_box`) — same Philox draws as the old
//! scalar loop, but batched `eval_batch` calls.

// Narrowing / float→int casts here are audited by `cargo xtask lint`
// (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::BaselineResult;
use crate::engine::{accumulate_uniform_box, PointBlock, BLOCK_POINTS};
use crate::integrands::Integrand;
use std::time::Instant; // lint:allow(MC003, wall-clock timing of the baseline run for reports; never feeds sampling — Philox is the only entropy source)

#[derive(Debug, Clone, Copy)]
pub struct ZmcConfig {
    /// Splits per axis at each tree level.
    pub k: usize,
    /// Samples per block per evaluation pass.
    pub samples_per_block: usize,
    /// Tree-search depth.
    pub depth: usize,
    /// Fraction of highest-sigma blocks re-explored per level.
    pub select_frac: f64,
    pub seed: u32,
    /// Cap on total blocks per level (memory guard, as in ZMC).
    pub max_blocks: usize,
}

impl Default for ZmcConfig {
    fn default() -> Self {
        ZmcConfig {
            k: 2,
            samples_per_block: 64,
            depth: 3,
            select_frac: 0.2,
            seed: 42,
            max_blocks: 1 << 16,
        }
    }
}

struct Block {
    lo: Vec<f64>,
    hi: Vec<f64>,
    integral: f64,
    variance: f64,
}

struct ZmcState<'a> {
    f: &'a dyn Integrand,
    seed: u32,
    counter: u64,
    calls: usize,
    /// Reused block-evaluation scratch across the whole tree search.
    block: PointBlock,
    vals: Vec<f64>,
}

impl<'a> ZmcState<'a> {
    fn sample_block(&mut self, lo: &[f64], hi: &[f64], n: usize) -> (f64, f64) {
        let (s1, s2) = accumulate_uniform_box(
            self.f,
            lo,
            hi,
            self.seed,
            2,
            self.counter,
            n,
            &mut self.block,
            &mut self.vals,
        );
        self.counter += n as u64;
        self.calls += n;
        let nf = n as f64;
        let mean = s1 / nf;
        let var = ((s2 / nf - mean * mean).max(0.0)) / (nf - 1.0).max(1.0);
        (mean, var)
    }

    fn split(&mut self, blk: &Block, k: usize, n: usize, out: &mut Vec<Block>) {
        let d = blk.lo.len();
        // Split only the widest `split_dims` axes when k^d would blow
        // up (ZMC splits per-axis too; cap for tractability at high d).
        let split_dims = d.min(13); // 2^13 = 8192 children max
        let children = k.pow(split_dims as u32);
        for c in 0..children {
            let mut lo = blk.lo.clone();
            let mut hi = blk.hi.clone();
            let mut idx = c;
            for i in 0..split_dims {
                let part = idx % k;
                idx /= k;
                let w = (blk.hi[i] - blk.lo[i]) / k as f64;
                lo[i] = blk.lo[i] + part as f64 * w;
                hi[i] = lo[i] + w;
            }
            let (integral, variance) = self.sample_block(&lo, &hi, n);
            out.push(Block {
                lo,
                hi,
                integral,
                variance,
            });
        }
    }
}

pub fn zmc_integrate(f: &dyn Integrand, cfg: &ZmcConfig) -> BaselineResult {
    let t0 = Instant::now();
    let d = f.dim();
    let mut st = ZmcState {
        f,
        seed: cfg.seed,
        counter: 0,
        calls: 0,
        block: PointBlock::with_capacity(d, BLOCK_POINTS),
        vals: Vec::new(),
    };

    let bounds = f.bounds();
    let root = Block {
        lo: (0..d).map(|i| bounds.lo(i)).collect(),
        hi: (0..d).map(|i| bounds.hi(i)).collect(),
        integral: 0.0,
        variance: 0.0,
    };
    // Level 0: initial stratification.
    let mut blocks: Vec<Block> = Vec::new();
    st.split(&root, cfg.k, cfg.samples_per_block, &mut blocks);

    let mut iterations = 1usize;
    for _ in 1..cfg.depth {
        if blocks.len() >= cfg.max_blocks {
            break;
        }
        // Rank by sigma, select the hot tail for re-exploration.
        blocks.sort_by(|a, b| a.variance.total_cmp(&b.variance));
        let n_sel = ((blocks.len() as f64 * cfg.select_frac).ceil() as usize)
            .clamp(1, blocks.len());
        let selected: Vec<Block> = blocks.split_off(blocks.len() - n_sel);
        for blk in &selected {
            st.split(blk, cfg.k, cfg.samples_per_block, &mut blocks);
        }
        iterations += 1;
    }

    let integral: f64 = blocks.iter().map(|b| b.integral).sum();
    let variance: f64 = blocks.iter().map(|b| b.variance).sum();
    BaselineResult {
        integral,
        sigma: variance.sqrt(),
        calls_used: st.calls,
        iterations,
        total_time: t0.elapsed().as_secs_f64(),
        converged: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    #[test]
    fn zmc_estimates_low_dim() {
        let f = by_name("f5", 3).unwrap();
        let r = zmc_integrate(
            &*f,
            &ZmcConfig {
                k: 2,
                samples_per_block: 256,
                depth: 3,
                seed: 4,
                ..Default::default()
            },
        );
        let truth = f.true_value().unwrap();
        assert!(
            (r.integral - truth).abs() < 6.0 * r.sigma + 1e-12,
            "I={} truth={truth} sigma={}",
            r.integral,
            r.sigma
        );
    }

    #[test]
    fn deeper_search_reduces_error() {
        // With select_frac = 1.0 every block is refined each level, so
        // depth strictly adds stratification + samples -> error drops.
        let f = by_name("f4", 3).unwrap();
        let shallow = zmc_integrate(
            &*f,
            &ZmcConfig {
                depth: 1,
                samples_per_block: 128,
                select_frac: 1.0,
                seed: 8,
                ..Default::default()
            },
        );
        let deep = zmc_integrate(
            &*f,
            &ZmcConfig {
                depth: 3,
                samples_per_block: 128,
                select_frac: 1.0,
                seed: 8,
                ..Default::default()
            },
        );
        assert!(
            deep.sigma < shallow.sigma,
            "{} vs {}",
            deep.sigma,
            shallow.sigma
        );
        assert!(deep.calls_used > shallow.calls_used);
    }
}
