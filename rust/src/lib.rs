//! # m-Cubes — portable VEGAS multi-dimensional integration
//!
//! A reproduction of *"m-Cubes: An Efficient and Portable Implementation
//! of Multi-Dimensional Integration for GPUs"* (Sakiotis et al., 2022)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1** — the V-Sample Pallas kernel (`python/compile/kernels/`),
//!   AOT-lowered to HLO-text artifacts at build time.
//! - **L2** — the JAX wrapper (`python/compile/model.py`) that reduces
//!   per-block partials; lowered together with L1.
//! - **L3** — this crate: the coordinator (iteration driver, importance
//!   grid adjustment, convergence, job service), the PJRT runtime that
//!   executes the artifacts, a native CPU engine that reproduces the
//!   identical sampling math, and the baselines the paper compares
//!   against (serial VEGAS, gVegas, ZMCintegral-style, plain MC, MISER).
//!
//! Python never runs on the request path; after `make artifacts` the
//! `mcubes` binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use mcubes::prelude::*;
//!
//! let f = mcubes::integrands::by_name("f4", 5).unwrap();
//! let cfg = JobConfig {
//!     maxcalls: 1 << 17,
//!     tau_rel: 1e-3,
//!     ..JobConfig::default()
//! };
//! let out = mcubes::coordinator::integrate_native(&*f, &cfg).unwrap();
//! println!("I = {} ± {}", out.integral, out.sigma);
//! ```

pub mod baselines;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod estimator;
pub mod grid;
pub mod integrands;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod strat;
pub mod util;

pub use error::{Error, Result};

/// Common imports for examples and benches.
pub mod prelude {
    pub use crate::coordinator::{IntegrationOutput, JobConfig};
    pub use crate::error::{Error, Result};
    pub use crate::estimator::{Convergence, IterationResult, WeightedEstimator};
    pub use crate::grid::{Bins, GridMode};
    pub use crate::integrands::{Integrand, IntegrandRef};
    pub use crate::strat::Layout;
}
