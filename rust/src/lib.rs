//! # m-Cubes — portable VEGAS multi-dimensional integration
//!
//! A reproduction of *"m-Cubes: An Efficient and Portable Implementation
//! of Multi-Dimensional Integration for GPUs"* (Sakiotis et al., 2022)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1** — the V-Sample Pallas kernel (`python/compile/kernels/`),
//!   AOT-lowered to HLO-text artifacts at build time.
//! - **L2** — the JAX wrapper (`python/compile/model.py`) that reduces
//!   per-block partials; lowered together with L1.
//! - **L3** — this crate: the [`api::Integrator`] facade, the
//!   coordinator (iteration driver, importance-grid adjustment,
//!   convergence, job service), the PJRT runtime that executes the
//!   artifacts, a native CPU engine that reproduces the identical
//!   sampling math, and the baselines the paper compares against
//!   (serial VEGAS, gVegas, ZMCintegral-style, plain MC, MISER).
//!
//! Python never runs on the request path; after `make artifacts` the
//! `mcubes` binary is self-contained.
//!
//! ## Quick start
//!
//! Everything goes through the [`api::Integrator`] builder:
//!
//! ```no_run
//! use mcubes::prelude::*;
//!
//! // A registry integrand (the paper's f4, a sharp 5-D Gaussian):
//! let out = Integrator::from_registry("f4", 5)?
//!     .maxcalls(1 << 17)
//!     .tolerance(1e-3)
//!     .run()?;
//! println!("I = {} ± {}", out.integral, out.sigma);
//!
//! // A closure over per-axis bounds — no registry entry needed:
//! let bounds = Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)])?;
//! let out = Integrator::from_fn(2, bounds, |x| x[0] * x[1])?
//!     .tolerance(1e-3)
//!     .run()?;
//! println!("I = {} ± {}", out.integral, out.sigma);
//! # Ok::<(), mcubes::Error>(())
//! ```
//!
//! ### Batch-first evaluation
//!
//! Every evaluation path — the native engine, the stratified engine, and
//! the CPU baselines — feeds points through
//! [`integrands::Integrand::eval_batch`] in structure-of-arrays
//! [`engine::PointBlock`]s (column-major `[d][block]`, mirroring the
//! paper's per-thread-block batches), so the inner per-axis loop
//! vectorizes instead of paying one virtual call per point. Registry
//! integrands ship hand-batched overrides; custom integrands opt in
//! with [`api::Integrator::custom_batch`]:
//!
//! ```no_run
//! use mcubes::prelude::*;
//!
//! let out = Integrator::custom_batch(2, Bounds::unit(2), |block, out| {
//!     // block.axis(i) is the contiguous column of axis-i coordinates.
//!     let (x, y) = (block.axis(0), block.axis(1));
//!     for (k, o) in out.iter_mut().enumerate() {
//!         *o = x[k] * y[k]; // raw values — the engine applies Jacobians
//!     }
//! })?
//! .tolerance(1e-3)
//! .run()?;
//! println!("I = {} ± {}", out.integral, out.sigma);
//! # Ok::<(), mcubes::Error>(())
//! ```
//!
//! Scalar closures (`Integrator::from_fn`) still work — the trait's
//! default `eval_batch` bridges them point by point, bit-identically
//! (property-tested across the whole registry).
//!
//! ### VEGAS+ adaptive stratification
//!
//! m-Cubes keeps the per-cube workload uniform (the paper's GPU
//! load-balance contribution). On sharply peaked integrands the VEGAS+
//! successor line wins statistically by re-apportioning each
//! iteration's budget toward high-variance sub-cubes; both strategies
//! ship behind one switch (see `docs/sampling.md` for the trade-offs
//! and the reproducibility contract):
//!
//! ```no_run
//! use mcubes::prelude::*;
//!
//! let out = Integrator::from_registry("f4", 8)?
//!     .maxcalls(1 << 16)
//!     .tolerance(1e-3)
//!     .sampling(Sampling::VegasPlus { beta: 0.75 })
//!     .run()?;
//! println!("I = {} ± {}", out.integral, out.sigma);
//! # Ok::<(), mcubes::Error>(())
//! ```
//!
//! ### Warm starts and observers
//!
//! ```no_run
//! use mcubes::prelude::*;
//!
//! let mut donor = Integrator::from_registry("f4", 5)?.seed(1);
//! donor.run()?;
//! let grid = donor.export_grid().unwrap();       // adapted VEGAS grid
//!
//! let out = Integrator::from_registry("f4", 5)?
//!     .seed(2)
//!     .warm_start(grid)                           // skip the warm-up
//!     .plan(RunPlan::classic(15, 0, 0))
//!     .observe(|ev| eprintln!("it {}: rel {:.2e}", ev.iteration, ev.rel_err))
//!     .run()?;
//! assert!(out.converged);
//! # Ok::<(), mcubes::Error>(())
//! ```
//!
//! ### Sessions, plans, and the scheduler
//!
//! Blocking `run()` is a convenience: the execution primitive is the
//! resumable [`api::Session`] (`step()` one iteration at a time,
//! `suspend()`/`resume()` through a bitwise [`api::Checkpoint`]),
//! driven by an [`api::RunPlan`] of composable stages
//! (`RunPlan::classic(itmax, ita, skip)` reproduces the seed's flat
//! knobs bitwise and is the default). Many sessions multiplex over
//! one machine through [`coordinator::Scheduler`] — priority-ordered,
//! time-sliced by a `calls_budget` fairness quantum, streaming
//! results in completion order. Every run ends with a typed
//! [`api::StopReason`].
//!
//! ```no_run
//! use mcubes::prelude::*;
//!
//! let mut session = Integrator::from_registry("f4", 5)?
//!     .maxcalls(1 << 16)
//!     .plan(RunPlan::warmup_then_final(5, 1 << 12, 10))
//!     .session()?;
//! while let Some(it) = session.step()? {
//!     eprintln!("it {} [{}]: rel {:.2e}", it.index, it.stage_label, it.rel_err);
//! }
//! let outcome = session.finish()?;
//! println!("I = {} ({:?})", outcome.output.integral, outcome.stop);
//! # Ok::<(), mcubes::Error>(())
//! ```
//!
//! ## Deprecation path
//!
//! The seed's free functions — `coordinator::integrate_native`,
//! `integrate_native_adaptive`, `run_driver`, `run_driver_traced` —
//! and the `coordinator::IntegrationService` alias have been
//! **removed** (they last shipped behind the since-removed
//! `legacy-api` cargo feature); the migration table in [`api`] maps
//! each onto its builder/\[`coordinator::Scheduler`\] equivalent. The
//! flat `max_iterations`/`adjust_iterations`/`skip_iterations`
//! builder knobs remain as `#[deprecated]` shims that rebuild a
//! classic [`api::RunPlan`]. Native execution now goes through the
//! [`engine::Engine`] trait — [`engine::UniformEngine`],
//! [`engine::VegasPlusEngine`], and [`baselines::GvegasSimEngine`]
//! are the three impls — adapted to the driver by one generic
//! [`coordinator::EngineBackend`] (see `docs/architecture.md`).

pub mod api;
pub mod baselines;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod estimator;
pub mod grid;
pub mod integrands;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod shard;
pub mod store;
pub mod strat;
pub mod util;

pub use error::{Error, Result};

/// Common imports for examples and benches.
pub mod prelude {
    pub use crate::api::{
        BackendSpec, Bounds, Checkpoint, FnBatchIntegrand, FnIntegrand, GridState, IntegrandSpec,
        Integrator, Iteration, IterationEvent, ObserverControl, PointBlock, RunPlan, Session,
        Stage, StopReason, StratSnapshot,
    };
    pub use crate::coordinator::{
        Daemon, DaemonReport, DriveOutcome, IntegrationOutput, JobConfig, JobRequest, JobResult,
        Scheduler, ServiceMetrics,
    };
    pub use crate::engine::{ExecPath, FillPath};
    pub use crate::error::{Error, Result};
    pub use crate::estimator::{Convergence, EstimatorState, IterationResult, WeightedEstimator};
    pub use crate::grid::{Bins, GridMode};
    pub use crate::integrands::{Integrand, IntegrandRef};
    pub use crate::shard::{
        run_spool_worker, spool_close, ShardPlan, ShardStats, ShardedBackend, SpoolOptions,
        SpoolTransport,
    };
    pub use crate::store::{JobManifest, ResultManifest, ResultNumbers, ServiceStore, StoreError};
    pub use crate::strat::{AllocStats, Layout, Sampling};
}

// Compile the README's and the docs mini-book's Rust code fences as
// doctests (`cargo test --doc` / the CI docs step), so the prose can
// never drift from the API. Non-Rust fences are labelled (`sh`,
// `text`) and skipped by rustdoc.
#[cfg(doctest)]
#[doc = include_str!("../../README.md")]
mod readme_doctests {}

#[cfg(doctest)]
#[doc = include_str!("../../docs/architecture.md")]
mod architecture_doctests {}

#[cfg(doctest)]
#[doc = include_str!("../../docs/sampling.md")]
mod sampling_doctests {}

#[cfg(doctest)]
#[doc = include_str!("../../docs/invariants.md")]
mod invariants_doctests {}

#[cfg(doctest)]
#[doc = include_str!("../../docs/service.md")]
mod service_doctests {}

#[cfg(doctest)]
#[doc = include_str!("../../docs/sharding.md")]
mod sharding_doctests {}
