//! `mcubes` — the leader binary: CLI over the job scheduler, PJRT
//! artifact runtime, native engine, and baselines.
//!
//! Subcommands:
//!   integrate     run one integration job (native or pjrt backend)
//!   serve         run a batch of jobs through the scheduler, print metrics;
//!                 with --store, run the durable spool daemon instead
//!   shard-worker  serve shard tasks from a spool directory (pair with
//!                 `integrate --shards N --shard-dir <dir>`)
//!   artifacts     list artifacts in the manifest
//!   selftest      quick native-vs-pjrt cross-check on one artifact
//!
//! Examples:
//!   mcubes integrate --integrand f4 --dim 5 --calls 131072 --tau 1e-3
//!   mcubes integrate --backend pjrt --integrand f4 --dim 5
//!   mcubes integrate --integrand f4 --dim 5 --grid-out /tmp/f4.grid.json
//!   mcubes integrate --integrand f4 --dim 5 --grid-in /tmp/f4.grid.json --ita 0
//!   mcubes integrate --integrand f4 --dim 8 --shards 8
//!   mcubes shard-worker --dir /tmp/shard-spool &
//!   mcubes integrate --integrand f4 --dim 8 --shards 4 --shard-dir /tmp/shard-spool
//!   mcubes serve --store /var/lib/mcubes --demo-jobs 3 --once
//!   mcubes artifacts
//!   mcubes selftest

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use mcubes::api::{BackendSpec, GridState, Integrator, RunPlan};
use mcubes::baselines::{vegas_serial_integrate, zmc_integrate, ZmcConfig};
use mcubes::coordinator::{drive, Daemon, JobConfig, JobRequest, PjrtBackend, Scheduler};
use mcubes::grid::GridMode;
use mcubes::integrands::by_name;
use mcubes::store::JobManifest;
use mcubes::runtime::{PjrtRuntime, Registry, DEFAULT_ARTIFACT_DIR};
use mcubes::util::cli::Cli;
use mcubes::util::table::{fmt_ms, fmt_sig, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    let code = match sub {
        "integrate" => cmd_integrate(rest),
        "serve" => cmd_serve(rest),
        "shard-worker" => cmd_shard_worker(rest),
        "artifacts" => cmd_artifacts(rest),
        "selftest" => cmd_selftest(rest),
        _ => {
            eprintln!(
                "usage: mcubes <integrate|serve|shard-worker|artifacts|selftest> [options]\n\
                 run `mcubes <subcommand> --help` for options"
            );
            if sub == "help" {
                0
            } else {
                2
            }
        }
    };
    std::process::exit(code);
}

fn integrate_cli() -> Cli {
    Cli::new("mcubes integrate", "run one integration job")
        .opt("integrand", "f4", "integrand name (f1..f6, fA, fB, cosmo)")
        .opt("dim", "5", "dimension (fixed-dim integrands check this)")
        .opt("calls", "131072", "evaluation budget per iteration")
        .opt("tau", "1e-3", "target relative error")
        .opt("itmax", "15", "max iterations")
        .opt("ita", "10", "iterations with bin adjustment")
        .opt("skip", "2", "warm-up iterations excluded from the estimate")
        .opt("seed", "42", "rng seed")
        .opt("backend", "native", "native | pjrt")
        .opt("artifacts", DEFAULT_ARTIFACT_DIR, "artifacts directory")
        .opt("shards", "1", "shard workers per iteration (1 = single worker)")
        .opt_opt(
            "shard-dir",
            "shard spool directory: scatter tasks for external \
             `mcubes shard-worker` processes",
        )
        .opt_opt("grid-in", "warm-start grid file (from --grid-out)")
        .opt_opt("grid-out", "save the adapted grid to this file")
        .flag("onedim", "use the m-Cubes1D shared-axis grid")
        .flag("baseline-serial", "also run serial VEGAS for comparison")
        .flag("baseline-zmc", "also run the ZMC-style baseline")
}

fn cmd_integrate(args: &[String]) -> i32 {
    let cli = integrate_cli();
    let p = match cli.parse(args) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let name = p.get("integrand").unwrap().to_string();
        let dim = p.get_usize("dim")?;
        let mut intg = Integrator::from_registry(&name, dim)
            .map_err(|e| e.to_string())?
            .maxcalls(p.get_usize("calls")?)
            .tolerance(p.get_f64("tau")?)
            .plan(RunPlan::classic(
                p.get_usize("itmax")?,
                p.get_usize("ita")?,
                p.get_usize("skip")?,
            ))
            .seed(p.get_u32("seed")?)
            .shards(p.get_usize("shards")?)
            .grid_mode(if p.is_set("onedim") {
                GridMode::Shared1D
            } else {
                GridMode::PerAxis
            });
        let shard_dir = p.get("shard-dir").map(str::to_string);
        if let Some(dir) = &shard_dir {
            intg = intg.shard_dir(dir.clone());
        }
        if p.get("backend").unwrap() == "pjrt" {
            intg = intg.backend(BackendSpec::Pjrt {
                artifacts_dir: p.get("artifacts").unwrap().to_string(),
            });
        } else if p.get("backend").unwrap() != "native" {
            return Err(format!("unknown backend {}", p.get("backend").unwrap()));
        }
        if let Some(path) = p.get("grid-in") {
            let grid = GridState::load(path).map_err(|e| e.to_string())?;
            intg = intg.warm_start(grid);
        }

        let run_result = intg.run();
        if let Some(dir) = &shard_dir {
            // Drop the stop marker so attached shard workers exit
            // instead of polling an idle spool forever — on failed
            // runs too (a close error must not mask the run's error).
            let closed = mcubes::shard::spool_close(std::path::Path::new(dir));
            if run_result.is_ok() {
                closed.map_err(|e| e.to_string())?;
            }
        }
        let out = run_result.map_err(|e| e.to_string())?;
        if let Some(path) = p.get("grid-out") {
            intg.export_grid()
                .expect("grid present after a successful run")
                .save(path)
                .map_err(|e| e.to_string())?;
            println!("adapted grid saved to {path}");
        }

        let f = by_name(&name, dim).map_err(|e| e.to_string())?;
        let truth = f.true_value();
        println!("integrand   : {name} (d={dim})");
        println!("backend     : {}", out.backend);
        println!("integral    : {}", fmt_sig(out.integral, 10));
        println!("sigma       : {}", fmt_sig(out.sigma, 4));
        println!("rel err     : {:.3e}", out.rel_err);
        if let Some(t) = truth {
            println!("true value  : {}", fmt_sig(t, 10));
            println!("true rel err: {:.3e}", ((out.integral - t) / t).abs());
        }
        println!("chi2/dof    : {:.3}", out.chi2_dof);
        println!(
            "iterations  : {} (converged: {})",
            out.iterations, out.converged
        );
        println!("calls used  : {}", out.calls_used);
        println!(
            "time        : total {} / kernel {}",
            fmt_ms(out.total_time * 1e3),
            fmt_ms(out.kernel_time * 1e3)
        );

        if p.is_set("baseline-serial") {
            let cfg = intg.job_config();
            let b = vegas_serial_integrate(
                &f,
                cfg.maxcalls,
                cfg.tau_rel,
                cfg.plan.total_iters(),
                cfg.seed,
            );
            println!(
                "serial vegas: I={} sigma={} time={}",
                fmt_sig(b.integral, 8),
                fmt_sig(b.sigma, 3),
                fmt_ms(b.total_time * 1e3)
            );
        }
        if p.is_set("baseline-zmc") {
            let b = zmc_integrate(&*f, &ZmcConfig::default());
            println!(
                "zmc-style   : I={} sigma={} time={}",
                fmt_sig(b.integral, 8),
                fmt_sig(b.sigma, 3),
                fmt_ms(b.total_time * 1e3)
            );
        }
        Ok(0)
    };
    match run() {
        Ok(c) => c,
        Err(msg) => {
            eprintln!("error: {msg}");
            1
        }
    }
}

fn cmd_serve(args: &[String]) -> i32 {
    let cli = Cli::new("mcubes serve", "run a batch of jobs through the scheduler")
        .opt("jobs", "16", "number of jobs")
        .opt("workers", "4", "worker threads")
        .opt("calls", "16384", "evaluation budget per iteration")
        .opt("tau", "1e-3", "target relative error")
        .opt(
            "quantum",
            "1048576",
            "fairness cap: integrand calls per scheduling slice",
        )
        .opt_opt(
            "store",
            "durable store root — switches to the spool daemon (see docs/service.md)",
        )
        .opt("poll-ms", "500", "daemon: spool poll interval")
        .opt("threads", "1", "daemon: worker threads per job")
        .opt("shards", "1", "daemon: shard workers per job (1 = single worker)")
        .opt("demo-jobs", "0", "daemon: submit N deterministic demo jobs before serving")
        .opt("demo-calls", "262144", "daemon: per-iteration budget of the demo jobs")
        .flag("once", "daemon: drain the spool once and exit instead of watching");
    let p = match cli.parse(args) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    if let Some(root) = p.get("store") {
        let root = root.to_string();
        return cmd_serve_daemon(&root, &p);
    }
    let jobs = p.get_usize("jobs").unwrap_or(16);
    let workers = p.get_usize("workers").unwrap_or(4);
    let suite = ["f2", "f3", "f4", "f5", "f6"];
    let dims = [6, 3, 5, 8, 6];
    let mut svc = Scheduler::new(workers);
    svc.calls_budget(p.get_usize("quantum").unwrap_or(1 << 20));
    for i in 0..jobs {
        let k = i % suite.len();
        svc.submit(JobRequest::registry(
            i as u64,
            suite[k],
            dims[k],
            JobConfig::default()
                .with_maxcalls(p.get_usize("calls").unwrap_or(16384))
                .with_tolerance(p.get_f64("tau").unwrap_or(1e-3))
                .with_seed(1000 + i as u32),
        ));
    }
    match svc.drain() {
        Ok((results, m)) => {
            let mut t = Table::new(&["id", "integrand", "I", "sigma", "iters", "latency"]);
            for r in &results {
                match &r.outcome {
                    Ok(o) => t.row(vec![
                        r.id.to_string(),
                        r.integrand.clone(),
                        fmt_sig(o.integral, 6),
                        fmt_sig(o.sigma, 3),
                        o.iterations.to_string(),
                        fmt_ms(r.latency * 1e3),
                    ]),
                    Err(e) => t.row(vec![
                        r.id.to_string(),
                        r.integrand.clone(),
                        format!("ERROR: {e}"),
                        "-".into(),
                        "-".into(),
                        fmt_ms(r.latency * 1e3),
                    ]),
                };
            }
            println!("{}", t.render());
            println!(
                "jobs={} failures={} wall={} throughput={:.1} jobs/s \
                 calls/s={:.2e} p50={} p95={}",
                m.jobs,
                m.failures,
                fmt_ms(m.wall_time * 1e3),
                m.throughput,
                m.calls_per_sec,
                fmt_ms(m.latency_p50 * 1e3),
                fmt_ms(m.latency_p95 * 1e3)
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// The deterministic demo-job suite for `--demo-jobs`: a pure function
/// of the index, so two stores fed the same count hold byte-identical
/// submissions (what the CI durability harness compares).
fn demo_job(i: usize, calls: usize) -> JobManifest {
    let suite = [("f4", 5), ("f5", 8), ("f3", 3)];
    let (integrand, dim) = suite[i % suite.len()];
    let cfg = JobConfig::default()
        .with_maxcalls(calls)
        .with_tolerance(1e-12) // run the full plan — deterministic length
        .with_plan(RunPlan::classic(8, 5, 1))
        .with_seed(1000 + i as u32);
    JobManifest::new(format!("demo-{i:03}"), integrand, dim, cfg)
}

/// `serve --store <root>`: the durable spool daemon. Watches
/// `<root>/spool/` for job manifests, answers them through the
/// checkpoint store / result cache, and publishes sealed result
/// manifests to `<root>/outbox/` (full flow: docs/service.md). With
/// `--once` it drains the current spool and exits — the mode the
/// durability CI and the examples use; without it, it polls forever.
fn cmd_serve_daemon(root: &str, p: &mcubes::util::cli::Parsed) -> i32 {
    let run = || -> Result<i32, String> {
        let poll_ms = p.get_usize("poll-ms")?.max(1);
        let threads = p.get_usize("threads")?.max(1);
        let shards = p.get_usize("shards")?.max(1);
        let demo_jobs = p.get_usize("demo-jobs")?;
        let demo_calls = p.get_usize("demo-calls")?;
        let mut daemon = Daemon::open(root)
            .map_err(|e| e.to_string())?
            .with_threads(threads)
            .with_shards(shards);
        for i in 0..demo_jobs {
            let job = demo_job(i, demo_calls);
            // Skip jobs that already have a published result so a
            // restarted demo run does not resubmit answered work.
            let answered = daemon
                .store()
                .spool()
                .result(&job.job_id)
                .map_err(|e| e.to_string())?
                .is_some();
            if !answered {
                daemon
                    .store()
                    .spool()
                    .submit(&job)
                    .map_err(|e| e.to_string())?;
                println!("submitted {} ({} d={})", job.job_id, job.integrand, job.dim);
            }
        }
        println!(
            "serving store {root} (threads={threads}, shards={shards}, poll={poll_ms}ms, once={})",
            p.is_set("once")
        );
        loop {
            let report = daemon.run_pending().map_err(|e| e.to_string())?;
            if report.processed > 0 {
                println!(
                    "drained {}: completed={} cache_hits={} resumed={} failures={}",
                    report.processed, report.completed, report.cache_hits, report.resumed,
                    report.failures
                );
            }
            if p.is_set("once") {
                let results = daemon
                    .store()
                    .spool()
                    .results()
                    .map_err(|e| e.to_string())?;
                let mut t = Table::new(&["job", "integrand", "I", "sigma", "cached", "resumed@"]);
                for r in &results {
                    match &r.outcome {
                        Ok(n) => t.row(vec![
                            r.job_id.clone(),
                            r.integrand.clone(),
                            fmt_sig(n.integral, 10),
                            fmt_sig(n.sigma, 4),
                            r.cached.to_string(),
                            r.resumed_iteration.to_string(),
                        ]),
                        Err(e) => t.row(vec![
                            r.job_id.clone(),
                            r.integrand.clone(),
                            format!("ERROR: {e}"),
                            "-".into(),
                            r.cached.to_string(),
                            "-".into(),
                        ]),
                    };
                }
                println!("{}", t.render());
                return Ok(0);
            }
            std::thread::sleep(std::time::Duration::from_millis(poll_ms as u64));
        }
    };
    match run() {
        Ok(c) => c,
        Err(msg) => {
            eprintln!("error: {msg}");
            1
        }
    }
}

/// `mcubes shard-worker --dir <spool>`: serve shard tasks scattered by
/// a sharded coordinator (`integrate --shards N --shard-dir <dir>`).
/// Polls the spool, answers each sealed task file with a sealed
/// report (idempotently — tasks that already have a report are
/// skipped), and exits once the coordinator drops the stop marker, or
/// after `--idle-ms` with no work.
fn cmd_shard_worker(args: &[String]) -> i32 {
    let cli = Cli::new(
        "mcubes shard-worker",
        "serve shard tasks from a spool directory",
    )
    .opt_opt("dir", "spool directory (required; shared with the coordinator)")
    .opt("threads", "1", "worker threads per task")
    .opt("poll-ms", "5", "spool poll interval")
    .opt(
        "idle-ms",
        "0",
        "exit after this long with no work (0 = wait for the stop marker)",
    );
    let p = match cli.parse(args) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let dir = p
            .get("dir")
            .ok_or("missing required option --dir <spool directory>")?
            .to_string();
        let threads = p.get_usize("threads")?.max(1);
        let poll = std::time::Duration::from_millis(p.get_usize("poll-ms")?.max(1) as u64);
        let idle = p.get_usize("idle-ms")?;
        let max_idle = if idle == 0 {
            None
        } else {
            Some(std::time::Duration::from_millis(idle as u64))
        };
        let out =
            mcubes::shard::run_spool_worker(std::path::Path::new(&dir), threads, poll, max_idle)
                .map_err(|e| e.to_string())?;
        println!(
            "shard worker done: processed={} skipped={}",
            out.processed, out.skipped
        );
        Ok(0)
    };
    match run() {
        Ok(c) => c,
        Err(msg) => {
            eprintln!("error: {msg}");
            1
        }
    }
}

fn cmd_artifacts(args: &[String]) -> i32 {
    let cli = Cli::new("mcubes artifacts", "list the artifact manifest")
        .opt("artifacts", DEFAULT_ARTIFACT_DIR, "artifacts directory");
    let p = match cli.parse(args) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    match Registry::load(p.get("artifacts").unwrap()) {
        Ok(reg) => {
            let mut t = Table::new(&[
                "name", "integrand", "d", "calls", "g", "m", "p", "adjust", "hist",
            ]);
            for a in reg.all() {
                t.row(vec![
                    a.name.clone(),
                    a.integrand.clone(),
                    a.dim.to_string(),
                    a.maxcalls.to_string(),
                    a.g.to_string(),
                    a.m.to_string(),
                    a.p.to_string(),
                    a.adjust.to_string(),
                    a.hist_mode.clone(),
                ]);
            }
            println!("{}", t.render());
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_selftest(args: &[String]) -> i32 {
    let cli = Cli::new(
        "mcubes selftest",
        "native-vs-pjrt cross-check on one artifact",
    )
    .opt("artifacts", DEFAULT_ARTIFACT_DIR, "artifacts directory")
    .opt("integrand", "f4", "integrand to check");
    let p = match cli.parse(args) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let run = || -> Result<(), String> {
        let registry = Registry::load(p.get("artifacts").unwrap()).map_err(|e| e.to_string())?;
        let runtime = PjrtRuntime::cpu().map_err(|e| e.to_string())?;
        println!(
            "pjrt platform: {} ({} devices)",
            runtime.platform_name(),
            runtime.device_count()
        );
        let name = p.get("integrand").unwrap();
        let mut backend =
            PjrtBackend::load(&runtime, &registry, name, 0).map_err(|e| e.to_string())?;
        let meta = backend.meta().clone();
        let cfg = JobConfig::default()
            .with_maxcalls(meta.maxcalls)
            .with_bins(meta.nb)
            .with_blocks(meta.nblocks)
            .with_plan(RunPlan::classic(5, 3, 0))
            .with_tolerance(1e-12) // run all 5 iterations
            .with_seed(2024);
        let pjrt_out = drive(&mut backend, &cfg, None, None)
            .map_err(|e| e.to_string())?
            .output;
        let native_out = Integrator::from_registry(&meta.integrand, meta.dim)
            .map_err(|e| e.to_string())?
            .config(cfg)
            .run()
            .map_err(|e| e.to_string())?;
        let rel = ((pjrt_out.integral - native_out.integral) / native_out.integral).abs();
        println!(
            "pjrt   I={} sigma={}",
            fmt_sig(pjrt_out.integral, 12),
            fmt_sig(pjrt_out.sigma, 4)
        );
        println!(
            "native I={} sigma={}",
            fmt_sig(native_out.integral, 12),
            fmt_sig(native_out.sigma, 4)
        );
        println!("cross-backend rel diff: {rel:.3e}");
        if rel > 1e-9 {
            return Err(format!("backends disagree: rel {rel:.3e}"));
        }
        println!("selftest OK");
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("selftest FAILED: {e}");
            1
        }
    }
}
