//! Task-subrange entry points — the engine surface the shard subsystem
//! ([`crate::shard`]) is built on.
//!
//! Every [`super::Engine`] shares one reduction contract: the cube
//! range is partitioned into the fixed task spans of
//! [`super::reduction_task_span`], every per-task accumulator starts
//! fresh per task, and the coordinator folds per-task partials in
//! global task order. That contract means a *subrange* of tasks can be
//! computed anywhere — another thread, another worker, another process
//! — and as long as the partials come back and are folded in the same
//! global task order, the result is bitwise identical to the
//! single-worker pass.
//!
//! This module exposes exactly that: [`vsample_tasks`] /
//! [`vsample_stratified_tasks`] compute the partials of tasks
//! `[task_lo, task_hi)` (each runs through the one shared walk,
//! [`super::walk`] — the identical per-task body the full pass runs),
//! and [`merge_task_partials`] reproduces the full pass's fold over
//! any complete, task-ordered collection of partials. Philox counters
//! are a pure function of the cube index (uniform: `cube * p + k`;
//! stratified: `offsets[cube] + k`), so disjoint task spans draw
//! disjoint counter sub-ranges by construction — no counter is ever
//! drawn twice across shards.

use super::simd::FillPath;
use super::walk::{self, ExecPath, StratSched, UniformSched};
use super::VSampleOpts;
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::strat::Layout;

/// One reduction task's partial, in transportable form: everything the
/// coordinator needs to reproduce the single-worker fold — and nothing
/// tied to the process that computed it.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskPartial {
    /// Global reduction-task index (`0..reduction_tasks(m)`).
    pub task: usize,
    /// First cube of the task span.
    pub cube_lo: usize,
    /// One past the last cube of the task span.
    pub cube_hi: usize,
    /// Task partial of the iteration integral estimate.
    pub integral: f64,
    /// Task partial of the iteration variance estimate.
    pub variance: f64,
    /// Row-major `[d][nb]` bin-contribution histogram partial
    /// (`Some` iff the pass ran with `opts.adjust`).
    pub contrib: Option<Vec<f64>>,
    /// Fresh per-cube variance observations `n_k * Var_k`, indexed
    /// relative to `cube_lo`. Empty on the uniform path (the uniform
    /// engine keeps no per-cube allocation state).
    pub d_new: Vec<f64>,
}

/// Uniform-allocation partials of reduction tasks `[task_lo, task_hi)`.
///
/// Each task runs the identical per-task body the full pass runs
/// (fill → `eval_batch` → ordered per-cube reduction, through the one
/// shared walk), so for any partition of `0..reduction_tasks(m)` into
/// subranges, concatenating the returned vectors reproduces the full
/// pass's partials bitwise. Internal parallelism (`opts.threads`)
/// never changes the numbers.
pub fn vsample_tasks(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    opts: &VSampleOpts,
    fill: FillPath,
    task_lo: usize,
    task_hi: usize,
) -> Vec<TaskPartial> {
    walk::run_tasks(
        f,
        layout,
        bins,
        &UniformSched { p: layout.p },
        opts,
        fill,
        ExecPath::default(),
        task_lo,
        task_hi,
    )
}

/// Stratified (VEGAS+) partials of reduction tasks `[task_lo, task_hi)`
/// under an *immutable* allocation view.
///
/// Unlike [`super::stratified::vsample_stratified`], this does **not**
/// fold the fresh `d_new` observations into an allocation — they ride
/// back inside each [`TaskPartial`] so the coordinator can absorb every
/// task's slice in global task order (each cube is observed exactly
/// once, so absorb placement is bitwise-neutral; see
/// `strat::Allocation::absorb_span`).
#[allow(clippy::too_many_arguments)]
pub fn vsample_stratified_tasks(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    counts: &[u32],
    offsets: &[u64],
    opts: &VSampleOpts,
    fill: FillPath,
    task_lo: usize,
    task_hi: usize,
) -> Vec<TaskPartial> {
    assert_eq!(counts.len(), layout.m, "allocation cube count != layout");
    assert_eq!(offsets.len(), layout.m, "allocation offsets != layout");
    walk::run_tasks(
        f,
        layout,
        bins,
        &StratSched { counts, offsets },
        opts,
        fill,
        ExecPath::default(),
        task_lo,
        task_hi,
    )
}

/// Fold a complete, task-ordered collection of partials exactly the way
/// the full-pass engines do: `integral` and `variance` accumulate in
/// task order, histogram partials add elementwise in task order.
///
/// The caller is responsible for task order and completeness (the shard
/// coordinator verifies both before merging); `d_new` slices are *not*
/// consumed here — stratified callers absorb them into their
/// `Allocation` in the same task order.
pub fn merge_task_partials(
    d: usize,
    nb: usize,
    adjust: bool,
    partials: &[TaskPartial],
) -> (IterationResult, Option<Vec<f64>>) {
    let mut integral = 0.0;
    let mut variance = 0.0;
    let mut contrib = adjust.then(|| vec![0.0; d * nb]);
    for p in partials {
        integral += p.integral;
        variance += p.variance;
        if let (Some(acc), Some(part)) = (contrib.as_mut(), p.contrib.as_ref()) {
            for (x, y) in acc.iter_mut().zip(part) {
                *x += y;
            }
        }
    }
    (
        IterationResult {
            integral,
            variance,
        },
        contrib,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{reduction_tasks, NativeEngine};
    use crate::integrands::by_name;
    use crate::strat::Allocation;

    fn opts(seed: u32, it: u32, threads: usize) -> VSampleOpts {
        VSampleOpts {
            seed,
            iteration: it,
            adjust: true,
            threads,
        }
    }

    #[test]
    fn subrange_concat_matches_full_pass_bitwise_uniform() {
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let o = opts(42, 0, 2);
        let (full, full_contrib) = NativeEngine.vsample(&*f, &layout, &bins, &o);

        let ntasks = reduction_tasks(layout.m);
        // Three uneven subranges, computed independently.
        let cuts = [0, ntasks / 3, ntasks / 2 + 1, ntasks];
        let mut partials = Vec::new();
        for w in cuts.windows(2) {
            partials.extend(vsample_tasks(&*f, &layout, &bins, &o, FillPath::Simd, w[0], w[1]));
        }
        assert_eq!(partials.len(), ntasks);
        for (t, p) in partials.iter().enumerate() {
            assert_eq!(p.task, t);
            assert!(p.d_new.is_empty());
        }
        let (merged, contrib) = merge_task_partials(layout.d, layout.nb, true, &partials);
        assert_eq!(full.integral.to_bits(), merged.integral.to_bits());
        assert_eq!(full.variance.to_bits(), merged.variance.to_bits());
        for (a, b) in full_contrib.unwrap().iter().zip(&contrib.unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn subrange_concat_matches_full_pass_bitwise_stratified() {
        let f = by_name("f3", 4).unwrap();
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(4, 16);
        let o = opts(9, 3, 2);
        // Skewed allocation so counts differ wildly across cubes.
        let mut reference = Allocation::uniform(&layout);
        reference.absorb(0, 100.0);
        for cube in 1..reference.m() {
            reference.absorb(cube, 0.01);
        }
        reference.reallocate(layout.calls(), crate::strat::DEFAULT_BETA);
        let mut sharded = reference.clone();

        let (full, full_contrib) =
            super::super::vsample_stratified(&*f, &layout, &bins, &mut reference, &o);

        let ntasks = reduction_tasks(layout.m);
        let mid = ntasks / 2;
        let mut partials = vsample_stratified_tasks(
            &*f,
            &layout,
            &bins,
            sharded.counts(),
            sharded.offsets(),
            &o,
            FillPath::Simd,
            0,
            mid,
        );
        partials.extend(vsample_stratified_tasks(
            &*f,
            &layout,
            &bins,
            sharded.counts(),
            sharded.offsets(),
            &o,
            FillPath::Simd,
            mid,
            ntasks,
        ));
        let (merged, contrib) = merge_task_partials(layout.d, layout.nb, true, &partials);
        for p in &partials {
            sharded.absorb_span(p.cube_lo, &p.d_new);
        }
        assert_eq!(full.integral.to_bits(), merged.integral.to_bits());
        assert_eq!(full.variance.to_bits(), merged.variance.to_bits());
        for (a, b) in full_contrib.unwrap().iter().zip(&contrib.unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in reference.damped().iter().zip(sharded.damped()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn internal_threads_never_change_partials() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let bins = Bins::uniform(4, 10);
        let ntasks = reduction_tasks(layout.m);
        let a = vsample_tasks(&*f, &layout, &bins, &opts(1, 0, 1), FillPath::Simd, 0, ntasks);
        let b = vsample_tasks(&*f, &layout, &bins, &opts(1, 0, 7), FillPath::Simd, 0, ntasks);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.integral.to_bits(), y.integral.to_bits());
            assert_eq!(x.variance.to_bits(), y.variance.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "task range")]
    fn out_of_range_task_span_panics() {
        let f = by_name("f3", 3).unwrap();
        let layout = Layout::compute(3, 512, 8, 1).unwrap();
        let bins = Bins::uniform(3, 8);
        let ntasks = reduction_tasks(layout.m);
        vsample_tasks(
            &*f,
            &layout,
            &bins,
            &opts(1, 0, 1),
            FillPath::Simd,
            0,
            ntasks + 1,
        );
    }
}
