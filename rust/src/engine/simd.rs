//! The SIMD sampling core — lane-parallel Philox + VEGAS transform
//! fill for [`PointBlock`]s.
//!
//! The paper's performance story is keeping the sampling kernel
//! saturated: the Philox counter RNG and the VEGAS change of variables
//! fused in the hot loop, uniform work per processor. The scalar
//! engine reproduced the math but generated points one Philox block at
//! a time; this module fills a whole *lane group* per step —
//! [`crate::rng::philox_simd::LANES`] consecutive sample counters
//! through the lane-parallel Philox ([`philox4x32_lanes`]), then the
//! bin lookup + affine transform for the group, written straight into
//! the [`PointBlock`] SoA columns. No intrinsics: the kernels are
//! autovectorizer-shaped array loops, so the same source runs
//! everywhere and widens under `-C target-cpu=native`.
//!
//! ## Determinism contract
//!
//! The lane-parallel fill is **bitwise identical** to the scalar
//! reference ([`VegasMap::fill_points_scalar`]) because nothing about
//! the arithmetic changes — only its schedule:
//!
//! * **Same counters.** Lane `l` of a group based at sample `s` draws
//!   Philox counter `s + l` — exactly the index the scalar loop used.
//!   Philox is exact integer math, so the uniforms agree bit for bit.
//! * **Same per-point fold order.** Each point's Jacobian is
//!   accumulated axis-by-axis in axis order within its own lane
//!   (`jac *= nbf * w` per axis), never across lanes, so the product
//!   tree of every point is unchanged.
//! * **Same destinations.** Lane `l` writes block slot `k0 + l` — the
//!   slot the scalar loop wrote — so evaluation and reduction order
//!   downstream are untouched.
//!
//! Property tests (`rust/tests/properties.rs`) assert engine results
//! are bitwise equal under [`FillPath::Simd`] and [`FillPath::Scalar`]
//! on both engines and both `Sampling` modes; docs/sampling.md states
//! the contract at the algorithm level.
//!
//! [`philox4x32_lanes`]: crate::rng::philox_simd::philox4x32_lanes

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::block::{PointBlock, VegasMap};
use super::MAX_DIM;
use crate::rng::philox_simd::{uniforms_lanes, LANES};
use crate::rng::uniforms_into;

/// Which fill implementation a V-Sample pass drives.
///
/// Both paths are bitwise identical (see the [module docs](self));
/// `Scalar` exists as the reference for the equivalence property tests
/// and as the baseline the `perf_microbench` `simd_fill_speedup`
/// series is measured against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FillPath {
    /// Lane-parallel fill ([`VegasMap::fill_points`]): [`LANES`]
    /// Philox counters per step with the VEGAS transform applied to
    /// the whole lane group. The default everywhere.
    #[default]
    Simd,
    /// The per-point reference loop ([`VegasMap::fill_points_scalar`]).
    Scalar,
}

impl VegasMap<'_> {
    /// Lane-parallel fill: transform the `n` consecutive samples
    /// `base_sidx .. base_sidx + n` of the sub-cube at lattice
    /// `coords` into block slots `k0 .. k0 + n` (coords + Jacobians)
    /// and their flat `d * nb` histogram rows into
    /// `bidx[(k0 + j) * d ..]` — bitwise identical to
    /// [`VegasMap::fill_points_scalar`].
    #[allow(clippy::too_many_arguments)]
    pub fn fill_points(
        &self,
        coords: &[usize],
        base_sidx: u64,
        n: usize,
        iteration: u32,
        seed: u32,
        block: &mut PointBlock,
        k0: usize,
        bidx: &mut [usize],
    ) {
        self.fill_lanes(coords, 1, n, base_sidx, iteration, seed, block, k0, bidx);
    }

    /// Lane-parallel fill of a whole multi-cube span: `ncubes`
    /// consecutive sub-cubes with `p` samples each, drawing the
    /// consecutive sample indices `base_sidx .. base_sidx + ncubes*p`
    /// (the uniform engine's counter layout runs straight across cube
    /// boundaries), with each cube's lattice coords provided row-major
    /// in `cube_coords` (`[ncubes][d]`). Writes block slots
    /// `0 .. ncubes*p`.
    ///
    /// This is the uniform engine's fill: lane groups stay full even
    /// when `p` is tiny (the common `p = 2` regime would waste most of
    /// a lane group under the per-cube [`VegasMap::fill_points`]).
    /// Bitwise identical to per-cube scalar fills over the same span.
    #[allow(clippy::too_many_arguments)]
    pub fn fill_span(
        &self,
        cube_coords: &[usize],
        ncubes: usize,
        p: usize,
        base_sidx: u64,
        iteration: u32,
        seed: u32,
        block: &mut PointBlock,
        bidx: &mut [usize],
    ) {
        self.fill_lanes(cube_coords, ncubes, p, base_sidx, iteration, seed, block, 0, bidx);
    }

    /// [`VegasMap::fill_span`] writing to block slots `k0 ..` — the
    /// streaming engine's whole-cube-run fill. Lane groups run across
    /// cube boundaries exactly as in `fill_span`; per the determinism
    /// contract the grouping leaves every point's bits unchanged.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fill_span_at(
        &self,
        cube_coords: &[usize],
        ncubes: usize,
        p: usize,
        base_sidx: u64,
        iteration: u32,
        seed: u32,
        block: &mut PointBlock,
        k0: usize,
        bidx: &mut [usize],
    ) {
        self.fill_lanes(cube_coords, ncubes, p, base_sidx, iteration, seed, block, k0, bidx);
    }

    /// The one lane-parallel fill kernel behind [`VegasMap::fill_points`]
    /// (`ncubes = 1`) and [`VegasMap::fill_span`] (`k0 = 0`): `ncubes`
    /// consecutive sub-cubes × `p` samples with consecutive sample
    /// indices, written to block slots `k0 ..`.
    #[allow(clippy::too_many_arguments)]
    fn fill_lanes(
        &self,
        cube_coords: &[usize],
        ncubes: usize,
        p: usize,
        base_sidx: u64,
        iteration: u32,
        seed: u32,
        block: &mut PointBlock,
        k0: usize,
        bidx: &mut [usize],
    ) {
        if ncubes == 0 || p == 0 {
            return;
        }
        let d = self.d;
        let nb = self.nb;
        debug_assert_eq!(cube_coords.len(), ncubes * d);
        debug_assert!(d <= MAX_DIM);
        let n = ncubes * p;
        let mut u = [[0.0f64; LANES]; MAX_DIM];
        let mut cube_of = [0usize; LANES];
        let mut done = 0usize;
        // Full lane groups with *constant* inner-loop bounds — the
        // shape the autovectorizer lowers to straight-line SIMD.
        while done + LANES <= n {
            uniforms_lanes::<LANES>(base_sidx + done as u64, iteration, seed, &mut u[..d]);
            for (l, c) in cube_of.iter_mut().enumerate() {
                *c = (done + l) / p;
            }
            let mut jac = [self.vol; LANES];
            for i in 0..d {
                let row = i * nb;
                for l in 0..LANES {
                    let ci = cube_coords[cube_of[l] * d + i] as f64;
                    let z = (ci + u[i][l]) * self.inv_g;
                    let loc = z * self.nbf;
                    let b = (loc as usize).min(nb - 1);
                    // SAFETY: i < d and b < nb, so row + b < d*nb ==
                    // edges.len() (same bound as the scalar fill).
                    let right = unsafe { *self.edges.get_unchecked(row + b) };
                    let left = if b == 0 {
                        0.0
                    } else {
                        unsafe { *self.edges.get_unchecked(row + b - 1) }
                    };
                    let w = right - left;
                    let xt = left + (loc - b as f64) * w;
                    jac[l] *= self.nbf * w;
                    block.set_coord(i, k0 + done + l, self.lo_ax[i] + xt * self.span_ax[i]);
                    bidx[(k0 + done + l) * d + i] = row + b;
                }
            }
            for l in 0..LANES {
                block.set_jac(k0 + done + l, jac[l]);
            }
            done += LANES;
        }
        // Ragged tail: per-point scalar math on each remaining
        // point's own cube — identical expressions, bitwise equal.
        while done < n {
            let c = done / p;
            self.fill_points_scalar(
                &cube_coords[c * d..(c + 1) * d],
                base_sidx + done as u64,
                1,
                iteration,
                seed,
                block,
                k0 + done,
                bidx,
            );
            done += 1;
        }
    }

    /// The scalar reference fill: one [`uniforms_into`] +
    /// [`VegasMap::fill_point`] per sample, in sample order — the loop
    /// the engines ran before the SIMD core, kept as the bitwise
    /// oracle for property tests and the microbench baseline.
    #[allow(clippy::too_many_arguments)]
    pub fn fill_points_scalar(
        &self,
        coords: &[usize],
        base_sidx: u64,
        n: usize,
        iteration: u32,
        seed: u32,
        block: &mut PointBlock,
        k0: usize,
        bidx: &mut [usize],
    ) {
        let d = self.d;
        debug_assert_eq!(coords.len(), d);
        let mut u = [0.0f64; MAX_DIM];
        for k in 0..n {
            uniforms_into(base_sidx + k as u64, iteration, seed, &mut u[..d]);
            self.fill_point(coords, &u[..d], block, k0 + k, bidx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::Bins;
    use crate::integrands::by_name;
    use crate::strat::Layout;

    fn fill_pair(
        layout: &Layout,
        bins: &Bins,
        base_sidx: u64,
        n: usize,
        cube: usize,
    ) -> (PointBlock, Vec<usize>, PointBlock, Vec<usize>) {
        let d = layout.d;
        let f = by_name("f4", d).unwrap();
        let map = VegasMap::new(layout, bins, &f.bounds());
        let mut coords = vec![0usize; d];
        layout.cube_coords(cube, &mut coords);
        let mut simd = PointBlock::with_capacity(d, n);
        let mut scalar = PointBlock::with_capacity(d, n);
        simd.reset(n);
        scalar.reset(n);
        let mut bidx_simd = vec![0usize; n * d];
        let mut bidx_scalar = vec![0usize; n * d];
        map.fill_points(&coords, base_sidx, n, 3, 42, &mut simd, 0, &mut bidx_simd);
        map.fill_points_scalar(&coords, base_sidx, n, 3, 42, &mut scalar, 0, &mut bidx_scalar);
        (simd, bidx_simd, scalar, bidx_scalar)
    }

    #[test]
    fn lane_fill_matches_scalar_fill_bitwise() {
        // Partial lane groups on purpose: n not a multiple of LANES.
        for (d, n) in [(1usize, 3usize), (4, 7), (7, 13), (16, 5)] {
            let layout = Layout::compute(d, 2048, 16, 1).unwrap();
            let bins = Bins::uniform(d, 16);
            let (simd, bi_s, scalar, bi_r) = fill_pair(&layout, &bins, 11, n, layout.m / 2);
            for k in 0..n {
                assert_eq!(
                    simd.jac(k).to_bits(),
                    scalar.jac(k).to_bits(),
                    "d={d} n={n} jac {k}"
                );
                for i in 0..d {
                    assert_eq!(
                        simd.coord(i, k).to_bits(),
                        scalar.coord(i, k).to_bits(),
                        "d={d} n={n} coord ({i}, {k})"
                    );
                }
            }
            assert_eq!(bi_s, bi_r, "d={d} n={n} histogram rows");
        }
    }

    /// The multi-cube span fill (lane groups crossing cube boundaries,
    /// the p = 2 workhorse) equals per-cube scalar fills bitwise.
    #[test]
    fn span_fill_matches_per_cube_scalar_bitwise() {
        for (d, ncubes, p) in [(2usize, 5usize, 2usize), (3, 3, 3), (5, 7, 2), (1, 11, 4)] {
            let layout = Layout::compute(d, 4096, 12, 1).unwrap();
            let bins = Bins::uniform(d, 12);
            let f = by_name("f4", d).unwrap();
            let map = VegasMap::new(&layout, &bins, &f.bounds());
            let n = ncubes * p;
            let mut span = PointBlock::with_capacity(d, n);
            let mut scalar = PointBlock::with_capacity(d, n);
            span.reset(n);
            scalar.reset(n);
            let mut bidx_span = vec![0usize; n * d];
            let mut bidx_scalar = vec![0usize; n * d];
            // ncubes consecutive cubes starting mid-layout.
            let cube0 = (layout.m / 3).min(layout.m - ncubes);
            let mut cube_coords = vec![0usize; ncubes * d];
            for c in 0..ncubes {
                layout.cube_coords(cube0 + c, &mut cube_coords[c * d..(c + 1) * d]);
            }
            let base = (cube0 * p) as u64;
            map.fill_span(&cube_coords, ncubes, p, base, 5, 9, &mut span, &mut bidx_span);
            for c in 0..ncubes {
                map.fill_points_scalar(
                    &cube_coords[c * d..(c + 1) * d],
                    base + (c * p) as u64,
                    p,
                    5,
                    9,
                    &mut scalar,
                    c * p,
                    &mut bidx_scalar,
                );
            }
            for k in 0..n {
                assert_eq!(
                    span.jac(k).to_bits(),
                    scalar.jac(k).to_bits(),
                    "d={d} ncubes={ncubes} p={p} jac {k}"
                );
                for i in 0..d {
                    assert_eq!(
                        span.coord(i, k).to_bits(),
                        scalar.coord(i, k).to_bits(),
                        "d={d} ncubes={ncubes} p={p} coord ({i}, {k})"
                    );
                }
            }
            assert_eq!(bidx_span, bidx_scalar);
        }
    }

    /// Regression for the truncation bug: a fill based just below the
    /// 2^32 sample boundary must keep drawing *new* counters past it,
    /// not wrap back to samples 0, 1, ..
    #[test]
    fn lane_fill_crosses_the_u32_boundary() {
        let d = 4;
        let layout = Layout::compute(d, 2048, 16, 1).unwrap();
        let bins = Bins::uniform(d, 16);
        let n = 6;
        let base = (1u64 << 32) - 2; // straddles the boundary
        let (simd, _, scalar, _) = fill_pair(&layout, &bins, base, n, 0);
        // What the truncating `as u32` pipeline would have drawn for
        // the samples past the boundary: indices 0, 1, 2, 3.
        let (low, _, _, _) = fill_pair(&layout, &bins, 0, 4, 0);
        let mut any_differs = false;
        for k in 0..n {
            assert_eq!(simd.coord(0, k).to_bits(), scalar.coord(0, k).to_bits());
            if k >= 2 && simd.coord(0, k).to_bits() != low.coord(0, k - 2).to_bits() {
                any_differs = true;
            }
        }
        assert!(any_differs, "stream wrapped at 2^32 — counter truncated");
    }
}
