//! VEGAS+ adaptive-stratification engine — variable per-cube sample
//! counts over the m-Cubes layout.
//!
//! The uniform engine ([`crate::engine::UniformEngine`]) gives every
//! sub-cube the same `p` samples. [`VegasPlusEngine`] drives the
//! identical fill-block → `eval_batch` → reduce walk
//! ([`crate::engine::walk`]) with a live per-cube [`Allocation`]: cube
//! `k` draws `counts[k]` samples from the 64-bit Philox indices
//! `offsets[k] .. offsets[k] + counts[k]` (exclusive prefix sums of
//! the counts — no wrapping, even past 2^32 total calls), so the
//! sample stream of every cube is a pure function of
//! `(seed, iteration, allocation)` — never of the thread count. The
//! engine's [`Engine::update`] hook folds each cube's fresh variance
//! observation `n_k * Var_k` into the allocation's damped accumulator
//! (`d_k <- d_k/2 + n_k Var_k / 2`) and then re-apportions the next
//! iteration's budget with weights `d_k^beta`
//! ([`Allocation::reallocate`]).
//!
//! ## Reproducibility contract
//!
//! The cube range is partitioned into the engine's fixed reduction
//! tasks and partials are folded in task order — the same contract as
//! the uniform engine, so:
//!
//! * results are bitwise identical for any `threads` value, and
//! * with a uniform allocation (`beta = 0`, or the initial state) the
//!   Philox offsets collapse to `cube * p` and the whole pass is
//!   bitwise identical to the uniform engine (property-tested in
//!   `rust/tests/properties.rs`).

use super::simd::FillPath;
use super::tasks::merge_task_partials;
use super::walk::{self, ExecPath, StratSched};
use super::{reduction_tasks, Engine, TaskPartial, VSampleOpts};
use crate::api::StratSnapshot;
use crate::error::Result;
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::strat::{AllocStats, Allocation, Layout};

/// VEGAS+ adaptively-stratified [`Engine`]: owns the layout and the
/// live [`Allocation`], samples through the shared walk with the
/// per-cube (counts, offsets) schedule, and re-apportions the
/// per-iteration budget in [`Engine::update`].
#[derive(Debug, Clone)]
pub struct VegasPlusEngine {
    layout: Layout,
    beta: f64,
    /// Per-iteration call budget (`layout.calls()`, matching the
    /// uniform engine so `calls_used` accounting is identical).
    budget: usize,
    alloc: Allocation,
}

impl VegasPlusEngine {
    /// Build a VEGAS+ engine, resuming `resume`'s allocation when its
    /// cube count matches `layout` (the re-apportionment is a pure
    /// function of the damped accumulator, so a matching snapshot
    /// restores the exact per-cube counts); any mismatch starts from
    /// the uniform split.
    pub fn new(
        layout: Layout,
        beta: f64,
        resume: Option<&StratSnapshot>,
    ) -> Result<VegasPlusEngine> {
        let alloc = match resume {
            Some(s) if s.counts.len() == layout.m => {
                let mut a = Allocation::from_parts(s.counts.clone(), s.damped.clone())?;
                a.reallocate(layout.calls(), beta);
                a
            }
            _ => Allocation::uniform(&layout),
        };
        Ok(VegasPlusEngine {
            layout,
            beta,
            budget: layout.calls(),
            alloc,
        })
    }

    /// Redistribution exponent this engine re-apportions with.
    pub fn beta(&self) -> f64 {
        self.beta
    }

    /// The live allocation (test/inspection hook).
    pub fn alloc(&self) -> &Allocation {
        &self.alloc
    }
}

impl Engine for VegasPlusEngine {
    fn name(&self) -> &'static str {
        "native-vegas+"
    }

    fn layout(&self) -> &Layout {
        &self.layout
    }

    fn sample_tasks(
        &self,
        f: &dyn Integrand,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
        task_lo: usize,
        task_hi: usize,
    ) -> Vec<TaskPartial> {
        walk::run_tasks(
            f,
            &self.layout,
            bins,
            &StratSched {
                counts: self.alloc.counts(),
                offsets: self.alloc.offsets(),
            },
            opts,
            fill,
            exec,
            task_lo,
            task_hi,
        )
    }

    /// Absorb the fresh per-cube variance observations in task order
    /// (each cube appears exactly once per iteration, so the absorb
    /// placement never changes the damped accumulator's bits), then
    /// re-apportion the next iteration's budget — which also leaves
    /// the exported snapshot ready for warm starts even when this was
    /// the final iteration.
    fn update(&mut self, partials: &[TaskPartial]) {
        for p in partials {
            self.alloc.absorb_span(p.cube_lo, &p.d_new);
        }
        self.alloc.reallocate(self.budget, self.beta);
    }

    fn allocation(&self) -> Option<(&[u32], &[u64])> {
        Some((self.alloc.counts(), self.alloc.offsets()))
    }

    fn alloc_stats(&self) -> Option<AllocStats> {
        Some(self.alloc.stats())
    }

    fn export(&self) -> Option<StratSnapshot> {
        Some(StratSnapshot {
            beta: self.beta,
            counts: self.alloc.counts().to_vec(),
            damped: self.alloc.damped().to_vec(),
        })
    }
}

/// One VEGAS+ V-Sample pass over every sub-cube in `layout`, against a
/// caller-owned [`Allocation`].
///
/// Samples cube `k` `alloc.counts()[k]` times and folds the fresh
/// per-cube variance into `alloc`'s damped accumulator; the *caller*
/// decides when to [`Allocation::reallocate`] ([`VegasPlusEngine`]
/// does so every iteration). Returns the iteration estimate plus
/// (when `opts.adjust`) the row-major `[d][nb]` bin-contribution
/// histogram — the same contract as the uniform engine's pass.
pub fn vsample_stratified(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    alloc: &mut Allocation,
    opts: &VSampleOpts,
) -> (IterationResult, Option<Vec<f64>>) {
    assert_eq!(alloc.m(), layout.m, "allocation cube count != layout");
    let ntasks = reduction_tasks(layout.m);
    let partials = {
        let sched = StratSched {
            counts: alloc.counts(),
            offsets: alloc.offsets(),
        };
        walk::run_tasks(
            f,
            layout,
            bins,
            &sched,
            opts,
            FillPath::Simd,
            ExecPath::default(),
            0,
            ntasks,
        )
    };
    let out = merge_task_partials(layout.d, layout.nb, opts.adjust, &partials);
    for p in &partials {
        alloc.absorb_span(p.cube_lo, &p.d_new);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::integrands::by_name;

    fn opts(seed: u32, it: u32, threads: usize) -> VSampleOpts {
        VSampleOpts {
            seed,
            iteration: it,
            adjust: true,
            threads,
        }
    }

    #[test]
    fn uniform_allocation_matches_uniform_engine_bitwise() {
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let (ru, cu) = NativeEngine.vsample(&*f, &layout, &bins, &opts(42, 0, 2));
        let mut alloc = Allocation::uniform(&layout);
        let (rs, cs) = vsample_stratified(&*f, &layout, &bins, &mut alloc, &opts(42, 0, 3));
        assert_eq!(ru.integral.to_bits(), rs.integral.to_bits());
        assert_eq!(ru.variance.to_bits(), rs.variance.to_bits());
        let (cu, cs) = (cu.unwrap(), cs.unwrap());
        for (a, b) in cu.iter().zip(&cs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bitwise_identical_across_thread_counts() {
        let f = by_name("f3", 4).unwrap();
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(4, 16);
        // Skewed allocation so counts differ wildly across cubes.
        let mut a1 = Allocation::uniform(&layout);
        a1.absorb(0, 100.0);
        for cube in 1..a1.m() {
            a1.absorb(cube, 0.01);
        }
        a1.reallocate(layout.calls(), crate::strat::DEFAULT_BETA);
        let mut a4 = a1.clone();
        let (r1, c1) = vsample_stratified(&*f, &layout, &bins, &mut a1, &opts(9, 3, 1));
        let (r4, c4) = vsample_stratified(&*f, &layout, &bins, &mut a4, &opts(9, 3, 4));
        assert_eq!(r1.integral.to_bits(), r4.integral.to_bits());
        assert_eq!(r1.variance.to_bits(), r4.variance.to_bits());
        for (a, b) in c1.unwrap().iter().zip(&c4.unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in a1.damped().iter().zip(a4.damped()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn engine_pass_matches_free_function_plus_reallocate_bitwise() {
        // VegasPlusEngine::vsample == vsample_stratified followed by
        // the caller's reallocate — pinning that the trait port did
        // not move the re-apportionment relative to the absorb fold.
        let f = by_name("f3", 4).unwrap();
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(4, 16);
        let beta = crate::strat::DEFAULT_BETA;
        let mut engine = VegasPlusEngine::new(layout, beta, None).unwrap();
        let mut alloc = Allocation::uniform(&layout);
        for it in 0..3 {
            let (re, ce) = engine.vsample(
                &*f,
                &bins,
                &opts(11, it, 2),
                FillPath::Simd,
                ExecPath::default(),
            );
            let (rf, cf) = vsample_stratified(&*f, &layout, &bins, &mut alloc, &opts(11, it, 3));
            alloc.reallocate(layout.calls(), beta);
            assert_eq!(re.integral.to_bits(), rf.integral.to_bits());
            assert_eq!(re.variance.to_bits(), rf.variance.to_bits());
            for (a, b) in ce.unwrap().iter().zip(&cf.unwrap()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            let (counts, _) = engine.allocation().unwrap();
            assert_eq!(counts, alloc.counts());
            for (a, b) in engine.alloc().damped().iter().zip(alloc.damped()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn resume_restores_the_allocation_bitwise() {
        // Export after two iterations, rebuild from the snapshot, and
        // the third iteration must match the uninterrupted engine.
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(5, 16);
        let beta = 0.5;
        let mut donor = VegasPlusEngine::new(layout, beta, None).unwrap();
        for it in 0..2 {
            donor.vsample(
                &*f,
                &bins,
                &opts(21, it, 2),
                FillPath::Simd,
                ExecPath::default(),
            );
        }
        let snap = donor.export().unwrap();
        let mut resumed = VegasPlusEngine::new(layout, beta, Some(&snap)).unwrap();
        let (rd, _) = donor.vsample(
            &*f,
            &bins,
            &opts(21, 2, 2),
            FillPath::Simd,
            ExecPath::default(),
        );
        let (rr, _) = resumed.vsample(
            &*f,
            &bins,
            &opts(21, 2, 4),
            FillPath::Simd,
            ExecPath::default(),
        );
        assert_eq!(rd.integral.to_bits(), rr.integral.to_bits());
        assert_eq!(rd.variance.to_bits(), rr.variance.to_bits());
    }

    #[test]
    fn no_adjust_skips_histogram_but_updates_accumulator() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let bins = Bins::uniform(4, 10);
        let mut alloc = Allocation::uniform(&layout);
        let (_, c) = vsample_stratified(
            &*f,
            &layout,
            &bins,
            &mut alloc,
            &VSampleOpts {
                adjust: false,
                ..opts(1, 0, 2)
            },
        );
        assert!(c.is_none());
        assert!(
            alloc.damped().iter().any(|&d| d > 0.0),
            "variance observations must land in the accumulator"
        );
    }

    #[test]
    fn allocation_concentrates_on_the_peak() {
        // f4's sharp Gaussian peaks at the box center: after a pass +
        // reallocation, the cubes nearest the center must hold more
        // samples than the corner cube. (d=5 @4096 gives p=4 — real
        // re-allocation headroom above the per-cube floor of 2.)
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(5, 16);
        let mut alloc = Allocation::uniform(&layout);
        vsample_stratified(&*f, &layout, &bins, &mut alloc, &opts(7, 0, 2));
        alloc.reallocate(layout.calls(), crate::strat::DEFAULT_BETA);
        let mut mid = [0usize; 5];
        for s in mid.iter_mut() {
            *s = layout.g / 2;
        }
        let center = layout.cube_index(&mid);
        assert!(
            alloc.counts()[center] > alloc.counts()[0],
            "center cube {} should outdraw corner cube {}",
            alloc.counts()[center],
            alloc.counts()[0]
        );
        assert_eq!(alloc.total(), layout.calls());
    }
}
