//! VEGAS+ adaptive-stratification sampling path — variable per-cube
//! sample counts over the m-Cubes layout.
//!
//! The uniform engine ([`crate::engine::NativeEngine::vsample`]) gives
//! every sub-cube the same `p` samples. This path drives the identical
//! fill-block → `eval_batch` → reduce pipeline with a per-cube
//! [`Allocation`]: cube `k` draws `counts[k]` samples from the 64-bit
//! Philox indices `offsets[k] .. offsets[k] + counts[k]` (exclusive
//! prefix sums of the counts — no wrapping, even past 2^32 total
//! calls), so the sample stream of every cube is a pure function of
//! `(seed, iteration, allocation)` — never of the thread count. After the pass each cube's fresh variance observation
//! `n_k * Var_k` is folded into the allocation's damped accumulator
//! (`d_k <- d_k/2 + n_k Var_k / 2`); the *caller* decides when to
//! [`Allocation::reallocate`] with weights `d_k^beta`
//! (`crate::coordinator`'s stratified backend does so every iteration).
//!
//! ## Reproducibility contract
//!
//! The cube range is partitioned into the engine's fixed reduction
//! tasks and partials are folded in task order — the same contract as
//! the uniform engine, so:
//!
//! * results are bitwise identical for any `threads` value, and
//! * with a uniform allocation (`beta = 0`, or the initial state) the
//!   Philox offsets collapse to `cube * p` and the whole pass is
//!   bitwise identical to `NativeEngine::vsample` (property-tested in
//!   `rust/tests/properties.rs`).

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::block::{PointBlock, VegasMap, BLOCK_POINTS};
use super::simd::FillPath;
use super::{reduction_task_span, reduction_tasks, VSampleOpts, MAX_DIM};
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::strat::{Allocation, Layout};
use crate::util::threadpool::parallel_chunks;

/// One reduction task's partial output. `pub(super)` so the
/// task-subrange entry points ([`super::tasks`]) reuse the exact same
/// per-task body the full pass runs.
pub(super) struct Partial {
    pub(super) cube_lo: usize,
    pub(super) integral: f64,
    pub(super) variance: f64,
    pub(super) contrib: Option<Vec<f64>>,
    /// Fresh per-cube variance observations `n_k * Var_k`, indexed
    /// relative to `cube_lo`.
    pub(super) d_new: Vec<f64>,
}

/// One reduction task's body: sample cubes `[cube_lo, cube_hi)` under
/// the per-cube allocation view (`counts`/`offsets`) and return the
/// task partial. This is THE stratified per-task arithmetic — both the
/// full pass below and the shard workers ([`super::tasks`]) call it, so
/// an N-shard merge folds bit-identical partials. Scratch is owned per
/// call; allocation placement never changes the float stream.
#[allow(clippy::too_many_arguments)]
pub(super) fn sample_task_stratified(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    counts: &[u32],
    offsets: &[u64],
    opts: &VSampleOpts,
    fill: FillPath,
    cube_lo: usize,
    cube_hi: usize,
) -> Partial {
    let d = layout.d;
    let nb = layout.nb;
    let m = layout.m as f64;
    let map = VegasMap::new(layout, bins, &f.bounds());
    let mut blk = PointBlock::with_capacity(d, BLOCK_POINTS);
    let mut vals = vec![0.0f64; BLOCK_POINTS];
    let mut bidx = vec![0usize; BLOCK_POINTS * d];
    let mut coords = [0usize; MAX_DIM];
    let mut out = Partial {
        cube_lo,
        integral: 0.0,
        variance: 0.0,
        contrib: opts.adjust.then(|| vec![0.0; d * nb]),
        d_new: Vec::with_capacity(cube_hi - cube_lo),
    };
    for cube in cube_lo..cube_hi {
        layout.cube_coords(cube, &mut coords[..d]);
        let n = counts[cube].max(2);
        let nf = n as f64;
        let mut s1 = 0.0;
        let mut s2 = 0.0;
        // A cube's (variable-size) sample set is processed in
        // block-sized chunks, carrying s1/s2 across chunks so the
        // accumulation order matches the uniform engine's.
        let mut k0 = 0u32;
        while k0 < n {
            let chunk = (n - k0).min(BLOCK_POINTS as u32);
            blk.reset(chunk as usize);
            // The cube's sample stream starts at its 64-bit
            // prefix-sum offset — no wrapping, even past 2^32 total
            // calls.
            let base_sidx = offsets[cube] + k0 as u64;
            match fill {
                FillPath::Simd => map.fill_points(
                    &coords[..d],
                    base_sidx,
                    chunk as usize,
                    opts.iteration,
                    opts.seed,
                    &mut blk,
                    0,
                    &mut bidx,
                ),
                FillPath::Scalar => map.fill_points_scalar(
                    &coords[..d],
                    base_sidx,
                    chunk as usize,
                    opts.iteration,
                    opts.seed,
                    &mut blk,
                    0,
                    &mut bidx,
                ),
            }
            f.eval_batch(&blk, &mut vals[..chunk as usize]);
            for j in 0..chunk as usize {
                let v = vals[j] * blk.jac(j);
                s1 += v;
                s2 += v * v;
                if let Some(cacc) = out.contrib.as_mut() {
                    let v2 = v * v;
                    for i in 0..d {
                        cacc[bidx[j * d + i]] += v2;
                    }
                }
            }
            k0 += chunk;
        }
        let mean = s1 / nf;
        let var = ((s2 / nf - mean * mean).max(0.0)) / (nf - 1.0);
        out.integral += mean / m;
        out.variance += var / (m * m);
        // Variance of the *cube total* — Lepage's d_k observation
        // driving the next allocation.
        out.d_new.push(var * nf);
    }
    out
}

/// One VEGAS+ V-Sample pass over every sub-cube in `layout`.
///
/// Samples cube `k` `alloc.counts()[k]` times, folds the fresh per-cube
/// variance into `alloc`'s damped accumulator, and returns the
/// iteration estimate plus (when `opts.adjust`) the row-major `[d][nb]`
/// bin-contribution histogram — the same contract as the uniform
/// engine's `vsample`.
pub fn vsample_stratified(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    alloc: &mut Allocation,
    opts: &VSampleOpts,
) -> (IterationResult, Option<Vec<f64>>) {
    vsample_stratified_with_fill(f, layout, bins, alloc, opts, FillPath::Simd)
}

/// [`vsample_stratified`] with an explicit [`FillPath`] — the two
/// paths are bitwise identical (SIMD determinism contract); `Scalar`
/// exists for the equivalence property tests and the microbench.
pub fn vsample_stratified_with_fill(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    alloc: &mut Allocation,
    opts: &VSampleOpts,
    fill: FillPath,
) -> (IterationResult, Option<Vec<f64>>) {
    assert!(layout.d <= MAX_DIM, "d > MAX_DIM");
    if let Err(e) = layout.validate() {
        panic!("invalid layout: {e}");
    }
    assert_eq!(bins.d(), layout.d);
    assert_eq!(bins.nb(), layout.nb);
    assert_eq!(alloc.m(), layout.m, "allocation cube count != layout");
    let d = layout.d;
    let nb = layout.nb;

    let ntasks = reduction_tasks(layout.m);
    let task_partials: Vec<Vec<Partial>> = {
        let counts = alloc.counts();
        let offsets = alloc.offsets();
        parallel_chunks(ntasks, opts.threads, |t0, t1| {
            (t0..t1)
                .map(|t| {
                    let (cube_lo, cube_hi) = reduction_task_span(layout.m, ntasks, t);
                    sample_task_stratified(
                        f, layout, bins, counts, offsets, opts, fill, cube_lo, cube_hi,
                    )
                })
                .collect()
        })
    };

    let mut integral = 0.0;
    let mut variance = 0.0;
    let mut contrib = opts.adjust.then(|| vec![0.0; d * nb]);
    for p in task_partials.into_iter().flatten() {
        integral += p.integral;
        variance += p.variance;
        if let (Some(acc), Some(part)) = (contrib.as_mut(), p.contrib.as_ref()) {
            for (x, y) in acc.iter_mut().zip(part) {
                *x += y;
            }
        }
        for (i, &dn) in p.d_new.iter().enumerate() {
            alloc.absorb(p.cube_lo + i, dn);
        }
    }
    (
        IterationResult {
            integral,
            variance,
        },
        contrib,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::integrands::by_name;

    fn opts(seed: u32, it: u32, threads: usize) -> VSampleOpts {
        VSampleOpts {
            seed,
            iteration: it,
            adjust: true,
            threads,
        }
    }

    #[test]
    fn uniform_allocation_matches_uniform_engine_bitwise() {
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let (ru, cu) = NativeEngine.vsample(&*f, &layout, &bins, &opts(42, 0, 2));
        let mut alloc = Allocation::uniform(&layout);
        let (rs, cs) = vsample_stratified(&*f, &layout, &bins, &mut alloc, &opts(42, 0, 3));
        assert_eq!(ru.integral.to_bits(), rs.integral.to_bits());
        assert_eq!(ru.variance.to_bits(), rs.variance.to_bits());
        let (cu, cs) = (cu.unwrap(), cs.unwrap());
        for (a, b) in cu.iter().zip(&cs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bitwise_identical_across_thread_counts() {
        let f = by_name("f3", 4).unwrap();
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(4, 16);
        // Skewed allocation so counts differ wildly across cubes.
        let mut a1 = Allocation::uniform(&layout);
        a1.absorb(0, 100.0);
        for cube in 1..a1.m() {
            a1.absorb(cube, 0.01);
        }
        a1.reallocate(layout.calls(), crate::strat::DEFAULT_BETA);
        let mut a4 = a1.clone();
        let (r1, c1) = vsample_stratified(&*f, &layout, &bins, &mut a1, &opts(9, 3, 1));
        let (r4, c4) = vsample_stratified(&*f, &layout, &bins, &mut a4, &opts(9, 3, 4));
        assert_eq!(r1.integral.to_bits(), r4.integral.to_bits());
        assert_eq!(r1.variance.to_bits(), r4.variance.to_bits());
        for (a, b) in c1.unwrap().iter().zip(&c4.unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in a1.damped().iter().zip(a4.damped()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn no_adjust_skips_histogram_but_updates_accumulator() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let bins = Bins::uniform(4, 10);
        let mut alloc = Allocation::uniform(&layout);
        let (_, c) = vsample_stratified(
            &*f,
            &layout,
            &bins,
            &mut alloc,
            &VSampleOpts {
                adjust: false,
                ..opts(1, 0, 2)
            },
        );
        assert!(c.is_none());
        assert!(
            alloc.damped().iter().any(|&d| d > 0.0),
            "variance observations must land in the accumulator"
        );
    }

    #[test]
    fn allocation_concentrates_on_the_peak() {
        // f4's sharp Gaussian peaks at the box center: after a pass +
        // reallocation, the cubes nearest the center must hold more
        // samples than the corner cube. (d=5 @4096 gives p=4 — real
        // re-allocation headroom above the per-cube floor of 2.)
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(5, 16);
        let mut alloc = Allocation::uniform(&layout);
        vsample_stratified(&*f, &layout, &bins, &mut alloc, &opts(7, 0, 2));
        alloc.reallocate(layout.calls(), crate::strat::DEFAULT_BETA);
        let mut mid = [0usize; 5];
        for s in mid.iter_mut() {
            *s = layout.g / 2;
        }
        let center = layout.cube_index(&mid);
        assert!(
            alloc.counts()[center] > alloc.counts()[0],
            "center cube {} should outdraw corner cube {}",
            alloc.counts()[center],
            alloc.counts()[0]
        );
        assert_eq!(alloc.total(), layout.calls());
    }
}
