//! Adaptive stratification — the paper's footnote-4 extension (the
//! "later versions of the algorithm deploy adaptive stratification
//! that adjust the number of integral estimates used in each
//! sub-cube", Lepage 2021 "VEGAS enhanced").
//!
//! Instead of a uniform `p` samples per sub-cube, each cube's sample
//! count is re-allocated every iteration proportionally to a damped
//! power of its accumulated sigma: `n_t ∝ sigma_t^(2β)` with β = 0.75
//! (Lepage's default), floored at 2 so every cube keeps a variance
//! estimate. This is exactly the *non-uniform workload* the m-Cubes
//! uniform mapping deliberately avoids on GPUs; shipping both lets the
//! ablation bench quantify the trade (statistical efficiency vs
//! workload balance).
//!
//! Counter mapping: sample k of cube t draws Philox index
//! `offset[t] + k` where `offset` is the exclusive prefix sum of the
//! per-cube counts — deterministic and collision-free per iteration.

use super::block::{PointBlock, VegasMap, BLOCK_POINTS};
use super::MAX_DIM;
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::rng::uniforms_into;
use crate::strat::Layout;
use crate::util::threadpool::parallel_chunks;

/// Damping exponent for sample re-allocation (Lepage 2021 uses
/// beta = 0.75; beta = 0 recovers uniform allocation).
pub const BETA: f64 = 0.75;

/// Per-iteration state of the adaptive-stratification sampler.
#[derive(Debug, Clone)]
pub struct StratState {
    /// Samples allocated to each cube this iteration.
    pub counts: Vec<u32>,
    /// Exclusive prefix sums of `counts` (Philox offsets).
    pub offsets: Vec<u32>,
    /// Damped per-cube sigma accumulator driving the allocation.
    pub sigmas: Vec<f64>,
}

impl StratState {
    /// Uniform initial allocation (the m-Cubes layout).
    pub fn uniform(layout: &Layout) -> StratState {
        let counts = vec![layout.p as u32; layout.m];
        let offsets = prefix_sums(&counts);
        StratState {
            counts,
            offsets,
            sigmas: vec![0.0; layout.m],
        }
    }

    /// Total samples this iteration.
    pub fn total(&self) -> usize {
        self.counts.iter().map(|&c| c as usize).sum()
    }

    /// Re-allocate the call budget from the damped sigmas.
    pub fn reallocate(&mut self, budget: usize) {
        let weights: Vec<f64> = self
            .sigmas
            .iter()
            .map(|&s| s.max(1e-300).powf(2.0 * BETA))
            .collect();
        let total_w: f64 = weights.iter().sum();
        let m = self.counts.len();
        let spendable = budget.saturating_sub(2 * m).max(0);
        let mut allocated = 0usize;
        for (i, w) in weights.iter().enumerate() {
            let extra = if total_w > 0.0 {
                (spendable as f64 * w / total_w) as u32
            } else {
                (spendable / m) as u32
            };
            self.counts[i] = 2 + extra;
            allocated += self.counts[i] as usize;
        }
        // Distribute rounding remainder deterministically.
        let mut leftover = budget.saturating_sub(allocated);
        let mut i = 0usize;
        while leftover > 0 && m > 0 {
            self.counts[i % m] += 1;
            leftover -= 1;
            i += 1;
        }
        self.offsets = prefix_sums(&self.counts);
    }
}

fn prefix_sums(counts: &[u32]) -> Vec<u32> {
    let mut offsets = Vec::with_capacity(counts.len());
    let mut acc = 0u32;
    for &c in counts {
        offsets.push(acc);
        acc = acc.wrapping_add(c);
    }
    offsets
}

/// One adaptive-stratification V-Sample pass. Updates `state.sigmas`
/// (damped) and returns the iteration estimate plus the bin histogram.
pub fn vsample_adaptive(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    state: &mut StratState,
    seed: u32,
    iteration: u32,
    threads: usize,
) -> (IterationResult, Vec<f64>) {
    assert!(layout.d <= MAX_DIM);
    assert_eq!(state.counts.len(), layout.m);
    let d = layout.d;
    let nb = layout.nb;
    let m = layout.m as f64;

    struct Partial {
        integral: f64,
        variance: f64,
        contrib: Vec<f64>,
        sigmas: Vec<(usize, f64)>,
    }

    let counts = &state.counts;
    let offsets = &state.offsets;
    let partials = parallel_chunks(layout.m, threads, |a, b| {
        let mut out = Partial {
            integral: 0.0,
            variance: 0.0,
            contrib: vec![0.0; d * nb],
            sigmas: Vec::with_capacity(b - a),
        };
        // Shared batch machinery: same transform as the uniform engine.
        let map = VegasMap::new(layout, bins, &f.bounds());
        let mut blk = PointBlock::with_capacity(d, BLOCK_POINTS);
        let mut vals = vec![0.0f64; BLOCK_POINTS];
        let mut bidx = vec![0usize; BLOCK_POINTS * d];
        let mut u = [0.0f64; MAX_DIM];
        let mut coords = [0usize; MAX_DIM];
        for cube in a..b {
            layout.cube_coords(cube, &mut coords[..d]);
            let n = counts[cube].max(2);
            let nf = n as f64;
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            // A cube's (variable-size) sample set is processed in
            // block-sized chunks, carrying s1/s2 across chunks so the
            // accumulation order matches the scalar per-point loop.
            let mut k0 = 0u32;
            while k0 < n {
                let chunk = (n - k0).min(BLOCK_POINTS as u32);
                blk.reset(chunk as usize);
                for k in 0..chunk {
                    let sidx = offsets[cube].wrapping_add(k0 + k);
                    uniforms_into(sidx, iteration, seed, &mut u[..d]);
                    map.fill_point(&coords[..d], &u[..d], &mut blk, k as usize, &mut bidx);
                }
                f.eval_batch(&blk, &mut vals[..chunk as usize]);
                for j in 0..chunk as usize {
                    let v = vals[j] * blk.jac(j);
                    s1 += v;
                    s2 += v * v;
                    let v2 = v * v;
                    for i in 0..d {
                        out.contrib[bidx[j * d + i]] += v2;
                    }
                }
                k0 += chunk;
            }
            let mean = s1 / nf;
            let var = ((s2 / nf - mean * mean).max(0.0)) / (nf - 1.0);
            out.integral += mean / m;
            out.variance += var / (m * m);
            // sigma of the *cube total*, not of the mean — drives the
            // next allocation (Lepage's d_t accumulator).
            out.sigmas.push((cube, (var * nf).sqrt()));
        }
        out
    });

    let mut integral = 0.0;
    let mut variance = 0.0;
    let mut contrib = vec![0.0; d * nb];
    for p in partials {
        integral += p.integral;
        variance += p.variance;
        for (x_, y) in contrib.iter_mut().zip(&p.contrib) {
            *x_ += y;
        }
        for (cube, s) in p.sigmas {
            // Damped accumulation across iterations.
            state.sigmas[cube] = 0.5 * state.sigmas[cube] + 0.5 * s;
        }
    }
    (
        IterationResult {
            integral,
            variance,
        },
        contrib,
    )
}

/// Full adaptive-stratification driver (native-only extension; the
/// m-Cubes artifacts keep uniform `p` by design — see module docs).
#[allow(clippy::too_many_arguments)]
pub fn integrate_adaptive_strat(
    f: &dyn Integrand,
    maxcalls: usize,
    nb: usize,
    tau_rel: f64,
    itmax: usize,
    ita: usize,
    seed: u32,
    threads: usize,
) -> crate::error::Result<crate::coordinator::IntegrationOutput> {
    use crate::estimator::{Convergence, WeightedEstimator};
    use std::time::Instant;

    let layout = Layout::compute(f.dim(), maxcalls, nb, 1)?;
    let mut bins = Bins::uniform(layout.d, nb);
    let mut state = StratState::uniform(&layout);
    let mut est = WeightedEstimator::new();
    let conv = Convergence::with_tau(tau_rel);
    let t0 = Instant::now();
    let mut kernel_time = 0.0;
    let mut calls_used = 0usize;
    let mut iterations = 0usize;
    let mut converged = false;
    for it in 0..itmax {
        let tk = Instant::now();
        let (r, contrib) =
            vsample_adaptive(f, &layout, &bins, &mut state, seed, it as u32, threads);
        kernel_time += tk.elapsed().as_secs_f64();
        calls_used += state.total();
        iterations += 1;
        if it >= 2.min(itmax - 1) {
            est.push(r);
        }
        if it < ita {
            bins.adjust(&contrib);
            state.reallocate(maxcalls);
            if est.iterations() >= 2 && est.chi2_dof() > conv.max_chi2_dof {
                est.reset();
            }
        }
        if conv.satisfied(&est) {
            converged = true;
            break;
        }
    }
    Ok(crate::coordinator::IntegrationOutput {
        integral: est.integral(),
        sigma: est.sigma(),
        chi2_dof: est.chi2_dof(),
        rel_err: est.rel_err(),
        iterations,
        converged,
        calls_used,
        total_time: t0.elapsed().as_secs_f64(),
        kernel_time,
        backend: "native-adaptive-strat",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    #[test]
    fn uniform_state_matches_layout() {
        let layout = Layout::compute(4, 4096, 20, 1).unwrap();
        let st = StratState::uniform(&layout);
        assert_eq!(st.total(), layout.m * layout.p);
        assert_eq!(st.offsets[0], 0);
        assert_eq!(
            st.offsets[1] - st.offsets[0],
            layout.p as u32
        );
    }

    #[test]
    fn reallocate_preserves_budget_and_floor() {
        let layout = Layout::compute(3, 8000, 20, 1).unwrap();
        let mut st = StratState::uniform(&layout);
        // Fake: one hot cube.
        st.sigmas[7] = 100.0;
        for s in st.sigmas.iter_mut().skip(8) {
            *s = 0.01;
        }
        st.reallocate(8000);
        assert_eq!(st.total(), 8000);
        assert!(st.counts.iter().all(|&c| c >= 2));
        assert!(
            st.counts[7] > st.counts[100],
            "hot cube must get more samples: {} vs {}",
            st.counts[7],
            st.counts[100]
        );
        // offsets consistent
        for i in 1..st.counts.len() {
            assert_eq!(
                st.offsets[i],
                st.offsets[i - 1] + st.counts[i - 1]
            );
        }
    }

    #[test]
    fn adaptive_converges_and_is_honest() {
        let f = by_name("f4", 5).unwrap();
        let out =
            integrate_adaptive_strat(&*f, 1 << 16, 50, 1e-3, 20, 12, 5, 2).unwrap();
        assert!(out.converged, "{out:?}");
        let truth = f.true_value().unwrap();
        assert!(
            (out.integral - truth).abs() < 4.0 * out.sigma,
            "I={} truth={truth} sigma={}",
            out.integral,
            out.sigma
        );
    }

    #[test]
    fn adaptive_beats_uniform_on_peaked_integrand() {
        // Same per-iteration budget, fixed iteration count: the
        // adaptive allocation should reach a smaller combined sigma on
        // a sharply peaked integrand.
        use crate::coordinator::{integrate_native_core, JobConfig};
        let f = by_name("f4", 5).unwrap();
        let budget = 1 << 14;
        let uni = integrate_native_core(
            &*f,
            &JobConfig {
                maxcalls: budget,
                tau_rel: 1e-15,
                itmax: 10,
                ita: 8,
                skip: 2,
                seed: 5,
                threads: 2,
                ..Default::default()
            },
            None,
            None,
        )
        .unwrap()
        .output;
        let ada = integrate_adaptive_strat(&*f, budget, 50, 1e-15, 10, 8, 5, 2).unwrap();
        assert!(
            ada.sigma < uni.sigma * 1.05,
            "adaptive {} should be <= ~uniform {}",
            ada.sigma,
            uni.sigma
        );
    }
}
