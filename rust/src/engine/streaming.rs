//! Fused streaming fill→eval→reduce execution path — the
//! cache-resident twin of the block engines.
//!
//! The block pipeline ([`super::NativeEngine::vsample`]'s historical
//! path, kept as [`ExecPath::Block`]) materializes a whole
//! [`super::BLOCK_POINTS`]-point [`PointBlock`] per batch of cubes,
//! then evaluates and reduces it in separate passes. For cheap
//! integrands that is memory-bandwidth-bound: at d = 8 a full block is
//! ~16 KiB of coordinates plus as much again of histogram rows — the
//! fill pass streams it out of L1 before `eval_batch` streams it back
//! in. This module fuses the three phases over a small
//! [`STREAM_TILE`]-point tile that stays cache-resident end to end,
//! and hoists the per-task scratch to per-*worker* scratch (the block
//! uniform path re-allocated its block once per reduction task).
//!
//! ## Why the stream is bitwise identical to the block path
//!
//! Nothing about the arithmetic changes — only its schedule:
//!
//! * **Same partition, same fold.** The cube range is split into the
//!   engine's fixed [`super::REDUCTION_TASKS`] spans and per-task
//!   partials are folded in task order, exactly as the block engines
//!   do, so the cross-task reduction tree is unchanged (and results
//!   stay independent of the thread count).
//! * **Same counters, lane grouping immaterial.** Tile boundaries cut
//!   cubes at different points than block boundaries did, so the SIMD
//!   fill sees different lane groups — but per the SIMD determinism
//!   contract ([`super::simd`]) every point's bits depend only on its
//!   own 64-bit Philox counter, never on its lane neighbours. The
//!   uniform stream keeps drawing counter `cube * p + k`, the
//!   stratified stream `offsets[cube] + k`; both unchanged.
//! * **Same accumulation orders.** Within a cube, `s1`/`s2` and the
//!   v² histogram accumulate in sample order; the open cube's partial
//!   sums are *carried across tile boundaries* (exactly like the
//!   stratified block path carries them across block-sized chunks), so
//!   each cube's sum is the same left-to-right fold. Per task,
//!   cube means fold in cube order. Nothing is re-associated.
//!
//! The equivalence is enforced three ways: unit tests here, the
//! `streaming == block` property tests in `rust/tests/properties.rs`
//! (both engines, both `Sampling` modes, partial lane groups,
//! suspend/resume mid-stream), and the golden-value suite
//! (`rust/tests/golden_values.rs`) that pins the numbers themselves.

use super::block::{PointBlock, VegasMap};
use super::simd::FillPath;
use super::{reduction_task_span, reduction_tasks, VSampleOpts, MAX_DIM};
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::strat::{Allocation, Layout};
use crate::util::threadpool::parallel_chunks;

/// Which fused-loop structure a native V-Sample pass executes.
///
/// Both paths are bitwise identical (see the [module docs](self));
/// `Block` survives as the reference the equivalence suite and the
/// `streaming_speedup` microbench compare against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecPath {
    /// Fused streaming tiles ([`vsample_streaming`]): fill → eval →
    /// reduce over one [`STREAM_TILE`]-point tile at a time. The
    /// default everywhere.
    #[default]
    Streaming,
    /// The block pipeline: materialize a whole-cube batch of up to
    /// [`super::BLOCK_POINTS`] points, then evaluate and reduce it.
    Block,
}

/// Points per streaming tile.
///
/// Small enough that tile coordinates, Jacobians, values, and
/// histogram rows all stay L1-resident even at `d = MAX_DIM`
/// (64 × 16 × 8 B = 8 KiB of coordinates), large enough to amortize
/// the `eval_batch` virtual call and keep SIMD lane groups full.
pub const STREAM_TILE: usize = 64;

/// One reduction task's partial output (uniform stream).
struct Partial {
    integral: f64,
    variance: f64,
    contrib: Option<Vec<f64>>,
}

/// One reduction task's partial output (stratified stream).
struct StratPartial {
    cube_lo: usize,
    integral: f64,
    variance: f64,
    contrib: Option<Vec<f64>>,
    /// Fresh per-cube variance observations `n_k * Var_k`, indexed
    /// relative to `cube_lo`.
    d_new: Vec<f64>,
}

/// Advance a base-`g` odometer of lattice coords by one cube.
#[inline]
fn advance_odometer(coords: &mut [usize], gm1: usize) {
    for slot in coords.iter_mut() {
        if *slot == gm1 {
            *slot = 0;
        } else {
            *slot += 1;
            break;
        }
    }
}

/// One uniform V-Sample pass over every sub-cube in `layout`, fused
/// streaming schedule — bitwise identical to
/// [`super::NativeEngine::vsample`]'s block path.
pub fn vsample_streaming(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    opts: &VSampleOpts,
) -> (IterationResult, Option<Vec<f64>>) {
    vsample_streaming_with_fill(f, layout, bins, opts, FillPath::Simd)
}

/// [`vsample_streaming`] with an explicit [`FillPath`].
pub fn vsample_streaming_with_fill(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    opts: &VSampleOpts,
    fill: FillPath,
) -> (IterationResult, Option<Vec<f64>>) {
    assert!(layout.d <= MAX_DIM, "d > MAX_DIM");
    if let Err(e) = layout.validate() {
        panic!("invalid layout: {e}");
    }
    assert_eq!(bins.d(), layout.d);
    assert_eq!(bins.nb(), layout.nb);
    let d = layout.d;
    let nb = layout.nb;
    let m = layout.m as f64;
    let p = layout.p;
    let pf = p as f64;

    let ntasks = reduction_tasks(layout.m);
    let task_partials: Vec<Vec<Partial>> = parallel_chunks(ntasks, opts.threads, |t0, t1| {
        // Per-worker scratch, shared across this worker's tasks — one
        // cache-resident tile (the threaded SIMD fill writes into it,
        // eval reads it back while still hot).
        let map = VegasMap::new(layout, bins, &f.bounds());
        let mut blk = PointBlock::with_capacity(d, STREAM_TILE);
        let mut vals = [0.0f64; STREAM_TILE];
        let mut bidx = vec![0usize; STREAM_TILE * d];
        let mut coords = [0usize; MAX_DIM];
        // Row-major `[ncubes][d]` lattice coords of the tile's run of
        // whole cubes — the span fill keeps lane groups full across
        // cube boundaries (crucial when p is 2).
        let mut cube_coords = vec![0usize; STREAM_TILE * d];
        let gm1 = layout.g - 1;
        (t0..t1)
            .map(|t| {
                let (cube_lo, cube_hi) = reduction_task_span(layout.m, ntasks, t);
                let mut contrib = opts.adjust.then(|| vec![0.0; d * nb]);
                let mut integral = 0.0;
                let mut variance = 0.0;
                // Decode the first cube, then advance as a base-g
                // odometer (same as the block path).
                layout.cube_coords(cube_lo, &mut coords[..d]);
                // Stream cursor: next tile starts `off` samples into
                // `cube`. The open cube's running sums are carried
                // across tile boundaries so its accumulation order
                // matches the block path's exactly.
                let mut cube = cube_lo;
                let mut off = 0usize;
                let mut s1 = 0.0;
                let mut s2 = 0.0;
                while cube < cube_hi {
                    let remaining = (cube_hi - cube) * p - off;
                    let tile_len = remaining.min(STREAM_TILE);
                    blk.reset(tile_len);

                    // Fill phase: the head of the open cube, a span of
                    // whole cubes (lane groups running straight across
                    // cube boundaries), then a partial tail cube. All
                    // three draw the same consecutive 64-bit counters
                    // `cube * p + k` the block path drew.
                    let mut fc = cube;
                    let mut foff = off;
                    let mut j = 0usize;
                    if foff > 0 {
                        let take = (p - foff).min(tile_len);
                        let base = fc as u64 * p as u64 + foff as u64;
                        match fill {
                            FillPath::Simd => map.fill_points(
                                &coords[..d],
                                base,
                                take,
                                opts.iteration,
                                opts.seed,
                                &mut blk,
                                j,
                                &mut bidx,
                            ),
                            FillPath::Scalar => map.fill_points_scalar(
                                &coords[..d],
                                base,
                                take,
                                opts.iteration,
                                opts.seed,
                                &mut blk,
                                j,
                                &mut bidx,
                            ),
                        }
                        j += take;
                        foff += take;
                        if foff == p {
                            foff = 0;
                            fc += 1;
                            advance_odometer(&mut coords[..d], gm1);
                        }
                    }
                    let whole = (tile_len - j) / p;
                    if j < tile_len && whole > 0 {
                        for c in 0..whole {
                            cube_coords[c * d..(c + 1) * d].copy_from_slice(&coords[..d]);
                            advance_odometer(&mut coords[..d], gm1);
                        }
                        let base = fc as u64 * p as u64;
                        match fill {
                            FillPath::Simd => map.fill_span_at(
                                &cube_coords[..whole * d],
                                whole,
                                p,
                                base,
                                opts.iteration,
                                opts.seed,
                                &mut blk,
                                j,
                                &mut bidx,
                            ),
                            FillPath::Scalar => {
                                for c in 0..whole {
                                    map.fill_points_scalar(
                                        &cube_coords[c * d..(c + 1) * d],
                                        base + (c * p) as u64,
                                        p,
                                        opts.iteration,
                                        opts.seed,
                                        &mut blk,
                                        j + c * p,
                                        &mut bidx,
                                    );
                                }
                            }
                        }
                        j += whole * p;
                        fc += whole;
                    }
                    if j < tile_len {
                        let take = tile_len - j;
                        let base = fc as u64 * p as u64;
                        match fill {
                            FillPath::Simd => map.fill_points(
                                &coords[..d],
                                base,
                                take,
                                opts.iteration,
                                opts.seed,
                                &mut blk,
                                j,
                                &mut bidx,
                            ),
                            FillPath::Scalar => map.fill_points_scalar(
                                &coords[..d],
                                base,
                                take,
                                opts.iteration,
                                opts.seed,
                                &mut blk,
                                j,
                                &mut bidx,
                            ),
                        }
                    }

                    // Eval phase: one virtual call per tile, while the
                    // tile is still L1-hot from the fill.
                    f.eval_batch(&blk, &mut vals[..tile_len]);

                    // Reduce phase: sample order, finalizing each cube
                    // as its last sample streams past.
                    let mut k = 0usize;
                    while k < tile_len {
                        let take = (p - off).min(tile_len - k);
                        for jj in k..k + take {
                            let v = vals[jj] * blk.jac(jj);
                            s1 += v;
                            s2 += v * v;
                            if let Some(cacc) = contrib.as_mut() {
                                let v2 = v * v;
                                for i in 0..d {
                                    // SAFETY: bidx slots hold i*nb + b
                                    // with b < nb, so each is < d*nb ==
                                    // cacc.len() (same bound as the
                                    // block path).
                                    unsafe { *cacc.get_unchecked_mut(bidx[jj * d + i]) += v2 };
                                }
                            }
                        }
                        k += take;
                        off += take;
                        if off == p {
                            let mean = s1 / pf;
                            let var = ((s2 / pf - mean * mean).max(0.0)) / (pf - 1.0);
                            integral += mean / m;
                            variance += var / (m * m);
                            s1 = 0.0;
                            s2 = 0.0;
                            off = 0;
                            cube += 1;
                        }
                    }
                }
                Partial {
                    integral,
                    variance,
                    contrib,
                }
            })
            .collect()
    });

    let mut integral = 0.0;
    let mut variance = 0.0;
    let mut contrib = opts.adjust.then(|| vec![0.0; d * nb]);
    for part in task_partials.into_iter().flatten() {
        integral += part.integral;
        variance += part.variance;
        if let (Some(acc), Some(pc)) = (contrib.as_mut(), part.contrib.as_ref()) {
            for (x, y) in acc.iter_mut().zip(pc) {
                *x += y;
            }
        }
    }
    (
        IterationResult {
            integral,
            variance,
        },
        contrib,
    )
}

/// One VEGAS+ V-Sample pass with variable per-cube counts, fused
/// streaming schedule — bitwise identical to
/// [`super::stratified::vsample_stratified`]'s block path, including
/// the damped-accumulator updates folded into `alloc` in task order.
pub fn vsample_stratified_streaming(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    alloc: &mut Allocation,
    opts: &VSampleOpts,
) -> (IterationResult, Option<Vec<f64>>) {
    vsample_stratified_streaming_with_fill(f, layout, bins, alloc, opts, FillPath::Simd)
}

/// [`vsample_stratified_streaming`] with an explicit [`FillPath`].
pub fn vsample_stratified_streaming_with_fill(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    alloc: &mut Allocation,
    opts: &VSampleOpts,
    fill: FillPath,
) -> (IterationResult, Option<Vec<f64>>) {
    assert!(layout.d <= MAX_DIM, "d > MAX_DIM");
    if let Err(e) = layout.validate() {
        panic!("invalid layout: {e}");
    }
    assert_eq!(bins.d(), layout.d);
    assert_eq!(bins.nb(), layout.nb);
    assert_eq!(alloc.m(), layout.m, "allocation cube count != layout");
    let d = layout.d;
    let nb = layout.nb;
    let m = layout.m as f64;

    let ntasks = reduction_tasks(layout.m);
    let task_partials: Vec<Vec<StratPartial>> = {
        let counts = alloc.counts();
        let offsets = alloc.offsets();
        parallel_chunks(ntasks, opts.threads, |t0, t1| {
            // Per-worker scratch, shared across this worker's tasks.
            let map = VegasMap::new(layout, bins, &f.bounds());
            let mut blk = PointBlock::with_capacity(d, STREAM_TILE);
            let mut vals = [0.0f64; STREAM_TILE];
            let mut bidx = vec![0usize; STREAM_TILE * d];
            let mut coords = [0usize; MAX_DIM];
            let gm1 = layout.g - 1;
            (t0..t1)
                .map(|t| {
                    let (cube_lo, cube_hi) = reduction_task_span(layout.m, ntasks, t);
                    let mut out = StratPartial {
                        cube_lo,
                        integral: 0.0,
                        variance: 0.0,
                        contrib: opts.adjust.then(|| vec![0.0; d * nb]),
                        d_new: Vec::with_capacity(cube_hi - cube_lo),
                    };
                    layout.cube_coords(cube_lo, &mut coords[..d]);
                    let mut cube = cube_lo;
                    let mut off = 0usize;
                    let mut s1 = 0.0;
                    let mut s2 = 0.0;
                    while cube < cube_hi {
                        // Measure the tile (counts arithmetic only).
                        let mut tile_len = 0usize;
                        {
                            let (mut mc, mut mo) = (cube, off);
                            while tile_len < STREAM_TILE && mc < cube_hi {
                                let n = counts[mc].max(2) as usize;
                                let take = (n - mo).min(STREAM_TILE - tile_len);
                                tile_len += take;
                                mo += take;
                                if mo == n {
                                    mo = 0;
                                    mc += 1;
                                }
                            }
                        }
                        blk.reset(tile_len);

                        // Fill phase: per-cube segments — each cube's
                        // stream starts at its own 64-bit prefix-sum
                        // offset, exactly like the block path.
                        {
                            let (mut fc, mut fo) = (cube, off);
                            let mut j = 0usize;
                            while j < tile_len {
                                let n = counts[fc].max(2) as usize;
                                let take = (n - fo).min(tile_len - j);
                                let base = offsets[fc] + fo as u64;
                                match fill {
                                    FillPath::Simd => map.fill_points(
                                        &coords[..d],
                                        base,
                                        take,
                                        opts.iteration,
                                        opts.seed,
                                        &mut blk,
                                        j,
                                        &mut bidx,
                                    ),
                                    FillPath::Scalar => map.fill_points_scalar(
                                        &coords[..d],
                                        base,
                                        take,
                                        opts.iteration,
                                        opts.seed,
                                        &mut blk,
                                        j,
                                        &mut bidx,
                                    ),
                                }
                                j += take;
                                fo += take;
                                if fo == n {
                                    fo = 0;
                                    fc += 1;
                                    advance_odometer(&mut coords[..d], gm1);
                                }
                            }
                        }

                        f.eval_batch(&blk, &mut vals[..tile_len]);

                        // Reduce phase: sample order, carrying the open
                        // cube's sums across tile boundaries (the block
                        // path carries them across chunk boundaries —
                        // same fold, different chunking).
                        let mut k = 0usize;
                        while k < tile_len {
                            let n = counts[cube].max(2) as usize;
                            let nf = n as f64;
                            let take = (n - off).min(tile_len - k);
                            for jj in k..k + take {
                                let v = vals[jj] * blk.jac(jj);
                                s1 += v;
                                s2 += v * v;
                                if let Some(cacc) = out.contrib.as_mut() {
                                    let v2 = v * v;
                                    for i in 0..d {
                                        cacc[bidx[jj * d + i]] += v2;
                                    }
                                }
                            }
                            k += take;
                            off += take;
                            if off == n {
                                let mean = s1 / nf;
                                let var = ((s2 / nf - mean * mean).max(0.0)) / (nf - 1.0);
                                out.integral += mean / m;
                                out.variance += var / (m * m);
                                // Variance of the cube total — Lepage's
                                // d_k observation for the allocator.
                                out.d_new.push(var * nf);
                                s1 = 0.0;
                                s2 = 0.0;
                                off = 0;
                                cube += 1;
                            }
                        }
                    }
                    out
                })
                .collect()
        })
    };

    let mut integral = 0.0;
    let mut variance = 0.0;
    let mut contrib = opts.adjust.then(|| vec![0.0; d * nb]);
    for part in task_partials.into_iter().flatten() {
        integral += part.integral;
        variance += part.variance;
        if let (Some(acc), Some(pc)) = (contrib.as_mut(), part.contrib.as_ref()) {
            for (x, y) in acc.iter_mut().zip(pc) {
                *x += y;
            }
        }
        for (i, &dn) in part.d_new.iter().enumerate() {
            alloc.absorb(part.cube_lo + i, dn);
        }
    }
    (
        IterationResult {
            integral,
            variance,
        },
        contrib,
    )
}

/// Dispatch a stratified V-Sample pass on an explicit [`ExecPath`] —
/// the two paths are bitwise identical (property-tested); `Block` is
/// the reference.
pub fn vsample_stratified_exec(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    alloc: &mut Allocation,
    opts: &VSampleOpts,
    fill: FillPath,
    exec: ExecPath,
) -> (IterationResult, Option<Vec<f64>>) {
    match exec {
        ExecPath::Streaming => vsample_stratified_streaming_with_fill(f, layout, bins, alloc, opts, fill),
        ExecPath::Block => super::stratified::vsample_stratified_with_fill(f, layout, bins, alloc, opts, fill),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::integrands::by_name;

    fn opts(seed: u32, it: u32, threads: usize) -> VSampleOpts {
        VSampleOpts {
            seed,
            iteration: it,
            adjust: true,
            threads,
        }
    }

    fn assert_bitwise(
        a: &(IterationResult, Option<Vec<f64>>),
        b: &(IterationResult, Option<Vec<f64>>),
        tag: &str,
    ) {
        assert_eq!(a.0.integral.to_bits(), b.0.integral.to_bits(), "{tag}: integral");
        assert_eq!(a.0.variance.to_bits(), b.0.variance.to_bits(), "{tag}: variance");
        match (&a.1, &b.1) {
            (Some(ca), Some(cb)) => {
                for (i, (x, y)) in ca.iter().zip(cb).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "{tag}: contrib[{i}]");
                }
            }
            (None, None) => {}
            _ => panic!("{tag}: histogram presence differs"),
        }
    }

    #[test]
    fn streaming_matches_block_uniform_bitwise() {
        // p = 5 here (d=6 @4096 -> m=729, p=5), so tiles split cubes:
        // head / whole-span / tail segments and carried sums all run.
        for (name, d, calls) in [("f3", 4usize, 4096usize), ("f1", 6, 4096), ("f4", 5, 4096)] {
            let f = by_name(name, d).unwrap();
            let layout = Layout::compute(d, calls, 16, 2).unwrap();
            let bins = Bins::uniform(d, 16);
            let block = NativeEngine.vsample_exec(
                &*f,
                &layout,
                &bins,
                &opts(42, 1, 2),
                FillPath::Simd,
                ExecPath::Block,
            );
            for threads in [1usize, 3, 8] {
                let stream =
                    vsample_streaming_with_fill(&*f, &layout, &bins, &opts(42, 1, threads), FillPath::Simd);
                assert_bitwise(&block, &stream, &format!("{name} d={d} threads={threads}"));
            }
            // Scalar fill path streams identically too.
            let stream_scalar =
                vsample_streaming_with_fill(&*f, &layout, &bins, &opts(42, 1, 2), FillPath::Scalar);
            assert_bitwise(&block, &stream_scalar, &format!("{name} d={d} scalar"));
        }
    }

    #[test]
    fn streaming_reproduces_python_anchor() {
        // Same pinned numbers as the block engine's
        // `matches_python_first_iteration_estimate`.
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let (r, _) = vsample_streaming(&*f, &layout, &bins, &opts(42, 0, 2));
        assert!(
            ((r.integral - 2.7858176280788316e-05) / 2.7858176280788316e-05).abs() < 1e-12,
            "I = {}",
            r.integral
        );
        assert!(
            ((r.variance - 7.757123669326781e-10) / 7.757123669326781e-10).abs() < 1e-10,
            "Var = {}",
            r.variance
        );
    }

    #[test]
    fn streaming_matches_block_stratified_bitwise() {
        let f = by_name("f3", 4).unwrap();
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(4, 16);
        // Skewed allocation: wildly different per-cube counts, so tile
        // segmentation differs completely from block chunking.
        let mut seed_alloc = Allocation::uniform(&layout);
        seed_alloc.absorb(0, 100.0);
        for cube in 1..seed_alloc.m() {
            seed_alloc.absorb(cube, 0.01 * (cube % 7) as f64);
        }
        seed_alloc.reallocate(layout.calls(), crate::strat::DEFAULT_BETA);
        let mut a_block = seed_alloc.clone();
        let mut a_stream = seed_alloc.clone();
        let block = vsample_stratified_exec(
            &*f,
            &layout,
            &bins,
            &mut a_block,
            &opts(9, 3, 2),
            FillPath::Simd,
            ExecPath::Block,
        );
        let stream = vsample_stratified_streaming_with_fill(
            &*f,
            &layout,
            &bins,
            &mut a_stream,
            &opts(9, 3, 5),
            FillPath::Simd,
        );
        assert_bitwise(&block, &stream, "stratified f3 d=4");
        // The damped accumulator (checkpoint state) must match too.
        for (a, b) in a_block.damped().iter().zip(a_stream.damped()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn streaming_stratified_uniform_alloc_matches_uniform_stream() {
        // beta = 0 / initial allocation: offsets collapse to cube * p
        // and the stratified stream equals the uniform stream bitwise
        // (the same contract the block paths hold).
        let f = by_name("f5", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let uni = vsample_streaming(&*f, &layout, &bins, &opts(42, 0, 2));
        let mut alloc = Allocation::uniform(&layout);
        let strat =
            vsample_stratified_streaming(&*f, &layout, &bins, &mut alloc, &opts(42, 0, 3));
        assert_bitwise(&uni, &strat, "uniform-alloc f5 d=5");
    }

    #[test]
    fn no_adjust_skips_histogram() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let bins = Bins::uniform(4, 10);
        let (_, c) = vsample_streaming(
            &*f,
            &layout,
            &bins,
            &VSampleOpts {
                adjust: false,
                ..opts(1, 0, 2)
            },
        );
        assert!(c.is_none());
    }
}
