//! The single fill→`eval_batch`→reduce tile walk every engine runs —
//! THE one copy of the tile loop, the Philox counter bookkeeping, and
//! the fixed reduction-task partition.
//!
//! Every sampling engine ([`super::UniformEngine`],
//! [`super::stratified::VegasPlusEngine`], and the task-subrange entry
//! points in [`super::tasks`] the shard workers call) funnels through
//! [`run_tasks`]: the task range is split across workers with
//! per-worker scratch, and each reduction task runs [`sample_task`] —
//! a fused walk over cache-resident tiles. The only thing an engine
//! contributes is a [`CubeSched`]: how many samples cube `k` draws and
//! where its 64-bit Philox counter range starts (uniform: `k * p`;
//! stratified: `offsets[k]`).
//!
//! ## Why one walk serves both schedules bitwise
//!
//! The historical code carried four copies of this loop (uniform
//! block, uniform streaming, stratified block, stratified streaming).
//! They were bitwise interchangeable by construction, which is exactly
//! why one copy suffices:
//!
//! * **Same partition, same fold.** The cube range is split into the
//!   engine's fixed [`super::REDUCTION_TASKS`] spans and per-task
//!   partials are folded in task order, so the cross-task reduction
//!   tree is a pure function of the layout — never of the thread
//!   count, the tile size, or the shard count.
//! * **Same counters, segmentation immaterial.** Tile boundaries cut
//!   cubes at arbitrary offsets, so the SIMD fill sees different lane
//!   groups than a whole-block fill did — but per the SIMD determinism
//!   contract ([`super::simd`]) every point's bits depend only on its
//!   own 64-bit Philox counter, never on its lane neighbours. The
//!   walk always draws counter `sched.counter_base(cube) + k` for
//!   sample `k` of `cube`, whatever the tiling.
//! * **Same accumulation orders.** Within a cube, `s1`/`s2` and the
//!   v² histogram accumulate in sample order; the open cube's partial
//!   sums are *carried across tile boundaries*, so each cube's sum is
//!   the same left-to-right fold regardless of where tiles cut it.
//!   Per task, cube means fold in cube order. Nothing is
//!   re-associated.
//!
//! [`ExecPath`] is therefore purely a tile-capacity knob:
//! `Streaming` (the default) walks [`STREAM_TILE`]-point tiles that
//! stay L1-resident end to end; `Block` walks
//! [`super::BLOCK_POINTS`]-point tiles (the historical whole-block
//! batch size, kept as the reference the equivalence suite compares
//! against). The equivalence is enforced three ways: unit tests here,
//! the `streaming == block` property tests in
//! `rust/tests/properties.rs` (both engines, both `Sampling` modes,
//! static and `Box<dyn Engine>` dispatch), and the golden-value suite
//! (`rust/tests/golden_values.rs`) that pins the numbers themselves.

use super::block::{PointBlock, VegasMap, BLOCK_POINTS};
use super::simd::FillPath;
use super::tasks::TaskPartial;
use super::{reduction_task_span, reduction_tasks, VSampleOpts, MAX_DIM};
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::strat::Layout;
use crate::util::threadpool::parallel_chunks;

/// Which tile capacity a native V-Sample pass walks with.
///
/// Both paths are bitwise identical (see the [module docs](self));
/// `Block` survives as the reference the equivalence suite and the
/// `streaming_speedup` microbench compare against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecPath {
    /// Fused streaming tiles: fill → eval → reduce over one
    /// [`STREAM_TILE`]-point tile at a time. The default everywhere.
    #[default]
    Streaming,
    /// The historical block pipeline's batch size: tiles of
    /// [`super::BLOCK_POINTS`] points.
    Block,
}

/// Points per streaming tile.
///
/// Small enough that tile coordinates, Jacobians, values, and
/// histogram rows all stay L1-resident even at `d = MAX_DIM`
/// (64 × 16 × 8 B = 8 KiB of coordinates), large enough to amortize
/// the `eval_batch` virtual call and keep SIMD lane groups full.
pub const STREAM_TILE: usize = 64;

impl ExecPath {
    /// Tile capacity in points.
    #[inline]
    fn tile_points(self) -> usize {
        match self {
            ExecPath::Streaming => STREAM_TILE,
            ExecPath::Block => BLOCK_POINTS,
        }
    }
}

/// Per-cube sampling schedule: the *only* thing that differs between
/// the uniform m-Cubes engine and the VEGAS+ stratified engine.
///
/// Disjoint cube ranges draw disjoint counter sub-ranges by
/// construction (uniform: `cube * p + k`; stratified: prefix-sum
/// `offsets[cube] + k`), which is what makes task spans relocatable
/// across threads, shards, and processes without re-drawing a counter.
pub(crate) trait CubeSched {
    /// Whether the walk records per-cube `n_k * Var_k` observations
    /// (the VEGAS+ allocator's `d_new` stream).
    const RECORDS_DNEW: bool;
    /// Samples cube `cube` draws this pass.
    fn count(&self, cube: usize) -> usize;
    /// First 64-bit Philox counter of cube `cube`'s sample stream.
    fn counter_base(&self, cube: usize) -> u64;
    /// `Some(p)` when every cube draws exactly `p` samples from
    /// consecutive counters — unlocks the whole-cube SIMD span fill.
    fn uniform_p(&self) -> Option<usize>;
}

/// Uniform m-Cubes schedule: every cube draws `p` samples at counter
/// base `cube * p`.
pub(crate) struct UniformSched {
    pub(crate) p: usize,
}

impl CubeSched for UniformSched {
    const RECORDS_DNEW: bool = false;

    #[inline]
    fn count(&self, _cube: usize) -> usize {
        self.p
    }

    #[inline]
    fn counter_base(&self, cube: usize) -> u64 {
        cube as u64 * self.p as u64
    }

    #[inline]
    fn uniform_p(&self) -> Option<usize> {
        Some(self.p)
    }
}

/// VEGAS+ stratified schedule: cube `k` draws `counts[k]` samples
/// (floored at 2 so the per-cube variance is defined) from the 64-bit
/// prefix-sum offsets — no wrapping, even past 2^32 total calls.
pub(crate) struct StratSched<'a> {
    pub(crate) counts: &'a [u32],
    pub(crate) offsets: &'a [u64],
}

impl CubeSched for StratSched<'_> {
    const RECORDS_DNEW: bool = true;

    #[inline]
    fn count(&self, cube: usize) -> usize {
        // lint:allow(MC001, u32 -> usize widens on every supported target; `cube` only indexes the slice, it is not the value being cast)
        self.counts[cube].max(2) as usize
    }

    #[inline]
    fn counter_base(&self, cube: usize) -> u64 {
        self.offsets[cube]
    }

    #[inline]
    fn uniform_p(&self) -> Option<usize> {
        None
    }
}

/// Per-worker scratch, shared across a worker's tasks — one
/// cache-resident tile (the SIMD fill writes into it, eval reads it
/// back while still hot).
struct Scratch {
    blk: PointBlock,
    vals: Vec<f64>,
    bidx: Vec<usize>,
    /// Row-major `[ncubes][d]` lattice coords of a tile's run of whole
    /// cubes — the span fill keeps lane groups full across cube
    /// boundaries (crucial when p is 2).
    cube_coords: Vec<usize>,
    coords: [usize; MAX_DIM],
}

/// Advance a base-`g` odometer of lattice coords by one cube.
#[inline]
fn advance_odometer(coords: &mut [usize], gm1: usize) {
    for slot in coords.iter_mut() {
        if *slot == gm1 {
            *slot = 0;
        } else {
            *slot += 1;
            break;
        }
    }
}

/// Validate the walk's inputs; returns the layout's task count.
pub(crate) fn check_task_range(
    layout: &Layout,
    bins: &Bins,
    task_lo: usize,
    task_hi: usize,
) -> usize {
    assert!(layout.d <= MAX_DIM, "d > MAX_DIM");
    if let Err(e) = layout.validate() {
        panic!("invalid layout: {e}");
    }
    assert_eq!(bins.d(), layout.d);
    assert_eq!(bins.nb(), layout.nb);
    let ntasks = reduction_tasks(layout.m);
    assert!(
        task_lo <= task_hi && task_hi <= ntasks,
        "task range [{task_lo}, {task_hi}) outside 0..{ntasks}"
    );
    ntasks
}

/// Partials of reduction tasks `[task_lo, task_hi)` under `sched` —
/// the one parallel task-range driver every engine runs.
///
/// Workers pick up contiguous runs of tasks (per-worker scratch is
/// hoisted out of the task loop), every per-task accumulator starts
/// fresh, and partials come back in global task order, so for any
/// partition of `0..reduction_tasks(m)` into subranges, concatenating
/// the returned vectors reproduces the full pass's partials bitwise.
/// Internal parallelism (`opts.threads`) never changes the numbers.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_tasks<S: CubeSched + Sync>(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    sched: &S,
    opts: &VSampleOpts,
    fill: FillPath,
    exec: ExecPath,
    task_lo: usize,
    task_hi: usize,
) -> Vec<TaskPartial> {
    let ntasks = check_task_range(layout, bins, task_lo, task_hi);
    let cap = exec.tile_points();
    let d = layout.d;
    let span = task_hi - task_lo;
    let nested: Vec<Vec<TaskPartial>> = parallel_chunks(span, opts.threads, |u0, u1| {
        let map = VegasMap::new(layout, bins, &f.bounds());
        let mut scratch = Scratch {
            blk: PointBlock::with_capacity(d, cap),
            vals: vec![0.0f64; cap],
            bidx: vec![0usize; cap * d],
            cube_coords: vec![0usize; cap * d],
            coords: [0usize; MAX_DIM],
        };
        (u0..u1)
            .map(|u| {
                let t = task_lo + u;
                let (cube_lo, cube_hi) = reduction_task_span(layout.m, ntasks, t);
                sample_task(
                    f, layout, &map, sched, opts, fill, cap, t, cube_lo, cube_hi, &mut scratch,
                )
            })
            .collect()
    });
    nested.into_iter().flatten().collect()
}

/// One reduction task's body: the fused fill→eval→reduce walk over
/// cubes `[cube_lo, cube_hi)` in `cap`-point tiles.
///
/// The open cube's running sums are carried across tile boundaries so
/// its accumulation order is the same left-to-right fold for every
/// tile capacity; see the [module docs](self) for the full bitwise
/// argument.
#[allow(clippy::too_many_arguments)]
fn sample_task<S: CubeSched>(
    f: &dyn Integrand,
    layout: &Layout,
    map: &VegasMap,
    sched: &S,
    opts: &VSampleOpts,
    fill: FillPath,
    cap: usize,
    task: usize,
    cube_lo: usize,
    cube_hi: usize,
    s: &mut Scratch,
) -> TaskPartial {
    let d = layout.d;
    let nb = layout.nb;
    let m = layout.m as f64;
    let gm1 = layout.g - 1;

    let mut contrib = opts.adjust.then(|| vec![0.0; d * nb]);
    let mut d_new = if S::RECORDS_DNEW {
        Vec::with_capacity(cube_hi - cube_lo)
    } else {
        Vec::new()
    };
    let mut integral = 0.0;
    let mut variance = 0.0;

    // Decode the first cube, then advance as a base-g odometer — avoids
    // d divisions per cube in the hot loop.
    layout.cube_coords(cube_lo, &mut s.coords[..d]);
    // Walk cursor: the next tile starts `off` samples into `cube`; the
    // open cube's running sums ride across tile boundaries.
    let mut cube = cube_lo;
    let mut off = 0usize;
    let mut s1 = 0.0;
    let mut s2 = 0.0;

    while cube < cube_hi {
        // Measure the tile (counts arithmetic only).
        let mut tile_len = 0usize;
        {
            let (mut mc, mut mo) = (cube, off);
            while tile_len < cap && mc < cube_hi {
                let n = sched.count(mc);
                let take = (n - mo).min(cap - tile_len);
                tile_len += take;
                mo += take;
                if mo == n {
                    mo = 0;
                    mc += 1;
                }
            }
        }
        s.blk.reset(tile_len);

        // Fill phase. Per-cube segments draw counters
        // `counter_base(cube) + k`; on the uniform schedule a run of
        // whole cubes goes through the SIMD span fill in one call
        // (lane groups running straight across cube boundaries — the
        // per-point bits are identical either way).
        {
            let (mut fc, mut fo) = (cube, off);
            let mut j = 0usize;
            while j < tile_len {
                if fo == 0 && fill == FillPath::Simd {
                    if let Some(p) = sched.uniform_p() {
                        let whole = (tile_len - j) / p;
                        if whole > 0 {
                            for c in 0..whole {
                                s.cube_coords[c * d..(c + 1) * d]
                                    .copy_from_slice(&s.coords[..d]);
                                advance_odometer(&mut s.coords[..d], gm1);
                            }
                            map.fill_span_at(
                                &s.cube_coords[..whole * d],
                                whole,
                                p,
                                sched.counter_base(fc),
                                opts.iteration,
                                opts.seed,
                                &mut s.blk,
                                j,
                                &mut s.bidx,
                            );
                            j += whole * p;
                            fc += whole;
                            continue;
                        }
                    }
                }
                let n = sched.count(fc);
                let take = (n - fo).min(tile_len - j);
                let base = sched.counter_base(fc) + fo as u64;
                match fill {
                    FillPath::Simd => map.fill_points(
                        &s.coords[..d],
                        base,
                        take,
                        opts.iteration,
                        opts.seed,
                        &mut s.blk,
                        j,
                        &mut s.bidx,
                    ),
                    FillPath::Scalar => map.fill_points_scalar(
                        &s.coords[..d],
                        base,
                        take,
                        opts.iteration,
                        opts.seed,
                        &mut s.blk,
                        j,
                        &mut s.bidx,
                    ),
                }
                j += take;
                fo += take;
                if fo == n {
                    fo = 0;
                    fc += 1;
                    advance_odometer(&mut s.coords[..d], gm1);
                }
            }
        }

        // Eval phase: one virtual call per tile, while the tile is
        // still L1-hot from the fill.
        f.eval_batch(&s.blk, &mut s.vals[..tile_len]);

        // Reduce phase: sample order, finalizing each cube as its last
        // sample streams past.
        let mut k = 0usize;
        while k < tile_len {
            let n = sched.count(cube);
            let nf = n as f64;
            let take = (n - off).min(tile_len - k);
            for jj in k..k + take {
                let v = s.vals[jj] * s.blk.jac(jj);
                s1 += v;
                s2 += v * v;
                if let Some(cacc) = contrib.as_mut() {
                    let v2 = v * v;
                    for i in 0..d {
                        // SAFETY: bidx slots hold i*nb + b with b < nb,
                        // so each is < d*nb == cacc.len().
                        unsafe { *cacc.get_unchecked_mut(s.bidx[jj * d + i]) += v2 };
                    }
                }
            }
            k += take;
            off += take;
            if off == n {
                let mean = s1 / nf;
                let var = ((s2 / nf - mean * mean).max(0.0)) / (nf - 1.0);
                integral += mean / m;
                variance += var / (m * m);
                if S::RECORDS_DNEW {
                    // Variance of the cube total — Lepage's d_k
                    // observation for the allocator.
                    d_new.push(var * nf);
                }
                s1 = 0.0;
                s2 = 0.0;
                off = 0;
                cube += 1;
            }
        }
    }

    TaskPartial {
        task,
        cube_lo,
        cube_hi,
        integral,
        variance,
        contrib,
        d_new,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{merge_task_partials, NativeEngine};
    use crate::estimator::IterationResult;
    use crate::integrands::by_name;
    use crate::strat::Allocation;

    fn opts(seed: u32, it: u32, threads: usize) -> VSampleOpts {
        VSampleOpts {
            seed,
            iteration: it,
            adjust: true,
            threads,
        }
    }

    /// Full stratified pass at an explicit tile capacity — test-local
    /// shim over the one walk (absorbs `d_new` in task order, no
    /// reallocation), mirroring what `VegasPlusEngine` runs.
    fn strat_exec(
        f: &dyn Integrand,
        layout: &Layout,
        bins: &Bins,
        alloc: &mut Allocation,
        o: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
    ) -> (IterationResult, Option<Vec<f64>>) {
        let ntasks = reduction_tasks(layout.m);
        let partials = run_tasks(
            f,
            layout,
            bins,
            &StratSched {
                counts: alloc.counts(),
                offsets: alloc.offsets(),
            },
            o,
            fill,
            exec,
            0,
            ntasks,
        );
        let out = merge_task_partials(layout.d, layout.nb, o.adjust, &partials);
        for p in &partials {
            alloc.absorb_span(p.cube_lo, &p.d_new);
        }
        out
    }

    fn assert_bitwise(
        a: &(IterationResult, Option<Vec<f64>>),
        b: &(IterationResult, Option<Vec<f64>>),
        tag: &str,
    ) {
        assert_eq!(a.0.integral.to_bits(), b.0.integral.to_bits(), "{tag}: integral");
        assert_eq!(a.0.variance.to_bits(), b.0.variance.to_bits(), "{tag}: variance");
        match (&a.1, &b.1) {
            (Some(ca), Some(cb)) => {
                for (i, (x, y)) in ca.iter().zip(cb).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "{tag}: contrib[{i}]");
                }
            }
            (None, None) => {}
            _ => panic!("{tag}: histogram presence differs"),
        }
    }

    #[test]
    fn streaming_matches_block_uniform_bitwise() {
        // p = 5 here (d=6 @4096 -> m=729, p=5), so tiles split cubes:
        // head / whole-span / tail segments and carried sums all run.
        for (name, d, calls) in [("f3", 4usize, 4096usize), ("f1", 6, 4096), ("f4", 5, 4096)] {
            let f = by_name(name, d).unwrap();
            let layout = Layout::compute(d, calls, 16, 2).unwrap();
            let bins = Bins::uniform(d, 16);
            let block = NativeEngine.vsample_exec(
                &*f,
                &layout,
                &bins,
                &opts(42, 1, 2),
                FillPath::Simd,
                ExecPath::Block,
            );
            for threads in [1usize, 3, 8] {
                let stream = NativeEngine.vsample_exec(
                    &*f,
                    &layout,
                    &bins,
                    &opts(42, 1, threads),
                    FillPath::Simd,
                    ExecPath::Streaming,
                );
                assert_bitwise(&block, &stream, &format!("{name} d={d} threads={threads}"));
            }
            // Scalar fill path streams identically too.
            let stream_scalar = NativeEngine.vsample_exec(
                &*f,
                &layout,
                &bins,
                &opts(42, 1, 2),
                FillPath::Scalar,
                ExecPath::Streaming,
            );
            assert_bitwise(&block, &stream_scalar, &format!("{name} d={d} scalar"));
        }
    }

    #[test]
    fn streaming_reproduces_python_anchor() {
        // Same pinned numbers as the block engine's
        // `matches_python_first_iteration_estimate`.
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let (r, _) = NativeEngine.vsample(&*f, &layout, &bins, &opts(42, 0, 2));
        assert!(
            ((r.integral - 2.7858176280788316e-05) / 2.7858176280788316e-05).abs() < 1e-12,
            "I = {}",
            r.integral
        );
        assert!(
            ((r.variance - 7.757123669326781e-10) / 7.757123669326781e-10).abs() < 1e-10,
            "Var = {}",
            r.variance
        );
    }

    #[test]
    fn streaming_matches_block_stratified_bitwise() {
        let f = by_name("f3", 4).unwrap();
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(4, 16);
        // Skewed allocation: wildly different per-cube counts, so tile
        // segmentation differs completely between the two capacities.
        let mut seed_alloc = Allocation::uniform(&layout);
        seed_alloc.absorb(0, 100.0);
        for cube in 1..seed_alloc.m() {
            seed_alloc.absorb(cube, 0.01 * (cube % 7) as f64);
        }
        seed_alloc.reallocate(layout.calls(), crate::strat::DEFAULT_BETA);
        let mut a_block = seed_alloc.clone();
        let mut a_stream = seed_alloc.clone();
        let block = strat_exec(
            &*f,
            &layout,
            &bins,
            &mut a_block,
            &opts(9, 3, 2),
            FillPath::Simd,
            ExecPath::Block,
        );
        let stream = strat_exec(
            &*f,
            &layout,
            &bins,
            &mut a_stream,
            &opts(9, 3, 5),
            FillPath::Simd,
            ExecPath::Streaming,
        );
        assert_bitwise(&block, &stream, "stratified f3 d=4");
        // The damped accumulator (checkpoint state) must match too.
        for (a, b) in a_block.damped().iter().zip(a_stream.damped()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn streaming_stratified_uniform_alloc_matches_uniform_stream() {
        // beta = 0 / initial allocation: offsets collapse to cube * p
        // and the stratified walk equals the uniform walk bitwise.
        let f = by_name("f5", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let uni = NativeEngine.vsample(&*f, &layout, &bins, &opts(42, 0, 2));
        let mut alloc = Allocation::uniform(&layout);
        let strat = strat_exec(
            &*f,
            &layout,
            &bins,
            &mut alloc,
            &opts(42, 0, 3),
            FillPath::Simd,
            ExecPath::Streaming,
        );
        assert_bitwise(&uni, &strat, "uniform-alloc f5 d=5");
    }

    #[test]
    fn no_adjust_skips_histogram() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let bins = Bins::uniform(4, 10);
        let (_, c) = NativeEngine.vsample(
            &*f,
            &layout,
            &bins,
            &VSampleOpts {
                adjust: false,
                ..opts(1, 0, 2)
            },
        );
        assert!(c.is_none());
    }
}
