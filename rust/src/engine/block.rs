//! Structure-of-arrays point blocks — the batch-first evaluation
//! vocabulary shared by the engine, the stratified engine, the CPU
//! baselines, and user batch integrands.
//!
//! The paper's whole performance story is evaluating *blocks* of points
//! per processor (a thread-block owns a batch of sub-cubes) rather than
//! one point at a time. [`PointBlock`] is the CPU-side twin of that
//! layout: a fixed-capacity buffer of up to `capacity` points in
//! `dim` dimensions, stored column-major (`[d][capacity]`) so the inner
//! loop of a batched integrand runs over one contiguous coordinate
//! column per axis and vectorizes.
//!
//! ## SoA layout contract
//!
//! * Coordinates are column-major: [`PointBlock::axis`]`(i)` is the
//!   contiguous slice of axis-`i` coordinates for points `0..len()`.
//!   There is **no** per-point stride — point `k` is `axis(i)[k]` for
//!   each `i`, never a contiguous `[x0, x1, ..]` row.
//! * `jacobians()[k]` carries the VEGAS/box weight of point `k`. Batch
//!   integrands must **not** apply it — the caller multiplies
//!   `out[k] * jacobians()[k]` during reduction, exactly like the
//!   scalar path multiplied `eval(x) * jac`.
//! * `eval_batch` implementations must write `out[k]` for every
//!   `k < len()` and must not read `out` before writing it (the buffer
//!   is reused across blocks and carries stale values).
//!
//! Fill helpers here ([`VegasMap`], [`accumulate_uniform_box`]) are the
//! single definition of the change-of-variables / uniform-box sampling
//! loops. The native engine, the stratified engine, and the uniform-box
//! baselines (`plain_mc`, `miser`, `zmc_sim`) draw bit-identical points
//! from the same Philox streams as before the batch redesign; the one
//! exception is `gvegas_sim`, whose old loop divided by `g` where
//! [`VegasMap`] multiplies by a precomputed `1/g` (≤ 1 ulp per
//! coordinate — see the note in `baselines/gvegas_sim.rs`).

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::MAX_DIM;
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::rng::philox_simd::{uniforms_lanes, LANES};
use crate::strat::{Bounds, Layout};

/// Default number of points a block holds — sized so coords + jacobians
/// + values of a high-dimensional block stay L1/L2-resident (mirrors
/// the paper's per-thread-block batch).
pub const BLOCK_POINTS: usize = 256;

/// A fixed-capacity structure-of-arrays batch of evaluation points.
///
/// See the [module docs](self) for the layout contract.
#[derive(Debug, Clone)]
pub struct PointBlock {
    dim: usize,
    capacity: usize,
    len: usize,
    /// Column-major coords: axis `i`, point `k` at `coords[i * capacity + k]`.
    coords: Vec<f64>,
    /// Per-point Jacobian / weight.
    jac: Vec<f64>,
}

impl PointBlock {
    /// An empty block for `dim`-dimensional points, holding up to
    /// `capacity` points.
    pub fn with_capacity(dim: usize, capacity: usize) -> PointBlock {
        assert!(dim >= 1, "dimension must be >= 1");
        assert!(capacity >= 1, "capacity must be >= 1");
        PointBlock {
            dim,
            capacity,
            len: 0,
            coords: vec![0.0; dim * capacity],
            jac: vec![0.0; capacity],
        }
    }

    /// Dimensionality of every point in the block.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Maximum number of points the block can hold.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of points currently in the block.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the block holds no points.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Restart the block with `n` points whose coordinates are about to
    /// be written via [`PointBlock::set_coord`] / [`PointBlock::set_jac`].
    /// Existing contents are stale, not zeroed.
    #[inline]
    pub fn reset(&mut self, n: usize) {
        assert!(n <= self.capacity, "block overflow: {n} > {}", self.capacity);
        self.len = n;
    }

    /// Write coordinate `axis` of point `k`.
    #[inline]
    pub fn set_coord(&mut self, axis: usize, k: usize, v: f64) {
        debug_assert!(axis < self.dim && k < self.len);
        self.coords[axis * self.capacity + k] = v;
    }

    /// Read coordinate `axis` of point `k`.
    #[inline]
    pub fn coord(&self, axis: usize, k: usize) -> f64 {
        debug_assert!(axis < self.dim && k < self.len);
        self.coords[axis * self.capacity + k]
    }

    /// Write the Jacobian / weight of point `k`.
    #[inline]
    pub fn set_jac(&mut self, k: usize, v: f64) {
        debug_assert!(k < self.len);
        self.jac[k] = v;
    }

    /// Jacobian / weight of point `k`.
    #[inline]
    pub fn jac(&self, k: usize) -> f64 {
        debug_assert!(k < self.len);
        self.jac[k]
    }

    /// The contiguous axis-`i` coordinate column for points `0..len()`.
    #[inline]
    pub fn axis(&self, axis: usize) -> &[f64] {
        debug_assert!(axis < self.dim);
        &self.coords[axis * self.capacity..axis * self.capacity + self.len]
    }

    /// Per-point Jacobians for points `0..len()`.
    #[inline]
    pub fn jacobians(&self) -> &[f64] {
        &self.jac[..self.len]
    }

    /// Append one point given row-major coordinates (AoS convenience
    /// for tests and one-off scalar bridging; the hot fills write
    /// columns directly).
    pub fn push_point(&mut self, x: &[f64], jac: f64) {
        assert_eq!(x.len(), self.dim, "point dimension mismatch");
        assert!(self.len < self.capacity, "block full");
        let k = self.len;
        self.len += 1;
        for (i, &xi) in x.iter().enumerate() {
            self.coords[i * self.capacity + k] = xi;
        }
        self.jac[k] = jac;
    }

    /// Gather point `k` into a row-major buffer (the scalar-fallback
    /// bridge used by the default `Integrand::eval_batch`).
    #[inline]
    pub fn gather(&self, k: usize, out: &mut [f64]) {
        debug_assert!(k < self.len);
        debug_assert!(out.len() >= self.dim);
        for (i, slot) in out.iter_mut().enumerate().take(self.dim) {
            *slot = self.coords[i * self.capacity + k];
        }
    }
}

/// Adapter that hides an integrand's hand-batched `eval_batch`
/// override, forcing the default scalar-loop implementation. Used by
/// the batch-vs-scalar property tests and the perf microbench to
/// compare the two paths through the identical engine pipeline.
pub struct ScalarEval<'a>(pub &'a dyn Integrand);

impl Integrand for ScalarEval<'_> {
    fn name(&self) -> &str {
        self.0.name()
    }
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn lo(&self) -> f64 {
        self.0.lo()
    }
    fn hi(&self) -> f64 {
        self.0.hi()
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        self.0.eval(x)
    }
    fn true_value(&self) -> Option<f64> {
        self.0.true_value()
    }
    fn symmetric(&self) -> bool {
        self.0.symmetric()
    }
    fn bounds(&self) -> Bounds {
        self.0.bounds()
    }
    // NOTE: eval_batch deliberately NOT forwarded — the trait default
    // (gather + scalar eval) applies.
}

/// The VEGAS change of variables for block fills — one definition of
/// the per-axis importance-grid transform shared by the native engine,
/// the stratified engine, and the gVegas simulator, so the batched fills
/// stay bit-identical to the scalar loops they replaced.
pub struct VegasMap<'a> {
    // Internals shared with the lane-parallel fill in `engine::simd`
    // (`VegasMap::fill_points` lives there, next to the SIMD core).
    pub(super) edges: &'a [f64],
    pub(super) d: usize,
    pub(super) nb: usize,
    pub(super) inv_g: f64,
    pub(super) nbf: f64,
    pub(super) lo_ax: [f64; MAX_DIM],
    pub(super) span_ax: [f64; MAX_DIM],
    /// Volume of the physical box (the global Jacobian factor).
    pub vol: f64,
}

impl<'a> VegasMap<'a> {
    /// Build the transform for one (layout, grid, bounds) triple.
    pub fn new(layout: &Layout, bins: &'a Bins, bounds: &Bounds) -> VegasMap<'a> {
        assert!(layout.d <= MAX_DIM, "d > MAX_DIM");
        assert_eq!(bins.d(), layout.d);
        assert_eq!(bins.nb(), layout.nb);
        assert_eq!(bounds.dim(), layout.d, "bounds dim != layout dim");
        let mut lo_ax = [0.0f64; MAX_DIM];
        let mut span_ax = [0.0f64; MAX_DIM];
        let vol = bounds.unpack(&mut lo_ax, &mut span_ax);
        VegasMap {
            edges: bins.flat(),
            d: layout.d,
            nb: layout.nb,
            inv_g: 1.0 / layout.g as f64,
            nbf: layout.nb as f64,
            lo_ax,
            span_ax,
            vol,
        }
    }

    /// Transform the stratified unit sample `u` of the sub-cube at
    /// lattice `coords` into physical coordinates, writing the point
    /// into block slot `k` (with its Jacobian) and the flat `d * nb`
    /// histogram rows into `bidx[k * d .. (k + 1) * d]`.
    #[inline]
    pub fn fill_point(
        &self,
        coords: &[usize],
        u: &[f64],
        block: &mut PointBlock,
        k: usize,
        bidx: &mut [usize],
    ) {
        let d = self.d;
        let nb = self.nb;
        let mut jac = self.vol;
        for i in 0..d {
            let z = (coords[i] as f64 + u[i]) * self.inv_g;
            let loc = z * self.nbf;
            let b = (loc as usize).min(nb - 1);
            let row = i * nb;
            // SAFETY: i < d and b < nb, so row + b < d*nb == edges.len().
            let right = unsafe { *self.edges.get_unchecked(row + b) };
            let left = if b == 0 {
                0.0
            } else {
                unsafe { *self.edges.get_unchecked(row + b - 1) }
            };
            let w = right - left;
            let xt = left + (loc - b as f64) * w;
            jac *= self.nbf * w;
            block.set_coord(i, k, self.lo_ax[i] + xt * self.span_ax[i]);
            bidx[k * d + i] = row + b;
        }
        block.set_jac(k, jac);
    }
}

/// Accumulate plain-MC sums over `n` uniform samples in the axis-aligned
/// box `[lo, hi]`, drawing Philox uniforms from the stream
/// `(counter0.., stream, seed)` and evaluating through
/// `Integrand::eval_batch` in block-sized chunks.
///
/// The fill runs through the lane-parallel SIMD core
/// ([`crate::rng::philox_simd::uniforms_lanes`]) — the same counters
/// in the same order as the scalar loop, so the sums stay
/// bitwise-identical to the per-point loop this replaces in
/// `plain_mc`, `miser`, and `zmc_sim`. The counter is 64-bit: for
/// `counter0 + n < 2^32` the draws match the old `u32` stream exactly,
/// and beyond it the stream extends instead of wrapping.
///
/// Returns `(sum v, sum v^2)` with `v = f(x) * vol`, accumulated in
/// counter order.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_uniform_box(
    f: &dyn Integrand,
    lo: &[f64],
    hi: &[f64],
    seed: u32,
    stream: u32,
    counter0: u64,
    n: usize,
    block: &mut PointBlock,
    vals: &mut Vec<f64>,
) -> (f64, f64) {
    let d = lo.len();
    assert_eq!(hi.len(), d);
    assert_eq!(block.dim(), d, "block dim != box dim");
    let vol: f64 = lo.iter().zip(hi).map(|(a, b)| b - a).product();
    let cap = block.capacity();
    if vals.len() < cap {
        vals.resize(cap, 0.0);
    }
    // Stack scratch for the lane-group uniforms (heap fallback above
    // MAX_DIM) — this runs once per MISER/ZMC tree node, so a per-call
    // heap alloc here would undo the callers' reused-scratch design.
    let mut u_small = [[0.0f64; LANES]; MAX_DIM];
    let mut u_big;
    let u: &mut [[f64; LANES]] = if d <= MAX_DIM {
        &mut u_small[..d]
    } else {
        u_big = vec![[0.0f64; LANES]; d];
        &mut u_big
    };
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut done = 0usize;
    while done < n {
        let m = (n - done).min(cap);
        block.reset(m);
        let mut filled = 0usize;
        while filled < m {
            let take = (m - filled).min(LANES);
            uniforms_lanes::<LANES>(counter0 + (done + filled) as u64, stream, seed, u);
            for i in 0..d {
                // Same per-point expression as the scalar loop
                // (`lo + u * (hi - lo)`), one lane group at a time.
                let (lo_i, w_i) = (lo[i], hi[i] - lo[i]);
                for l in 0..take {
                    block.set_coord(i, filled + l, lo_i + u[i][l] * w_i);
                }
            }
            for l in 0..take {
                block.set_jac(filled + l, vol);
            }
            filled += take;
        }
        f.eval_batch(block, &mut vals[..m]);
        for &fv in vals[..m].iter() {
            let v = fv * vol;
            s1 += v;
            s2 += v * v;
        }
        done += m;
    }
    (s1, s2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;
    use crate::rng::uniforms_into;

    #[test]
    fn block_layout_round_trips() {
        let mut b = PointBlock::with_capacity(3, 8);
        assert_eq!(b.dim(), 3);
        assert_eq!(b.capacity(), 8);
        assert!(b.is_empty());
        b.push_point(&[1.0, 2.0, 3.0], 0.5);
        b.push_point(&[4.0, 5.0, 6.0], 0.25);
        assert_eq!(b.len(), 2);
        assert_eq!(b.axis(0), &[1.0, 4.0]);
        assert_eq!(b.axis(1), &[2.0, 5.0]);
        assert_eq!(b.axis(2), &[3.0, 6.0]);
        assert_eq!(b.jacobians(), &[0.5, 0.25]);
        let mut x = [0.0; 3];
        b.gather(1, &mut x);
        assert_eq!(x, [4.0, 5.0, 6.0]);
        b.reset(1);
        assert_eq!(b.len(), 1);
        b.set_coord(0, 0, 9.0);
        b.set_jac(0, 2.0);
        assert_eq!(b.coord(0, 0), 9.0);
        assert_eq!(b.jac(0), 2.0);
    }

    #[test]
    #[should_panic(expected = "block overflow")]
    fn reset_past_capacity_panics() {
        PointBlock::with_capacity(2, 4).reset(5);
    }

    #[test]
    fn default_eval_batch_matches_scalar_loop() {
        let f = by_name("f4", 3).unwrap();
        let mut b = PointBlock::with_capacity(3, 4);
        let pts = [[0.1, 0.2, 0.3], [0.5, 0.5, 0.5], [0.9, 0.1, 0.4]];
        for p in &pts {
            b.push_point(p, 1.0);
        }
        let mut out = [0.0f64; 4];
        f.eval_batch(&b, &mut out[..3]);
        for (k, p) in pts.iter().enumerate() {
            assert_eq!(out[k].to_bits(), f.eval(p).to_bits());
        }
    }

    #[test]
    fn scalar_eval_adapter_hides_batch_override() {
        let f = by_name("f5", 4).unwrap();
        let scalar = ScalarEval(&*f);
        assert_eq!(scalar.name(), "f5");
        assert_eq!(scalar.dim(), 4);
        assert_eq!(scalar.bounds(), f.bounds());
        assert_eq!(scalar.true_value(), f.true_value());
        let mut b = PointBlock::with_capacity(4, 2);
        b.push_point(&[0.3, 0.6, 0.1, 0.9], 1.0);
        b.push_point(&[0.5, 0.5, 0.5, 0.5], 1.0);
        let mut via_batch = [0.0f64; 2];
        let mut via_scalar = [0.0f64; 2];
        f.eval_batch(&b, &mut via_batch);
        scalar.eval_batch(&b, &mut via_scalar);
        assert_eq!(via_batch[0].to_bits(), via_scalar[0].to_bits());
        assert_eq!(via_batch[1].to_bits(), via_scalar[1].to_bits());
    }

    #[test]
    fn accumulate_uniform_box_matches_scalar_stream() {
        // Reference: the scalar per-point loop the helper replaced.
        let f = by_name("f3", 3).unwrap();
        let lo = [0.0, 0.25, 0.5];
        let hi = [1.0, 0.75, 0.9];
        let vol: f64 = lo.iter().zip(&hi).map(|(a, b)| b - a).product();
        let n = 777usize;
        let (seed, stream, counter0) = (9u32, 2u32, 13u64);
        let mut s1 = 0.0;
        let mut s2 = 0.0;
        let mut u = [0.0f64; 3];
        let mut x = [0.0f64; 3];
        for s in 0..n {
            uniforms_into(counter0 + s as u64, stream, seed, &mut u);
            for i in 0..3 {
                x[i] = lo[i] + u[i] * (hi[i] - lo[i]);
            }
            let v = f.eval(&x) * vol;
            s1 += v;
            s2 += v * v;
        }
        let mut block = PointBlock::with_capacity(3, 64);
        let mut vals = Vec::new();
        let (b1, b2) = accumulate_uniform_box(
            &*f, &lo, &hi, seed, stream, counter0, n, &mut block, &mut vals,
        );
        assert_eq!(s1.to_bits(), b1.to_bits());
        assert_eq!(s2.to_bits(), b2.to_bits());
    }
}
