//! Native CPU V-Sample engines — the "second backend" (portability
//! Table 2) and the reference the PJRT path is cross-checked against.
//!
//! Implements exactly the same sampling math as the Pallas kernel
//! (`python/compile/sampling.py`): identical Philox stream, cube decode,
//! VEGAS change of variables, per-cube reduction, and v^2 bin histogram.
//! For the same (seed, iteration) the native engine and the AOT artifact
//! agree to fp-summation-order tolerance — this is asserted by
//! `rust/tests/integration_runtime.rs`.
//!
//! ## The [`Engine`] trait
//!
//! Every sampling strategy is an [`Engine`]: it owns its [`Layout`]
//! (and, when adaptive, its per-cube [`crate::strat::Allocation`]),
//! samples any reduction-task subrange on demand
//! ([`Engine::sample_tasks`] — the shard entry point), folds the
//! complete task-ordered partials into its state once per iteration
//! ([`Engine::update`]), and exports that state for checkpoints
//! ([`Engine::export`]). Three impls ship today:
//!
//! * [`UniformEngine`] — the paper's uniform m-Cubes schedule
//!   (`p` samples per cube, counter base `cube * p`);
//! * [`stratified::VegasPlusEngine`] — VEGAS+ adaptive stratification
//!   (variable per-cube counts, damped-variance reallocation);
//! * [`crate::baselines::GvegasSimEngine`] — the gVegas cost model,
//!   ported onto the trait as the seam a PAGANI-style region-adaptive
//!   engine plugs into next.
//!
//! All of them funnel through ONE fill→`eval_batch`→reduce tile walk
//! ([`walk`]): the trait contributes only the per-cube sample schedule,
//! so the Philox counter bookkeeping, the tile loop, and the fixed
//! 64-task reduction exist in exactly one place.
//!
//! ## Reproducibility contract
//!
//! Parallelization mirrors the paper's Algorithm 3: the cube range is
//! split into contiguous *reduction tasks* (a fixed partition of
//! [`REDUCTION_TASKS`] spans, independent of the thread count); workers
//! pick up contiguous runs of tasks, each task accumulates a private
//! estimate + histogram over its cubes, and the coordinator folds task
//! partials in task order. Because both the partition and the fold
//! order are fixed, results are **bitwise identical for any thread
//! count** — and for any shard count, since the shard subsystem
//! partitions the same task index space. The stratified engine shares
//! the partition, so `Sampling::VegasPlus { beta: 0 }` reproduces the
//! uniform engine bitwise.
//!
//! Evaluation is batch-first (the paper's per-thread-block batches):
//! each worker fills a structure-of-arrays [`PointBlock`] with the
//! VEGAS-transformed points of a cache-resident tile, evaluates the
//! tile through one `Integrand::eval_batch` call, then reduces per
//! cube in sample order. The fill runs through the lane-parallel SIMD
//! core ([`simd`]) by default; sample indices are 64-bit end to end —
//! layouts above 2^32 calls draw distinct counters instead of silently
//! truncating. [`ExecPath`] selects the tile capacity (streaming
//! [`STREAM_TILE`] tiles by default, [`BLOCK_POINTS`] block tiles as
//! the reference); both are bitwise identical (property-tested).

pub mod block;
pub mod simd;
pub mod stratified;
pub mod tasks;
pub mod walk;

pub use block::{accumulate_uniform_box, PointBlock, ScalarEval, VegasMap, BLOCK_POINTS};
pub use simd::FillPath;
pub use stratified::{vsample_stratified, VegasPlusEngine};
pub use tasks::{merge_task_partials, vsample_stratified_tasks, vsample_tasks, TaskPartial};
pub use walk::{ExecPath, STREAM_TILE};

use crate::api::StratSnapshot;
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::strat::{AllocStats, Layout};

/// Maximum dimension supported by the stack-allocated hot path.
pub const MAX_DIM: usize = 16;

/// Fixed number of reduction tasks the cube range is partitioned into.
///
/// Work is split into (at most) this many contiguous cube spans and the
/// per-task partials are folded in task order, so the floating-point
/// reduction is a pure function of the layout — never of the thread
/// count. 64 keeps every realistic worker count busy while the
/// per-task scratch stays negligible next to the sampling work.
pub const REDUCTION_TASKS: usize = 64;

/// Number of reduction tasks for an `m`-cube layout:
/// `min(m, REDUCTION_TASKS)`, at least 1.
///
/// Public because the shard subsystem ([`crate::shard`]) partitions
/// exactly this task index space across workers — the task, not the
/// cube, is the unit of distribution, which is what makes an N-shard
/// merge reproduce the single-worker fold bitwise.
///
/// ```
/// use mcubes::engine::{reduction_tasks, REDUCTION_TASKS};
/// assert_eq!(reduction_tasks(3), 3);
/// assert_eq!(reduction_tasks(1_000_000), REDUCTION_TASKS);
/// ```
#[inline]
pub fn reduction_tasks(m: usize) -> usize {
    m.min(REDUCTION_TASKS).max(1)
}

/// Cube span `[lo, hi)` of reduction task `t` (balanced partition of
/// `m` cubes into `ntasks` contiguous spans: the first `m % ntasks`
/// tasks hold one extra cube).
///
/// ```
/// use mcubes::engine::reduction_task_span;
/// // 10 cubes over 4 tasks: spans of 3, 3, 2, 2 — contiguous, exact.
/// let spans: Vec<_> = (0..4).map(|t| reduction_task_span(10, 4, t)).collect();
/// assert_eq!(spans, vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
/// ```
#[inline]
pub fn reduction_task_span(m: usize, ntasks: usize, t: usize) -> (usize, usize) {
    let q = m / ntasks;
    let r = m % ntasks;
    let lo = t * q + t.min(r);
    (lo, lo + q + usize::from(t < r))
}

/// Configuration for a V-Sample pass.
#[derive(Debug, Clone, Copy)]
pub struct VSampleOpts {
    pub seed: u32,
    pub iteration: u32,
    /// Accumulate the v^2 histogram (V-Sample) or skip it
    /// (V-Sample-No-Adjust, Algorithm 2 line 15).
    pub adjust: bool,
    pub threads: usize,
}

/// One sampling strategy over an m-Cubes layout — the seam every
/// engine (uniform, VEGAS+ stratified, gVegas-sim, and the planned
/// PAGANI region-adaptive engine) plugs into.
///
/// An engine owns its layout and any per-cube allocation state; the
/// coordinator drives it through exactly five hooks:
///
/// * [`Engine::sample_tasks`] — sample a reduction-task subrange (the
///   shard entry point; every task's partial is bitwise independent of
///   who computes it);
/// * [`Engine::update`] — fold the complete, task-ordered partials of
///   one iteration into the engine's state (`&mut self`, which is what
///   lets the backend layer drop its historical `RefCell` shims);
/// * [`Engine::allocation`] — the live per-cube (counts, offsets) view
///   shard plans are built from, `None` on uniform schedules;
/// * [`Engine::export`] — checkpoint state for suspend/resume;
/// * [`Engine::vsample`] — one full pass (default impl: sample every
///   task, merge in task order, update).
///
/// Engines are `Send + Sync`: shard workers sample disjoint task
/// ranges through `&self` from scoped threads, while `update` keeps
/// all mutation single-threaded at the merge point.
pub trait Engine: Send + Sync {
    /// Backend label for reports ("native" / "native-vegas+" / ...).
    fn name(&self) -> &'static str;

    /// The stratification layout this engine samples.
    fn layout(&self) -> &Layout;

    /// Partials of reduction tasks `[task_lo, task_hi)` — bitwise
    /// identical for any `opts.threads`, any tile capacity (`exec`),
    /// and either fill path; concatenating subrange results in task
    /// order reproduces the full pass bitwise.
    #[allow(clippy::too_many_arguments)]
    fn sample_tasks(
        &self,
        f: &dyn Integrand,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
        task_lo: usize,
        task_hi: usize,
    ) -> Vec<TaskPartial>;

    /// Fold one iteration's complete, task-ordered partials into the
    /// engine's state (e.g. absorb `d_new` observations and
    /// re-apportion the next iteration's budget). Uniform engines
    /// no-op.
    fn update(&mut self, partials: &[TaskPartial]);

    /// Live per-cube allocation view `(counts, offsets)` — `Some` only
    /// for adaptively-stratified engines. Shard plans are built from
    /// this.
    fn allocation(&self) -> Option<(&[u32], &[u64])> {
        None
    }

    /// Summary of the live allocation (`Some` only when adaptive).
    fn alloc_stats(&self) -> Option<AllocStats> {
        None
    }

    /// Checkpoint state export (`Some` only when adaptive): restoring
    /// an engine from this snapshot resumes the allocation
    /// bit-identically.
    fn export(&self) -> Option<StratSnapshot> {
        None
    }

    /// One full V-Sample pass: sample every reduction task, merge the
    /// partials in global task order, and fold them into the engine's
    /// state. Returns the iteration result and, when `opts.adjust`,
    /// the row-major `[d][nb]` bin-contribution histogram.
    fn vsample(
        &mut self,
        f: &dyn Integrand,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
    ) -> (IterationResult, Option<Vec<f64>>) {
        let (d, nb, ntasks) = {
            let l = self.layout();
            (l.d, l.nb, reduction_tasks(l.m))
        };
        let partials = self.sample_tasks(f, bins, opts, fill, exec, 0, ntasks);
        let out = merge_task_partials(d, nb, opts.adjust, &partials);
        self.update(&partials);
        out
    }
}

/// Trait-object forwarding: a boxed engine is an engine, so generic
/// plumbing (`EngineBackend<E>`, the shard coordinator) runs over
/// `Box<dyn Engine>` exactly as it runs over a concrete impl — the
/// dyn-dispatch golden/property tests pin that both produce the same
/// bits.
impl Engine for Box<dyn Engine> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn layout(&self) -> &Layout {
        (**self).layout()
    }

    fn sample_tasks(
        &self,
        f: &dyn Integrand,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
        task_lo: usize,
        task_hi: usize,
    ) -> Vec<TaskPartial> {
        (**self).sample_tasks(f, bins, opts, fill, exec, task_lo, task_hi)
    }

    fn update(&mut self, partials: &[TaskPartial]) {
        (**self).update(partials);
    }

    fn allocation(&self) -> Option<(&[u32], &[u64])> {
        (**self).allocation()
    }

    fn alloc_stats(&self) -> Option<AllocStats> {
        (**self).alloc_stats()
    }

    fn export(&self) -> Option<StratSnapshot> {
        (**self).export()
    }

    fn vsample(
        &mut self,
        f: &dyn Integrand,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
    ) -> (IterationResult, Option<Vec<f64>>) {
        (**self).vsample(f, bins, opts, fill, exec)
    }
}

/// The paper's uniform m-Cubes schedule: every sub-cube draws exactly
/// `p` samples from the consecutive Philox counters `cube * p .. cube
/// * p + p`. Stateless beyond the layout — [`Engine::update`] is a
/// no-op.
#[derive(Debug, Clone)]
pub struct UniformEngine {
    layout: Layout,
}

impl UniformEngine {
    pub fn new(layout: Layout) -> UniformEngine {
        UniformEngine { layout }
    }
}

impl Engine for UniformEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn layout(&self) -> &Layout {
        &self.layout
    }

    fn sample_tasks(
        &self,
        f: &dyn Integrand,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
        task_lo: usize,
        task_hi: usize,
    ) -> Vec<TaskPartial> {
        walk::run_tasks(
            f,
            &self.layout,
            bins,
            &walk::UniformSched { p: self.layout.p },
            opts,
            fill,
            exec,
            task_lo,
            task_hi,
        )
    }

    fn update(&mut self, _partials: &[TaskPartial]) {}
}

/// Stateless convenience handle over [`UniformEngine`] for callers
/// that hold the layout themselves (tests, benches, shard workers):
/// `NativeEngine.vsample(f, &layout, &bins, &opts)` is one full
/// uniform pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeEngine;

impl NativeEngine {
    /// One uniform V-Sample pass over every sub-cube in `layout`.
    ///
    /// Returns the iteration result and, when `opts.adjust`, the
    /// row-major `[d][nb]` bin-contribution histogram.
    pub fn vsample(
        &self,
        f: &dyn Integrand,
        layout: &Layout,
        bins: &Bins,
        opts: &VSampleOpts,
    ) -> (IterationResult, Option<Vec<f64>>) {
        self.vsample_exec(f, layout, bins, opts, FillPath::Simd, ExecPath::default())
    }

    /// [`NativeEngine::vsample`] with explicit fill and execution
    /// paths. Both [`ExecPath`]s and both [`FillPath`]s are bitwise
    /// identical (property-tested), so the choice is purely a
    /// performance knob.
    pub fn vsample_exec(
        &self,
        f: &dyn Integrand,
        layout: &Layout,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
    ) -> (IterationResult, Option<Vec<f64>>) {
        UniformEngine::new(*layout).vsample(f, bins, opts, fill, exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    fn opts(seed: u32, it: u32) -> VSampleOpts {
        VSampleOpts {
            seed,
            iteration: it,
            adjust: true,
            threads: 2,
        }
    }

    #[test]
    fn bitwise_identical_across_thread_counts() {
        // The fixed task partition makes the reduction independent of
        // the worker count: not just close — bit-for-bit equal.
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let e = NativeEngine;
        let (r1, c1) = e.vsample(
            &*f,
            &layout,
            &bins,
            &VSampleOpts {
                threads: 1,
                ..opts(42, 0)
            },
        );
        let (r8, c8) = e.vsample(
            &*f,
            &layout,
            &bins,
            &VSampleOpts {
                threads: 8,
                ..opts(42, 0)
            },
        );
        assert_eq!(r1.integral.to_bits(), r8.integral.to_bits());
        assert_eq!(r1.variance.to_bits(), r8.variance.to_bits());
        let (c1, c8) = (c1.unwrap(), c8.unwrap());
        for (a, b) in c1.iter().zip(&c8) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn reduction_task_partition_covers_cubes() {
        for m in [1, 2, 63, 64, 65, 1000, 6561] {
            let ntasks = reduction_tasks(m);
            assert!(ntasks >= 1 && ntasks <= REDUCTION_TASKS.min(m).max(1));
            let mut next = 0usize;
            for t in 0..ntasks {
                let (lo, hi) = reduction_task_span(m, ntasks, t);
                assert_eq!(lo, next, "m={m} t={t}");
                assert!(hi > lo, "empty task: m={m} t={t}");
                next = hi;
            }
            assert_eq!(next, m);
        }
    }

    #[test]
    fn matches_python_first_iteration_estimate() {
        // Python prototype printed for f4 d=5 calls=4096 nb=20 seed=42 it=0:
        //   I = 2.7858176280788316e-05, Var = 7.757123669326781e-10
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let (r, _) = NativeEngine.vsample(&*f, &layout, &bins, &opts(42, 0));
        assert!(
            ((r.integral - 2.7858176280788316e-05) / 2.7858176280788316e-05).abs() < 1e-12,
            "I = {}",
            r.integral
        );
        assert!(
            ((r.variance - 7.757123669326781e-10) / 7.757123669326781e-10).abs() < 1e-10,
            "Var = {}",
            r.variance
        );
    }

    #[test]
    fn dyn_engine_matches_static_engine_bitwise() {
        // Trait-object dispatch must be invisible: a `Box<dyn Engine>`
        // pass produces the same bits as the concrete impl.
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let o = opts(42, 0);
        let mut stat = UniformEngine::new(layout);
        let (rs, cs) = stat.vsample(&*f, &bins, &o, FillPath::Simd, ExecPath::default());
        let mut dynamic: Box<dyn Engine> = Box::new(UniformEngine::new(layout));
        let (rd, cd) = dynamic.vsample(&*f, &bins, &o, FillPath::Simd, ExecPath::default());
        assert_eq!(rs.integral.to_bits(), rd.integral.to_bits());
        assert_eq!(rs.variance.to_bits(), rd.variance.to_bits());
        for (a, b) in cs.unwrap().iter().zip(&cd.unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(dynamic.name(), "native");
        assert!(dynamic.allocation().is_none());
        assert!(dynamic.export().is_none());
    }

    #[test]
    fn no_adjust_skips_histogram() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let bins = Bins::uniform(4, 10);
        let (_, c) = NativeEngine.vsample(
            &*f,
            &layout,
            &bins,
            &VSampleOpts {
                adjust: false,
                ..opts(1, 0)
            },
        );
        assert!(c.is_none());
    }

    #[test]
    fn histogram_mass_equals_sum_v2() {
        // Each axis's histogram totals the same sum of v^2.
        let f = by_name("f3", 3).unwrap();
        let layout = Layout::compute(3, 2048, 12, 2).unwrap();
        let bins = Bins::uniform(3, 12);
        let (_, c) = NativeEngine.vsample(&*f, &layout, &bins, &opts(7, 2));
        let c = c.unwrap();
        let per_axis: Vec<f64> = (0..3)
            .map(|i| c[i * 12..(i + 1) * 12].iter().sum())
            .collect();
        for w in per_axis.windows(2) {
            assert!(
                ((w[0] - w[1]) / w[0]).abs() < 1e-12,
                "axis masses differ: {per_axis:?}"
            );
        }
    }

    #[test]
    fn per_axis_bounds_constant_integrand() {
        // f == 1 over [0,2] x [1,4] x [-1,0]: integral is the box
        // volume (6), exactly, for any importance grid.
        struct Box3;
        impl crate::integrands::Integrand for Box3 {
            fn name(&self) -> &str {
                "box3"
            }
            fn dim(&self) -> usize {
                3
            }
            fn lo(&self) -> f64 {
                -1.0
            }
            fn hi(&self) -> f64 {
                4.0
            }
            fn eval(&self, _x: &[f64]) -> f64 {
                1.0
            }
            fn true_value(&self) -> Option<f64> {
                Some(6.0)
            }
            fn bounds(&self) -> crate::strat::Bounds {
                crate::strat::Bounds::per_axis(&[(0.0, 2.0), (1.0, 4.0), (-1.0, 0.0)])
                    .unwrap()
            }
        }
        let layout = Layout::compute(3, 2048, 16, 2).unwrap();
        let bins = Bins::uniform(3, 16);
        let (r, _) = NativeEngine.vsample(&Box3, &layout, &bins, &opts(5, 0));
        assert!((r.integral - 6.0).abs() < 1e-10, "I = {}", r.integral);
        assert!(r.variance.abs() < 1e-18, "Var = {}", r.variance);
    }

    #[test]
    fn per_axis_bounds_sample_points_in_box() {
        // Samples must land inside the per-axis box, never the hull.
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Probe(AtomicUsize);
        impl crate::integrands::Integrand for Probe {
            fn name(&self) -> &str {
                "probe"
            }
            fn dim(&self) -> usize {
                2
            }
            fn lo(&self) -> f64 {
                0.0
            }
            fn hi(&self) -> f64 {
                3.0
            }
            fn eval(&self, x: &[f64]) -> f64 {
                assert!((0.0..=2.0).contains(&x[0]), "x0 = {}", x[0]);
                assert!((1.0..=3.0).contains(&x[1]), "x1 = {}", x[1]);
                self.0.fetch_add(1, Ordering::Relaxed);
                x[0] + x[1]
            }
            fn true_value(&self) -> Option<f64> {
                None
            }
            fn bounds(&self) -> crate::strat::Bounds {
                crate::strat::Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)]).unwrap()
            }
        }
        let layout = Layout::compute(2, 512, 8, 1).unwrap();
        let bins = Bins::uniform(2, 8);
        let probe = Probe(AtomicUsize::new(0));
        let (r, _) = NativeEngine.vsample(&probe, &layout, &bins, &opts(9, 1));
        assert_eq!(probe.0.load(Ordering::Relaxed), layout.calls());
        // E[x0 + x1] * area = (1 + 2) * 4 = 12
        assert!((r.integral - 12.0).abs() < 0.5, "I = {}", r.integral);
    }

    #[test]
    fn estimate_within_5_sigma_of_truth() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 1 << 14, 50, 8).unwrap();
        let bins = Bins::uniform(4, 50);
        let (r, _) = NativeEngine.vsample(&*f, &layout, &bins, &opts(3, 0));
        let truth = f.true_value().unwrap();
        assert!(
            (r.integral - truth).abs() < 5.0 * r.variance.sqrt(),
            "I={} true={truth} sigma={}",
            r.integral,
            r.variance.sqrt()
        );
    }
}
