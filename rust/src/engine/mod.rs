//! Native CPU V-Sample engine — the "second backend" (portability
//! Table 2) and the reference the PJRT path is cross-checked against.
//!
//! Implements exactly the same sampling math as the Pallas kernel
//! (`python/compile/sampling.py`): identical Philox stream, cube decode,
//! VEGAS change of variables, per-cube reduction, and v^2 bin histogram.
//! For the same (seed, iteration) the native engine and the AOT artifact
//! agree to fp-summation-order tolerance — this is asserted by
//! `rust/tests/integration_runtime.rs`.
//!
//! Parallelization mirrors the paper's Algorithm 3: the cube range is
//! split into contiguous *reduction tasks* (a fixed partition of
//! [`REDUCTION_TASKS`] spans, independent of the thread count); workers
//! pick up contiguous runs of tasks, each task accumulates a private
//! estimate + histogram over its cubes, and the coordinator folds task
//! partials in task order. Because both the partition and the fold
//! order are fixed, results are **bitwise identical for any thread
//! count** (deterministic, unlike atomics — and stronger than the
//! per-worker chunking this replaced, which was only reproducible up to
//! summation-order rounding). The stratified VEGAS+ path
//! ([`stratified::vsample_stratified`]) shares the same partition, so
//! `Sampling::VegasPlus { beta: 0 }` reproduces this engine bitwise.
//!
//! Evaluation is batch-first (the paper's per-thread-block batches):
//! each worker fills a structure-of-arrays [`PointBlock`] with the
//! VEGAS-transformed points of a batch of whole sub-cubes, evaluates
//! the whole block through one `Integrand::eval_batch` call, then
//! reduces per cube in sample order. The fill itself runs through the
//! lane-parallel SIMD core ([`simd`]): [`crate::rng::philox_simd`]
//! computes `LANES` Philox counters per step and
//! [`VegasMap::fill_points`] applies the bin lookup + affine transform
//! to the whole lane group. The Philox streams, the transform, and the
//! ordered reduction are unchanged, so results are bitwise identical
//! to the scalar per-point loop this replaced (asserted by the
//! batch-vs-scalar and simd-vs-scalar property tests). Sample indices
//! are 64-bit end to end — layouts above 2^32 calls draw distinct
//! counters instead of silently truncating.
//!
//! The default execution schedule is the fused streaming tile loop
//! ([`streaming`]): fill → eval → reduce over small cache-resident
//! tiles instead of whole blocks, bitwise identical to the block
//! pipeline described above (which survives as [`ExecPath::Block`],
//! the reference the equivalence suite compares against).

pub mod block;
pub mod simd;
pub mod stratified;
pub mod streaming;
pub mod tasks;

pub use block::{accumulate_uniform_box, PointBlock, ScalarEval, VegasMap, BLOCK_POINTS};
pub use simd::FillPath;
pub use stratified::{vsample_stratified, vsample_stratified_with_fill};
pub use streaming::{
    vsample_stratified_exec, vsample_stratified_streaming, vsample_stratified_streaming_with_fill,
    vsample_streaming, vsample_streaming_with_fill, ExecPath, STREAM_TILE,
};
pub use tasks::{merge_task_partials, vsample_stratified_tasks, vsample_tasks, TaskPartial};

use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::strat::Layout;
use crate::util::threadpool::parallel_chunks;

/// Maximum dimension supported by the stack-allocated hot path.
pub const MAX_DIM: usize = 16;

/// Fixed number of reduction tasks the cube range is partitioned into.
///
/// Work is split into (at most) this many contiguous cube spans and the
/// per-task partials are folded in task order, so the floating-point
/// reduction is a pure function of the layout — never of the thread
/// count. 64 keeps every realistic worker count busy while the
/// per-task scratch stays negligible next to the sampling work.
pub const REDUCTION_TASKS: usize = 64;

/// Number of reduction tasks for an `m`-cube layout:
/// `min(m, REDUCTION_TASKS)`, at least 1.
///
/// Public because the shard subsystem ([`crate::shard`]) partitions
/// exactly this task index space across workers — the task, not the
/// cube, is the unit of distribution, which is what makes an N-shard
/// merge reproduce the single-worker fold bitwise.
///
/// ```
/// use mcubes::engine::{reduction_tasks, REDUCTION_TASKS};
/// assert_eq!(reduction_tasks(3), 3);
/// assert_eq!(reduction_tasks(1_000_000), REDUCTION_TASKS);
/// ```
#[inline]
pub fn reduction_tasks(m: usize) -> usize {
    m.min(REDUCTION_TASKS).max(1)
}

/// Cube span `[lo, hi)` of reduction task `t` (balanced partition of
/// `m` cubes into `ntasks` contiguous spans: the first `m % ntasks`
/// tasks hold one extra cube).
///
/// ```
/// use mcubes::engine::reduction_task_span;
/// // 10 cubes over 4 tasks: spans of 3, 3, 2, 2 — contiguous, exact.
/// let spans: Vec<_> = (0..4).map(|t| reduction_task_span(10, 4, t)).collect();
/// assert_eq!(spans, vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
/// ```
#[inline]
pub fn reduction_task_span(m: usize, ntasks: usize, t: usize) -> (usize, usize) {
    let q = m / ntasks;
    let r = m % ntasks;
    let lo = t * q + t.min(r);
    (lo, lo + q + usize::from(t < r))
}

/// One worker's partial output.
struct Partial {
    integral: f64,
    variance: f64,
    contrib: Option<Vec<f64>>,
}

/// Configuration for a V-Sample pass.
#[derive(Debug, Clone, Copy)]
pub struct VSampleOpts {
    pub seed: u32,
    pub iteration: u32,
    /// Accumulate the v^2 histogram (V-Sample) or skip it
    /// (V-Sample-No-Adjust, Algorithm 2 line 15).
    pub adjust: bool,
    pub threads: usize,
}

/// The native engine. Stateless; all state flows through arguments so
/// the coordinator can drive PJRT and native backends identically.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeEngine;

impl NativeEngine {
    /// One V-Sample pass over every sub-cube in `layout`.
    ///
    /// Returns the iteration result and, when `opts.adjust`, the
    /// row-major `[d][nb]` bin-contribution histogram.
    pub fn vsample(
        &self,
        f: &dyn Integrand,
        layout: &Layout,
        bins: &Bins,
        opts: &VSampleOpts,
    ) -> (IterationResult, Option<Vec<f64>>) {
        self.vsample_with_fill(f, layout, bins, opts, FillPath::Simd)
    }

    /// [`NativeEngine::vsample`] with an explicit [`FillPath`].
    ///
    /// The two paths are bitwise identical (the SIMD determinism
    /// contract, property-tested); `FillPath::Scalar` exists for the
    /// equivalence tests and the `simd_fill_speedup` microbench.
    pub fn vsample_with_fill(
        &self,
        f: &dyn Integrand,
        layout: &Layout,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
    ) -> (IterationResult, Option<Vec<f64>>) {
        self.vsample_exec(f, layout, bins, opts, fill, ExecPath::default())
    }

    /// [`NativeEngine::vsample`] with explicit fill and execution
    /// paths. `ExecPath::Streaming` (the default) runs the fused
    /// streaming tile loop ([`streaming`]); `ExecPath::Block` runs the
    /// historical whole-block pipeline. Bitwise identical either way
    /// (property-tested), so the choice is purely a performance knob.
    pub fn vsample_exec(
        &self,
        f: &dyn Integrand,
        layout: &Layout,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
        exec: ExecPath,
    ) -> (IterationResult, Option<Vec<f64>>) {
        match exec {
            ExecPath::Streaming => streaming::vsample_streaming_with_fill(f, layout, bins, opts, fill),
            ExecPath::Block => self.vsample_block(f, layout, bins, opts, fill),
        }
    }

    /// The block pipeline: materialize a whole-cube batch, then
    /// evaluate and reduce it — the reference [`ExecPath::Block`] body.
    fn vsample_block(
        &self,
        f: &dyn Integrand,
        layout: &Layout,
        bins: &Bins,
        opts: &VSampleOpts,
        fill: FillPath,
    ) -> (IterationResult, Option<Vec<f64>>) {
        assert!(layout.d <= MAX_DIM, "d > MAX_DIM");
        if let Err(e) = layout.validate() {
            panic!("invalid layout: {e}");
        }
        assert_eq!(bins.d(), layout.d);
        assert_eq!(bins.nb(), layout.nb);

        // Fixed task partition: the same spans (and the same fold
        // order below) for every thread count — see `REDUCTION_TASKS`.
        let ntasks = reduction_tasks(layout.m);
        let task_partials: Vec<Vec<Partial>> =
            parallel_chunks(ntasks, opts.threads, |t0, t1| {
                (t0..t1)
                    .map(|t| {
                        let (lo, hi) = reduction_task_span(layout.m, ntasks, t);
                        sample_cube_range(f, layout, bins, opts, lo, hi, fill)
                    })
                    .collect()
            });

        let mut integral = 0.0;
        let mut variance = 0.0;
        let mut contrib = opts.adjust.then(|| vec![0.0; layout.d * layout.nb]);
        for p in task_partials.into_iter().flatten() {
            integral += p.integral;
            variance += p.variance;
            if let (Some(acc), Some(part)) = (contrib.as_mut(), p.contrib.as_ref()) {
                for (x, y) in acc.iter_mut().zip(part) {
                    *x += y;
                }
            }
        }
        (
            IterationResult {
                integral,
                variance,
            },
            contrib,
        )
    }
}

/// Serial V-Sample over cubes [cube_lo, cube_hi) — the per-worker body.
///
/// Batch pipeline: fill a [`PointBlock`] with the points of a batch of
/// whole cubes → one `eval_batch` call → ordered per-cube reduction.
/// The fill runs through the lane-parallel SIMD core by default
/// (`FillPath::Simd`, see [`simd`]); point order, Philox counters, and
/// every accumulation order match the scalar loop, so partials are
/// bitwise identical either way. The global sample index is 64-bit —
/// layouts beyond 2^32 calls keep distinct counters per sample instead
/// of silently truncating.
fn sample_cube_range(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    opts: &VSampleOpts,
    cube_lo: usize,
    cube_hi: usize,
    fill: FillPath,
) -> Partial {
    let d = layout.d;
    let nb = layout.nb;
    let m = layout.m as f64;
    let p = layout.p;
    let pf = p as f64;
    // Per-axis affine map unit box -> physical box + importance-grid
    // transform, shared with the stratified engine and gVegas-sim.
    let map = VegasMap::new(layout, bins, &f.bounds());

    let mut contrib = opts.adjust.then(|| vec![0.0; d * nb]);
    let mut integral = 0.0;
    let mut variance = 0.0;

    let mut coords = [0usize; MAX_DIM];

    // Whole cubes per block: at least one cube, and as many as fit the
    // target block size when p is small.
    let cubes_per_block = (BLOCK_POINTS / p).max(1);
    let cap = cubes_per_block * p;
    let mut blk = PointBlock::with_capacity(d, cap);
    let mut vals = vec![0.0f64; cap];
    let mut bidx = vec![0usize; cap * d];
    // Row-major `[ncubes][d]` lattice coords of the block's cubes —
    // the SIMD span fill reads each lane's cube from here, so lane
    // groups stay full across cube boundaries (crucial when p is 2).
    let mut cube_coords = vec![0usize; cubes_per_block * d];

    // Decode the first cube, then advance coords as a base-g odometer —
    // avoids d divisions per cube in the hot loop (perf pass).
    layout.cube_coords(cube_lo, &mut coords[..d]);
    let gm1 = layout.g - 1;

    let mut cube = cube_lo;
    while cube < cube_hi {
        let ncubes = cubes_per_block.min(cube_hi - cube);
        let npts = ncubes * p;
        blk.reset(npts);

        // Decode the block's cube coords (odometer, one step per cube).
        for c in 0..ncubes {
            cube_coords[c * d..(c + 1) * d].copy_from_slice(&coords[..d]);
            for slot in coords.iter_mut().take(d) {
                if *slot == gm1 {
                    *slot = 0;
                } else {
                    *slot += 1;
                    break;
                }
            }
        }

        // Fill phase: the block's points in (cube, sample) order — the
        // global sample indices run consecutively across the block.
        let base_sidx = cube as u64 * p as u64;
        match fill {
            FillPath::Simd => map.fill_span(
                &cube_coords[..ncubes * d],
                ncubes,
                p,
                base_sidx,
                opts.iteration,
                opts.seed,
                &mut blk,
                &mut bidx,
            ),
            FillPath::Scalar => {
                for c in 0..ncubes {
                    map.fill_points_scalar(
                        &cube_coords[c * d..(c + 1) * d],
                        base_sidx + (c * p) as u64,
                        p,
                        opts.iteration,
                        opts.seed,
                        &mut blk,
                        c * p,
                        &mut bidx,
                    );
                }
            }
        }

        // Eval phase: one virtual call for the whole block.
        f.eval_batch(&blk, &mut vals[..npts]);

        // Reduce phase: per cube, in sample order.
        for c in 0..ncubes {
            let base = c * p;
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            for k in 0..p {
                let j = base + k;
                let v = vals[j] * blk.jac(j);
                s1 += v;
                s2 += v * v;
                if let Some(cacc) = contrib.as_mut() {
                    let v2 = v * v;
                    for i in 0..d {
                        // SAFETY: bidx slots hold i*nb + b with b < nb,
                        // so each is < d*nb == cacc.len().
                        unsafe { *cacc.get_unchecked_mut(bidx[j * d + i]) += v2 };
                    }
                }
            }
            let mean = s1 / pf;
            let var = ((s2 / pf - mean * mean).max(0.0)) / (pf - 1.0);
            integral += mean / m;
            variance += var / (m * m);
        }

        cube += ncubes;
    }

    Partial {
        integral,
        variance,
        contrib,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrands::by_name;

    fn opts(seed: u32, it: u32) -> VSampleOpts {
        VSampleOpts {
            seed,
            iteration: it,
            adjust: true,
            threads: 2,
        }
    }

    #[test]
    fn bitwise_identical_across_thread_counts() {
        // The fixed task partition makes the reduction independent of
        // the worker count: not just close — bit-for-bit equal.
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let e = NativeEngine;
        let (r1, c1) = e.vsample(
            &*f,
            &layout,
            &bins,
            &VSampleOpts {
                threads: 1,
                ..opts(42, 0)
            },
        );
        let (r8, c8) = e.vsample(
            &*f,
            &layout,
            &bins,
            &VSampleOpts {
                threads: 8,
                ..opts(42, 0)
            },
        );
        assert_eq!(r1.integral.to_bits(), r8.integral.to_bits());
        assert_eq!(r1.variance.to_bits(), r8.variance.to_bits());
        let (c1, c8) = (c1.unwrap(), c8.unwrap());
        for (a, b) in c1.iter().zip(&c8) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn reduction_task_partition_covers_cubes() {
        for m in [1, 2, 63, 64, 65, 1000, 6561] {
            let ntasks = reduction_tasks(m);
            assert!(ntasks >= 1 && ntasks <= REDUCTION_TASKS.min(m).max(1));
            let mut next = 0usize;
            for t in 0..ntasks {
                let (lo, hi) = reduction_task_span(m, ntasks, t);
                assert_eq!(lo, next, "m={m} t={t}");
                assert!(hi > lo, "empty task: m={m} t={t}");
                next = hi;
            }
            assert_eq!(next, m);
        }
    }

    #[test]
    fn matches_python_first_iteration_estimate() {
        // Python prototype printed for f4 d=5 calls=4096 nb=20 seed=42 it=0:
        //   I = 2.7858176280788316e-05, Var = 7.757123669326781e-10
        let f = by_name("f4", 5).unwrap();
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let bins = Bins::uniform(5, 20);
        let (r, _) = NativeEngine.vsample(&*f, &layout, &bins, &opts(42, 0));
        assert!(
            ((r.integral - 2.7858176280788316e-05) / 2.7858176280788316e-05).abs() < 1e-12,
            "I = {}",
            r.integral
        );
        assert!(
            ((r.variance - 7.757123669326781e-10) / 7.757123669326781e-10).abs() < 1e-10,
            "Var = {}",
            r.variance
        );
    }

    #[test]
    fn no_adjust_skips_histogram() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let bins = Bins::uniform(4, 10);
        let (_, c) = NativeEngine.vsample(
            &*f,
            &layout,
            &bins,
            &VSampleOpts {
                adjust: false,
                ..opts(1, 0)
            },
        );
        assert!(c.is_none());
    }

    #[test]
    fn histogram_mass_equals_sum_v2() {
        // Each axis's histogram totals the same sum of v^2.
        let f = by_name("f3", 3).unwrap();
        let layout = Layout::compute(3, 2048, 12, 2).unwrap();
        let bins = Bins::uniform(3, 12);
        let (_, c) = NativeEngine.vsample(&*f, &layout, &bins, &opts(7, 2));
        let c = c.unwrap();
        let per_axis: Vec<f64> = (0..3)
            .map(|i| c[i * 12..(i + 1) * 12].iter().sum())
            .collect();
        for w in per_axis.windows(2) {
            assert!(
                ((w[0] - w[1]) / w[0]).abs() < 1e-12,
                "axis masses differ: {per_axis:?}"
            );
        }
    }

    #[test]
    fn per_axis_bounds_constant_integrand() {
        // f == 1 over [0,2] x [1,4] x [-1,0]: integral is the box
        // volume (6), exactly, for any importance grid.
        struct Box3;
        impl crate::integrands::Integrand for Box3 {
            fn name(&self) -> &str {
                "box3"
            }
            fn dim(&self) -> usize {
                3
            }
            fn lo(&self) -> f64 {
                -1.0
            }
            fn hi(&self) -> f64 {
                4.0
            }
            fn eval(&self, _x: &[f64]) -> f64 {
                1.0
            }
            fn true_value(&self) -> Option<f64> {
                Some(6.0)
            }
            fn bounds(&self) -> crate::strat::Bounds {
                crate::strat::Bounds::per_axis(&[(0.0, 2.0), (1.0, 4.0), (-1.0, 0.0)])
                    .unwrap()
            }
        }
        let layout = Layout::compute(3, 2048, 16, 2).unwrap();
        let bins = Bins::uniform(3, 16);
        let (r, _) = NativeEngine.vsample(&Box3, &layout, &bins, &opts(5, 0));
        assert!((r.integral - 6.0).abs() < 1e-10, "I = {}", r.integral);
        assert!(r.variance.abs() < 1e-18, "Var = {}", r.variance);
    }

    #[test]
    fn per_axis_bounds_sample_points_in_box() {
        // Samples must land inside the per-axis box, never the hull.
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Probe(AtomicUsize);
        impl crate::integrands::Integrand for Probe {
            fn name(&self) -> &str {
                "probe"
            }
            fn dim(&self) -> usize {
                2
            }
            fn lo(&self) -> f64 {
                0.0
            }
            fn hi(&self) -> f64 {
                3.0
            }
            fn eval(&self, x: &[f64]) -> f64 {
                assert!((0.0..=2.0).contains(&x[0]), "x0 = {}", x[0]);
                assert!((1.0..=3.0).contains(&x[1]), "x1 = {}", x[1]);
                self.0.fetch_add(1, Ordering::Relaxed);
                x[0] + x[1]
            }
            fn true_value(&self) -> Option<f64> {
                None
            }
            fn bounds(&self) -> crate::strat::Bounds {
                crate::strat::Bounds::per_axis(&[(0.0, 2.0), (1.0, 3.0)]).unwrap()
            }
        }
        let layout = Layout::compute(2, 512, 8, 1).unwrap();
        let bins = Bins::uniform(2, 8);
        let probe = Probe(AtomicUsize::new(0));
        let (r, _) = NativeEngine.vsample(&probe, &layout, &bins, &opts(9, 1));
        assert_eq!(probe.0.load(Ordering::Relaxed), layout.calls());
        // E[x0 + x1] * area = (1 + 2) * 4 = 12
        assert!((r.integral - 12.0).abs() < 0.5, "I = {}", r.integral);
    }

    #[test]
    fn estimate_within_5_sigma_of_truth() {
        let f = by_name("f5", 4).unwrap();
        let layout = Layout::compute(4, 1 << 14, 50, 8).unwrap();
        let bins = Bins::uniform(4, 50);
        let (r, _) = NativeEngine.vsample(&*f, &layout, &bins, &opts(3, 0));
        let truth = f.true_value().unwrap();
        assert!(
            (r.integral - truth).abs() < 5.0 * r.variance.sqrt(),
            "I={} true={truth} sigma={}",
            r.integral,
            r.variance.sqrt()
        );
    }
}
