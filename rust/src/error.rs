//! Library error type. All public APIs return `Result<T, Error>`.

use thiserror::Error;

/// Unified error for the m-Cubes library.
#[derive(Error, Debug)]
pub enum Error {
    /// Malformed or missing artifact manifest / JSON payload.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// JSON syntax error at a byte offset.
    #[error("json parse error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Unknown integrand, artifact, or backend name.
    #[error("unknown {kind}: {name}")]
    Unknown { kind: &'static str, name: String },

    /// Invalid configuration (dimensions, calls, tolerances...).
    #[error("invalid config: {0}")]
    Config(String),

    /// PJRT/XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// The integrator failed to converge within its budget.
    #[error("did not converge: reached {iterations} iterations, rel-err {relerr:.3e} > target {target:.3e}")]
    NotConverged {
        iterations: usize,
        relerr: f64,
        target: f64,
    },

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
