//! Library error type. All public APIs return `Result<T, Error>`.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror` in the offline
//! registry — see `util::mod` on the dependency constraints).

use std::fmt;

/// Unified error for the m-Cubes library.
#[derive(Debug)]
pub enum Error {
    /// Malformed or missing artifact manifest / JSON payload.
    Manifest(String),

    /// JSON syntax error at a byte offset.
    Json { offset: usize, msg: String },

    /// Unknown integrand, artifact, or backend name.
    Unknown { kind: &'static str, name: String },

    /// Invalid configuration (dimensions, calls, tolerances...).
    Config(String),

    /// PJRT/XLA runtime failure (or the runtime not being compiled in).
    Runtime(String),

    /// The integrator failed to converge within its budget.
    NotConverged {
        iterations: usize,
        relerr: f64,
        target: f64,
    },

    /// Underlying I/O failure.
    Io(std::io::Error),

    /// Persistent-store failure (durable checkpoint/result store) —
    /// see `crate::store::StoreError` for the typed detail.
    Store(crate::store::StoreError),

    /// Sharded-execution failure: a shard worker went missing, timed
    /// out past its retry budget, or returned an inconsistent report.
    Shard(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Manifest(msg) => write!(f, "manifest error: {msg}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Unknown { kind, name } => write!(f, "unknown {kind}: {name}"),
            Error::Config(msg) => write!(f, "invalid config: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::NotConverged {
                iterations,
                relerr,
                target,
            } => write!(
                f,
                "did not converge: reached {iterations} iterations, \
                 rel-err {relerr:.3e} > target {target:.3e}"
            ),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Store(e) => write!(f, "store error: {e}"),
            Error::Shard(msg) => write!(f, "shard error: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Store(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        assert_eq!(
            Error::Config("bad".into()).to_string(),
            "invalid config: bad"
        );
        assert_eq!(
            Error::Unknown {
                kind: "integrand",
                name: "nope".into()
            }
            .to_string(),
            "unknown integrand: nope"
        );
        let e = Error::Json {
            offset: 7,
            msg: "oops".into(),
        };
        assert!(e.to_string().contains("byte 7"));
        assert_eq!(
            Error::Shard("worker 3 missing".into()).to_string(),
            "shard error: worker 3 missing"
        );
    }

    #[test]
    fn io_conversion_keeps_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
