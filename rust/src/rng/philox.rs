//! Philox4x32-10 (Random123 / curand family).
//!
//! Counter layout must match `python/compile/philox.py`:
//!   ctr = (sample_idx, draw_block, iteration, CTR_MAGIC)
//!   key = (seed, KEY_MAGIC)
//! Each call yields 4 words; a d-dimensional sample consumes
//! ceil(d/4) calls. Word w of block j is dimension 4*j + w.

const M0: u32 = 0xD251_1F53;
const M1: u32 = 0xCD9E_8D57;
const W0: u32 = 0x9E37_79B9;
const W1: u32 = 0xBB67_AE85;

/// Domain-separation constant in counter word 3 ("mCUB").
pub const CTR_MAGIC: u32 = 0x6D43_5542;
/// Key word 1 constant ("mcub").
pub const KEY_MAGIC: u32 = 0x6D63_7562;

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

/// One Philox4x32-10 block: 10 rounds, round-then-bump key schedule.
#[inline(always)]
pub fn philox4x32(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    let [mut c0, mut c1, mut c2, mut c3] = ctr;
    let [mut k0, mut k1] = key;
    for _ in 0..10 {
        let (hi0, lo0) = mulhilo(c0, M0);
        let (hi1, lo1) = mulhilo(c2, M1);
        let n0 = hi1 ^ c1 ^ k0;
        let n1 = lo1;
        let n2 = hi0 ^ c3 ^ k1;
        let n3 = lo0;
        c0 = n0;
        c1 = n1;
        c2 = n2;
        c3 = n3;
        k0 = k0.wrapping_add(W0);
        k1 = k1.wrapping_add(W1);
    }
    [c0, c1, c2, c3]
}

/// u32 -> double in the open interval (0,1); matches
/// `philox.u32_to_unit_f64`.
#[inline(always)]
pub fn u32_to_unit_f64(u: u32) -> f64 {
    (u as f64 + 0.5) * (1.0 / 4294967296.0)
}

/// The uniform for (sample, iteration, seed, dim) — identical to word
/// `dim % 4` of Philox block `dim / 4` in the Python sampler.
#[inline]
pub fn uniform_for(sample_idx: u32, iteration: u32, seed: u32, dim: usize) -> f64 {
    let block = (dim / 4) as u32;
    let word = dim % 4;
    let out = philox4x32(
        [sample_idx, block, iteration, CTR_MAGIC],
        [seed, KEY_MAGIC],
    );
    u32_to_unit_f64(out[word])
}

/// Fill `out[0..d]` with the d uniforms of one sample. Amortizes the
/// Philox call over 4 dims — this is the engine hot path.
#[inline]
pub fn uniforms_into(sample_idx: u32, iteration: u32, seed: u32, out: &mut [f64]) {
    let d = out.len();
    let mut j = 0u32;
    let mut i = 0usize;
    while i < d {
        let blk = philox4x32(
            [sample_idx, j, iteration, CTR_MAGIC],
            [seed, KEY_MAGIC],
        );
        let n = (d - i).min(4);
        for w in 0..n {
            out[i + w] = u32_to_unit_f64(blk[w]);
        }
        i += n;
        j += 1;
    }
}

/// Convenience stateful view over the counter space for one
/// (seed, iteration): mirrors how the kernel walks samples.
pub struct PhiloxStream {
    pub seed: u32,
    pub iteration: u32,
}

impl PhiloxStream {
    pub fn new(seed: u32, iteration: u32) -> Self {
        Self { seed, iteration }
    }

    /// Uniforms for global sample index `s` into `out`.
    #[inline]
    pub fn sample(&self, s: u32, out: &mut [f64]) {
        uniforms_into(s, self.iteration, self.seed, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Random123 published known-answer vectors for philox4x32-10.
    #[test]
    fn kat_zeros() {
        let r = philox4x32([0; 4], [0; 2]);
        assert_eq!(r, [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]);
    }

    #[test]
    fn kat_ones_complement() {
        let r = philox4x32([u32::MAX; 4], [u32::MAX; 2]);
        assert_eq!(r, [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]);
    }

    #[test]
    fn unit_interval_open() {
        assert!(u32_to_unit_f64(0) > 0.0);
        assert!(u32_to_unit_f64(u32::MAX) < 1.0);
    }

    #[test]
    fn uniforms_into_matches_uniform_for() {
        let mut buf = [0.0; 7];
        uniforms_into(12345, 3, 42, &mut buf);
        for (dim, &v) in buf.iter().enumerate() {
            assert_eq!(v, uniform_for(12345, 3, 42, dim));
        }
    }

    #[test]
    fn mean_and_variance() {
        let mut sum = 0.0;
        let mut sq = 0.0;
        let n = 100_000u32;
        let mut buf = [0.0; 2];
        for s in 0..n {
            uniforms_into(s, 0, 7, &mut buf);
            for &v in &buf {
                sum += v;
                sq += v * v;
            }
        }
        let cnt = (n * 2) as f64;
        let mean = sum / cnt;
        let var = sq / cnt - mean * mean;
        assert!((mean - 0.5).abs() < 2e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 2e-3, "var {var}");
    }

    #[test]
    fn streams_decorrelate() {
        let mut a = [0.0; 4];
        let mut b = [0.0; 4];
        uniforms_into(9, 0, 1, &mut a);
        uniforms_into(9, 1, 1, &mut b);
        assert_ne!(a, b);
        uniforms_into(9, 0, 2, &mut b);
        assert_ne!(a, b);
    }
}
