//! Philox4x32-10 (Random123 / curand family).
//!
//! Counter layout:
//!   ctr = (sample_lo, draw_block | sample_hi << BLOCK_BITS, iteration, CTR_MAGIC)
//!   key = (seed, KEY_MAGIC)
//! Each call yields 4 words; a d-dimensional sample consumes
//! ceil(d/4) calls. Word w of block j is dimension 4*j + w.
//! For sample indices below 2^32 (`sample_hi == 0`) this is exactly
//! the layout of `python/compile/philox.py`, whose device-side indices
//! are uint32 — the registry caps PJRT artifacts at 2^32 calls, so the
//! kernel and this module agree on every counter either can draw.
//!
//! ## 64-bit sample indices
//!
//! The sample index is 64-bit, split across the first two counter
//! words: word 0 carries bits 0..32, and bits 32..56 sit above the
//! draw-block byte in word 1 (see [`BLOCK_BITS`]). For indices below
//! 2^32 the high part is zero and the counter is identical to the
//! original 32-bit layout, so every existing seed reproduces its
//! historical stream exactly; above 2^32 the stream *continues* instead
//! of silently truncating back to sample 0 (the bug this layout fixes —
//! GPU-scale runs in the cuVegas / ZMCintegral regime routinely exceed
//! 2^32 calls per iteration). The packing addresses up to
//! [`MAX_SAMPLE_INDEX`] samples and 2^[`BLOCK_BITS`] draw blocks
//! (d <= 1024).

// The u64→u32 word splits below are the counter layout itself — every
// one is deliberate and audited by `cargo xtask lint` (MC001); see
// docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

pub(super) const M0: u32 = 0xD251_1F53;
pub(super) const M1: u32 = 0xCD9E_8D57;
pub(super) const W0: u32 = 0x9E37_79B9;
pub(super) const W1: u32 = 0xBB67_AE85;

/// Domain-separation constant in counter word 3 ("mCUB").
pub const CTR_MAGIC: u32 = 0x6D43_5542;
/// Key word 1 constant ("mcub").
pub const KEY_MAGIC: u32 = 0x6D63_7562;

/// Bits of counter word 1 reserved for the draw-block index (so
/// d <= 4 * 2^BLOCK_BITS = 1024); bits 32..56 of the sample index are
/// packed above them.
pub const BLOCK_BITS: u32 = 8;

/// One past the largest addressable sample index (2^56): 32 bits in
/// counter word 0 plus the 24 bits of word 1 above the draw-block byte.
/// `strat::Layout::validate` rejects layouts whose total calls exceed
/// this, so the engine can never wrap a counter stream.
pub const MAX_SAMPLE_INDEX: u64 = 1 << (32 + 32 - BLOCK_BITS);

/// Pack a 64-bit sample index and a draw-block index into counter
/// words 0 and 1. For `sample_idx < 2^32` this is exactly the legacy
/// `(sample_idx as u32, block)` layout.
#[inline(always)]
pub(crate) fn ctr_words(sample_idx: u64, block: u32) -> (u32, u32) {
    debug_assert!(
        block < (1 << BLOCK_BITS),
        "draw block {block} overflows the counter packing (d > {})",
        4 << BLOCK_BITS
    );
    debug_assert!(
        sample_idx < MAX_SAMPLE_INDEX,
        "sample index {sample_idx} exceeds the 2^56 counter capacity"
    );
    (
        sample_idx as u32, // lint:allow(MC001, deliberate split — low 32 bits of the 64-bit sample index go to counter word 0)
        block | (((sample_idx >> 32) as u32) << BLOCK_BITS), // lint:allow(MC001, deliberate split — bits 32..56 packed above the draw-block byte; capacity asserted above)
    )
}

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

/// One Philox4x32-10 block: 10 rounds, round-then-bump key schedule.
#[inline(always)]
pub fn philox4x32(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    let [mut c0, mut c1, mut c2, mut c3] = ctr;
    let [mut k0, mut k1] = key;
    for _ in 0..10 {
        let (hi0, lo0) = mulhilo(c0, M0);
        let (hi1, lo1) = mulhilo(c2, M1);
        let n0 = hi1 ^ c1 ^ k0;
        let n1 = lo1;
        let n2 = hi0 ^ c3 ^ k1;
        let n3 = lo0;
        c0 = n0;
        c1 = n1;
        c2 = n2;
        c3 = n3;
        k0 = k0.wrapping_add(W0);
        k1 = k1.wrapping_add(W1);
    }
    [c0, c1, c2, c3]
}

/// u32 -> double in the open interval (0,1); matches
/// `philox.u32_to_unit_f64`.
#[inline(always)]
pub fn u32_to_unit_f64(u: u32) -> f64 {
    (u as f64 + 0.5) * (1.0 / 4294967296.0)
}

/// Hard cap on dimensions per sample: the draw-block index lives in
/// the low [`BLOCK_BITS`] bits of counter word 1, so a larger `d`
/// would collide with the packed sample-index high bits. Enforced
/// with a real assert at the public entry points — silent stream
/// corruption is exactly what this module exists to rule out.
pub const MAX_UNIFORM_DIMS: usize = 4 << BLOCK_BITS;

/// The uniform for (sample, iteration, seed, dim) — identical to word
/// `dim % 4` of Philox block `dim / 4` in the Python sampler.
#[inline]
pub fn uniform_for(sample_idx: u64, iteration: u32, seed: u32, dim: usize) -> f64 {
    assert!(dim < MAX_UNIFORM_DIMS, "dim {dim} >= {MAX_UNIFORM_DIMS}");
    let block = (dim / 4) as u32;
    let word = dim % 4;
    let (w0, w1) = ctr_words(sample_idx, block);
    let out = philox4x32([w0, w1, iteration, CTR_MAGIC], [seed, KEY_MAGIC]);
    u32_to_unit_f64(out[word])
}

/// Fill `out[0..d]` with the d uniforms of one sample. Amortizes the
/// Philox call over 4 dims — this is the engine hot path (the
/// lane-parallel twin is [`crate::rng::philox_simd::uniforms_lanes`]).
#[inline]
pub fn uniforms_into(sample_idx: u64, iteration: u32, seed: u32, out: &mut [f64]) {
    let d = out.len();
    assert!(d <= MAX_UNIFORM_DIMS, "d = {d} > {MAX_UNIFORM_DIMS} dims per sample");
    let mut j = 0u32;
    let mut i = 0usize;
    while i < d {
        let (w0, w1) = ctr_words(sample_idx, j);
        let blk = philox4x32([w0, w1, iteration, CTR_MAGIC], [seed, KEY_MAGIC]);
        let n = (d - i).min(4);
        for w in 0..n {
            out[i + w] = u32_to_unit_f64(blk[w]);
        }
        i += n;
        j += 1;
    }
}

/// Convenience stateful view over the counter space for one
/// (seed, iteration): mirrors how the kernel walks samples.
pub struct PhiloxStream {
    pub seed: u32,
    pub iteration: u32,
}

impl PhiloxStream {
    pub fn new(seed: u32, iteration: u32) -> Self {
        Self { seed, iteration }
    }

    /// Uniforms for global sample index `s` into `out`.
    #[inline]
    pub fn sample(&self, s: u64, out: &mut [f64]) {
        uniforms_into(s, self.iteration, self.seed, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Random123 published known-answer vectors for philox4x32-10.
    #[test]
    fn kat_zeros() {
        let r = philox4x32([0; 4], [0; 2]);
        assert_eq!(r, [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]);
    }

    #[test]
    fn kat_ones_complement() {
        let r = philox4x32([u32::MAX; 4], [u32::MAX; 2]);
        assert_eq!(r, [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]);
    }

    #[test]
    fn unit_interval_open() {
        assert!(u32_to_unit_f64(0) > 0.0);
        assert!(u32_to_unit_f64(u32::MAX) < 1.0);
    }

    #[test]
    fn uniforms_into_matches_uniform_for() {
        let mut buf = [0.0; 7];
        uniforms_into(12345, 3, 42, &mut buf);
        for (dim, &v) in buf.iter().enumerate() {
            assert_eq!(v, uniform_for(12345, 3, 42, dim));
        }
    }

    /// Below 2^32 the counter is exactly the legacy 32-bit layout —
    /// every pre-widening seed reproduces its historical stream.
    #[test]
    fn low_indices_reproduce_legacy_counter_layout() {
        for s in [0u64, 1, 12345, u32::MAX as u64] {
            for dim in 0..8usize {
                let legacy = philox4x32(
                    [s as u32, (dim / 4) as u32, 7, CTR_MAGIC],
                    [99, KEY_MAGIC],
                );
                let got = uniform_for(s, 7, 99, dim);
                assert_eq!(got, u32_to_unit_f64(legacy[dim % 4]), "s={s} dim={dim}");
            }
        }
    }

    /// Regression for the sample-counter truncation bug: indices that
    /// collide mod 2^32 must draw *different* uniforms (the old `as
    /// u32` pipeline made sample 2^32 + k replay sample k's stream).
    #[test]
    fn high_word_extends_the_stream() {
        let mut lo = [0.0; 6];
        let mut hi = [0.0; 6];
        for k in [0u64, 5, 4096] {
            uniforms_into(k, 0, 42, &mut lo);
            uniforms_into((1u64 << 32) + k, 0, 42, &mut hi);
            assert_ne!(lo, hi, "k={k}: high sample word was dropped");
        }
        // And the packing really lands in counter word 1 above the
        // draw-block byte.
        let (w0, w1) = ctr_words((3u64 << 32) | 9, 2);
        assert_eq!(w0, 9);
        assert_eq!(w1, 2 | (3 << BLOCK_BITS));
        assert_eq!(ctr_words(7, 1), (7, 1));
    }

    #[test]
    fn mean_and_variance() {
        let mut sum = 0.0;
        let mut sq = 0.0;
        let n = 100_000u32;
        let mut buf = [0.0; 2];
        for s in 0..n {
            uniforms_into(s as u64, 0, 7, &mut buf);
            for &v in &buf {
                sum += v;
                sq += v * v;
            }
        }
        let cnt = (n * 2) as f64;
        let mean = sum / cnt;
        let var = sq / cnt - mean * mean;
        assert!((mean - 0.5).abs() < 2e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 2e-3, "var {var}");
    }

    #[test]
    fn streams_decorrelate() {
        let mut a = [0.0; 4];
        let mut b = [0.0; 4];
        uniforms_into(9, 0, 1, &mut a);
        uniforms_into(9, 1, 1, &mut b);
        assert_ne!(a, b);
        uniforms_into(9, 0, 2, &mut b);
        assert_ne!(a, b);
    }
}
