//! Counter-based RNG — Philox4x32-10, bit-identical to the Python/Pallas
//! implementation (`python/compile/philox.py`).
//!
//! Every backend (Pallas kernel via PJRT, native Rust engine, serial
//! baselines) draws the *same* uniform for (seed, iteration, sample,
//! dim), which is what makes the cross-layer equivalence tests possible
//! and keeps results reproducible across backends.
//!
//! Sample indices are 64-bit — split across two counter words, low
//! word first, with the high bits packed above the draw-block byte
//! (see [`BLOCK_BITS`] / [`MAX_SAMPLE_INDEX`]) — and [`philox_simd`]
//! carries the lane-parallel implementation the engine's SIMD fill
//! path uses; both are bitwise identical to the scalar 32-bit-era
//! stream for indices below 2^32.

mod philox;
pub mod philox_simd;

pub use philox::{
    philox4x32, u32_to_unit_f64, uniform_for, uniforms_into, PhiloxStream, BLOCK_BITS,
    CTR_MAGIC, KEY_MAGIC, MAX_SAMPLE_INDEX, MAX_UNIFORM_DIMS,
};
