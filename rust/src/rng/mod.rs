//! Counter-based RNG — Philox4x32-10, bit-identical to the Python/Pallas
//! implementation (`python/compile/philox.py`).
//!
//! Every backend (Pallas kernel via PJRT, native Rust engine, serial
//! baselines) draws the *same* uniform for (seed, iteration, sample,
//! dim), which is what makes the cross-layer equivalence tests possible
//! and keeps results reproducible across backends.

mod philox;

pub use philox::{philox4x32, uniform_for, uniforms_into, PhiloxStream, CTR_MAGIC, KEY_MAGIC};
