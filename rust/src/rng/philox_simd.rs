//! Lane-parallel Philox4x32-10 — the RNG half of the SIMD sampling
//! core (`engine::simd` owns the transform half).
//!
//! [`philox4x32_lanes`] runs `L` independent Philox blocks with every
//! round expressed over `[u32; L]` arrays in structure-of-arrays form.
//! The per-lane loop bodies are branch-free integer ops on fixed-size
//! arrays — exactly the shape LLVM's autovectorizer lowers to SSE2 /
//! AVX2 (the 32x32→64 `mulhilo` pair becomes `vpmuludq`). There are no
//! intrinsics and no unsafe: the same source compiles on every target
//! and simply gets wider with `-C target-cpu=native`.
//!
//! ## Lane width dispatch
//!
//! [`LANES`] is 8 when the crate is compiled with AVX2 available
//! (`cfg(target_feature = "avx2")`, e.g. via `-C target-cpu=native`)
//! and 4 otherwise (one SSE2 register of u32s — the x86_64 baseline).
//! Because each lane computes *exactly* the scalar [`philox4x32`]
//! function, results are bitwise identical for any lane width — the
//! width only changes throughput, never a single output bit. That is
//! the foundation of the engine's SIMD determinism contract
//! (docs/sampling.md).

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::philox::{
    ctr_words, u32_to_unit_f64, CTR_MAGIC, KEY_MAGIC, M0, M1, MAX_UNIFORM_DIMS, W0, W1,
};

/// Lane width the engine's fill path instantiates: 8 under AVX2, 4
/// otherwise. Purely a throughput knob — see the module docs.
#[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
pub const LANES: usize = 8;
/// Lane width the engine's fill path instantiates: 8 under AVX2, 4
/// otherwise. Purely a throughput knob — see the module docs.
#[cfg(not(all(target_arch = "x86_64", target_feature = "avx2")))]
pub const LANES: usize = 4;

/// `L` independent Philox4x32-10 blocks, counters in lane-major SoA
/// form: `ctr[w][l]` is counter word `w` of lane `l`. Every lane
/// produces exactly `philox4x32([ctr[0][l], .., ctr[3][l]], key)`.
#[inline]
pub fn philox4x32_lanes<const L: usize>(ctr: &[[u32; L]; 4], key: [u32; 2]) -> [[u32; L]; 4] {
    let [mut c0, mut c1, mut c2, mut c3] = *ctr;
    let [mut k0, mut k1] = key;
    for _ in 0..10 {
        let mut n0 = [0u32; L];
        let mut n1 = [0u32; L];
        let mut n2 = [0u32; L];
        let mut n3 = [0u32; L];
        for l in 0..L {
            let p0 = (c0[l] as u64) * (M0 as u64);
            let p1 = (c2[l] as u64) * (M1 as u64);
            n0[l] = ((p1 >> 32) as u32) ^ c1[l] ^ k0;
            n1[l] = p1 as u32;
            n2[l] = ((p0 >> 32) as u32) ^ c3[l] ^ k1;
            n3[l] = p0 as u32;
        }
        c0 = n0;
        c1 = n1;
        c2 = n2;
        c3 = n3;
        k0 = k0.wrapping_add(W0);
        k1 = k1.wrapping_add(W1);
    }
    [c0, c1, c2, c3]
}

/// Fill `out[dim][lane]` with the `out.len()` uniforms of the `L`
/// consecutive sample indices `base .. base + L` — the lane-parallel
/// twin of [`crate::rng::uniforms_into`], bitwise identical per lane
/// (same counters, same conversion).
#[inline]
pub fn uniforms_lanes<const L: usize>(base: u64, iteration: u32, seed: u32, out: &mut [[f64; L]]) {
    let d = out.len();
    assert!(
        d <= MAX_UNIFORM_DIMS,
        "d = {d} > {MAX_UNIFORM_DIMS} dims per sample"
    );
    let key = [seed, KEY_MAGIC];
    // Counter words 0/1 per lane; only the draw-block byte of word 1
    // changes across blocks, so pack the sample words once.
    let mut w0 = [0u32; L];
    let mut w1base = [0u32; L];
    for (l, (a, b)) in w0.iter_mut().zip(w1base.iter_mut()).enumerate() {
        let (lo, hi) = ctr_words(base + l as u64, 0);
        *a = lo;
        *b = hi;
    }
    let mut ctr = [[0u32; L]; 4];
    ctr[0] = w0;
    ctr[2] = [iteration; L];
    ctr[3] = [CTR_MAGIC; L];
    let mut j = 0u32;
    let mut i = 0usize;
    while i < d {
        for l in 0..L {
            ctr[1][l] = w1base[l] | j;
        }
        let blk = philox4x32_lanes(&ctr, key);
        let n = (d - i).min(4);
        for (w, words) in blk.iter().enumerate().take(n) {
            for l in 0..L {
                out[i + w][l] = u32_to_unit_f64(words[l]);
            }
        }
        i += n;
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{philox4x32, uniforms_into};

    /// Random123 known-answer vectors, every lane at once.
    #[test]
    fn lanes_reproduce_scalar_kats() {
        let zeros = philox4x32_lanes::<4>(&[[0; 4]; 4], [0, 0]);
        let ones = philox4x32_lanes::<8>(&[[u32::MAX; 8]; 4], [u32::MAX; 2]);
        for l in 0..4 {
            assert_eq!(
                [zeros[0][l], zeros[1][l], zeros[2][l], zeros[3][l]],
                [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]
            );
        }
        for l in 0..8 {
            assert_eq!(
                [ones[0][l], ones[1][l], ones[2][l], ones[3][l]],
                [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]
            );
        }
    }

    /// Distinct per-lane counters: each lane equals the scalar block.
    #[test]
    fn lanes_match_scalar_per_lane() {
        let mut ctr = [[0u32; LANES]; 4];
        for l in 0..LANES {
            ctr[0][l] = 1000 + l as u32;
            ctr[1][l] = l as u32;
            ctr[2][l] = 7;
            ctr[3][l] = CTR_MAGIC;
        }
        let out = philox4x32_lanes(&ctr, [42, KEY_MAGIC]);
        for l in 0..LANES {
            let scalar = philox4x32(
                [ctr[0][l], ctr[1][l], ctr[2][l], ctr[3][l]],
                [42, KEY_MAGIC],
            );
            for w in 0..4 {
                assert_eq!(out[w][l], scalar[w], "lane {l} word {w}");
            }
        }
    }

    /// uniforms_lanes == uniforms_into per lane, including across the
    /// 2^32 sample-index boundary and partial trailing Philox blocks.
    #[test]
    fn uniform_lanes_match_scalar_across_boundary() {
        for base in [0u64, 3, u32::MAX as u64 - 2, (1u64 << 32) - 2, (1u64 << 40) + 5] {
            for d in [1usize, 4, 7, 16] {
                let mut lanes = vec![[0.0f64; LANES]; d];
                uniforms_lanes::<LANES>(base, 9, 77, &mut lanes);
                let mut buf = vec![0.0f64; d];
                for l in 0..LANES {
                    uniforms_into(base + l as u64, 9, 77, &mut buf);
                    for dim in 0..d {
                        assert_eq!(
                            lanes[dim][l].to_bits(),
                            buf[dim].to_bits(),
                            "base={base} d={d} lane={l} dim={dim}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lane_width_is_a_supported_value() {
        let lanes = LANES;
        assert!(lanes == 4 || lanes == 8, "unexpected lane width {lanes}");
    }
}
