//! Iteration-result accumulation: Lepage's weighted estimates (eq. 5/6
//! of [11]), chi-square consistency, and convergence policy
//! (Algorithm 2 lines 11/13, "Weighted-Estimates" / "Check-Convergence").

/// Result of a single V-Sample pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterationResult {
    /// Integral estimate of this iteration.
    pub integral: f64,
    /// Variance of that estimate (sigma^2, not sigma).
    pub variance: f64,
}

/// Weighted combination of iteration results.
///
/// Iterations are weighted by inverse variance; `chi2_dof` measures
/// whether the per-iteration estimates are mutually consistent (VEGAS
/// results are only trustworthy when chi2/dof is O(1) — the paper's
/// §5.1 discussion).
#[derive(Debug, Clone, Default)]
pub struct WeightedEstimator {
    sum_w: f64,     // sum of 1/sigma_j^2
    sum_wi: f64,    // sum of I_j/sigma_j^2
    sum_wi2: f64,   // sum of I_j^2/sigma_j^2
    n: usize,
}

/// The estimator's complete accumulated state — the serializable
/// currency of `api::Checkpoint`. Exporting with
/// [`WeightedEstimator::state`] and restoring with
/// [`WeightedEstimator::from_state`] round-trips bitwise, so a
/// suspended run resumes with the exact weighted combination it left
/// off with.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct EstimatorState {
    /// Sum of inverse variances `1/sigma_j^2`.
    pub sum_w: f64,
    /// Sum of `I_j/sigma_j^2`.
    pub sum_wi: f64,
    /// Sum of `I_j^2/sigma_j^2`.
    pub sum_wi2: f64,
    /// Number of iterations folded in.
    pub n: usize,
}

impl EstimatorState {
    /// Check the sums are finite and the shape is plausible (an empty
    /// estimator has all-zero sums).
    pub fn validate(&self) -> crate::error::Result<()> {
        if !self.sum_w.is_finite() || !self.sum_wi.is_finite() || !self.sum_wi2.is_finite() {
            return Err(crate::error::Error::Config(format!(
                "estimator state must be finite, got sums ({}, {}, {})",
                self.sum_w, self.sum_wi, self.sum_wi2
            )));
        }
        if self.sum_w < 0.0 || self.sum_wi2 < 0.0 {
            return Err(crate::error::Error::Config(format!(
                "estimator weight sums must be >= 0, got ({}, {})",
                self.sum_w, self.sum_wi2
            )));
        }
        if self.n == 0 && (self.sum_w != 0.0 || self.sum_wi != 0.0 || self.sum_wi2 != 0.0) {
            return Err(crate::error::Error::Config(
                "estimator state claims 0 iterations but carries non-zero sums".into(),
            ));
        }
        Ok(())
    }

    /// Fold another state's sums into this one, component-wise and in
    /// place — the merge primitive the shard coordinator builds on.
    ///
    /// Merging is exact in a precisely scoped sense (property-tested in
    /// this module): folding *singleton* states (one push each) into an
    /// empty state in iteration order performs the identical sequence
    /// of additions as pushing the iterations sequentially, so the
    /// result is bitwise equal. Merging the empty state is a bitwise
    /// no-op. General regrouping of multi-iteration states is NOT
    /// claimed to be bitwise-neutral (f64 addition does not
    /// re-associate); the coordinator therefore always merges in the
    /// fixed task order, never in arrival order.
    pub fn merge(&mut self, other: &EstimatorState) {
        self.sum_w += other.sum_w;
        self.sum_wi += other.sum_wi;
        self.sum_wi2 += other.sum_wi2;
        self.n += other.n;
    }
}

/// Floor for variances to keep weights finite when an iteration
/// happens to sample an exactly-constant region.
const VAR_FLOOR: f64 = 1e-300;

impl WeightedEstimator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold in one iteration.
    pub fn push(&mut self, r: IterationResult) {
        let var = r.variance.max(VAR_FLOOR);
        let w = 1.0 / var;
        self.sum_w += w;
        self.sum_wi += w * r.integral;
        self.sum_wi2 += w * r.integral * r.integral;
        self.n += 1;
    }

    /// Number of iterations folded in.
    pub fn iterations(&self) -> usize {
        self.n
    }

    /// Combined integral estimate (undefined before the first push).
    pub fn integral(&self) -> f64 {
        if self.sum_w > 0.0 {
            self.sum_wi / self.sum_w
        } else {
            0.0
        }
    }

    /// Standard deviation of the combined estimate.
    pub fn sigma(&self) -> f64 {
        if self.sum_w > 0.0 {
            (1.0 / self.sum_w).sqrt()
        } else {
            f64::INFINITY
        }
    }

    /// chi^2 per degree of freedom across iterations.
    pub fn chi2_dof(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let ibar = self.integral();
        // sum w_j (I_j - Ibar)^2 = sum w I^2 - Ibar * sum w I
        let chi2 = (self.sum_wi2 - ibar * self.sum_wi).max(0.0);
        chi2 / (self.n - 1) as f64
    }

    /// Achieved relative error |sigma / integral|.
    pub fn rel_err(&self) -> f64 {
        let i = self.integral();
        if i == 0.0 {
            f64::INFINITY
        } else {
            (self.sigma() / i).abs()
        }
    }

    /// Reset (used when the adjust phase ends and the caller chooses to
    /// discard warm-up iterations, or when chi2 signals inconsistency).
    pub fn reset(&mut self) {
        *self = Self::default();
    }

    /// Export the complete accumulated state (for checkpoints).
    pub fn state(&self) -> EstimatorState {
        EstimatorState {
            sum_w: self.sum_w,
            sum_wi: self.sum_wi,
            sum_wi2: self.sum_wi2,
            n: self.n,
        }
    }

    /// Rebuild an estimator from exported state, bitwise.
    pub fn from_state(s: EstimatorState) -> WeightedEstimator {
        WeightedEstimator {
            sum_w: s.sum_w,
            sum_wi: s.sum_wi,
            sum_wi2: s.sum_wi2,
            n: s.n,
        }
    }
}

/// Convergence policy: relative-error target plus chi-square guard.
#[derive(Debug, Clone, Copy)]
pub struct Convergence {
    /// Target relative error tau_rel.
    pub tau_rel: f64,
    /// Require at least this many folded iterations before claiming
    /// convergence (statistical sanity; default 2).
    pub min_iterations: usize,
    /// Reject convergence while chi2/dof exceeds this (default 5.0).
    pub max_chi2_dof: f64,
}

impl Default for Convergence {
    fn default() -> Self {
        Convergence {
            tau_rel: 1e-3,
            min_iterations: 2,
            max_chi2_dof: 5.0,
        }
    }
}

impl Convergence {
    pub fn with_tau(tau_rel: f64) -> Self {
        Convergence {
            tau_rel,
            ..Default::default()
        }
    }

    /// Has the estimator met this policy?
    pub fn satisfied(&self, est: &WeightedEstimator) -> bool {
        est.iterations() >= self.min_iterations
            && est.rel_err() <= self.tau_rel
            && est.chi2_dof() <= self.max_chi2_dof
    }
}

/// The paper's precision ladder (§5.1): start at 1e-3, divide by 5
/// until passing 1e-9. `digits` is -log10(tau).
pub fn precision_ladder() -> Vec<f64> {
    let mut taus = Vec::new();
    let mut tau = 1e-3;
    while tau >= 1e-9 {
        taus.push(tau);
        tau /= 5.0;
    }
    taus
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(i: f64, v: f64) -> IterationResult {
        IterationResult {
            integral: i,
            variance: v,
        }
    }

    #[test]
    fn single_iteration_passthrough() {
        let mut e = WeightedEstimator::new();
        e.push(r(2.5, 0.04));
        assert_eq!(e.integral(), 2.5);
        assert!((e.sigma() - 0.2).abs() < 1e-15);
        assert_eq!(e.chi2_dof(), 0.0);
    }

    #[test]
    fn equal_variance_is_mean() {
        let mut e = WeightedEstimator::new();
        e.push(r(1.0, 1.0));
        e.push(r(3.0, 1.0));
        assert!((e.integral() - 2.0).abs() < 1e-15);
        // combined sigma = sqrt(1/2)
        assert!((e.sigma() - (0.5f64).sqrt()).abs() < 1e-15);
        // chi2 = (1-2)^2 + (3-2)^2 = 2, dof = 1
        assert!((e.chi2_dof() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn precision_weighted() {
        let mut e = WeightedEstimator::new();
        e.push(r(10.0, 1e-6)); // very precise
        e.push(r(20.0, 1e6)); // junk
        assert!((e.integral() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn consistent_iterations_have_small_chi2() {
        let mut e = WeightedEstimator::new();
        for k in 0..10 {
            // scatter ~ sigma around 5.0
            let noise = ((k * 2654435761u64 % 1000) as f64 / 1000.0 - 0.5) * 0.02;
            e.push(r(5.0 + noise, 1e-4));
        }
        assert!(e.chi2_dof() < 3.0, "chi2/dof = {}", e.chi2_dof());
    }

    #[test]
    fn zero_variance_guard() {
        let mut e = WeightedEstimator::new();
        e.push(r(1.0, 0.0));
        assert!(e.sigma().is_finite());
        assert_eq!(e.integral(), 1.0);
    }

    #[test]
    fn convergence_policy() {
        let conv = Convergence::with_tau(1e-2);
        let mut e = WeightedEstimator::new();
        e.push(r(1.0, 1e-8));
        assert!(!conv.satisfied(&e), "needs min_iterations");
        e.push(r(1.0, 1e-8));
        assert!(conv.satisfied(&e));
    }

    #[test]
    fn convergence_rejects_inconsistent() {
        let conv = Convergence::with_tau(1e-1);
        let mut e = WeightedEstimator::new();
        e.push(r(1.0, 1e-8));
        e.push(r(2.0, 1e-8)); // wildly inconsistent
        assert!(e.rel_err() < 1e-1);
        assert!(!conv.satisfied(&e), "chi2 guard must trip");
    }

    #[test]
    fn ladder_matches_paper() {
        let l = precision_ladder();
        assert_eq!(l[0], 1e-3);
        assert!((l[1] - 2e-4).abs() < 1e-18);
        assert!(*l.last().unwrap() >= 1e-9);
        assert!(l.last().unwrap() / 5.0 < 1e-9);
        // 1e-3 / 5^k >= 1e-9  =>  k = 0..=8
        assert_eq!(l.len(), 9);
    }

    #[test]
    fn reset_clears() {
        let mut e = WeightedEstimator::new();
        e.push(r(1.0, 1.0));
        e.reset();
        assert_eq!(e.iterations(), 0);
        assert_eq!(e.integral(), 0.0);
    }

    #[test]
    fn state_round_trips_bitwise() {
        let mut e = WeightedEstimator::new();
        e.push(r(1.000000000001, 0.3333333333333333));
        e.push(r(-2.5e-7, 1.7e11));
        e.push(r(3.14159, 0.125));
        let s = e.state();
        assert!(s.validate().is_ok());
        let back = WeightedEstimator::from_state(s);
        assert_eq!(back.integral().to_bits(), e.integral().to_bits());
        assert_eq!(back.sigma().to_bits(), e.sigma().to_bits());
        assert_eq!(back.chi2_dof().to_bits(), e.chi2_dof().to_bits());
        assert_eq!(back.iterations(), 3);
        assert_eq!(back.state(), s);
    }

    /// Property: merging the empty state is a bitwise no-op, from
    /// either side.
    #[test]
    fn merge_identity_is_bitwise_exact() {
        let mut e = WeightedEstimator::new();
        e.push(r(1.0 / 3.0, 0.7));
        e.push(r(-2.5e-7, 1.7e11));
        let s = e.state();

        let mut left = s;
        left.merge(&EstimatorState::default());
        assert_eq!(left, s);
        assert_eq!(left.sum_w.to_bits(), s.sum_w.to_bits());
        assert_eq!(left.sum_wi.to_bits(), s.sum_wi.to_bits());
        assert_eq!(left.sum_wi2.to_bits(), s.sum_wi2.to_bits());

        let mut right = EstimatorState::default();
        right.merge(&s);
        assert_eq!(right.sum_w.to_bits(), s.sum_w.to_bits());
        assert_eq!(right.sum_wi.to_bits(), s.sum_wi.to_bits());
        assert_eq!(right.sum_wi2.to_bits(), s.sum_wi2.to_bits());
        assert_eq!(right.n, s.n);
    }

    /// Property: left-folding singleton states over the fixed 64-task
    /// partition order performs the exact addition sequence of
    /// sequential pushes — the coordinator's merge order is
    /// bitwise-neutral relative to the single-worker estimator.
    #[test]
    fn merge_of_ordered_singletons_matches_sequential_pushes_bitwise() {
        // Awkward values: subnormal-adjacent, huge, negative, repeating
        // fractions — anything where re-association would show.
        let iters: Vec<IterationResult> = (0..64)
            .map(|k| {
                let kf = k as f64;
                r(
                    (kf - 31.5) * (1.0 / 3.0) + 1e-13 * kf.sin(),
                    (kf + 1.0).powi(3) * 0.7e-5,
                )
            })
            .collect();

        let mut sequential = WeightedEstimator::new();
        for &it in &iters {
            sequential.push(it);
        }

        let mut merged = EstimatorState::default();
        for &it in &iters {
            let mut single = WeightedEstimator::new();
            single.push(it);
            merged.merge(&single.state());
        }

        let want = sequential.state();
        assert_eq!(merged.sum_w.to_bits(), want.sum_w.to_bits());
        assert_eq!(merged.sum_wi.to_bits(), want.sum_wi.to_bits());
        assert_eq!(merged.sum_wi2.to_bits(), want.sum_wi2.to_bits());
        assert_eq!(merged.n, want.n);
        let back = WeightedEstimator::from_state(merged);
        assert_eq!(back.integral().to_bits(), sequential.integral().to_bits());
        assert_eq!(back.sigma().to_bits(), sequential.sigma().to_bits());
    }

    #[test]
    fn state_validation_rejects_corrupt() {
        let ok = EstimatorState::default();
        assert!(ok.validate().is_ok());
        for bad in [
            EstimatorState {
                sum_w: f64::NAN,
                ..ok
            },
            EstimatorState {
                sum_wi: f64::INFINITY,
                ..ok
            },
            EstimatorState { sum_w: -1.0, n: 1, ..ok },
            EstimatorState { sum_w: 2.0, n: 0, ..ok },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }
}
