//! Experiment reporting: box-plot statistics (Fig. 1), speedup series,
//! and markdown/CSV emission helpers shared by the benches.

use crate::util::benchkit::percentile_sorted;

/// Five-number summary + outliers — what each box in the paper's Fig. 1
/// displays (quartile box, median line, whiskers, outlier points).
#[derive(Debug, Clone)]
pub struct BoxStats {
    pub n: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    /// Points beyond 1.5 IQR whiskers.
    pub outliers: Vec<f64>,
}

impl BoxStats {
    pub fn from_samples(samples: &[f64]) -> BoxStats {
        let mut s: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        s.sort_by(f64::total_cmp);
        if s.is_empty() {
            return BoxStats {
                n: 0,
                min: 0.0,
                q1: 0.0,
                median: 0.0,
                q3: 0.0,
                max: 0.0,
                mean: 0.0,
                outliers: vec![],
            };
        }
        let q1 = percentile_sorted(&s, 25.0);
        let q3 = percentile_sorted(&s, 75.0);
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let outliers = s
            .iter()
            .copied()
            .filter(|&x| x < lo_fence || x > hi_fence)
            .collect();
        BoxStats {
            n: s.len(),
            min: s[0],
            q1,
            median: percentile_sorted(&s, 50.0),
            q3,
            max: s[s.len() - 1],
            mean: s.iter().sum::<f64>() / s.len() as f64,
            outliers,
        }
    }

    /// Whisker ends (min/max of non-outlier points).
    pub fn whiskers(&self) -> (f64, f64) {
        let iqr = self.q3 - self.q1;
        let lo_fence = self.q1 - 1.5 * iqr;
        let hi_fence = self.q3 + 1.5 * iqr;
        (self.min.max(lo_fence), self.max.min(hi_fence))
    }
}

/// One Fig.-1 style cell: requested tolerance vs achieved rel-errors.
#[derive(Debug, Clone)]
pub struct AccuracyCell {
    pub integrand: String,
    pub dim: usize,
    pub tau_rel: f64,
    pub digits: f64,
    pub achieved: BoxStats,
    pub runs_converged: usize,
    pub runs_total: usize,
}

impl AccuracyCell {
    /// Did the median achieved error meet the requested tolerance?
    /// (The paper's criterion: box boundaries encompass the target.)
    pub fn median_meets_target(&self) -> bool {
        self.achieved.median <= self.tau_rel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_number_summary() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let b = BoxStats::from_samples(&s);
        assert_eq!(b.n, 100);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.max, 100.0);
        assert!((b.median - 50.5).abs() < 1e-9);
        assert!((b.q1 - 25.75).abs() < 1e-9);
        assert!((b.q3 - 75.25).abs() < 1e-9);
        assert!(b.outliers.is_empty());
    }

    #[test]
    fn detects_outliers() {
        let mut s: Vec<f64> = vec![1.0; 20];
        for (i, v) in s.iter_mut().enumerate() {
            *v = 1.0 + (i as f64) * 0.01;
        }
        s.push(50.0); // gross outlier
        let b = BoxStats::from_samples(&s);
        assert_eq!(b.outliers.len(), 1);
        assert_eq!(b.outliers[0], 50.0);
        let (_, hi) = b.whiskers();
        assert!(hi < 50.0);
    }

    #[test]
    fn handles_nan_and_empty() {
        let b = BoxStats::from_samples(&[f64::NAN, 1.0, 2.0]);
        assert_eq!(b.n, 2);
        let e = BoxStats::from_samples(&[]);
        assert_eq!(e.n, 0);
    }

    #[test]
    fn accuracy_cell_target() {
        let cell = AccuracyCell {
            integrand: "f4".into(),
            dim: 5,
            tau_rel: 1e-3,
            digits: 3.0,
            achieved: BoxStats::from_samples(&[5e-4, 8e-4, 2e-4]),
            runs_converged: 3,
            runs_total: 3,
        };
        assert!(cell.median_meets_target());
    }
}
