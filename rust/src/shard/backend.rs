//! The sharded execution backend: one [`VSampleBackend`] that splits
//! every iteration across N shard workers and merges their partials
//! back bitwise.
//!
//! Both transports produce the same bytes:
//!
//! * **In-process** (default): the shard spans run on a scoped thread
//!   pool inside this process — one worker per span.
//! * **Spool** ([`ShardedBackend::with_spool`]): spans are scattered
//!   as sealed task files and gathered as sealed reports, so external
//!   `mcubes shard-worker` processes can join; missing or corrupt
//!   reports take the coordinator's straggler path.
//!
//! The backend holds a `Box<dyn Engine>` — the same
//! [`crate::engine::Engine`] impls the single-worker
//! [`crate::coordinator::EngineBackend`] wraps — and routes everything
//! through the trait: shard plans come from [`Engine::allocation`],
//! spans run through [`Engine::sample_tasks`] (the shard entry point),
//! and the merged partials fold back through [`Engine::update`].
//!
//! Determinism: every shard draws its own Philox counter sub-range
//! (disjoint by construction — see [`super::ShardPlan`]), per-task
//! partials are bitwise independent of who computed them, and the
//! merge folds them in global task order. The N-shard result is
//! therefore bitwise equal to the single-worker pass on both sampling
//! modes; `rust/tests/shard_equivalence.rs` pins this.

// lint:allow(MC003, merge-time accounting only — no time value ever feeds the sample stream)
use std::time::Instant;

use super::coordinator::{ReportShape, SpoolTransport};
use super::plan::ShardPlan;
use super::report::ShardTask;
use super::ShardStats;
use crate::api::GridState;
use crate::api::StratSnapshot;
use crate::coordinator::VSampleBackend;
use crate::engine::{
    merge_task_partials, Engine, ExecPath, FillPath, TaskPartial, UniformEngine, VSampleOpts,
    VegasPlusEngine,
};
use crate::error::{Error, Result};
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::{Integrand, IntegrandRef};
use crate::strat::{AllocStats, Bounds, Layout, Sampling};
use crate::util::threadpool::parallel_chunks;

/// Sharded twin of the single-worker [`crate::coordinator::EngineBackend`]:
/// same [`VSampleBackend`] contract, N-worker execution over any
/// [`Engine`].
pub struct ShardedBackend {
    integrand: IntegrandRef,
    layout: Layout,
    shards: usize,
    threads: usize,
    spool: Option<SpoolTransport>,
    /// The engine owns the layout/allocation state; sharding is purely
    /// an execution strategy layered over [`Engine::sample_tasks`].
    engine: Box<dyn Engine>,
    /// Stats snapshot of the allocation the most recent iteration
    /// sampled with (taken before the engine's update re-apportions).
    last: Option<AllocStats>,
    /// Cumulative shard-execution accounting.
    stats: ShardStats,
}

impl ShardedBackend {
    /// Build a sharded backend for `shards` workers. For
    /// [`Sampling::VegasPlus`], `resume` restores a matching-layout
    /// allocation exactly as [`VegasPlusEngine::new`] does.
    pub fn new(
        integrand: IntegrandRef,
        layout: Layout,
        shards: usize,
        threads: usize,
        sampling: Sampling,
        resume: Option<&StratSnapshot>,
    ) -> Result<ShardedBackend> {
        let engine: Box<dyn Engine> = match sampling {
            Sampling::Uniform => Box::new(UniformEngine::new(layout)),
            Sampling::VegasPlus { beta } => Box::new(VegasPlusEngine::new(layout, beta, resume)?),
        };
        Ok(ShardedBackend {
            integrand,
            layout,
            shards,
            threads,
            spool: None,
            engine,
            last: None,
            stats: ShardStats::default(),
        })
    }

    /// Route iterations through a spool directory so external worker
    /// processes can compute spans (chainable).
    #[must_use]
    pub fn with_spool(mut self, spool: SpoolTransport) -> Self {
        self.spool = Some(spool);
        self
    }

    /// The shard plan the next iteration will scatter (pure function
    /// of the layout and the engine's live allocation).
    pub fn plan(&self) -> ShardPlan {
        match self.engine.allocation() {
            Some((counts, offsets)) => {
                ShardPlan::stratified(&self.layout, counts, offsets).shards(self.shards)
            }
            None => ShardPlan::uniform(&self.layout, self.shards),
        }
    }
}

/// In-process fan-out: one scoped worker per span, results in span
/// (= global task) order. Every span runs through the engine's own
/// [`Engine::sample_tasks`] — the same code path as the single-worker
/// pass, so the bytes cannot differ.
fn run_in_process(
    engine: &dyn Engine,
    f: &dyn Integrand,
    plan: &ShardPlan,
    bins: &Bins,
    opts: &VSampleOpts,
) -> Vec<TaskPartial> {
    let spans = plan.spans();
    let per_shard: Vec<Vec<Vec<TaskPartial>>> =
        parallel_chunks(spans.len(), spans.len(), |s0, s1| {
            (s0..s1)
                .map(|s| {
                    engine.sample_tasks(
                        f,
                        bins,
                        opts,
                        FillPath::Simd,
                        ExecPath::default(),
                        spans[s].task_lo,
                        spans[s].task_hi,
                    )
                })
                .collect()
        });
    per_shard.into_iter().flatten().flatten().collect()
}

/// Spool fan-out: scatter sealed tasks, gather sealed reports
/// (straggler policy inside), partials in global task order. The
/// straggler fallback recomputes a span locally through the same
/// [`Engine::sample_tasks`] entry point external workers use.
#[allow(clippy::too_many_arguments)]
fn run_spooled(
    spool: &SpoolTransport,
    engine: &dyn Engine,
    integrand: &IntegrandRef,
    layout: &Layout,
    plan: &ShardPlan,
    bins: &Bins,
    opts: &VSampleOpts,
    stats: &mut ShardStats,
) -> Result<Vec<TaskPartial>> {
    let grid = match engine.export() {
        Some(snap) => GridState::from_bins(bins.clone()).with_strat(snap),
        None => GridState::from_bins(bins.clone()),
    };
    let tasks: Vec<ShardTask> = plan
        .spans()
        .iter()
        .map(|sp| ShardTask {
            integrand: integrand.name().to_string(),
            layout: *layout,
            grid: grid.clone(),
            seed: opts.seed,
            iteration: opts.iteration,
            adjust: opts.adjust,
            shard: sp.shard,
            task_lo: sp.task_lo,
            task_hi: sp.task_hi,
        })
        .collect();
    spool.scatter(&tasks)?;
    let shape = ReportShape {
        contrib_len: if opts.adjust {
            Some(layout.d * layout.nb)
        } else {
            None
        },
        stratified: engine.allocation().is_some(),
    };
    let f: &dyn Integrand = &**integrand;
    let fallback = |sp: &super::plan::ShardSpan| {
        engine.sample_tasks(
            f,
            bins,
            opts,
            FillPath::Simd,
            ExecPath::default(),
            sp.task_lo,
            sp.task_hi,
        )
    };
    let partials = spool.gather(plan, &tasks, layout, opts.iteration, &shape, &fallback, stats)?;
    spool.cleanup(plan, opts.iteration);
    Ok(partials)
}

impl VSampleBackend for ShardedBackend {
    fn layout(&self) -> Layout {
        self.layout
    }

    fn bounds(&self) -> Bounds {
        self.integrand.bounds()
    }

    fn name(&self) -> &'static str {
        if self.engine.allocation().is_some() {
            "native-sharded-vegas+"
        } else {
            "native-sharded"
        }
    }

    fn run(
        &mut self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)> {
        // Snapshot before the pass: observers see the allocation this
        // iteration sampled with, not the re-apportioned one
        // `Engine::update` leaves behind.
        self.last = self.engine.alloc_stats();
        let plan = self.plan();
        // Give each in-process span worker an equal slice of the
        // thread budget (bitwise-neutral either way).
        let opts = VSampleOpts {
            seed,
            iteration,
            adjust,
            threads: (self.threads / plan.nshards()).max(1),
        };
        // Disjoint field borrows: the span workers read the engine,
        // the spool gatherer accumulates into `stats`.
        let ShardedBackend {
            integrand,
            layout,
            spool,
            engine,
            stats,
            ..
        } = self;
        let partials = match spool {
            Some(spool) => run_spooled(
                spool, &**engine, integrand, layout, &plan, bins, &opts, stats,
            )?,
            None => run_in_process(&**engine, &**integrand, &plan, bins, &opts),
        };
        // The merge refuses to fold anything but the complete,
        // in-order task partition (shard bugs must not become silent
        // numeric drift).
        if partials.len() != plan.ntasks()
            || partials.iter().enumerate().any(|(i, p)| p.task != i)
        {
            return Err(Error::Shard(format!(
                "gathered {} partials for {} tasks (or out of order)",
                partials.len(),
                plan.ntasks()
            )));
        }
        let merge_start = Instant::now();
        let out = merge_task_partials(layout.d, layout.nb, adjust, &partials);
        // Same per-cube absorb stream (global task order) and
        // reallocation as the single-worker engine pass.
        engine.update(&partials);
        stats.merge_ms += merge_start.elapsed().as_secs_f64() * 1e3;
        stats.shards = stats.shards.max(plan.nshards());
        Ok(out)
    }

    fn alloc_stats(&self) -> Option<AllocStats> {
        self.last
    }

    fn strat_export(&self) -> Option<StratSnapshot> {
        self.engine.export()
    }

    fn shard_stats(&self) -> Option<ShardStats> {
        Some(self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineBackend;
    use crate::integrands::by_name;
    use crate::strat::DEFAULT_BETA;

    fn bitwise_eq(a: &(IterationResult, Option<Vec<f64>>), b: &(IterationResult, Option<Vec<f64>>)) {
        assert_eq!(a.0.integral.to_bits(), b.0.integral.to_bits());
        assert_eq!(a.0.variance.to_bits(), b.0.variance.to_bits());
        match (&a.1, &b.1) {
            (Some(x), Some(y)) => {
                assert_eq!(x.len(), y.len());
                for (u, v) in x.iter().zip(y.iter()) {
                    assert_eq!(u.to_bits(), v.to_bits());
                }
            }
            (None, None) => {}
            _ => panic!("contrib presence mismatch"),
        }
    }

    #[test]
    fn sharded_uniform_matches_engine_backend_bitwise() {
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let f = by_name("f4", 4).unwrap();
        let bins = Bins::uniform(4, 16);
        let mut reference = EngineBackend::uniform(f.clone(), layout, 3);
        let mut sharded =
            ShardedBackend::new(f, layout, 8, 4, Sampling::Uniform, None).unwrap();
        for it in 0..3u32 {
            let want = reference.run(&bins, 17, it, true).unwrap();
            let got = sharded.run(&bins, 17, it, true).unwrap();
            bitwise_eq(&got, &want);
        }
        let stats = sharded.shard_stats().unwrap();
        assert_eq!(stats.shards, 8);
        assert_eq!(stats.straggler_retries, 0);
        assert!(sharded.strat_export().is_none());
        assert_eq!(sharded.name(), "native-sharded");
    }

    #[test]
    fn sharded_vegas_plus_matches_engine_backend_bitwise() {
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let f = by_name("f5", 5).unwrap();
        let bins = Bins::uniform(5, 20);
        let mut reference =
            EngineBackend::vegas_plus(f.clone(), layout, 2, DEFAULT_BETA, None).unwrap();
        let mut sharded = ShardedBackend::new(
            f,
            layout,
            8,
            8,
            Sampling::VegasPlus { beta: DEFAULT_BETA },
            None,
        )
        .unwrap();
        // Multiple adaptive iterations: the allocation evolves and the
        // plans diverge from uniform — the merge must still track the
        // single-worker stream bitwise.
        for it in 0..4u32 {
            let want = reference.run(&bins, 99, it, true).unwrap();
            let got = sharded.run(&bins, 99, it, true).unwrap();
            bitwise_eq(&got, &want);
            // Allocation state stays in lockstep, iteration by
            // iteration.
            let (se, re) = (sharded.strat_export().unwrap(), reference.strat_export().unwrap());
            assert_eq!(se.counts, re.counts);
            for (x, y) in se.damped.iter().zip(re.damped.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(
            sharded.alloc_stats().map(|s| s.total),
            reference.alloc_stats().map(|s| s.total)
        );
        assert_eq!(sharded.name(), "native-sharded-vegas+");
    }

    #[test]
    fn shard_count_does_not_change_the_bits() {
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let f = by_name("f2", 4).unwrap();
        let bins = Bins::uniform(4, 10);
        let mut one =
            ShardedBackend::new(f.clone(), layout, 1, 1, Sampling::Uniform, None).unwrap();
        let want = one.run(&bins, 4, 0, false).unwrap();
        for shards in [2, 3, 5, 64, 1000] {
            let mut b =
                ShardedBackend::new(f.clone(), layout, shards, 2, Sampling::Uniform, None)
                    .unwrap();
            let got = b.run(&bins, 4, 0, false).unwrap();
            bitwise_eq(&got, &want);
        }
    }

    #[test]
    fn resume_restores_the_allocation_like_the_engine_backend() {
        let layout = Layout::compute(3, 2048, 12, 1).unwrap();
        let f = by_name("f3", 3).unwrap();
        let bins = Bins::uniform(3, 12);
        // Run two iterations, export, resume both backend kinds.
        let mut donor = ShardedBackend::new(
            f.clone(),
            layout,
            4,
            2,
            Sampling::VegasPlus { beta: 0.5 },
            None,
        )
        .unwrap();
        for it in 0..2u32 {
            donor.run(&bins, 31, it, true).unwrap();
        }
        let snap = donor.strat_export().unwrap();
        let mut resumed_ref =
            EngineBackend::vegas_plus(f.clone(), layout, 2, 0.5, Some(&snap)).unwrap();
        let mut resumed_sharded = ShardedBackend::new(
            f,
            layout,
            4,
            2,
            Sampling::VegasPlus { beta: 0.5 },
            Some(&snap),
        )
        .unwrap();
        let want = resumed_ref.run(&bins, 31, 2, true).unwrap();
        let got = resumed_sharded.run(&bins, 31, 2, true).unwrap();
        bitwise_eq(&got, &want);
    }
}
