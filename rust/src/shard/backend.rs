//! The sharded execution backend: one [`VSampleBackend`] that splits
//! every iteration across N shard workers and merges their partials
//! back bitwise.
//!
//! Both transports produce the same bytes:
//!
//! * **In-process** (default): the shard spans run on a scoped thread
//!   pool inside this process — one worker per span.
//! * **Spool** ([`ShardedBackend::with_spool`]): spans are scattered
//!   as sealed task files and gathered as sealed reports, so external
//!   `mcubes shard-worker` processes can join; missing or corrupt
//!   reports take the coordinator's straggler path.
//!
//! Determinism: every shard draws its own Philox counter sub-range
//! (disjoint by construction — see [`super::ShardPlan`]), per-task
//! partials are bitwise independent of who computed them, and the
//! merge folds them in global task order. The N-shard result is
//! therefore bitwise equal to the single-worker pass on both sampling
//! modes; `rust/tests/shard_equivalence.rs` pins this.

// lint:allow(MC003, merge-time accounting only — no time value ever feeds the sample stream)
use std::time::Instant;

use super::coordinator::{ReportShape, SpoolTransport};
use super::plan::{ShardPlan, ShardSpan};
use super::report::ShardTask;
use super::worker::run_span;
use super::ShardStats;
use crate::api::{GridState, StratSnapshot};
use crate::coordinator::VSampleBackend;
use crate::engine::{merge_task_partials, TaskPartial, VSampleOpts};
use crate::error::{Error, Result};
use crate::estimator::IterationResult;
use crate::grid::Bins;
use crate::integrands::IntegrandRef;
use crate::strat::{AllocStats, Allocation, Bounds, Layout, Sampling};
use crate::util::threadpool::parallel_chunks;
use std::cell::RefCell;

/// Mutable per-run state: the live VEGAS+ allocation (when adaptive),
/// the stats snapshot of the iteration that just ran, and the
/// cumulative shard accounting.
struct ShardCell {
    alloc: Option<Allocation>,
    last: Option<AllocStats>,
    stats: ShardStats,
}

/// Sharded twin of `NativeBackend`/`StratifiedBackend`: same
/// [`VSampleBackend`] contract, N-worker execution.
pub struct ShardedBackend {
    integrand: IntegrandRef,
    layout: Layout,
    shards: usize,
    threads: usize,
    /// `Some(beta)` for VEGAS+ adaptive stratification.
    beta: Option<f64>,
    /// Per-iteration call budget (`layout.calls()`, matching the
    /// single-worker backends so `calls_used` accounting is
    /// identical).
    budget: usize,
    spool: Option<SpoolTransport>,
    cell: RefCell<ShardCell>,
}

impl ShardedBackend {
    /// Build a sharded backend for `shards` workers. For
    /// [`Sampling::VegasPlus`], `resume` restores a matching-layout
    /// allocation exactly as `StratifiedBackend::new` does.
    pub fn new(
        integrand: IntegrandRef,
        layout: Layout,
        shards: usize,
        threads: usize,
        sampling: Sampling,
        resume: Option<&StratSnapshot>,
    ) -> Result<ShardedBackend> {
        let beta = match sampling {
            Sampling::Uniform => None,
            Sampling::VegasPlus { beta } => Some(beta),
        };
        let alloc = match beta {
            Some(b) => Some(match resume {
                Some(s) if s.counts.len() == layout.m => {
                    let mut a = Allocation::from_parts(s.counts.clone(), s.damped.clone())?;
                    a.reallocate(layout.calls(), b);
                    a
                }
                _ => Allocation::uniform(&layout),
            }),
            None => None,
        };
        Ok(ShardedBackend {
            integrand,
            layout,
            shards,
            threads,
            beta,
            budget: layout.calls(),
            spool: None,
            cell: RefCell::new(ShardCell {
                alloc,
                last: None,
                stats: ShardStats::default(),
            }),
        })
    }

    /// Route iterations through a spool directory so external worker
    /// processes can compute spans (chainable).
    #[must_use]
    pub fn with_spool(mut self, spool: SpoolTransport) -> Self {
        self.spool = Some(spool);
        self
    }

    /// The shard plan the next iteration will scatter (pure function
    /// of the layout and the live allocation).
    pub fn plan(&self) -> ShardPlan {
        let cell = self.cell.borrow();
        match &cell.alloc {
            Some(a) => ShardPlan::stratified(&self.layout, a.counts(), a.offsets())
                .shards(self.shards),
            None => ShardPlan::uniform(&self.layout, self.shards),
        }
    }

    /// In-process fan-out: one scoped worker per span, results in
    /// span (= global task) order.
    fn run_in_process(
        &self,
        plan: &ShardPlan,
        bins: &Bins,
        alloc: Option<&Allocation>,
        opts: &VSampleOpts,
    ) -> Vec<TaskPartial> {
        let spans = plan.spans();
        // Bind the Sync captures explicitly: the closure must not
        // capture `self` (the RefCell makes it !Sync).
        let f: &dyn crate::integrands::Integrand = &*self.integrand;
        let layout = &self.layout;
        let per_shard: Vec<Vec<Vec<TaskPartial>>> =
            parallel_chunks(spans.len(), spans.len(), |s0, s1| {
                (s0..s1)
                    .map(|s| {
                        run_span(
                            f,
                            layout,
                            bins,
                            alloc,
                            opts,
                            spans[s].task_lo,
                            spans[s].task_hi,
                        )
                    })
                    .collect()
            });
        per_shard.into_iter().flatten().flatten().collect()
    }

    /// Spool fan-out: scatter sealed tasks, gather sealed reports
    /// (straggler policy inside), partials in global task order.
    fn run_spooled(
        &self,
        spool: &SpoolTransport,
        plan: &ShardPlan,
        bins: &Bins,
        alloc: Option<&Allocation>,
        opts: &VSampleOpts,
        stats: &mut ShardStats,
    ) -> Result<Vec<TaskPartial>> {
        let grid = match alloc {
            Some(a) => GridState::from_bins(bins.clone()).with_strat(StratSnapshot {
                beta: self.beta.unwrap_or(0.0),
                counts: a.counts().to_vec(),
                damped: a.damped().to_vec(),
            }),
            None => GridState::from_bins(bins.clone()),
        };
        let tasks: Vec<ShardTask> = plan
            .spans()
            .iter()
            .map(|sp| ShardTask {
                integrand: self.integrand.name().to_string(),
                layout: self.layout,
                grid: grid.clone(),
                seed: opts.seed,
                iteration: opts.iteration,
                adjust: opts.adjust,
                shard: sp.shard,
                task_lo: sp.task_lo,
                task_hi: sp.task_hi,
            })
            .collect();
        spool.scatter(&tasks)?;
        let shape = ReportShape {
            contrib_len: if opts.adjust {
                Some(self.layout.d * self.layout.nb)
            } else {
                None
            },
            stratified: alloc.is_some(),
        };
        // Bind the Sync captures explicitly: the closure must not
        // capture `self` (the RefCell makes it !Sync).
        let f: &dyn crate::integrands::Integrand = &*self.integrand;
        let layout = &self.layout;
        let fallback =
            |sp: &ShardSpan| run_span(f, layout, bins, alloc, opts, sp.task_lo, sp.task_hi);
        let partials =
            spool.gather(plan, &tasks, &self.layout, opts.iteration, &shape, &fallback, stats)?;
        spool.cleanup(plan, opts.iteration);
        Ok(partials)
    }
}

impl VSampleBackend for ShardedBackend {
    fn layout(&self) -> Layout {
        self.layout
    }

    fn bounds(&self) -> Bounds {
        self.integrand.bounds()
    }

    fn name(&self) -> &'static str {
        if self.beta.is_some() {
            "native-sharded-vegas+"
        } else {
            "native-sharded"
        }
    }

    fn run(
        &self,
        bins: &Bins,
        seed: u32,
        iteration: u32,
        adjust: bool,
    ) -> Result<(IterationResult, Option<Vec<f64>>)> {
        let mut cell = self.cell.borrow_mut();
        let ShardCell { alloc, last, stats } = &mut *cell;
        if let Some(a) = alloc.as_ref() {
            *last = Some(a.stats());
        }
        let plan = match alloc.as_ref() {
            Some(a) => {
                ShardPlan::stratified(&self.layout, a.counts(), a.offsets()).shards(self.shards)
            }
            None => ShardPlan::uniform(&self.layout, self.shards),
        };
        // Give each in-process span worker an equal slice of the
        // thread budget (bitwise-neutral either way).
        let opts = VSampleOpts {
            seed,
            iteration,
            adjust,
            threads: (self.threads / plan.nshards()).max(1),
        };
        let partials = match &self.spool {
            Some(spool) => {
                self.run_spooled(spool, &plan, bins, alloc.as_ref(), &opts, stats)?
            }
            None => self.run_in_process(&plan, bins, alloc.as_ref(), &opts),
        };
        // The merge refuses to fold anything but the complete,
        // in-order task partition (shard bugs must not become silent
        // numeric drift).
        if partials.len() != plan.ntasks()
            || partials.iter().enumerate().any(|(i, p)| p.task != i)
        {
            return Err(Error::Shard(format!(
                "gathered {} partials for {} tasks (or out of order)",
                partials.len(),
                plan.ntasks()
            )));
        }
        let merge_start = Instant::now();
        let out = merge_task_partials(self.layout.d, self.layout.nb, adjust, &partials);
        if let Some(a) = alloc.as_mut() {
            // Absorb in global task order — the same per-cube absorb
            // stream as the single-worker stratified pass.
            for p in &partials {
                a.absorb_span(p.cube_lo, &p.d_new);
            }
            if let Some(b) = self.beta {
                a.reallocate(self.budget, b);
            }
        }
        stats.merge_ms += merge_start.elapsed().as_secs_f64() * 1e3;
        stats.shards = stats.shards.max(plan.nshards());
        Ok(out)
    }

    fn alloc_stats(&self) -> Option<AllocStats> {
        self.cell.borrow().last
    }

    fn strat_export(&self) -> Option<StratSnapshot> {
        let cell = self.cell.borrow();
        match (&cell.alloc, self.beta) {
            (Some(a), Some(beta)) => Some(StratSnapshot {
                beta,
                counts: a.counts().to_vec(),
                damped: a.damped().to_vec(),
            }),
            _ => None,
        }
    }

    fn shard_stats(&self) -> Option<ShardStats> {
        Some(self.cell.borrow().stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{NativeBackend, StratifiedBackend};
    use crate::integrands::by_name;
    use crate::strat::DEFAULT_BETA;

    fn bitwise_eq(a: &(IterationResult, Option<Vec<f64>>), b: &(IterationResult, Option<Vec<f64>>)) {
        assert_eq!(a.0.integral.to_bits(), b.0.integral.to_bits());
        assert_eq!(a.0.variance.to_bits(), b.0.variance.to_bits());
        match (&a.1, &b.1) {
            (Some(x), Some(y)) => {
                assert_eq!(x.len(), y.len());
                for (u, v) in x.iter().zip(y.iter()) {
                    assert_eq!(u.to_bits(), v.to_bits());
                }
            }
            (None, None) => {}
            _ => panic!("contrib presence mismatch"),
        }
    }

    #[test]
    fn sharded_uniform_matches_native_backend_bitwise() {
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let f = by_name("f4", 4).unwrap();
        let bins = Bins::uniform(4, 16);
        let reference = NativeBackend::new(f.clone(), layout, 3);
        let sharded =
            ShardedBackend::new(f, layout, 8, 4, Sampling::Uniform, None).unwrap();
        for it in 0..3u32 {
            let want = reference.run(&bins, 17, it, true).unwrap();
            let got = sharded.run(&bins, 17, it, true).unwrap();
            bitwise_eq(&got, &want);
        }
        let stats = sharded.shard_stats().unwrap();
        assert_eq!(stats.shards, 8);
        assert_eq!(stats.straggler_retries, 0);
        assert!(sharded.strat_export().is_none());
    }

    #[test]
    fn sharded_vegas_plus_matches_stratified_backend_bitwise() {
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        let f = by_name("f5", 5).unwrap();
        let bins = Bins::uniform(5, 20);
        let reference =
            StratifiedBackend::new(f.clone(), layout, 2, DEFAULT_BETA, None).unwrap();
        let sharded = ShardedBackend::new(
            f,
            layout,
            8,
            8,
            Sampling::VegasPlus { beta: DEFAULT_BETA },
            None,
        )
        .unwrap();
        // Multiple adaptive iterations: the allocation evolves and the
        // plans diverge from uniform — the merge must still track the
        // single-worker stream bitwise.
        for it in 0..4u32 {
            let want = reference.run(&bins, 99, it, true).unwrap();
            let got = sharded.run(&bins, 99, it, true).unwrap();
            bitwise_eq(&got, &want);
            // Allocation state stays in lockstep, iteration by
            // iteration.
            let (se, re) = (sharded.strat_export().unwrap(), reference.strat_export().unwrap());
            assert_eq!(se.counts, re.counts);
            for (x, y) in se.damped.iter().zip(re.damped.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(
            sharded.alloc_stats().map(|s| s.total),
            reference.alloc_stats().map(|s| s.total)
        );
    }

    #[test]
    fn shard_count_does_not_change_the_bits() {
        let layout = Layout::compute(4, 2048, 10, 2).unwrap();
        let f = by_name("f2", 4).unwrap();
        let bins = Bins::uniform(4, 10);
        let one = ShardedBackend::new(f.clone(), layout, 1, 1, Sampling::Uniform, None).unwrap();
        let want = one.run(&bins, 4, 0, false).unwrap();
        for shards in [2, 3, 5, 64, 1000] {
            let b =
                ShardedBackend::new(f.clone(), layout, shards, 2, Sampling::Uniform, None)
                    .unwrap();
            let got = b.run(&bins, 4, 0, false).unwrap();
            bitwise_eq(&got, &want);
        }
    }

    #[test]
    fn resume_restores_the_allocation_like_the_stratified_backend() {
        let layout = Layout::compute(3, 2048, 12, 1).unwrap();
        let f = by_name("f3", 3).unwrap();
        let bins = Bins::uniform(3, 12);
        // Run two iterations, export, resume both backend kinds.
        let donor = ShardedBackend::new(
            f.clone(),
            layout,
            4,
            2,
            Sampling::VegasPlus { beta: 0.5 },
            None,
        )
        .unwrap();
        for it in 0..2u32 {
            donor.run(&bins, 31, it, true).unwrap();
        }
        let snap = donor.strat_export().unwrap();
        let resumed_ref =
            StratifiedBackend::new(f.clone(), layout, 2, 0.5, Some(&snap)).unwrap();
        let resumed_sharded = ShardedBackend::new(
            f,
            layout,
            4,
            2,
            Sampling::VegasPlus { beta: 0.5 },
            Some(&snap),
        )
        .unwrap();
        let want = resumed_ref.run(&bins, 31, 2, true).unwrap();
        let got = resumed_sharded.run(&bins, 31, 2, true).unwrap();
        bitwise_eq(&got, &want);
    }
}
