//! Shard workers: compute one shard span of one iteration.
//!
//! Two flavours share the same span computation:
//!
//! * [`run_span`] — in-process: called directly by the
//!   [`crate::shard::ShardedBackend`] pool and by the coordinator's
//!   straggler fallback.
//! * [`run_spool_worker`] — process-transport: scans a spool
//!   directory for sealed [`ShardTask`] files, computes each span, and
//!   writes the sealed [`ShardReport`] next to it. This is what the
//!   `mcubes shard-worker` CLI runs; any number of worker processes
//!   may watch the same directory — reports are atomic, idempotent
//!   (identical bytes for identical tasks), and written only when
//!   absent, so racing workers waste work but never corrupt it.

// lint:allow(MC003, worker polling cadence only — no time value ever feeds the sample stream)
use std::time::{Duration, Instant};

use super::report::{ShardReport, ShardTask};
use crate::engine::{vsample_stratified_tasks, vsample_tasks, FillPath, TaskPartial, VSampleOpts};
use crate::error::{Error, Result};
use crate::grid::Bins;
use crate::integrands::Integrand;
use crate::strat::{Allocation, Layout};
use std::path::{Path, PathBuf};

/// Compute the per-task partials of one shard span. Pure function of
/// its arguments: the result is bitwise independent of `opts.threads`
/// and of which process runs it.
pub fn run_span(
    f: &dyn Integrand,
    layout: &Layout,
    bins: &Bins,
    alloc: Option<&Allocation>,
    opts: &VSampleOpts,
    task_lo: usize,
    task_hi: usize,
) -> Vec<TaskPartial> {
    match alloc {
        Some(a) => vsample_stratified_tasks(
            f,
            layout,
            bins,
            a.counts(),
            a.offsets(),
            opts,
            FillPath::Simd,
            task_lo,
            task_hi,
        ),
        None => vsample_tasks(f, layout, bins, opts, FillPath::Simd, task_lo, task_hi),
    }
}

/// Execute one sealed shard task end to end: resolve the integrand
/// from the registry, rebuild the allocation from the task's grid
/// snapshot (when VEGAS+), compute the span, and package the report.
pub fn process_task(task: &ShardTask, threads: usize) -> Result<ShardReport> {
    let f = crate::integrands::by_name(&task.integrand, task.layout.d)?;
    let alloc = match task.grid.strat() {
        Some(s) => {
            if s.counts.len() != task.layout.m {
                return Err(Error::Shard(format!(
                    "shard task allocation has {} cubes, layout has {}",
                    s.counts.len(),
                    task.layout.m
                )));
            }
            Some(Allocation::from_parts(s.counts.clone(), s.damped.clone())?)
        }
        None => None,
    };
    let opts = VSampleOpts {
        seed: task.seed,
        iteration: task.iteration,
        adjust: task.adjust,
        threads,
    };
    let partials = run_span(
        &*f,
        &task.layout,
        task.grid.bins(),
        alloc.as_ref(),
        &opts,
        task.task_lo,
        task.task_hi,
    );
    Ok(ShardReport::from_partials(
        task.shard,
        task.iteration,
        task.digest(),
        partials,
    ))
}

/// What one spool-worker invocation did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerOutcome {
    /// Task files computed and reported by this worker.
    pub processed: usize,
    /// Skip events: a task that was unreadable, semantically
    /// unserveable (unresolvable integrand, layout/allocation
    /// mismatch), or whose report failed to write — the coordinator's
    /// retry/straggler path owns every one of them. A task skipped on
    /// several sweeps counts once per sweep.
    pub skipped: usize,
}

/// Tasks sub-directory of a spool root.
pub(crate) fn tasks_dir(dir: &Path) -> PathBuf {
    dir.join("tasks")
}

/// Reports sub-directory of a spool root.
pub(crate) fn reports_dir(dir: &Path) -> PathBuf {
    dir.join("reports")
}

/// Stop-marker path of a spool root (written by
/// [`crate::shard::spool_close`]).
pub(crate) fn stop_path(dir: &Path) -> PathBuf {
    dir.join("stop")
}

/// Run a spool worker loop over `dir` until the coordinator writes the
/// stop marker (and every *serveable* task has a report), or until
/// `max_idle` passes without any new work. Returns what it did.
///
/// The loop is crash-tolerant by construction: a worker killed
/// mid-computation leaves no report (the coordinator's timeout +
/// retry path covers the span), and a worker killed mid-write leaves
/// only a `.tmp` file the atomic-rename protocol ignores. A task that
/// cannot be served — unreadable file, unresolvable integrand,
/// inconsistent allocation — is counted in
/// [`WorkerOutcome::skipped`] and left for the coordinator's
/// retry/straggler path; it never kills the loop and never blocks the
/// stop marker (a pending-but-unserveable task must not pin a worker
/// to a finished spool forever).
pub fn run_spool_worker(
    dir: &Path,
    threads: usize,
    poll: Duration,
    max_idle: Option<Duration>,
) -> Result<WorkerOutcome> {
    let tasks = tasks_dir(dir);
    let reports = reports_dir(dir);
    std::fs::create_dir_all(&tasks)?;
    std::fs::create_dir_all(&reports)?;
    let mut out = WorkerOutcome::default();
    let mut last_progress = Instant::now();
    loop {
        let mut pending = 0usize;
        let mut unserved = 0usize; // pending tasks this sweep could not answer
        let mut progressed = false;
        for task_path in crate::store::list_json_sorted(&tasks)? {
            let Some(name) = task_path.file_name() else {
                continue;
            };
            let report_path = reports.join(name);
            if report_path.exists() {
                continue;
            }
            pending += 1;
            // A torn/corrupt task file is the coordinator's to replace;
            // skip it rather than dying (another sweep may see the
            // rewritten version).
            let Ok(Some(task)) = ShardTask::load(&task_path) else {
                out.skipped += 1;
                unserved += 1;
                continue;
            };
            // Same policy for a task that loads but cannot be served
            // (bad integrand name, allocation mismatch) or whose
            // report fails to write: skip, keep sweeping — the
            // coordinator's straggler path owns the span.
            match process_task(&task, threads).and_then(|rep| rep.save(&report_path)) {
                Ok(()) => {
                    out.processed += 1;
                    pending -= 1;
                    progressed = true;
                }
                Err(_) => {
                    out.skipped += 1;
                    unserved += 1;
                }
            }
        }
        if progressed {
            last_progress = Instant::now();
            continue; // re-scan immediately: more tasks may have landed
        }
        // Stop once the coordinator says so and nothing serveable is
        // left — tasks that only ever fail to load/serve must not pin
        // the worker to a finished spool.
        if pending == unserved && stop_path(dir).exists() {
            return Ok(out);
        }
        if let Some(idle) = max_idle {
            if last_progress.elapsed() >= idle {
                return Ok(out);
            }
        }
        std::thread::sleep(poll);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::GridState;
    use crate::engine::{reduction_tasks, NativeEngine};

    fn scratch(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "mcubes-shard-worker-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn process_task_matches_in_process_span_bitwise() {
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let bins = Bins::uniform(4, 16);
        let f = crate::integrands::by_name("f4", 4).unwrap();
        let opts = VSampleOpts {
            seed: 91,
            iteration: 2,
            adjust: true,
            threads: 2,
        };
        let ntasks = reduction_tasks(layout.m);
        let (lo, hi) = (ntasks / 4, ntasks / 2);
        let direct = run_span(&*f, &layout, &bins, None, &opts, lo, hi);
        let task = ShardTask {
            integrand: "f4".to_string(),
            layout,
            grid: GridState::from_bins(bins.clone()),
            seed: 91,
            iteration: 2,
            adjust: true,
            shard: 1,
            task_lo: lo,
            task_hi: hi,
        };
        let rep = process_task(&task, 1).unwrap();
        let via_report = rep.into_partials(&layout);
        assert_eq!(via_report.len(), direct.len());
        for (a, b) in via_report.iter().zip(direct.iter()) {
            assert_eq!(a.task, b.task);
            assert_eq!(a.integral.to_bits(), b.integral.to_bits());
            assert_eq!(a.variance.to_bits(), b.variance.to_bits());
        }
    }

    #[test]
    fn spool_worker_drains_tasks_and_stops_on_marker() {
        let layout = Layout::compute(3, 512, 8, 1).unwrap();
        let bins = Bins::uniform(3, 8);
        let dir = scratch("drain");
        std::fs::create_dir_all(tasks_dir(&dir)).unwrap();
        std::fs::create_dir_all(reports_dir(&dir)).unwrap();
        let ntasks = reduction_tasks(layout.m);
        for shard in 0..2 {
            let (lo, hi) = crate::engine::reduction_task_span(ntasks, 2, shard);
            let task = ShardTask {
                integrand: "f3".to_string(),
                layout,
                grid: GridState::from_bins(bins.clone()),
                seed: 7,
                iteration: 0,
                adjust: false,
                shard,
                task_lo: lo,
                task_hi: hi,
            };
            task.save(&tasks_dir(&dir).join(format!("it00000000-s{shard:03}.json")))
                .unwrap();
        }
        std::fs::write(stop_path(&dir), b"").unwrap();
        let out = run_spool_worker(&dir, 1, Duration::from_millis(1), None).unwrap();
        assert_eq!(out.processed, 2);
        // Reports reproduce the full single-pass fold when merged.
        let mut partials = Vec::new();
        for shard in 0..2 {
            let rep = ShardReport::load(
                &reports_dir(&dir).join(format!("it00000000-s{shard:03}.json")),
            )
            .unwrap()
            .unwrap();
            partials.extend(rep.into_partials(&layout));
        }
        let opts = VSampleOpts {
            seed: 7,
            iteration: 0,
            adjust: false,
            threads: 1,
        };
        let f = crate::integrands::by_name("f3", 3).unwrap();
        let (merged, _) =
            crate::engine::merge_task_partials(layout.d, layout.nb, false, &partials);
        let (reference, _) = NativeEngine.vsample(&*f, &layout, &bins, &opts);
        assert_eq!(merged.integral.to_bits(), reference.integral.to_bits());
        assert_eq!(merged.variance.to_bits(), reference.variance.to_bits());
        // Second worker pass: everything already reported → no work,
        // immediate exit on the stop marker.
        let again = run_spool_worker(&dir, 1, Duration::from_millis(1), None).unwrap();
        assert_eq!(again.processed, 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn unserveable_tasks_are_skipped_and_do_not_block_the_stop_marker() {
        let layout = Layout::compute(3, 512, 8, 1).unwrap();
        let bins = Bins::uniform(3, 8);
        let dir = scratch("unserveable");
        std::fs::create_dir_all(tasks_dir(&dir)).unwrap();
        std::fs::create_dir_all(reports_dir(&dir)).unwrap();
        let ntasks = reduction_tasks(layout.m);
        let good = ShardTask {
            integrand: "f3".to_string(),
            layout,
            grid: GridState::from_bins(bins.clone()),
            seed: 7,
            iteration: 0,
            adjust: false,
            shard: 0,
            task_lo: 0,
            task_hi: ntasks,
        };
        good.save(&tasks_dir(&dir).join("it00000000-s000.json"))
            .unwrap();
        // Loads fine but cannot be served: no such integrand in the
        // registry (e.g. a task scattered by a newer build).
        let bad = ShardTask {
            integrand: "no-such-integrand".to_string(),
            shard: 1,
            ..good.clone()
        };
        bad.save(&tasks_dir(&dir).join("it00000000-s001.json"))
            .unwrap();
        // And one that never parses at all.
        std::fs::write(tasks_dir(&dir).join("it00000000-s002.json"), b"{ torn").unwrap();
        std::fs::write(stop_path(&dir), b"").unwrap();
        // With idle timeout *disabled*, only the stop-marker path can
        // end the loop — the two unserveable tasks must not pin it.
        let out = run_spool_worker(&dir, 1, Duration::from_millis(1), None).unwrap();
        assert_eq!(out.processed, 1);
        assert!(out.skipped >= 2, "both bad tasks were skipped: {out:?}");
        assert!(reports_dir(&dir).join("it00000000-s000.json").exists());
        assert!(!reports_dir(&dir).join("it00000000-s001.json").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn idle_timeout_returns_instead_of_hanging() {
        let dir = scratch("idle");
        let out = run_spool_worker(
            &dir,
            1,
            Duration::from_millis(1),
            Some(Duration::from_millis(20)),
        )
        .unwrap();
        assert_eq!(out, WorkerOutcome::default());
        let _ = std::fs::remove_dir_all(dir);
    }
}
