//! Shard coordinator: scatter spans, gather sealed reports, survive
//! stragglers.
//!
//! The coordinator side of the spool (process) transport. One
//! iteration proceeds as:
//!
//! 1. **Scatter** — write one sealed [`ShardTask`] per shard span into
//!    `<dir>/tasks/` (atomic rename; workers never observe a torn
//!    task).
//! 2. **Gather** — poll `<dir>/reports/` for each shard's sealed
//!    [`ShardReport`]. Every report must carry the
//!    [`ShardTask::digest`] of the task it answers, so a stale report
//!    left over from another run (different seed, integrand, grid, or
//!    layout — spool file names are only (iteration, shard)-scoped) is
//!    rejected instead of silently merged. A corrupt or inconsistent
//!    report is deleted and counted against that shard's retry budget
//!    (the file's absence re-opens the task for any live worker). A
//!    shard that is still
//!    missing at the deadline — or that exhausts its retry budget — is
//!    recomputed by a fresh in-process worker when `local_fallback` is
//!    on, and surfaces as a typed [`Error::Shard`] when it is off.
//!    Either way the coordinator never hangs and never merges a
//!    partial iteration.
//! 3. **Cleanup** — the iteration's task + report files are removed
//!    after a successful merge, bounding spool growth.
//!
//! Determinism: a recomputed span is bitwise identical to what the
//! missing worker would have reported (same plan, same Philox counter
//! sub-range), so retries and fallbacks never change the merged
//! result — only `straggler_retries` in [`super::ShardStats`].

// lint:allow(MC003, gather deadline/poll cadence only — no time value ever feeds the sample stream)
use std::time::{Duration, Instant};

use super::plan::{ShardPlan, ShardSpan};
use super::report::{ShardReport, ShardTask};
use super::worker::{reports_dir, stop_path, tasks_dir};
use super::ShardStats;
use crate::engine::TaskPartial;
use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// Spool-transport tuning knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpoolOptions {
    /// Per-iteration gather deadline; shards still missing when it
    /// expires take the straggler path.
    pub timeout: Duration,
    /// Sleep between report-directory sweeps.
    pub poll: Duration,
    /// Corrupt/inconsistent reports tolerated per shard per iteration
    /// before the shard takes the straggler path.
    pub max_retries: usize,
    /// Recompute missing spans with a fresh in-process worker
    /// (`true`, the default) instead of failing the iteration with
    /// [`Error::Shard`] (`false` — for tests and strict deployments).
    pub local_fallback: bool,
}

impl Default for SpoolOptions {
    fn default() -> SpoolOptions {
        SpoolOptions {
            timeout: Duration::from_secs(30),
            poll: Duration::from_millis(10),
            max_retries: 2,
            local_fallback: true,
        }
    }
}

/// What a well-formed report must contain, so shape violations are
/// caught before they can silently truncate the merge's zip folds.
pub(crate) struct ReportShape {
    /// `Some(d * nb)` when the pass accumulates the adjust histogram.
    pub contrib_len: Option<usize>,
    /// Whether per-cube damped observations are expected (VEGAS+).
    pub stratified: bool,
}

/// Canonical spool file name of (iteration, shard) — shared by
/// coordinator, workers, and the CI outbox comparison.
pub fn spool_file_name(iteration: u32, shard: usize) -> String {
    format!("it{iteration:08}-s{shard:03}.json")
}

/// Write the stop marker: spool workers exit once it exists and no
/// serveable task is left (tasks they can never answer don't keep
/// them alive — see [`super::run_spool_worker`]).
pub fn spool_close(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(stop_path(dir), b"stop\n")?;
    Ok(())
}

/// Coordinator handle on one spool directory.
pub struct SpoolTransport {
    dir: PathBuf,
    opts: SpoolOptions,
}

impl SpoolTransport {
    /// Open (creating `tasks/` + `reports/` as needed) a spool rooted
    /// at `dir`, clear any stale stop marker so workers launched
    /// afterwards stay alive, and purge leftover task/report/`.tmp`
    /// files from earlier runs — a run that errored out mid-iteration
    /// (cleanup only runs after a successful merge) or a straggler
    /// that reported after cleanup must not seed the next run's
    /// directory. (The gather path additionally rejects any stale
    /// report by its [`ShardTask::digest`], so the purge is hygiene,
    /// not the safety mechanism.)
    pub fn open(dir: impl AsRef<Path>, opts: SpoolOptions) -> Result<SpoolTransport> {
        let dir = dir.as_ref().to_path_buf();
        for sub in [tasks_dir(&dir), reports_dir(&dir)] {
            std::fs::create_dir_all(&sub)?;
            for entry in std::fs::read_dir(&sub)? {
                let path = entry?.path();
                let stale = path
                    .extension()
                    .is_some_and(|e| e == "json" || e == "tmp");
                if stale {
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
        let _ = std::fs::remove_file(stop_path(&dir));
        Ok(SpoolTransport { dir, opts })
    }

    /// The spool root.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The transport's tuning knobs.
    pub fn options(&self) -> SpoolOptions {
        self.opts
    }

    /// Scatter one iteration's work orders.
    pub(crate) fn scatter(&self, tasks: &[ShardTask]) -> Result<()> {
        for t in tasks {
            super::report::check_spool_layout(&t.layout)?;
            // Fail fast on integrands a fresh worker process cannot
            // resolve (closure integrands have no registry name).
            crate::integrands::by_name(&t.integrand, t.layout.d).map_err(|_| {
                Error::Shard(format!(
                    "integrand `{}` is not registry-resolvable; the spool transport \
                     needs `by_name` (use in-process sharding for closures)",
                    t.integrand
                ))
            })?;
            t.save(&tasks_dir(&self.dir).join(spool_file_name(t.iteration, t.shard)))?;
        }
        Ok(())
    }

    /// Gather every shard's report for `iteration`, applying the
    /// corruption/straggler policy. `tasks` are the scattered work
    /// orders — each report must echo its task's digest, which is what
    /// rejects stale reports computed for a different run. `fallback`
    /// recomputes one span in-process; `shape` pins the expected
    /// report geometry. Returns the full iteration's partials in
    /// global task order.
    pub(crate) fn gather(
        &self,
        plan: &ShardPlan,
        tasks: &[ShardTask],
        layout: &crate::strat::Layout,
        iteration: u32,
        shape: &ReportShape,
        fallback: &(dyn Fn(&ShardSpan) -> Vec<TaskPartial> + Sync),
        stats: &mut ShardStats,
    ) -> Result<Vec<TaskPartial>> {
        let reports = reports_dir(&self.dir);
        let nshards = plan.nshards();
        // One digest per shard, computed once (not per poll sweep).
        let mut digests: Vec<Option<String>> = vec![None; nshards];
        for t in tasks {
            if t.shard < nshards {
                digests[t.shard] = Some(t.digest());
            }
        }
        let mut collected: Vec<Option<Vec<TaskPartial>>> = Vec::new();
        collected.resize_with(nshards, || None);
        let mut retries = vec![0usize; nshards];
        let deadline = Instant::now() + self.opts.timeout;
        loop {
            let mut missing = 0usize;
            for span in plan.spans() {
                if collected[span.shard].is_some() {
                    continue;
                }
                let Some(want_sha) = digests[span.shard].as_deref() else {
                    return Err(Error::Shard(format!(
                        "gather has no scattered task for shard {}",
                        span.shard
                    )));
                };
                let path = reports.join(spool_file_name(iteration, span.shard));
                match ShardReport::load(&path) {
                    Ok(Some(rep)) => match check_report(&rep, span, iteration, want_sha, layout, shape)
                    {
                        Ok(()) => collected[span.shard] = Some(rep.into_partials(layout)),
                        Err(detail) => {
                            // Inconsistent ≙ corrupt: drop the file so a
                            // live worker recomputes it, burn one retry.
                            let _ = std::fs::remove_file(&path);
                            retries[span.shard] += 1;
                            if retries[span.shard] > self.opts.max_retries {
                                self.straggle(span, &detail, fallback, stats, &mut collected)?;
                            } else {
                                missing += 1;
                            }
                        }
                    },
                    Ok(None) => missing += 1,
                    Err(_) => {
                        // Torn mid-write or tampered: same policy as an
                        // inconsistent report.
                        let _ = std::fs::remove_file(&path);
                        retries[span.shard] += 1;
                        if retries[span.shard] > self.opts.max_retries {
                            self.straggle(span, "corrupt report", fallback, stats, &mut collected)?;
                        } else {
                            missing += 1;
                        }
                    }
                }
            }
            if missing == 0 && collected.iter().all(Option::is_some) {
                break;
            }
            if Instant::now() >= deadline {
                for span in plan.spans() {
                    if collected[span.shard].is_none() {
                        self.straggle(
                            span,
                            "no report before the deadline",
                            fallback,
                            stats,
                            &mut collected,
                        )?;
                    }
                }
                break;
            }
            std::thread::sleep(self.opts.poll);
        }
        let mut out = Vec::with_capacity(plan.ntasks());
        for got in collected {
            match got {
                Some(partials) => out.extend(partials),
                None => return Err(Error::Shard("gather ended with a missing shard".into())),
            }
        }
        Ok(out)
    }

    /// Straggler path for one span: recompute in-process when allowed,
    /// typed failure otherwise.
    fn straggle(
        &self,
        span: &ShardSpan,
        why: &str,
        fallback: &(dyn Fn(&ShardSpan) -> Vec<TaskPartial> + Sync),
        stats: &mut ShardStats,
        collected: &mut [Option<Vec<TaskPartial>>],
    ) -> Result<()> {
        if !self.opts.local_fallback {
            return Err(Error::Shard(format!(
                "shard {} failed ({why}) and local fallback is disabled",
                span.shard
            )));
        }
        stats.straggler_retries += 1;
        collected[span.shard] = Some(fallback(span));
        Ok(())
    }

    /// Remove one iteration's task + report files after a successful
    /// merge (failures are ignored: the next `open` purges leftovers,
    /// and `gather` rejects any stale report by its task digest).
    pub(crate) fn cleanup(&self, plan: &ShardPlan, iteration: u32) {
        for span in plan.spans() {
            let name = spool_file_name(iteration, span.shard);
            let _ = std::fs::remove_file(tasks_dir(&self.dir).join(&name));
            let _ = std::fs::remove_file(reports_dir(&self.dir).join(&name));
        }
    }
}

/// Validate one report against its span, its task's digest, and the
/// expected geometry.
fn check_report(
    rep: &ShardReport,
    span: &ShardSpan,
    iteration: u32,
    want_sha: &str,
    layout: &crate::strat::Layout,
    shape: &ReportShape,
) -> std::result::Result<(), String> {
    if rep.shard != span.shard || rep.iteration != iteration {
        return Err(format!(
            "report identity (shard {}, iteration {}) != expected (shard {}, iteration {})",
            rep.shard, rep.iteration, span.shard, iteration
        ));
    }
    // The digest binds the report to the *content* of the task it
    // answered — seed, integrand, layout, grid, span — so a stale
    // report from another run sharing the spool (file names are only
    // (iteration, shard)-scoped) can never be merged.
    if rep.task_sha != want_sha {
        return Err(format!(
            "report answers task {} but the scattered task is {want_sha} \
             (stale report from a different run?)",
            rep.task_sha
        ));
    }
    if rep.tasks.len() != span.ntasks() {
        return Err(format!(
            "report covers {} tasks, span owns {}",
            rep.tasks.len(),
            span.ntasks()
        ));
    }
    let ntasks = crate::engine::reduction_tasks(layout.m);
    for (i, t) in rep.tasks.iter().enumerate() {
        if t.task != span.task_lo + i {
            return Err(format!("task {} out of order (expected {})", t.task, span.task_lo + i));
        }
        match (shape.contrib_len, &t.contrib) {
            (Some(want), Some(c)) if c.len() == want => {}
            (None, None) => {}
            _ => return Err(format!("task {} contrib shape mismatch", t.task)),
        }
        let (cube_lo, cube_hi) = crate::engine::reduction_task_span(layout.m, ntasks, t.task);
        let want_dnew = if shape.stratified { cube_hi - cube_lo } else { 0 };
        if t.d_new.len() != want_dnew {
            return Err(format!(
                "task {} carries {} damped observations, expected {want_dnew}",
                t.task,
                t.d_new.len()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::GridState;
    use crate::engine::VSampleOpts;
    use crate::grid::Bins;
    use crate::integrands::by_name;
    use crate::strat::Layout;

    fn scratch(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "mcubes-shard-coord-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn fast_opts(local_fallback: bool) -> SpoolOptions {
        SpoolOptions {
            timeout: Duration::from_millis(50),
            poll: Duration::from_millis(1),
            max_retries: 1,
            local_fallback,
        }
    }

    fn setting() -> (Layout, Bins, ShardPlan, Vec<ShardTask>) {
        let layout = Layout::compute(3, 512, 8, 1).unwrap();
        let bins = Bins::uniform(3, 8);
        let plan = ShardPlan::uniform(&layout, 4);
        let tasks: Vec<ShardTask> = plan
            .spans()
            .iter()
            .map(|sp| ShardTask {
                integrand: "f3".to_string(),
                layout,
                grid: GridState::from_bins(bins.clone()),
                seed: 5,
                iteration: 1,
                adjust: false,
                shard: sp.shard,
                task_lo: sp.task_lo,
                task_hi: sp.task_hi,
            })
            .collect();
        (layout, bins, plan, tasks)
    }

    fn run_gather(
        t: &SpoolTransport,
        layout: &Layout,
        bins: &Bins,
        plan: &ShardPlan,
        tasks: &[ShardTask],
        stats: &mut ShardStats,
    ) -> Result<Vec<TaskPartial>> {
        let f = by_name("f3", 3).unwrap();
        let opts = VSampleOpts {
            seed: 5,
            iteration: 1,
            adjust: false,
            threads: 1,
        };
        let shape = ReportShape {
            contrib_len: None,
            stratified: false,
        };
        let fallback = move |sp: &ShardSpan| {
            super::super::worker::run_span(&*f, layout, bins, None, &opts, sp.task_lo, sp.task_hi)
        };
        t.gather(plan, tasks, layout, 1, &shape, &fallback, stats)
    }

    #[test]
    fn gather_falls_back_for_missing_and_corrupt_reports() {
        let dir = scratch("fallback");
        let t = SpoolTransport::open(&dir, fast_opts(true)).unwrap();
        let (layout, bins, plan, tasks) = setting();
        t.scatter(&tasks).unwrap();
        // Worker answers shards 0 and 1 only; shard 1's report is torn.
        for task in &tasks[..2] {
            super::super::worker::process_task(task, 1)
                .unwrap()
                .save(&reports_dir(&dir).join(spool_file_name(1, task.shard)))
                .unwrap();
        }
        let torn = reports_dir(&dir).join(spool_file_name(1, 1));
        let bytes = std::fs::read(&torn).unwrap();
        std::fs::write(&torn, &bytes[..bytes.len() / 3]).unwrap();
        let mut stats = ShardStats::default();
        let partials = run_gather(&t, &layout, &bins, &plan, &tasks, &mut stats).unwrap();
        // Shards 1 (corrupt, retries exhausted at deadline), 2, 3
        // (never reported) all took the straggler path.
        assert_eq!(stats.straggler_retries, 3);
        // The merged fold is still the single-worker fold, bitwise.
        assert_eq!(partials.len(), plan.ntasks());
        let f = by_name("f3", 3).unwrap();
        let opts = VSampleOpts {
            seed: 5,
            iteration: 1,
            adjust: false,
            threads: 1,
        };
        let (merged, _) =
            crate::engine::merge_task_partials(layout.d, layout.nb, false, &partials);
        let (reference, _) = crate::engine::NativeEngine.vsample(&*f, &layout, &bins, &opts);
        assert_eq!(merged.integral.to_bits(), reference.integral.to_bits());
        assert_eq!(merged.variance.to_bits(), reference.variance.to_bits());
        t.cleanup(&plan, 1);
        assert!(crate::store::list_json_sorted(&tasks_dir(&dir))
            .unwrap()
            .is_empty());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn strict_mode_surfaces_a_typed_shard_error() {
        let dir = scratch("strict");
        let t = SpoolTransport::open(&dir, fast_opts(false)).unwrap();
        let (layout, bins, plan, tasks) = setting();
        t.scatter(&tasks).unwrap();
        let mut stats = ShardStats::default();
        let err = run_gather(&t, &layout, &bins, &plan, &tasks, &mut stats).unwrap_err();
        assert!(matches!(err, Error::Shard(_)), "got {err}");
        assert!(err.to_string().contains("shard"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn inconsistent_reports_are_rejected_not_merged() {
        let dir = scratch("inconsistent");
        let t = SpoolTransport::open(&dir, fast_opts(true)).unwrap();
        let (layout, bins, plan, tasks) = setting();
        t.scatter(&tasks).unwrap();
        // Shard 0 reports shard 3's span: identity mismatch.
        let mut rogue = super::super::worker::process_task(&tasks[3], 1).unwrap();
        rogue.shard = 0;
        rogue
            .save(&reports_dir(&dir).join(spool_file_name(1, 0)))
            .unwrap();
        let mut stats = ShardStats::default();
        let partials = run_gather(&t, &layout, &bins, &plan, &tasks, &mut stats).unwrap();
        assert!(stats.straggler_retries >= 1);
        let (merged, _) =
            crate::engine::merge_task_partials(layout.d, layout.nb, false, &partials);
        let f = by_name("f3", 3).unwrap();
        let opts = VSampleOpts {
            seed: 5,
            iteration: 1,
            adjust: false,
            threads: 1,
        };
        let (reference, _) = crate::engine::NativeEngine.vsample(&*f, &layout, &bins, &opts);
        assert_eq!(merged.integral.to_bits(), reference.integral.to_bits());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn stale_reports_from_a_different_run_are_rejected_by_digest() {
        let dir = scratch("stale");
        let t = SpoolTransport::open(&dir, fast_opts(true)).unwrap();
        let (layout, bins, plan, tasks) = setting();
        t.scatter(&tasks).unwrap();
        // A straggler from a *previous run with a different seed* wrote
        // its report under the same (iteration, shard) file name. Its
        // identity and shapes all line up — only the task digest can
        // tell it apart from the real answer.
        for task in &tasks {
            let stale_task = ShardTask {
                seed: task.seed + 1,
                ..task.clone()
            };
            super::super::worker::process_task(&stale_task, 1)
                .unwrap()
                .save(&reports_dir(&dir).join(spool_file_name(1, task.shard)))
                .unwrap();
        }
        let mut stats = ShardStats::default();
        let partials = run_gather(&t, &layout, &bins, &plan, &tasks, &mut stats).unwrap();
        // Every stale report was rejected (never merged) and the spans
        // recomputed — the merge is still the seed-5 single-worker
        // fold, bitwise.
        assert_eq!(stats.straggler_retries, plan.nshards());
        let (merged, _) =
            crate::engine::merge_task_partials(layout.d, layout.nb, false, &partials);
        let f = by_name("f3", 3).unwrap();
        let opts = VSampleOpts {
            seed: 5,
            iteration: 1,
            adjust: false,
            threads: 1,
        };
        let (reference, _) = crate::engine::NativeEngine.vsample(&*f, &layout, &bins, &opts);
        assert_eq!(merged.integral.to_bits(), reference.integral.to_bits());
        assert_eq!(merged.variance.to_bits(), reference.variance.to_bits());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn open_purges_leftover_spool_files() {
        let dir = scratch("purge");
        // Seed the directory with a prior run's leftovers: a task, a
        // report, a torn .tmp, and a stop marker.
        std::fs::create_dir_all(tasks_dir(&dir)).unwrap();
        std::fs::create_dir_all(reports_dir(&dir)).unwrap();
        std::fs::write(tasks_dir(&dir).join("it00000000-s000.json"), b"{}").unwrap();
        std::fs::write(reports_dir(&dir).join("it00000000-s000.json"), b"{}").unwrap();
        std::fs::write(reports_dir(&dir).join("it00000000-s001.json.tmp"), b"{").unwrap();
        std::fs::write(stop_path(&dir), b"stop\n").unwrap();
        let _ = SpoolTransport::open(&dir, fast_opts(true)).unwrap();
        assert!(crate::store::list_json_sorted(&tasks_dir(&dir))
            .unwrap()
            .is_empty());
        assert!(crate::store::list_json_sorted(&reports_dir(&dir))
            .unwrap()
            .is_empty());
        assert!(!reports_dir(&dir).join("it00000000-s001.json.tmp").exists());
        assert!(!stop_path(&dir).exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn scatter_rejects_unresolvable_integrands_up_front() {
        let dir = scratch("unresolvable");
        let t = SpoolTransport::open(&dir, fast_opts(true)).unwrap();
        let (layout, bins, _, mut tasks) = setting();
        let _ = (layout, bins);
        tasks[0].integrand = "no-such-integrand".to_string();
        let err = t.scatter(&tasks).unwrap_err();
        assert!(matches!(err, Error::Shard(_)), "got {err}");
        let _ = std::fs::remove_dir_all(dir);
    }
}
