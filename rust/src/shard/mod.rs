//! Sharded multi-worker execution: split one integral across N shard
//! workers with a bitwise-deterministic merge.
//!
//! The engine folds every V-Sample pass over a fixed partition of the
//! cube range into reduction tasks ([`crate::engine::reduction_tasks`])
//! and merges per-task partials in task order, so the float stream is
//! a pure function of the layout — never of the thread count. This
//! module distributes exactly that task index space:
//!
//! * [`ShardPlan`] — deterministic partition of the tasks (and, for
//!   VEGAS+, the per-cube allocation's Philox counter sub-ranges) into
//!   N contiguous shard spans; no counter is drawn twice.
//! * [`ShardedBackend`] — a `VSampleBackend` that scatters spans to
//!   workers (in-process pool, or external processes via the spool
//!   transport), gathers sealed [`ShardReport`]s, and merges partials
//!   in global task order — bitwise equal to the single-worker run on
//!   both engines and both sampling modes.
//! * [`SpoolTransport`] / [`run_spool_worker`] — the process
//!   transport: sealed `$schema`-versioned task/report files with the
//!   store's canonical-JSON + sha256 integrity machinery, per-shard
//!   timeout, bounded retry, and a typed [`crate::Error::Shard`]
//!   straggler path instead of a hang.
//!
//! See `docs/sharding.md` for partition rules, counter sub-ranges,
//! merge order, and crash/straggler semantics; and
//! `examples/sharded_run.rs` for an end-to-end 2^33-call run.

mod backend;
mod coordinator;
mod plan;
mod report;
mod worker;

pub use backend::ShardedBackend;
pub use coordinator::{spool_close, spool_file_name, SpoolOptions, SpoolTransport};
pub use plan::{ShardPlan, ShardSpan};
pub use report::{ShardReport, ShardTask, TaskReport, SHARD_REPORT_SCHEMA, SHARD_TASK_SCHEMA};
pub use worker::{process_task, run_span, run_spool_worker, WorkerOutcome};

/// Cumulative shard-execution accounting for one run, surfaced
/// through `VSampleBackend::shard_stats`, `api::Session`, and the
/// service layer's `ServiceMetrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardStats {
    /// Largest effective shard count any iteration ran with.
    pub shards: usize,
    /// Total wall-clock milliseconds spent merging gathered partials
    /// (and absorbing damped observations) across iterations.
    pub merge_ms: f64,
    /// Spans recomputed by the coordinator's straggler path (timeout,
    /// corrupt report, or retry-budget exhaustion).
    pub straggler_retries: usize,
}

impl ShardStats {
    /// Fold another run segment's accounting into this one (used when
    /// a session retires one backend per stage).
    pub fn absorb(&mut self, other: ShardStats) {
        self.shards = self.shards.max(other.shards);
        self.merge_ms += other.merge_ms;
        self.straggler_retries += other.straggler_retries;
    }
}
