//! Deterministic shard partition of one iteration's work.
//!
//! The engine already folds every V-Sample pass over a fixed partition
//! of the cube range into [`reduction_tasks`] contiguous *reduction
//! tasks* (the float stream is a pure function of the layout, never of
//! the thread count). A [`ShardPlan`] regroups that same task index
//! space into `N` contiguous shard spans — the task, not the cube, is
//! the unit of distribution. Because the coordinator merges per-task
//! partials back in global task order, an N-shard run reproduces the
//! single-worker fold bitwise; see `docs/sharding.md`.
//!
//! Each span also records its Philox counter sub-range so the
//! no-counter-drawn-twice invariant is visible (and testable) at the
//! plan level: uniform sampling draws counters `cube * p + k`,
//! stratified sampling draws `offsets[cube] + k` — disjoint contiguous
//! cube spans therefore own disjoint contiguous counter sub-ranges by
//! construction.

use crate::engine::{reduction_task_span, reduction_tasks};
use crate::strat::Layout;

/// One shard's slice of an iteration: a contiguous run of reduction
/// tasks, the cube span they cover, and the Philox sample-counter
/// sub-range those cubes draw from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpan {
    /// Shard index in `0..nshards`.
    pub shard: usize,
    /// First reduction task owned by this shard.
    pub task_lo: usize,
    /// One past the last reduction task owned by this shard.
    pub task_hi: usize,
    /// First cube of `task_lo`.
    pub cube_lo: usize,
    /// One past the last cube of `task_hi - 1`.
    pub cube_hi: usize,
    /// First Philox sample counter drawn by this shard.
    pub counter_lo: u64,
    /// One past the last Philox sample counter drawn by this shard.
    pub counter_hi: u64,
}

impl ShardSpan {
    /// Number of reduction tasks in the span.
    pub fn ntasks(&self) -> usize {
        self.task_hi - self.task_lo
    }

    /// Number of cubes in the span.
    pub fn ncubes(&self) -> usize {
        self.cube_hi - self.cube_lo
    }
}

/// Deterministic partition of one iteration's reduction-task index
/// space into `N` contiguous shard spans. Pure function of
/// `(layout, allocation, shards)` — every participant (in-process
/// pool, spool coordinator, external `mcubes shard-worker` processes)
/// derives the identical plan independently.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    ntasks: usize,
    spans: Vec<ShardSpan>,
}

impl ShardPlan {
    /// Plan for the uniform (paper) allocation: every cube draws
    /// `layout.p` samples, so the counter range of cube span
    /// `[lo, hi)` is `[lo * p, hi * p)`.
    pub fn uniform(layout: &Layout, shards: usize) -> ShardPlan {
        let p = layout.p as u64;
        Self::build(layout.m, shards, |cube| cube as u64 * p)
    }

    /// Plan for a VEGAS+ adaptive allocation: cube `c` draws
    /// `counts[c]` samples starting at `offsets[c]` (exclusive prefix
    /// sum), so the counter range of cube span `[lo, hi)` is
    /// `[offsets[lo], offsets[hi])` (with the final boundary closed by
    /// `offsets[m-1] + counts[m-1]`).
    pub fn stratified(layout: &Layout, counts: &[u32], offsets: &[u64]) -> ShardPlanBuilder<'_> {
        ShardPlanBuilder {
            layout: *layout,
            counts,
            offsets,
        }
    }

    fn build(m: usize, shards: usize, counter_at: impl Fn(usize) -> u64) -> ShardPlan {
        let ntasks = reduction_tasks(m);
        let nshards = shards.min(ntasks).max(1);
        let spans = (0..nshards)
            .map(|shard| {
                let (task_lo, task_hi) = reduction_task_span(ntasks, nshards, shard);
                let (cube_lo, _) = reduction_task_span(m, ntasks, task_lo);
                let (_, cube_hi) = reduction_task_span(m, ntasks, task_hi - 1);
                ShardSpan {
                    shard,
                    task_lo,
                    task_hi,
                    cube_lo,
                    cube_hi,
                    counter_lo: counter_at(cube_lo),
                    counter_hi: counter_at(cube_hi),
                }
            })
            .collect();
        ShardPlan { ntasks, spans }
    }

    /// Number of reduction tasks being distributed
    /// (`reduction_tasks(layout.m)`).
    pub fn ntasks(&self) -> usize {
        self.ntasks
    }

    /// Effective shard count: the requested count clamped to
    /// `[1, ntasks]` (a shard always owns at least one task).
    pub fn nshards(&self) -> usize {
        self.spans.len()
    }

    /// The shard spans, in shard order. Task and cube spans are
    /// contiguous, ascending, and partition their index spaces
    /// exactly.
    pub fn spans(&self) -> &[ShardSpan] {
        &self.spans
    }
}

/// Borrow-carrying builder for [`ShardPlan::stratified`] (keeps the
/// two slice arguments next to their validation).
pub struct ShardPlanBuilder<'a> {
    layout: Layout,
    counts: &'a [u32],
    offsets: &'a [u64],
}

impl ShardPlanBuilder<'_> {
    /// Finish the stratified plan for `shards` workers.
    ///
    /// # Panics
    /// When `counts`/`offsets` do not match the layout's cube count —
    /// a caller bug (the allocation and layout travel together).
    pub fn shards(self, shards: usize) -> ShardPlan {
        let m = self.layout.m;
        assert_eq!(self.counts.len(), m, "counts/layout cube mismatch");
        assert_eq!(self.offsets.len(), m, "offsets/layout cube mismatch");
        let total = self.offsets[m - 1] + u64::from(self.counts[m - 1]);
        let offsets = self.offsets;
        ShardPlan::build(m, shards, move |cube| {
            if cube < m {
                offsets[cube]
            } else {
                total
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strat::{Allocation, DEFAULT_BETA};

    #[test]
    fn uniform_plan_partitions_tasks_cubes_and_counters_exactly() {
        // d=4, 4096 calls: m = 1296 cubes, p = 3 samples per cube.
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        for shards in [1, 2, 3, 8, 64] {
            let plan = ShardPlan::uniform(&layout, shards);
            assert_eq!(plan.nshards(), shards.min(plan.ntasks()));
            let spans = plan.spans();
            assert_eq!(spans[0].task_lo, 0);
            assert_eq!(spans[0].cube_lo, 0);
            assert_eq!(spans[0].counter_lo, 0);
            for w in spans.windows(2) {
                assert_eq!(w[0].task_hi, w[1].task_lo);
                assert_eq!(w[0].cube_hi, w[1].cube_lo);
                assert_eq!(w[0].counter_hi, w[1].counter_lo);
                assert!(w[0].ntasks() >= 1);
            }
            let last = spans[spans.len() - 1];
            assert_eq!(last.task_hi, plan.ntasks());
            assert_eq!(last.cube_hi, layout.m);
            assert_eq!(last.counter_hi, (layout.m * layout.p) as u64);
        }
    }

    #[test]
    fn shard_count_is_clamped_to_task_count() {
        let layout = Layout::compute(1, 64, 10, 1).unwrap();
        // Tiny layout: fewer tasks than requested shards.
        let ntasks = reduction_tasks(layout.m);
        let plan = ShardPlan::uniform(&layout, 1000);
        assert_eq!(plan.nshards(), ntasks);
        // Degenerate request: 0 shards still yields one.
        assert_eq!(ShardPlan::uniform(&layout, 0).nshards(), 1);
    }

    #[test]
    fn stratified_plan_counters_follow_the_allocation() {
        let layout = Layout::compute(3, 8000, 20, 1).unwrap();
        let mut alloc = Allocation::uniform(&layout);
        // Skew the allocation so offsets are genuinely non-uniform.
        alloc.absorb(0, 250.0);
        alloc.absorb(layout.m / 2, 40.0);
        alloc.reallocate(layout.calls(), DEFAULT_BETA);
        let plan = ShardPlan::stratified(&layout, alloc.counts(), alloc.offsets()).shards(8);
        let total: u64 = alloc.counts().iter().map(|&c| u64::from(c)).sum();
        let spans = plan.spans();
        assert_eq!(spans[0].counter_lo, 0);
        assert_eq!(spans[spans.len() - 1].counter_hi, total);
        for sp in spans {
            assert_eq!(sp.counter_lo, alloc.offsets()[sp.cube_lo]);
            // Span width == sum of its cubes' counts: no counter is
            // drawn twice, none is skipped.
            let width: u64 = alloc.counts()[sp.cube_lo..sp.cube_hi]
                .iter()
                .map(|&c| u64::from(c))
                .sum();
            assert_eq!(sp.counter_hi - sp.counter_lo, width);
        }
        for w in spans.windows(2) {
            assert_eq!(w[0].counter_hi, w[1].counter_lo);
        }
    }

    #[test]
    fn plan_is_a_pure_function_of_its_inputs() {
        let layout = Layout::compute(5, 4096, 20, 4).unwrap();
        assert_eq!(
            ShardPlan::uniform(&layout, 8),
            ShardPlan::uniform(&layout, 8)
        );
    }
}
