//! Sealed shard-task and shard-report documents — the spool-transport
//! wire format of the shard subsystem.
//!
//! Both document families ride the store's canonical-JSON + sha256
//! seal machinery (`crate::store`): every file carries a `$schema`
//! version tag and an integrity seal, so a torn or tampered write
//! surfaces as a typed corruption error at read time instead of a
//! silently wrong merge.
//!
//! Numeric fidelity: the JSON writer emits every non-integral f64 with
//! 17 significant digits (exact round-trip) and integers below `1e15`
//! verbatim, so estimator partials, histogram contributions, and the
//! damped-variance observations cross the process boundary bitwise.
//! Task indices are tiny (at most [`crate::engine::REDUCTION_TASKS`]);
//! cube spans are *not* serialized — both sides re-derive them from
//! the layout, which also keeps reports independent of how the cube
//! range was balanced.

use crate::engine::{reduction_task_span, reduction_tasks, TaskPartial};
use crate::error::{Error, Result};
use crate::strat::Layout;
use crate::api::GridState;
use crate::util::digest::sha256_hex;
use crate::util::json::{to_canonical_json, ObjBuilder, Value};
use std::path::Path;

/// Schema tag of a sealed shard-task file (coordinator → worker).
pub const SHARD_TASK_SCHEMA: &str = "mcubes/shard-task/v1";

/// Schema tag of a sealed shard-report file (worker → coordinator).
pub const SHARD_REPORT_SCHEMA: &str = "mcubes/shard-report/v1";

/// Largest integer the JSON number lane carries exactly (f64
/// mantissa). Layout fields beyond this cannot ride the spool
/// transport; [`check_spool_layout`] rejects them up front.
const MAX_JSON_EXACT: usize = 1 << 53;

/// Reject layouts whose fields would lose precision in JSON (cube
/// counts beyond 2^53 — far past any realistic configuration, but the
/// failure must be typed, not silent).
pub(crate) fn check_spool_layout(layout: &Layout) -> Result<()> {
    if layout.m > MAX_JSON_EXACT || layout.calls() > MAX_JSON_EXACT {
        return Err(Error::Shard(format!(
            "layout too large for the spool transport: m = {} (limit 2^53)",
            layout.m
        )));
    }
    Ok(())
}

fn layout_to_json(l: &Layout) -> Value {
    ObjBuilder::new()
        .field("d", l.d)
        .field("nb", l.nb)
        .field("g", l.g)
        .field("m", l.m)
        .field("p", l.p)
        .field("nblocks", l.nblocks)
        .field("cpb", l.cpb)
        .build()
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    v.req(key)?
        .as_usize()
        .ok_or_else(|| Error::Manifest(format!("field `{key}` is not a non-negative integer")))
}

fn req_f64(v: &Value, key: &str) -> Result<f64> {
    v.req(key)?
        .as_f64()
        .ok_or_else(|| Error::Manifest(format!("field `{key}` is not a number")))
}

fn req_u32(v: &Value, key: &str) -> Result<u32> {
    let n = req_usize(v, key)?;
    u32::try_from(n).map_err(|_| Error::Manifest(format!("field `{key}` exceeds u32: {n}")))
}

fn layout_from_json(v: &Value) -> Result<Layout> {
    let layout = Layout {
        d: req_usize(v, "d")?,
        nb: req_usize(v, "nb")?,
        g: req_usize(v, "g")?,
        m: req_usize(v, "m")?,
        p: req_usize(v, "p")?,
        nblocks: req_usize(v, "nblocks")?,
        cpb: req_usize(v, "cpb")?,
    };
    layout.validate()?;
    Ok(layout)
}

/// One shard's work order for one iteration: everything a fresh
/// process needs to reproduce its slice of the pass bitwise —
/// integrand (by registry name), layout, grid + optional VEGAS+
/// allocation snapshot, Philox seed, and the owned task range.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardTask {
    /// Registry name of the integrand (`crate::integrands::by_name`).
    pub integrand: String,
    /// The iteration's stratification layout, shipped field-for-field
    /// (never re-derived from a call budget, which could re-balance).
    pub layout: Layout,
    /// Importance grid; carries the per-cube allocation snapshot
    /// (counts + damped accumulator) when the pass is VEGAS+.
    pub grid: GridState,
    /// Philox seed of the run.
    pub seed: u32,
    /// Iteration index (part of the counter derivation).
    pub iteration: u32,
    /// Whether to accumulate the adjustment histogram.
    pub adjust: bool,
    /// Shard index in `0..nshards`.
    pub shard: usize,
    /// First owned reduction task.
    pub task_lo: usize,
    /// One past the last owned reduction task.
    pub task_hi: usize,
}

impl ShardTask {
    /// Serialize (unsealed; `save` adds the seal).
    pub fn to_json(&self) -> Value {
        ObjBuilder::new()
            .field("$schema", SHARD_TASK_SCHEMA)
            .field("integrand", self.integrand.as_str())
            .field("layout", layout_to_json(&self.layout))
            .field("grid", self.grid.to_json())
            .field("seed", i64::from(self.seed))
            .field("iteration", i64::from(self.iteration))
            .field("adjust", self.adjust)
            .field("shard", self.shard)
            .field("task_lo", self.task_lo)
            .field("task_hi", self.task_hi)
            .build()
    }

    /// Restore from `to_json` output, validating the layout and the
    /// task range.
    pub fn from_json(v: &Value) -> Result<ShardTask> {
        let layout = layout_from_json(v.req("layout")?)?;
        let task = ShardTask {
            integrand: v
                .req("integrand")?
                .as_str()
                .ok_or_else(|| Error::Manifest("integrand name".into()))?
                .to_string(),
            layout,
            grid: GridState::from_json(v.req("grid")?)?,
            seed: req_u32(v, "seed")?,
            iteration: req_u32(v, "iteration")?,
            adjust: v
                .req("adjust")?
                .as_bool()
                .ok_or_else(|| Error::Manifest("adjust flag".into()))?,
            shard: req_usize(v, "shard")?,
            task_lo: req_usize(v, "task_lo")?,
            task_hi: req_usize(v, "task_hi")?,
        };
        let ntasks = reduction_tasks(task.layout.m);
        if task.task_lo >= task.task_hi || task.task_hi > ntasks {
            return Err(Error::Manifest(format!(
                "shard task range [{}, {}) outside 0..{ntasks}",
                task.task_lo, task.task_hi
            )));
        }
        Ok(task)
    }

    /// Content digest of this task: sha256 over its canonical JSON —
    /// by construction the same hex the store's seal records in the
    /// task file. Reports carry it back ([`ShardReport::task_sha`]) so
    /// the coordinator can reject a report computed for a *different*
    /// task (stale spool leftovers from another run, seed, grid, or
    /// layout) no matter how its file is named.
    pub fn digest(&self) -> String {
        sha256_hex(to_canonical_json(&self.to_json()).as_bytes())
    }

    /// Seal and atomically write to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let sealed = crate::store::seal(self.to_json());
        crate::store::write_atomic(path, &sealed.to_json())?;
        Ok(())
    }

    /// Load a sealed task file; `Ok(None)` when absent, a typed store
    /// error when torn, tampered, or schema-mismatched.
    pub fn load(path: &Path) -> Result<Option<ShardTask>> {
        match crate::store::read_sealed(path, SHARD_TASK_SCHEMA)? {
            Some(v) => Ok(Some(ShardTask::from_json(&v)?)),
            None => Ok(None),
        }
    }
}

/// One reduction task's partial sums, as carried by a shard report.
/// The cube span is re-derived from the layout on import — see
/// [`ShardReport::into_partials`].
#[derive(Debug, Clone, PartialEq)]
pub struct TaskReport {
    /// Global reduction-task index.
    pub task: usize,
    /// Partial integral estimate.
    pub integral: f64,
    /// Partial variance estimate.
    pub variance: f64,
    /// Partial `d * nb` adjustment histogram (adjust passes only).
    pub contrib: Option<Vec<f64>>,
    /// Per-cube damped-variance observations (VEGAS+ passes only;
    /// one entry per cube of the task's span, in cube order).
    pub d_new: Vec<f64>,
}

impl From<TaskPartial> for TaskReport {
    fn from(p: TaskPartial) -> TaskReport {
        TaskReport {
            task: p.task,
            integral: p.integral,
            variance: p.variance,
            contrib: p.contrib,
            d_new: p.d_new,
        }
    }
}

/// One shard's sealed result for one iteration: the per-task partial
/// sums of every reduction task it owns, in task order.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardReport {
    /// Shard index the report answers for.
    pub shard: usize,
    /// Iteration the partials belong to.
    pub iteration: u32,
    /// [`ShardTask::digest`] of the task this report answers — binds
    /// the report to the full work order (integrand, layout, grid,
    /// seed, span), not just to a file name.
    pub task_sha: String,
    /// Per-task partials, ascending by task index.
    pub tasks: Vec<TaskReport>,
}

impl ShardReport {
    /// Package a worker's partials (already in task order) as the
    /// answer to the task whose [`ShardTask::digest`] is `task_sha`.
    pub fn from_partials(
        shard: usize,
        iteration: u32,
        task_sha: String,
        partials: Vec<TaskPartial>,
    ) -> ShardReport {
        ShardReport {
            shard,
            iteration,
            task_sha,
            tasks: partials.into_iter().map(TaskReport::from).collect(),
        }
    }

    /// Rehydrate engine partials, re-deriving each task's cube span
    /// from `layout` (`reduction_task_span` is a pure function, so
    /// every participant derives the same spans).
    pub fn into_partials(self, layout: &Layout) -> Vec<TaskPartial> {
        let ntasks = reduction_tasks(layout.m);
        self.tasks
            .into_iter()
            .map(|t| {
                let (cube_lo, cube_hi) = reduction_task_span(layout.m, ntasks, t.task);
                TaskPartial {
                    task: t.task,
                    cube_lo,
                    cube_hi,
                    integral: t.integral,
                    variance: t.variance,
                    contrib: t.contrib,
                    d_new: t.d_new,
                }
            })
            .collect()
    }

    /// Serialize (unsealed; `save` adds the seal).
    pub fn to_json(&self) -> Value {
        let tasks: Vec<Value> = self
            .tasks
            .iter()
            .map(|t| {
                let mut b = ObjBuilder::new()
                    .field("task", t.task)
                    .field("integral", t.integral)
                    .field("variance", t.variance);
                if let Some(c) = &t.contrib {
                    b = b.field("contrib", c.clone());
                }
                b.field("d_new", t.d_new.clone()).build()
            })
            .collect();
        ObjBuilder::new()
            .field("$schema", SHARD_REPORT_SCHEMA)
            .field("shard", self.shard)
            .field("iteration", i64::from(self.iteration))
            .field("task_sha", self.task_sha.as_str())
            .field("tasks", tasks)
            .build()
    }

    /// Restore from `to_json` output.
    pub fn from_json(v: &Value) -> Result<ShardReport> {
        let raw = v
            .req("tasks")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("shard report tasks".into()))?;
        let mut tasks = Vec::with_capacity(raw.len());
        for tv in raw {
            let contrib = match tv.get("contrib") {
                Some(c) => Some(
                    c.as_f64_vec()
                        .ok_or_else(|| Error::Manifest("task contrib".into()))?,
                ),
                None => None,
            };
            tasks.push(TaskReport {
                task: req_usize(tv, "task")?,
                integral: req_f64(tv, "integral")?,
                variance: req_f64(tv, "variance")?,
                contrib,
                d_new: tv
                    .req("d_new")?
                    .as_f64_vec()
                    .ok_or_else(|| Error::Manifest("task d_new".into()))?,
            });
        }
        Ok(ShardReport {
            shard: req_usize(v, "shard")?,
            iteration: req_u32(v, "iteration")?,
            task_sha: v
                .req("task_sha")?
                .as_str()
                .ok_or_else(|| Error::Manifest("task_sha digest".into()))?
                .to_string(),
            tasks,
        })
    }

    /// Seal and atomically write to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let sealed = crate::store::seal(self.to_json());
        crate::store::write_atomic(path, &sealed.to_json())?;
        Ok(())
    }

    /// Load a sealed report file; `Ok(None)` when absent, a typed
    /// store error when torn, tampered, or schema-mismatched.
    pub fn load(path: &Path) -> Result<Option<ShardReport>> {
        match crate::store::read_sealed(path, SHARD_REPORT_SCHEMA)? {
            Some(v) => Ok(Some(ShardReport::from_json(&v)?)),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::StratSnapshot;
    use crate::grid::Bins;

    fn scratch(tag: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "mcubes-shard-report-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn awkward(k: usize) -> f64 {
        let kf = k as f64;
        (kf - 17.5) * (1.0 / 3.0) + 1e-13 * kf.sin()
    }

    #[test]
    fn task_file_roundtrips_bitwise_including_strat_snapshot() {
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let grid = GridState::from_bins(Bins::uniform(4, 16)).with_strat(StratSnapshot {
            beta: 0.75,
            counts: vec![3; layout.m],
            damped: (0..layout.m).map(|k| awkward(k).abs()).collect(),
        });
        let task = ShardTask {
            integrand: "f4".to_string(),
            layout,
            grid,
            seed: 42,
            iteration: 3,
            adjust: true,
            shard: 5,
            task_lo: 40,
            task_hi: 48,
        };
        let dir = scratch("task");
        let path = dir.join("it00000003-s005.json");
        task.save(&path).unwrap();
        let back = ShardTask::load(&path).unwrap().unwrap();
        assert_eq!(back, task);
        let s = back.grid.strat().unwrap();
        for (a, b) in s.damped.iter().zip(task.grid.strat().unwrap().damped.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn task_digest_matches_the_file_seal_and_tracks_content() {
        let layout = Layout::compute(3, 512, 8, 1).unwrap();
        let task = ShardTask {
            integrand: "f3".to_string(),
            layout,
            grid: GridState::from_bins(Bins::uniform(3, 8)),
            seed: 5,
            iteration: 1,
            adjust: false,
            shard: 0,
            task_lo: 0,
            task_hi: 4,
        };
        let dir = scratch("digest");
        let path = dir.join("it00000001-s000.json");
        task.save(&path).unwrap();
        // digest() is exactly the sha256 seal the store wrote.
        let text = std::fs::read_to_string(&path).unwrap();
        let sealed = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            sealed.get("sha256").and_then(Value::as_str),
            Some(task.digest().as_str())
        );
        // Any semantic change — here the seed — moves the digest.
        let other = ShardTask { seed: 6, ..task.clone() };
        assert_ne!(task.digest(), other.digest());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn report_roundtrips_bitwise_and_rederives_cube_spans() {
        let layout = Layout::compute(4, 4096, 16, 1).unwrap();
        let ntasks = reduction_tasks(layout.m);
        let partials: Vec<TaskPartial> = (10..14)
            .map(|t| {
                let (cube_lo, cube_hi) = reduction_task_span(layout.m, ntasks, t);
                TaskPartial {
                    task: t,
                    cube_lo,
                    cube_hi,
                    integral: awkward(t),
                    variance: awkward(t + 1).abs(),
                    contrib: Some((0..layout.d * layout.nb).map(awkward).collect()),
                    d_new: (cube_lo..cube_hi).map(awkward).collect(),
                }
            })
            .collect();
        let rep = ShardReport::from_partials(2, 7, "a".repeat(64), partials.clone());
        let dir = scratch("report");
        let path = dir.join("it00000007-s002.json");
        rep.save(&path).unwrap();
        let back = ShardReport::load(&path).unwrap().unwrap();
        assert_eq!(back.shard, 2);
        assert_eq!(back.iteration, 7);
        assert_eq!(back.task_sha, rep.task_sha);
        let restored = back.into_partials(&layout);
        assert_eq!(restored.len(), partials.len());
        for (a, b) in restored.iter().zip(partials.iter()) {
            assert_eq!(a.task, b.task);
            assert_eq!(a.cube_lo, b.cube_lo);
            assert_eq!(a.cube_hi, b.cube_hi);
            assert_eq!(a.integral.to_bits(), b.integral.to_bits());
            assert_eq!(a.variance.to_bits(), b.variance.to_bits());
            let (ca, cb) = (a.contrib.as_ref().unwrap(), b.contrib.as_ref().unwrap());
            for (x, y) in ca.iter().zip(cb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in a.d_new.iter().zip(b.d_new.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn torn_and_tampered_files_surface_typed_errors() {
        let layout = Layout::compute(3, 512, 8, 1).unwrap();
        let rep = ShardReport::from_partials(
            0,
            1,
            "b".repeat(64),
            vec![TaskPartial {
                task: 0,
                cube_lo: 0,
                cube_hi: 9,
                integral: 1.25,
                variance: 0.5,
                contrib: None,
                d_new: Vec::new(),
            }],
        );
        let dir = scratch("torn");
        let path = dir.join("it00000001-s000.json");
        rep.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Truncation (torn write) → corrupt, never a silent partial.
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(ShardReport::load(&path).is_err());
        // Bit flip inside the payload → seal mismatch.
        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        assert!(ShardReport::load(&path).is_err());
        // Wrong schema family → UnsupportedSchema, not a parse of
        // look-alike fields (restore the intact report bytes first so
        // the seal verifies and only the schema check can fire).
        std::fs::write(&path, &good).unwrap();
        assert!(ShardTask::load(&path).is_err());
        // Missing file → Ok(None).
        let _ = std::fs::remove_dir_all(&dir);
        assert!(ShardReport::load(&path).unwrap().is_none());
        // Oversized layouts are rejected up front.
        assert!(check_spool_layout(&layout).is_ok());
        let huge = Layout {
            m: (1usize << 53) + 1,
            ..layout
        };
        assert!(check_spool_layout(&huge).is_err());
    }
}
