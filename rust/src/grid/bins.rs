//! Bin-boundary storage: d axes × nb right edges in unit space.

use super::adjust::{rebin, smooth_weights};
use super::GridMode;
use crate::error::{Error, Result};

/// Importance-bin boundaries. Row-major `[d][nb]` right edges; the left
/// edge of bin 0 is implicitly 0.0 and `edges[axis][nb-1] == 1.0`.
#[derive(Debug, Clone, PartialEq)]
pub struct Bins {
    d: usize,
    nb: usize,
    edges: Vec<f64>,
    mode: GridMode,
}

impl Bins {
    /// Equal-width bins (Init-Bins, Algorithm 2 line 6).
    pub fn uniform(d: usize, nb: usize) -> Bins {
        Self::uniform_mode(d, nb, GridMode::PerAxis)
    }

    pub fn uniform_mode(d: usize, nb: usize, mode: GridMode) -> Bins {
        assert!(d >= 1 && nb >= 2, "need d>=1, nb>=2");
        let mut edges = Vec::with_capacity(d * nb);
        for _ in 0..d {
            for b in 1..=nb {
                edges.push(b as f64 / nb as f64);
            }
        }
        Bins { d, nb, edges, mode }
    }

    /// Build from explicit edges (row-major d*nb). Validates monotonicity.
    pub fn from_edges(d: usize, nb: usize, edges: Vec<f64>, mode: GridMode) -> Result<Bins> {
        if edges.len() != d * nb {
            return Err(Error::Config(format!(
                "edges len {} != d*nb {}",
                edges.len(),
                d * nb
            )));
        }
        let b = Bins { d, nb, edges, mode };
        b.validate()?;
        Ok(b)
    }

    pub fn d(&self) -> usize {
        self.d
    }
    pub fn nb(&self) -> usize {
        self.nb
    }
    pub fn mode(&self) -> GridMode {
        self.mode
    }

    /// Right edges of one axis.
    #[inline]
    pub fn axis(&self, axis: usize) -> &[f64] {
        &self.edges[axis * self.nb..(axis + 1) * self.nb]
    }

    /// Flat row-major view (what the PJRT executable consumes).
    pub fn flat(&self) -> &[f64] {
        &self.edges
    }

    /// Left edge of bin `b` on `axis`.
    #[inline]
    pub fn left(&self, axis: usize, b: usize) -> f64 {
        if b == 0 {
            0.0
        } else {
            self.axis(axis)[b - 1]
        }
    }

    /// Width of bin `b` on `axis`.
    #[inline]
    pub fn width(&self, axis: usize, b: usize) -> f64 {
        self.axis(axis)[b] - self.left(axis, b)
    }

    /// Check structural invariants: monotone, positive widths, ends at 1.
    pub fn validate(&self) -> Result<()> {
        for axis in 0..self.d {
            let e = self.axis(axis);
            let mut prev = 0.0;
            for (i, &x) in e.iter().enumerate() {
                if !(x > prev) {
                    return Err(Error::Config(format!(
                        "axis {axis} bin {i}: edge {x} <= previous {prev}"
                    )));
                }
                prev = x;
            }
            if (e[self.nb - 1] - 1.0).abs() > 1e-12 {
                return Err(Error::Config(format!(
                    "axis {axis}: last edge {} != 1.0",
                    e[self.nb - 1]
                )));
            }
        }
        Ok(())
    }

    /// One VEGAS refinement step from a contribution histogram
    /// `contrib[d][nb]` (row-major). In `Shared1D` mode only axis 0 of
    /// the histogram drives the (shared) boundary update and every axis
    /// receives identical edges — the m-Cubes1D variant.
    pub fn adjust(&mut self, contrib: &[f64]) {
        assert_eq!(contrib.len(), self.d * self.nb, "contrib shape");
        match self.mode {
            GridMode::PerAxis => {
                let mut scratch = vec![0.0; self.nb];
                for axis in 0..self.d {
                    let c = &contrib[axis * self.nb..(axis + 1) * self.nb];
                    if let Some(w) = smooth_weights(c, &mut scratch) {
                        let row =
                            &mut self.edges[axis * self.nb..(axis + 1) * self.nb];
                        rebin(row, w);
                    }
                }
            }
            GridMode::Shared1D => {
                // Accumulate every axis's histogram into one row so the
                // shared boundaries see all the evidence (for a fully
                // symmetric integrand the rows are statistically
                // identical; summing reduces variance).
                let mut c = vec![0.0; self.nb];
                for axis in 0..self.d {
                    for b in 0..self.nb {
                        c[b] += contrib[axis * self.nb + b];
                    }
                }
                let mut scratch = vec![0.0; self.nb];
                if let Some(w) = smooth_weights(&c, &mut scratch) {
                    rebin(&mut self.edges[0..self.nb], w);
                    let (first, rest) = self.edges.split_at_mut(self.nb);
                    for axis in 1..self.d {
                        rest[(axis - 1) * self.nb..axis * self.nb]
                            .copy_from_slice(first);
                    }
                }
            }
        }
        debug_assert!(self.validate().is_ok());
    }

    /// Serialize the adapted grid to JSON — checkpoint/resume support
    /// for long pipelines (the paper's "complicated pipelines" §6 use
    /// case: adapt once on a cheap target, reuse the grid later).
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::ObjBuilder;
        ObjBuilder::new()
            .field("d", self.d)
            .field("nb", self.nb)
            .field(
                "mode",
                match self.mode {
                    GridMode::PerAxis => "per_axis",
                    GridMode::Shared1D => "shared_1d",
                },
            )
            .field("edges", self.edges.clone())
            .build()
    }

    /// Restore a grid from `to_json` output (validates invariants).
    pub fn from_json(v: &crate::util::json::Value) -> Result<Bins> {
        let d = v
            .req("d")?
            .as_usize()
            .ok_or_else(|| Error::Manifest("d".into()))?;
        let nb = v
            .req("nb")?
            .as_usize()
            .ok_or_else(|| Error::Manifest("nb".into()))?;
        let mode = match v.req("mode")?.as_str() {
            Some("per_axis") => GridMode::PerAxis,
            Some("shared_1d") => GridMode::Shared1D,
            other => {
                return Err(Error::Manifest(format!("bad grid mode {other:?}")))
            }
        };
        let edges = v
            .req("edges")?
            .as_f64_vec()
            .ok_or_else(|| Error::Manifest("edges".into()))?;
        Bins::from_edges(d, nb, edges, mode)
    }

    /// Save to a file (JSON).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_json())?;
        Ok(())
    }

    /// Load from a file written by `save`.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Bins> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&crate::util::json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_valid() {
        let b = Bins::uniform(3, 50);
        b.validate().unwrap();
        assert_eq!(b.axis(2)[49], 1.0);
        assert!((b.width(1, 7) - 0.02).abs() < 1e-15);
        assert_eq!(b.left(0, 0), 0.0);
    }

    #[test]
    fn from_edges_validates() {
        assert!(Bins::from_edges(1, 3, vec![0.5, 0.4, 1.0], GridMode::PerAxis).is_err());
        assert!(Bins::from_edges(1, 3, vec![0.2, 0.8, 0.9], GridMode::PerAxis).is_err());
        assert!(Bins::from_edges(1, 3, vec![0.2, 0.8, 1.0], GridMode::PerAxis).is_ok());
    }

    #[test]
    fn adjust_concentrates_bins_at_peak() {
        // Put all contribution mass in the first 10% of the axis; bins
        // must migrate left (smaller widths near 0).
        let mut b = Bins::uniform(1, 10);
        let mut contrib = vec![0.0; 10];
        contrib[0] = 100.0;
        contrib[1] = 50.0;
        for _ in 0..5 {
            b.adjust(&contrib);
        }
        b.validate().unwrap();
        assert!(
            b.width(0, 0) < 0.05,
            "first bin should shrink, got {}",
            b.width(0, 0)
        );
    }

    #[test]
    fn adjust_flat_contributions_keeps_uniform() {
        let mut b = Bins::uniform(2, 8);
        let before = b.flat().to_vec();
        b.adjust(&vec![3.0; 16]);
        for (x, y) in b.flat().iter().zip(&before) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn adjust_zero_contributions_noop() {
        let mut b = Bins::uniform(2, 8);
        let before = b.flat().to_vec();
        b.adjust(&vec![0.0; 16]);
        assert_eq!(b.flat(), &before[..]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut b = Bins::uniform(3, 16);
        let mut contrib = vec![1.0; 48];
        contrib[5] = 40.0;
        contrib[20] = 25.0;
        b.adjust(&contrib);
        let back = Bins::from_json(&b.to_json()).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn checkpoint_file_roundtrip() {
        let mut b = Bins::uniform_mode(2, 8, GridMode::Shared1D);
        b.adjust(&{
            let mut c = vec![1.0; 16];
            c[0] = 30.0;
            c
        });
        let path = std::env::temp_dir().join("mcubes_bins_ckpt_test.json");
        b.save(&path).unwrap();
        let back = Bins::load(&path).unwrap();
        assert_eq!(back, b);
        assert_eq!(back.mode(), GridMode::Shared1D);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn checkpoint_rejects_corrupt() {
        let v = crate::util::json::parse(
            r#"{"d": 1, "nb": 3, "mode": "per_axis", "edges": [0.9, 0.5, 1.0]}"#,
        )
        .unwrap();
        assert!(Bins::from_json(&v).is_err()); // non-monotone
    }

    #[test]
    fn shared1d_keeps_axes_identical() {
        let mut b = Bins::uniform_mode(3, 12, GridMode::Shared1D);
        let mut contrib = vec![0.0; 36];
        // asymmetric evidence on axis 0 only — Shared1D pools it
        for i in 0..12 {
            contrib[i] = (i as f64).exp().min(100.0);
        }
        b.adjust(&contrib);
        b.validate().unwrap();
        assert_eq!(b.axis(0), b.axis(1));
        assert_eq!(b.axis(0), b.axis(2));
    }
}
