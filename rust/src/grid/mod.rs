//! The VEGAS importance grid: per-axis bin boundaries + their adaptive
//! refinement (Algorithm 2 line 12, "Adjust-Bin-Bounds").
//!
//! This runs on the *coordinator* (host) side, exactly as the paper's
//! CUDA implementation adjusts bins on the CPU between kernel launches.
//! Only `bins` (d*nb doubles) and the contribution histogram cross the
//! host/device boundary — the m-Cubes data-movement contribution.

mod adjust;
mod bins;

pub use adjust::{rebin, smooth_weights, ALPHA};
pub use bins::Bins;

/// How bin boundaries are shared across axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridMode {
    /// Standard m-Cubes: independent bins per axis.
    PerAxis,
    /// m-Cubes1D (paper §5.4): one shared boundary set for all axes —
    /// correct only for fully-symmetric integrands, and faster because
    /// a single axis histogram is accumulated and adjusted.
    Shared1D,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_mode_eq() {
        assert_ne!(GridMode::PerAxis, GridMode::Shared1D);
    }
}
