//! Contribution smoothing + boundary re-partitioning (classic VEGAS,
//! Lepage 1978; same damped scheme as GSL's `refine_grid`).
//!
//! Validated against the Python prototype in the repo history and the
//! golden-driven integration tests: given the same histogram the Rust
//! and Python adjusters produce identical edges to fp round-off.

/// Damping exponent. 1.5 is the standard VEGAS choice.
pub const ALPHA: f64 = 1.5;

const TINY: f64 = 1e-30;

/// Smooth a raw contribution histogram and convert it to re-partition
/// weights: w = ((r - 1)/ln r)^ALPHA with r the normalized smoothed
/// contribution. Returns `None` when the histogram carries no signal
/// (all zeros) — callers must leave the grid unchanged in that case.
///
/// `scratch` must have the same length and is used for the smoothed
/// values to avoid per-iteration allocation in the driver loop.
pub fn smooth_weights<'a>(contrib: &[f64], scratch: &'a mut [f64]) -> Option<&'a [f64]> {
    let nb = contrib.len();
    assert!(nb >= 2, "need at least 2 bins");
    assert_eq!(scratch.len(), nb);

    // 3-point smoothing (endpoints: 2-point), as in GSL/Lepage.
    scratch[0] = (contrib[0] + contrib[1]) / 2.0;
    scratch[nb - 1] = (contrib[nb - 2] + contrib[nb - 1]) / 2.0;
    for i in 1..nb - 1 {
        scratch[i] = (contrib[i - 1] + contrib[i] + contrib[i + 1]) / 3.0;
    }
    let total: f64 = scratch.iter().sum();
    if total <= 0.0 {
        return None;
    }
    for v in scratch.iter_mut() {
        let r = *v / total;
        *v = if r > TINY {
            // lim_{r->1} (r-1)/ln r = 1, and the expression is smooth;
            // guard the removable singularity explicitly.
            let q = if (r - 1.0).abs() < 1e-12 {
                1.0
            } else {
                (r - 1.0) / r.ln()
            };
            q.powf(ALPHA)
        } else {
            0.0
        };
        if *v < TINY {
            *v = TINY;
        }
    }
    Some(scratch)
}

/// Re-partition one axis's right edges so each new bin carries an equal
/// share of `weights`. `edges` holds the nb right edges (left edge 0
/// implicit, last edge stays exactly 1.0).
///
/// Robust against fp drift: when the running weight sum rounds below
/// `target` on the final marks, the `j < nb` guard exits the consume
/// loop early and `acc` goes negative, which would interpolate a mark
/// *past* 1.0 (or, with degenerate weights, produce a non-finite or
/// non-increasing mark). Every mark is therefore clamped strictly
/// inside `(previous mark, 1.0)`, so the grid stays strictly monotone
/// with its final edge exactly 1.0 for any weight vector — one-hot,
/// TINY-floored, and near-equal vectors are property-tested. A weight
/// vector with no usable signal (all-zero / non-finite total) leaves
/// the grid unchanged, matching `smooth_weights`' `None`.
pub fn rebin(edges: &mut [f64], weights: &[f64]) {
    let nb = edges.len();
    assert_eq!(weights.len(), nb);
    let total: f64 = weights.iter().sum();
    if !(total > 0.0) || !total.is_finite() {
        // No usable signal (all-zero, negative-sum, or non-finite
        // weights): leave the grid unchanged, matching the
        // `smooth_weights` -> `None` contract upstream.
        return;
    }
    let target = total / nb as f64;

    let mut new_edges = vec![0.0; nb];
    let mut acc = 0.0; // weight accumulated so far
    let mut j = 0usize; // old bin cursor (0-based; consumed bins < j)
    let mut prev_edge = 0.0;
    let mut last_new = 0.0; // previous mark — enforced lower bound
    for k in 0..nb - 1 {
        // Consume old bins until we pass the (k+1)-th equal-weight mark.
        // (j < nb guards fp drift on the final marks.)
        while acc < target && j < nb {
            acc += weights[j];
            prev_edge = if j == 0 { 0.0 } else { edges[j - 1] };
            j += 1;
        }
        acc -= target;
        // We overshot inside old bin j-1: interpolate back.
        let right = edges[j - 1];
        let width = right - prev_edge;
        let mut e = right - acc / weights[j - 1] * width;
        if !(e > last_new && e < 1.0) {
            // fp drift (negative `acc` after an early exit above, or a
            // zero-weight division) pushed the mark out of range; pin
            // it to the midpoint of what remains so later marks still
            // have room.
            e = last_new + (1.0 - last_new) * 0.5;
        }
        debug_assert!(
            e > last_new && e < 1.0,
            "rebin mark {k} = {e} escaped ({last_new}, 1)"
        );
        new_edges[k] = e;
        last_new = e;
    }
    new_edges[nb - 1] = 1.0;
    edges.copy_from_slice(&new_edges);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_weights_give_uniform_edges() {
        let mut edges: Vec<f64> = (1..=8).map(|i| i as f64 / 8.0).collect();
        let w = vec![2.0; 8];
        rebin(&mut edges, &w);
        for (i, &e) in edges.iter().enumerate() {
            assert!((e - (i + 1) as f64 / 8.0).abs() < 1e-12);
        }
    }

    #[test]
    fn rebin_preserves_monotonicity_and_ends() {
        let mut edges: Vec<f64> = (1..=16).map(|i| (i as f64 / 16.0).powf(1.4)).collect();
        edges[15] = 1.0;
        let w: Vec<f64> = (0..16).map(|i| 1.0 + (i as f64).sin().abs()).collect();
        rebin(&mut edges, &w);
        let mut prev = 0.0;
        for &e in &edges {
            assert!(e > prev);
            prev = e;
        }
        assert_eq!(edges[15], 1.0);
    }

    #[test]
    fn rebin_equalizes_weight_mass() {
        // After rebinning with piecewise-constant density, each new bin
        // should hold ~equal mass of that density.
        let nb = 10;
        let mut edges: Vec<f64> = (1..=nb).map(|i| i as f64 / nb as f64).collect();
        let mut w = vec![1.0; nb];
        w[0] = 9.0; // hot first bin
        let old_edges = edges.clone();
        let old_w = w.clone();
        rebin(&mut edges, &w);
        // density over [0, 0.1) is 90, elsewhere 1 (per unit length)
        let mass = |a: f64, b: f64| -> f64 {
            let mut m = 0.0;
            let mut lo = a;
            for i in 0..nb {
                let left = if i == 0 { 0.0 } else { old_edges[i - 1] };
                let right = old_edges[i];
                let dens = old_w[i] / (right - left);
                let seg_lo = lo.max(left);
                let seg_hi = b.min(right);
                if seg_hi > seg_lo {
                    m += dens * (seg_hi - seg_lo);
                }
                lo = a;
            }
            m
        };
        let total: f64 = old_w.iter().sum();
        let target = total / nb as f64;
        let mut prev = 0.0;
        for &e in &edges {
            let got = mass(prev, e);
            assert!(
                (got - target).abs() < 1e-9,
                "bin [{prev},{e}] mass {got} != {target}"
            );
            prev = e;
        }
    }

    #[test]
    fn rebin_one_hot_weights_stay_strictly_monotone() {
        // One-hot with exact zeros elsewhere: the consume loop can run
        // off the end (zero bins add nothing), leaving `acc` negative —
        // unclamped, the final marks land at or above 1.0.
        for hot in [0usize, 7, 15] {
            let nb = 16;
            let mut edges: Vec<f64> = (1..=nb).map(|i| i as f64 / nb as f64).collect();
            let mut w = vec![0.0; nb];
            w[hot] = 3.0;
            rebin(&mut edges, &w);
            let mut prev = 0.0;
            for &e in &edges {
                assert!(e > prev && e <= 1.0, "hot={hot}: edges {edges:?}");
                prev = e;
            }
            assert_eq!(edges[nb - 1], 1.0);
        }
    }

    #[test]
    fn rebin_without_signal_leaves_grid_unchanged() {
        let mut edges: Vec<f64> = (1..=8).map(|i| (i as f64 / 8.0).powi(2)).collect();
        edges[7] = 1.0;
        let before = edges.clone();
        rebin(&mut edges, &[0.0; 8]);
        assert_eq!(edges, before);
        rebin(&mut edges, &[f64::NAN; 8]);
        assert_eq!(edges, before);
    }

    #[test]
    fn rebin_survives_repeated_near_equal_weights() {
        // Compound hundreds of rebins with weights a few ulps apart —
        // the drift regime where the running sum rounds below target
        // on the last mark.
        let nb = 48;
        let mut edges: Vec<f64> = (1..=nb).map(|i| i as f64 / nb as f64).collect();
        for round in 0..300 {
            let w: Vec<f64> = (0..nb)
                .map(|i| 1.0 + ((i + round) % 7) as f64 * 1e-16)
                .collect();
            rebin(&mut edges, &w);
            let mut prev = 0.0;
            for &e in &edges {
                assert!(e > prev && e <= 1.0, "round {round}: {edges:?}");
                prev = e;
            }
            assert_eq!(edges[nb - 1], 1.0);
        }
    }

    #[test]
    fn smooth_weights_none_on_zero() {
        let mut scratch = vec![0.0; 5];
        assert!(smooth_weights(&[0.0; 5], &mut scratch).is_none());
    }

    #[test]
    fn smooth_weights_flat_is_constant() {
        // Flat contributions give equal (not unit) weights — rebinning
        // with constant weights leaves the grid uniform.
        let mut scratch = vec![0.0; 6];
        let w = smooth_weights(&[4.0; 6], &mut scratch).unwrap();
        for pair in w.windows(2) {
            assert!((pair[0] - pair[1]).abs() < 1e-12, "{w:?}");
        }
        assert!(w[0] > 0.0);
    }

    #[test]
    fn smooth_weights_monotone_in_contribution() {
        let mut scratch = vec![0.0; 8];
        let mut c = vec![1.0; 8];
        c[3] = 50.0;
        let w = smooth_weights(&c, &mut scratch).unwrap().to_vec();
        assert!(w[3] > w[0], "hot bin must get more weight: {w:?}");
        assert!(w.iter().all(|&x| x >= TINY));
    }

    #[test]
    fn weights_positive_even_with_empty_bins() {
        let mut scratch = vec![0.0; 6];
        let c = [0.0, 0.0, 10.0, 0.0, 0.0, 0.0];
        let w = smooth_weights(&c, &mut scratch).unwrap();
        assert!(w.iter().all(|&x| x > 0.0));
    }
}
