//! Minimal self-contained JSON parser + writer.
//!
//! The offline crate registry has no `serde`, so the artifact manifest,
//! golden files, and report outputs go through this module. It supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) and preserves object insertion order.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Field lookup that errors with context instead of returning None.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing field `{key}`")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect::<Vec<_>>())
            .filter(|v| Some(v.len()) == self.as_arr().map(|a| a.len()))
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_to(&mut s);
        s
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Value::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects preserving insertion order.
#[derive(Default)]
pub struct ObjBuilder {
    fields: Vec<(String, Value)>,
}

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn field(mut self, key: &str, value: impl Into<Value>) -> Self {
        self.fields.push((key.to_string(), value.into()));
        self
    }
    pub fn build(self) -> Value {
        Value::Obj(self.fields)
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            // 17 significant digits round-trips f64 exactly.
            let _ = write!(out, "{n:.17e}");
        }
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(fields)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode the UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Recursively sort every object's fields by key (arrays keep their
/// order — array position is semantic, field order is not). Duplicate
/// keys keep their relative order (the sort is stable); the writers in
/// this crate never emit duplicates.
///
/// This is the normalization half of the store's canonical form: two
/// `Value`s that differ only in field order canonicalize identically.
pub fn canonicalize(v: &Value) -> Value {
    match v {
        Value::Arr(items) => Value::Arr(items.iter().map(canonicalize).collect()),
        Value::Obj(fields) => {
            let mut sorted: Vec<(String, Value)> = fields
                .iter()
                .map(|(k, val)| (k.clone(), canonicalize(val)))
                .collect();
            sorted.sort_by(|a, b| a.0.cmp(&b.0));
            Value::Obj(sorted)
        }
        other => other.clone(),
    }
}

/// Canonical serialization: sorted keys ([`canonicalize`]) + the
/// compact writer's fixed number format (`write_num`: integers with
/// |n| < 1e15 as plain integers, everything else as 17-significant-
/// digit scientific notation, which round-trips f64 exactly). The
/// same `Value` — however its fields were ordered, on whatever
/// platform — always yields the same bytes, so this is the input both
/// to the store's content-address digests and to its anti-torn-write
/// checksums (see `store::` and docs/service.md).
pub fn to_canonical_json(v: &Value) -> String {
    canonicalize(v).to_json()
}

/// Group object fields into a BTreeMap for order-insensitive comparison.
pub fn to_map(v: &Value) -> BTreeMap<String, Value> {
    match v {
        Value::Obj(f) => f.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_raw() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("\"\\ud800x\"").is_err());
    }

    #[test]
    fn roundtrip_f64_exact() {
        for x in [1.0 / 3.0, 2.5e-308, 1.23456789012345e300, -0.1] {
            let s = Value::Num(x).to_json();
            let back = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "roundtrip {s}");
        }
    }

    #[test]
    fn roundtrip_document() {
        let doc = ObjBuilder::new()
            .field("name", "f4_d5")
            .field("dim", 5usize)
            .field("vals", vec![1.5f64, 2.5, -3.0])
            .field("ok", true)
            .build();
        let s = doc.to_json();
        let back = parse(&s).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(vec![]));
        assert_eq!(parse(" [ ] ").unwrap(), Value::Arr(vec![]));
    }

    #[test]
    fn as_f64_vec() {
        let v = parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.5, 3.0]);
        let bad = parse("[1, \"x\"]").unwrap();
        assert!(bad.as_f64_vec().is_none());
    }

    #[test]
    fn req_errors() {
        let v = parse("{\"a\": 1}").unwrap();
        assert!(v.req("a").is_ok());
        assert!(v.req("b").is_err());
    }

    #[test]
    fn canonical_is_field_order_invariant() {
        let a = parse(r#"{"b": 1, "a": {"y": true, "x": [1, {"q": 2, "p": 3}]}}"#).unwrap();
        let b = parse(r#"{"a": {"x": [1, {"p": 3, "q": 2}], "y": true}, "b": 1}"#).unwrap();
        assert_eq!(to_canonical_json(&a), to_canonical_json(&b));
        assert_eq!(
            to_canonical_json(&a),
            r#"{"a":{"x":[1,{"p":3,"q":2}],"y":true},"b":1}"#
        );
    }

    #[test]
    fn canonical_preserves_array_order() {
        let a = parse("[1, 2, 3]").unwrap();
        let b = parse("[3, 2, 1]").unwrap();
        assert_ne!(to_canonical_json(&a), to_canonical_json(&b));
    }

    #[test]
    fn canonical_number_format_is_fixed() {
        // The same f64 reached through different decimal spellings
        // serializes identically — cache keys cannot depend on how a
        // hand-written manifest formatted its numbers.
        let a = parse(r#"{"t": 0.5, "n": 42, "big": 1e300}"#).unwrap();
        let b = parse(r#"{"n": 42.0, "big": 10e299, "t": 5e-1}"#).unwrap();
        assert_eq!(to_canonical_json(&a), to_canonical_json(&b));
        let canon = to_canonical_json(&a);
        assert!(canon.contains(r#""n":42"#), "{canon}");
        assert!(canon.contains(r#""t":5.00000000000000000e-1"#), "{canon}");
    }

    #[test]
    fn canonical_roundtrips_through_parse() {
        // parse(canonical(v)) re-canonicalizes to the same bytes: the
        // property the store's checksum verification relies on.
        let doc = ObjBuilder::new()
            .field("z", 1.0 / 3.0)
            .field("a", vec![1.5f64, -0.0, 2e-308])
            .field("m", ObjBuilder::new().field("k", "v").build())
            .build();
        let canon = to_canonical_json(&doc);
        let back = parse(&canon).unwrap();
        assert_eq!(to_canonical_json(&back), canon);
    }
}
