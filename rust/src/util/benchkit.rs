//! Micro/macro benchmark harness (no criterion offline).
//!
//! `cargo bench` targets are `harness = false` binaries built on this:
//! warmup, repeated timed runs, robust stats, paper-style table
//! printing via `util::table`, and machine-greppable `BENCH {...}`
//! JSON lines via [`bench_json_line`] / [`emit_bench`] so the perf
//! trajectory of a series can be recorded across runs.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use crate::util::json::ObjBuilder;
use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples_ms: Vec<f64>,
}

impl Stats {
    pub fn from_ms(mut samples_ms: Vec<f64>) -> Stats {
        samples_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats { samples_ms }
    }

    pub fn n(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean_ms(&self) -> f64 {
        self.samples_ms.iter().sum::<f64>() / self.n().max(1) as f64
    }

    pub fn median_ms(&self) -> f64 {
        percentile_sorted(&self.samples_ms, 50.0)
    }

    pub fn min_ms(&self) -> f64 {
        self.samples_ms.first().copied().unwrap_or(0.0)
    }

    pub fn max_ms(&self) -> f64 {
        self.samples_ms.last().copied().unwrap_or(0.0)
    }

    pub fn stddev_ms(&self) -> f64 {
        if self.n() < 2 {
            return 0.0;
        }
        let m = self.mean_ms();
        let v = self
            .samples_ms
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.n() - 1) as f64;
        v.sqrt()
    }

    pub fn p(&self, pct: f64) -> f64 {
        percentile_sorted(&self.samples_ms, pct)
    }
}

/// Percentile of an ascending-sorted slice (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup: usize,
    pub runs: usize,
    /// Hard wall-clock cap; stops sampling early when exceeded.
    pub max_total: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: 1,
            runs: 5,
            max_total: Duration::from_secs(120),
        }
    }
}

/// Quick-mode detection: `MCUBES_BENCH_QUICK=1` shrinks runs so the
/// full `cargo bench` suite stays tractable in CI.
pub fn quick_mode() -> bool {
    std::env::var("MCUBES_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

impl BenchOpts {
    pub fn quick_aware(mut self) -> Self {
        if quick_mode() {
            self.warmup = 0;
            self.runs = self.runs.min(2);
            self.max_total = Duration::from_secs(30);
        }
        self
    }
}

/// Time `f` under `opts`; `f` returns an arbitrary value that is
/// black-boxed to keep the optimizer honest.
pub fn bench<R>(opts: BenchOpts, mut f: impl FnMut() -> R) -> Stats {
    for _ in 0..opts.warmup {
        black_box(f());
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(opts.runs);
    for i in 0..opts.runs {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
        if started.elapsed() > opts.max_total && i >= 1 {
            break;
        }
    }
    Stats::from_ms(samples)
}

/// Opaque value sink (stable `std::hint::black_box`).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One machine-greppable benchmark record: a `BENCH `-prefixed JSON
/// object (`{"bench": .., "metric": .., "value": .., "unit": ..}`)
/// suitable for `grep '^BENCH ' | cut -d' ' -f2- | jq`.
pub fn bench_json_line(bench: &str, metric: &str, value: f64, unit: &str) -> String {
    let obj = ObjBuilder::new()
        .field("bench", bench)
        .field("metric", metric)
        .field("value", value)
        .field("unit", unit)
        .build();
    format!("BENCH {}", obj.to_json())
}

/// Print a [`bench_json_line`] record to stdout.
pub fn emit_bench(bench: &str, metric: &str, value: f64, unit: &str) {
    println!("{}", bench_json_line(bench, metric, value, unit));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_ms(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min_ms(), 1.0);
        assert_eq!(s.max_ms(), 3.0);
        assert!((s.mean_ms() - 2.0).abs() < 1e-12);
        assert!((s.median_ms() - 2.0).abs() < 1e-12);
        assert!((s.stddev_ms() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&v, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 100.0) - 100.0).abs() < 1e-12);
        let p50 = percentile_sorted(&v, 50.0);
        assert!((p50 - 50.5).abs() < 1e-9, "{p50}");
    }

    #[test]
    fn bench_runs_and_counts() {
        let opts = BenchOpts {
            warmup: 1,
            runs: 3,
            max_total: Duration::from_secs(10),
        };
        let mut count = 0u32;
        let s = bench(opts, || {
            count += 1;
            count
        });
        assert_eq!(s.n(), 3);
        assert_eq!(count, 4); // warmup + 3
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = Stats::from_ms(vec![]);
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.median_ms(), 0.0);
    }

    #[test]
    fn bench_json_line_round_trips() {
        let line = bench_json_line("batch_vs_scalar_f4_d5", "speedup", 2.0, "x");
        let json = line.strip_prefix("BENCH ").expect("BENCH prefix");
        let v = crate::util::json::parse(json).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("batch_vs_scalar_f4_d5"));
        assert_eq!(v.get("metric").unwrap().as_str(), Some("speedup"));
        assert_eq!(v.get("value").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("unit").unwrap().as_str(), Some("x"));
    }
}
