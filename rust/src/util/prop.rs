//! Tiny property-testing driver (no proptest offline).
//!
//! Deterministic, seeded random-input generation with failure reporting
//! that includes the case seed, so failures are reproducible with
//! `Gen::from_seed`. Used by `rust/tests/properties.rs` for grid,
//! estimator, and coordinator invariants.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use crate::rng::philox4x32;

/// Deterministic generator over a Philox stream.
pub struct Gen {
    seed: u32,
    counter: u32,
    buf: [u32; 4],
    have: usize,
}

impl Gen {
    pub fn from_seed(seed: u32) -> Gen {
        Gen {
            seed,
            counter: 0,
            buf: [0; 4],
            have: 0,
        }
    }

    fn next_u32(&mut self) -> u32 {
        if self.have == 0 {
            self.buf = philox4x32(
                [self.counter, 0xA5A5_5A5A, 0, 0x9E37_0001],
                [self.seed, 0x7070_7070],
            );
            self.counter = self.counter.wrapping_add(1);
            self.have = 4;
        }
        self.have -= 1;
        self.buf[self.have]
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        self.next_u32() as f64 / 4294967296.0
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + (self.next_u32() as usize) % (hi - lo + 1)
    }

    /// Vector of positive weights, some possibly zero.
    pub fn weights(&mut self, n: usize, zero_frac: f64) -> Vec<f64> {
        (0..n)
            .map(|_| {
                if self.f64() < zero_frac {
                    0.0
                } else {
                    self.f64_range(1e-6, 10.0)
                }
            })
            .collect()
    }
}

/// Run `check(gen, case_index)` for `cases` cases; panic with the seed
/// of the failing case on error return.
pub fn property(name: &str, cases: usize, mut check: impl FnMut(&mut Gen, usize) -> Result<(), String>) {
    for i in 0..cases {
        let seed = 0xC0FF_EE00u32.wrapping_add(i as u32);
        let mut gen = Gen::from_seed(seed);
        if let Err(msg) = check(&mut gen, i) {
            panic!("property `{name}` failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::from_seed(1);
        let mut b = Gen::from_seed(1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::from_seed(2);
        for _ in 0..1000 {
            let v = g.f64_range(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
            let u = g.usize_range(5, 9);
            assert!((5..=9).contains(&u));
        }
    }

    #[test]
    fn property_runs_all_cases() {
        let mut n = 0;
        property("count", 17, |_, _| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic(expected = "property `boom` failed")]
    fn property_reports_failure() {
        property("boom", 5, |_, i| {
            if i == 3 {
                Err("intentional".into())
            } else {
                Ok(())
            }
        });
    }
}
