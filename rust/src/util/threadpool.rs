//! Minimal scoped data-parallel helpers (no rayon/tokio offline).
//!
//! The native engine splits V-Sample's cube range across OS threads via
//! `parallel_chunks`. `WorkerPool` is a general long-lived worker pool
//! fed by an MPSC channel; the coordinator's `Scheduler` runs its own
//! priority/requeue-aware pool instead (plain FIFO can't time-slice),
//! so `WorkerPool` remains as a utility for fire-and-forget workloads.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default: physical parallelism,
/// clamped to keep test machines responsive.
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 32)
}

/// Map `f` over `0..n` items in contiguous chunks across `threads`
/// scoped threads, collecting per-chunk results in order.
///
/// `f(chunk_start, chunk_end) -> R` runs on a worker; results come back
/// ordered by chunk index, so deterministic reductions stay
/// deterministic regardless of scheduling.
pub fn parallel_chunks<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        return vec![f(0, n)];
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .filter_map(|t| {
                let start = t * chunk;
                let end = ((t + 1) * chunk).min(n);
                if start >= end {
                    return None;
                }
                let f = &f;
                Some(s.spawn(move || f(start, end)))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                // Re-raise the worker's original panic payload on the
                // caller thread, so upstream `catch_unwind` isolation
                // (the job service's per-job error reporting) sees the
                // user integrand's own message instead of a generic
                // "worker panicked".
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    })
}

/// A long-lived worker pool consuming boxed jobs from a shared queue.
/// Used by `coordinator::service`.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    active: Arc<AtomicUsize>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    /// Spawn `threads` workers.
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let active = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads.max(1) {
            let rx = Arc::clone(&rx);
            let active = Arc::clone(&active);
            handles.push(
                thread::Builder::new()
                    .name(format!("mcubes-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                active.fetch_add(1, Ordering::SeqCst);
                                job();
                                active.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        WorkerPool {
            tx: Some(tx),
            handles,
            active,
        }
    }

    /// Enqueue a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Jobs currently executing (not queued).
    pub fn active(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Close the queue and join all workers (drains remaining jobs).
    pub fn shutdown(mut self) {
        self.tx.take(); // drop sender -> workers exit after drain
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Unbounded MPSC used by the service for result collection; re-export
/// to keep call sites decoupled from std details.
pub fn result_channel<T>() -> (Sender<T>, Receiver<T>) {
    channel()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_chunks_covers_range() {
        let n = 1003;
        let parts = parallel_chunks(n, 7, |a, b| (a, b));
        // Contiguous, ordered, complete.
        let mut expect_start = 0;
        for &(a, b) in &parts {
            assert_eq!(a, expect_start);
            assert!(b > a);
            expect_start = b;
        }
        assert_eq!(expect_start, n);
    }

    #[test]
    fn parallel_chunks_sums_correctly() {
        let n = 10_000usize;
        let parts = parallel_chunks(n, 8, |a, b| (a..b).sum::<usize>());
        let total: usize = parts.iter().sum();
        assert_eq!(total, n * (n - 1) / 2);
    }

    #[test]
    fn parallel_chunks_single_thread() {
        let parts = parallel_chunks(10, 1, |a, b| b - a);
        assert_eq!(parts, vec![10]);
    }

    #[test]
    fn parallel_chunks_more_threads_than_items() {
        let parts = parallel_chunks(3, 16, |a, b| b - a);
        let total: usize = parts.iter().sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn parallel_chunks_preserves_panic_payload() {
        // The original panic message must survive the worker boundary
        // (resume_unwind), not be replaced by "worker panicked".
        let caught = std::panic::catch_unwind(|| {
            parallel_chunks(100, 4, |a, _b| {
                if a >= 25 {
                    panic!("integrand exploded at {a}");
                }
                a
            })
        })
        .expect_err("must propagate the panic");
        let msg = caught
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| caught.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.contains("integrand exploded"),
            "payload lost: {msg:?}"
        );
    }

    #[test]
    fn worker_pool_runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(i, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn worker_pool_drop_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
