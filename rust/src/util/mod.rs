//! Substrate utilities built in-repo (the offline registry has no
//! serde/clap/tokio/criterion/proptest — see DESIGN.md §Dependency
//! constraints).

pub mod benchkit;
pub mod cli;
pub mod digest;
pub mod json;
pub mod prop;
pub mod table;
pub mod threadpool;
