//! Paper-style table / CSV rendering for bench + report output.

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns (for terminal output).
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}", c, w = widths[i] + 2);
                let _ = i;
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().map(|w| w + 2).sum();
        out.push_str(&"-".repeat(total.min(120)));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        let _ = ncol;
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(esc)
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV next to terminal output (used by benches to persist
    /// experiment series under results/).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())
    }
}

/// Format helpers used across benches.
pub fn fmt_sig(v: f64, digits: usize) -> String {
    if v == 0.0 || !v.is_finite() {
        return format!("{v}");
    }
    let mag = v.abs().log10().floor() as i32;
    if (-3..6).contains(&mag) {
        let dec = (digits as i32 - 1 - mag).max(0) as usize;
        format!("{v:.dec$}")
    } else {
        format!("{v:.prec$e}", prec = digits - 1)
    }
}

pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2}s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{ms:.1}ms")
    } else {
        format!("{:.0}us", ms * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn row_width_checked() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ms(2500.0), "2.50s");
        assert_eq!(fmt_ms(12.3), "12.3ms");
        assert_eq!(fmt_ms(0.5), "500us");
        assert_eq!(fmt_sig(1234.5678, 4), "1235");
        assert!(fmt_sig(1.2345e-7, 3).contains('e'));
    }
}
