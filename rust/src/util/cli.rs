//! Tiny declarative CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args,
//! subcommands (first positional), and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative command-line parser.
#[derive(Debug, Default)]
pub struct Cli {
    program: &'static str,
    about: &'static str,
    opts: Vec<OptSpec>,
}

/// Parse outcome: option map + positionals.
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    pub positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli {
            program,
            about,
            opts: Vec::new(),
        }
    }

    /// `--name <value>` option with default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default.to_string()),
        });
        self
    }

    /// `--name <value>` option without default (optional).
    pub fn opt_opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: None,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nOptions:");
        for o in &self.opts {
            let arg = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  {arg:<24} {}{def}", o.help);
        }
        s
    }

    /// Parse an argv slice (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut out = Parsed::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name, d.clone());
            }
            if !o.takes_value {
                out.flags.insert(o.name, false);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.help_text()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    out.values.insert(spec.name, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    out.flags.insert(spec.name, true);
                }
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_u32(&self, name: &str) -> Result<u32, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn is_set(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("calls", "1000", "max calls")
            .opt_opt("seed", "rng seed")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let p = cli().parse(&argv(&[])).unwrap();
        assert_eq!(p.get("calls"), Some("1000"));
        assert_eq!(p.get("seed"), None);
        assert!(!p.is_set("verbose"));
    }

    #[test]
    fn parses_values_and_flags() {
        let p = cli()
            .parse(&argv(&["--calls", "42", "--verbose", "pos1", "--seed=7"]))
            .unwrap();
        assert_eq!(p.get_usize("calls").unwrap(), 42);
        assert_eq!(p.get_u32("seed").unwrap(), 7);
        assert!(p.is_set("verbose"));
        assert_eq!(p.positionals, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse(&argv(&["--calls"])).is_err());
    }

    #[test]
    fn help_is_error_with_text() {
        let err = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--calls"));
        assert!(err.contains("max calls"));
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cli().parse(&argv(&["--verbose=yes"])).is_err());
    }

    #[test]
    fn bad_number_reports() {
        let p = cli().parse(&argv(&["--calls", "abc"])).unwrap();
        assert!(p.get_usize("calls").is_err());
    }
}
