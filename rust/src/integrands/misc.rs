//! fA/fB (the ZMCintegral comparison integrands, eq. 7-8) and the
//! stateful cosmology-style integrand (§6.1).

use super::interp::Interp1D;
use super::Integrand;
use crate::engine::block::PointBlock;
use std::f64::consts::PI;

/// fA: sin(sum x) over (0,10)^6 — paper Table 1, true value -49.165073.
pub struct FaSin6;

impl FaSin6 {
    pub fn new() -> Self {
        FaSin6
    }
}

impl Default for FaSin6 {
    fn default() -> Self {
        Self::new()
    }
}

impl Integrand for FaSin6 {
    fn name(&self) -> &str {
        "fA"
    }
    fn dim(&self) -> usize {
        6
    }
    fn lo(&self) -> f64 {
        0.0
    }
    fn hi(&self) -> f64 {
        10.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        x.iter().sum::<f64>().sin()
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        let out = &mut out[..block.len()];
        out.fill(0.0);
        for i in 0..6 {
            for (o, &xi) in out.iter_mut().zip(block.axis(i)) {
                *o += xi;
            }
        }
        for o in out.iter_mut() {
            *o = (*o).sin();
        }
    }
    fn true_value(&self) -> Option<f64> {
        // Im[ (sin10 + i(1-cos10))^6 ]
        let a = 10.0f64.sin();
        let b = 1.0 - 10.0f64.cos();
        let (mut re, mut im) = (1.0f64, 0.0f64);
        for _ in 0..6 {
            let (nre, nim) = (re * a - im * b, re * b + im * a);
            re = nre;
            im = nim;
        }
        Some(im)
    }
}

/// fB: 9-D Gaussian with sigma = 0.1 over (-1,1)^9 — integrates to ~1.
/// (Self-consistent version of the paper's eq. 8; see the Python
/// registry's note about the formula/true-value mismatch in the paper.)
pub struct FbGauss9;

impl FbGauss9 {
    pub fn new() -> Self {
        FbGauss9
    }
}

impl Default for FbGauss9 {
    fn default() -> Self {
        Self::new()
    }
}

impl Integrand for FbGauss9 {
    fn name(&self) -> &str {
        "fB"
    }
    fn dim(&self) -> usize {
        9
    }
    fn lo(&self) -> f64 {
        -1.0
    }
    fn hi(&self) -> f64 {
        1.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        let var = 0.01; // sigma^2
        let norm = (2.0 * PI * var).powf(-4.5);
        let s: f64 = x.iter().map(|&v| v * v).sum();
        norm * (-s / (2.0 * var)).exp()
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        let var = 0.01; // sigma^2
        let norm = (2.0 * PI * var).powf(-4.5);
        let out = &mut out[..block.len()];
        out.fill(0.0);
        for i in 0..9 {
            for (o, &xi) in out.iter_mut().zip(block.axis(i)) {
                *o += xi * xi;
            }
        }
        for o in out.iter_mut() {
            *o = norm * (-*o / (2.0 * var)).exp();
        }
    }
    fn true_value(&self) -> Option<f64> {
        let one = super::genz::erf(1.0 / (0.1 * 2.0f64.sqrt()));
        Some(one.powi(9))
    }
    fn symmetric(&self) -> bool {
        true
    }
}

/// The stateful 6-D "cosmology-style" integrand (§6.1 substitution):
/// evaluation flows through two runtime interpolation tables, mirroring
/// the paper's cosmology integrand whose cost is table lookups.
///
/// f(x) = T0(x0) * T1(x1) * exp(-(x2^2+x3^2)) * (1 + 0.5*x4*x5)
pub struct Cosmo {
    t0: Interp1D,
    t1: Interp1D,
}

/// Knot count of the default tables (must match the Python registry).
pub const COSMO_KNOTS: usize = 64;

impl Cosmo {
    pub fn new(t0: Interp1D, t1: Interp1D) -> Self {
        Cosmo { t0, t1 }
    }

    /// The deterministic default tables — same formulas as
    /// `integrands.make_tables` in Python.
    pub fn default_tables() -> (Vec<f64>, Vec<f64>) {
        let k = COSMO_KNOTS;
        let mut t0 = Vec::with_capacity(k);
        let mut t1 = Vec::with_capacity(k);
        for i in 0..k {
            let x = i as f64 / (k - 1) as f64;
            t0.push(1.0 + 0.5 * (2.0 * PI * x).sin() + 0.25 * x * x);
            t1.push((-2.0 * (x - 0.3) * (x - 0.3)).exp() + 0.1);
        }
        (t0, t1)
    }

    pub fn with_default_tables() -> Self {
        let (t0, t1) = Self::default_tables();
        Cosmo::new(Interp1D::new(t0, 0.0, 1.0), Interp1D::new(t1, 0.0, 1.0))
    }

    /// Semi-analytic reference by high-resolution product quadrature
    /// (same method as `integrands.cosmo_true_value`).
    pub fn quadrature_true_value(&self, n: usize) -> f64 {
        let trapz = |f: &dyn Fn(f64) -> f64| -> f64 {
            let mut s = 0.0;
            for i in 0..n {
                let x0 = i as f64 / n as f64;
                let x1 = (i + 1) as f64 / n as f64;
                s += 0.5 * (f(x0) + f(x1)) * (x1 - x0);
            }
            s
        };
        let i0 = trapz(&|x| self.t0.eval(x));
        let i1 = trapz(&|x| self.t1.eval(x));
        let ig = trapz(&|x| (-x * x).exp());
        i0 * i1 * ig * ig * 1.125
    }
}

impl Integrand for Cosmo {
    fn name(&self) -> &str {
        "cosmo"
    }
    fn dim(&self) -> usize {
        6
    }
    fn lo(&self) -> f64 {
        0.0
    }
    fn hi(&self) -> f64 {
        1.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        let a = self.t0.eval(x[0]);
        let b = self.t1.eval(x[1]);
        let g = (-(x[2] * x[2] + x[3] * x[3])).exp();
        let p = 1.0 + 0.5 * x[4] * x[5];
        a * b * g * p
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        // Slice first so an undersized buffer panics (the documented
        // contract) instead of silently truncating the batch.
        let out = &mut out[..block.len()];
        let (x0, x1) = (block.axis(0), block.axis(1));
        let (x2, x3) = (block.axis(2), block.axis(3));
        let (x4, x5) = (block.axis(4), block.axis(5));
        for (k, o) in out.iter_mut().enumerate() {
            let a = self.t0.eval(x0[k]);
            let b = self.t1.eval(x1[k]);
            let g = (-(x2[k] * x2[k] + x3[k] * x3[k])).exp();
            let p = 1.0 + 0.5 * x4[k] * x5[k];
            *o = a * b * g * p;
        }
    }
    fn true_value(&self) -> Option<f64> {
        Some(self.quadrature_true_value(50_000))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fa_true_value_matches_paper() {
        let f = FaSin6::new();
        let tv = f.true_value().unwrap();
        assert!((tv - (-49.165073)).abs() < 1e-5, "{tv}");
    }

    #[test]
    fn fa_eval() {
        let f = FaSin6::new();
        assert_eq!(f.eval(&[0.0; 6]), 0.0);
        let x = [PI / 12.0; 6]; // sum = pi/2
        assert!((f.eval(&x) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fb_true_value_near_one() {
        let f = FbGauss9::new();
        assert!((f.true_value().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fb_center_value() {
        let f = FbGauss9::new();
        let want = (2.0 * PI * 0.01f64).powf(-4.5);
        assert!((f.eval(&[0.0; 9]) - want).abs() / want < 1e-12);
    }

    #[test]
    fn cosmo_tables_scale_linearly() {
        let c = Cosmo::with_default_tables();
        let (t0, t1) = Cosmo::default_tables();
        let doubled = Cosmo::new(
            Interp1D::new(t0.iter().map(|v| v * 2.0).collect(), 0.0, 1.0),
            Interp1D::new(t1.iter().map(|v| v * 2.0).collect(), 0.0, 1.0),
        );
        let x = [0.25; 6];
        assert!((doubled.eval(&x) - 4.0 * c.eval(&x)).abs() < 1e-10);
    }

    #[test]
    fn batched_overrides_match_scalar_bitwise() {
        fn check(f: &dyn Integrand, pts: &[Vec<f64>]) {
            let d = f.dim();
            let mut block = PointBlock::with_capacity(d, pts.len());
            for p in pts {
                block.push_point(p, 1.0);
            }
            let mut out = vec![0.0f64; pts.len()];
            f.eval_batch(&block, &mut out);
            for (k, p) in pts.iter().enumerate() {
                assert_eq!(
                    out[k].to_bits(),
                    f.eval(p).to_bits(),
                    "{} point {k}",
                    f.name()
                );
            }
        }
        let mk = |d: usize, scale: f64, shift: f64| -> Vec<Vec<f64>> {
            (0..5)
                .map(|k| {
                    (0..d)
                        .map(|i| shift + scale * ((k * d + i) as f64 * 0.37).fract())
                        .collect()
                })
                .collect()
        };
        check(&FaSin6::new(), &mk(6, 10.0, 0.0));
        check(&FbGauss9::new(), &mk(9, 2.0, -1.0));
        check(&Cosmo::with_default_tables(), &mk(6, 1.0, 0.0));
    }

    #[test]
    fn cosmo_true_value_matches_python() {
        // python cosmo_true_value() ~ 0.617448 (printed in the proto run)
        let c = Cosmo::with_default_tables();
        let tv = c.true_value().unwrap();
        assert!((tv - 0.617448).abs() < 5e-4, "{tv}");
    }
}
