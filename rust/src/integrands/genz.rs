//! The paper's standard test suite (eq. 1-6): Genz-style integrands
//! with the parameter constants preselected as in PAGANI [12].
//!
//! Every integrand overrides `eval_batch` with a hand-batched
//! column-major pass (one contiguous loop per axis over the
//! [`PointBlock`] SoA layout) that the compiler can vectorize. The
//! accumulation order per point matches the scalar `eval` exactly, so
//! both paths are bit-identical (property-tested).

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

use super::Integrand;
use crate::engine::block::PointBlock;

/// f1: oscillatory, cos(sum_i i*x_i) over [0,1]^d.
pub struct F1 {
    d: usize,
}

impl F1 {
    pub fn new(d: usize) -> Self {
        F1 { d }
    }
}

impl Integrand for F1 {
    fn name(&self) -> &str {
        "f1"
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn lo(&self) -> f64 {
        0.0
    }
    fn hi(&self) -> f64 {
        1.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for (i, &xi) in x.iter().enumerate() {
            s += (i + 1) as f64 * xi;
        }
        s.cos()
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        let out = &mut out[..block.len()];
        out.fill(0.0);
        for i in 0..self.d {
            let ci = (i + 1) as f64;
            for (o, &xi) in out.iter_mut().zip(block.axis(i)) {
                *o += ci * xi;
            }
        }
        for o in out.iter_mut() {
            *o = (*o).cos();
        }
    }
    fn true_value(&self) -> Option<f64> {
        // Re[prod_j ((sin j)/j + i (1-cos j)/j)]
        let (mut re, mut im) = (1.0f64, 0.0f64);
        for j in 1..=self.d {
            let jf = j as f64;
            let a = jf.sin() / jf;
            let b = (1.0 - jf.cos()) / jf;
            let (nre, nim) = (re * a - im * b, re * b + im * a);
            re = nre;
            im = nim;
        }
        Some(re)
    }
}

/// f2: product peak, prod_i (1/50^2 + (x_i-1/2)^2)^-1.
pub struct F2 {
    d: usize,
}

impl F2 {
    pub fn new(d: usize) -> Self {
        F2 { d }
    }
}

impl Integrand for F2 {
    fn name(&self) -> &str {
        "f2"
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn lo(&self) -> f64 {
        0.0
    }
    fn hi(&self) -> f64 {
        1.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        let a = 1.0 / 2500.0;
        let mut prod = 1.0;
        for &xi in x {
            let t = xi - 0.5;
            prod *= 1.0 / (a + t * t);
        }
        prod
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        let a = 1.0 / 2500.0;
        let out = &mut out[..block.len()];
        out.fill(1.0);
        for i in 0..self.d {
            for (o, &xi) in out.iter_mut().zip(block.axis(i)) {
                let t = xi - 0.5;
                *o *= 1.0 / (a + t * t);
            }
        }
    }
    fn true_value(&self) -> Option<f64> {
        let one = 50.0 * 2.0 * 25.0f64.atan();
        Some(one.powi(self.d as i32))
    }
    fn symmetric(&self) -> bool {
        true
    }
}

/// f3: corner peak, (1 + sum_i i*x_i)^(-d-1).
pub struct F3 {
    d: usize,
}

impl F3 {
    pub fn new(d: usize) -> Self {
        F3 { d }
    }
}

impl Integrand for F3 {
    fn name(&self) -> &str {
        "f3"
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn lo(&self) -> f64 {
        0.0
    }
    fn hi(&self) -> f64 {
        1.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        let mut s = 1.0;
        for (i, &xi) in x.iter().enumerate() {
            s += (i + 1) as f64 * xi;
        }
        s.powi(-(self.d as i32) - 1)
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        let out = &mut out[..block.len()];
        out.fill(1.0);
        for i in 0..self.d {
            let ci = (i + 1) as f64;
            for (o, &xi) in out.iter_mut().zip(block.axis(i)) {
                *o += ci * xi;
            }
        }
        let e = -(self.d as i32) - 1;
        for o in out.iter_mut() {
            *o = (*o).powi(e);
        }
    }
    fn true_value(&self) -> Option<f64> {
        // Inclusion-exclusion closed form (see python integrands.py).
        let d = self.d;
        let mut total = 0.0f64;
        for mask in 0..(1u32 << d) {
            let mut sum_c = 0.0;
            let bits = mask.count_ones();
            for i in 0..d {
                if mask & (1 << i) != 0 {
                    sum_c += (i + 1) as f64;
                }
            }
            let sign = if bits % 2 == 0 { 1.0 } else { -1.0 };
            total += sign / (1.0 + sum_c);
        }
        let mut denom = 1.0f64;
        for i in 1..=d {
            denom *= i as f64; // d!
        }
        for i in 1..=d {
            denom *= i as f64; // prod c_i = d!
        }
        Some(total / denom)
    }
}

/// f4: Gaussian, exp(-625 sum (x_i-1/2)^2).
pub struct F4 {
    d: usize,
}

impl F4 {
    pub fn new(d: usize) -> Self {
        F4 { d }
    }
}

impl Integrand for F4 {
    fn name(&self) -> &str {
        "f4"
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn lo(&self) -> f64 {
        0.0
    }
    fn hi(&self) -> f64 {
        1.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for &xi in x {
            let t = xi - 0.5;
            s += t * t;
        }
        (-625.0 * s).exp()
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        let out = &mut out[..block.len()];
        out.fill(0.0);
        for i in 0..self.d {
            for (o, &xi) in out.iter_mut().zip(block.axis(i)) {
                let t = xi - 0.5;
                *o += t * t;
            }
        }
        for o in out.iter_mut() {
            *o = (-625.0 * *o).exp();
        }
    }
    fn true_value(&self) -> Option<f64> {
        let one = std::f64::consts::PI.sqrt() / 25.0 * erf(12.5);
        Some(one.powi(self.d as i32))
    }
    fn symmetric(&self) -> bool {
        true
    }
}

/// f5: C0-continuous, exp(-10 sum |x_i - 1/2|).
pub struct F5 {
    d: usize,
}

impl F5 {
    pub fn new(d: usize) -> Self {
        F5 { d }
    }
}

impl Integrand for F5 {
    fn name(&self) -> &str {
        "f5"
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn lo(&self) -> f64 {
        0.0
    }
    fn hi(&self) -> f64 {
        1.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for &xi in x {
            s += (xi - 0.5).abs();
        }
        (-10.0 * s).exp()
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        let out = &mut out[..block.len()];
        out.fill(0.0);
        for i in 0..self.d {
            for (o, &xi) in out.iter_mut().zip(block.axis(i)) {
                *o += (xi - 0.5).abs();
            }
        }
        for o in out.iter_mut() {
            *o = (-10.0 * *o).exp();
        }
    }
    fn true_value(&self) -> Option<f64> {
        let one = 0.2 * (1.0 - (-5.0f64).exp());
        Some(one.powi(self.d as i32))
    }
    fn symmetric(&self) -> bool {
        true
    }
}

/// f6: discontinuous, exp(sum (i+4) x_i) on x_i < (3+i)/10, else 0.
pub struct F6 {
    d: usize,
}

impl F6 {
    pub fn new(d: usize) -> Self {
        F6 { d }
    }
}

impl Integrand for F6 {
    fn name(&self) -> &str {
        "f6"
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn lo(&self) -> f64 {
        0.0
    }
    fn hi(&self) -> f64 {
        1.0
    }
    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for (i, &xi) in x.iter().enumerate() {
            let c = (i + 1) as f64;
            if xi >= (3.0 + c) / 10.0 {
                return 0.0;
            }
            s += (c + 4.0) * xi;
        }
        s.exp()
    }
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        // Branch-light batch form: a point past any cutoff gets its
        // accumulator pinned at -inf, and exp(-inf) == 0.0 exactly —
        // the same bits the scalar early-return produces.
        let out = &mut out[..block.len()];
        out.fill(0.0);
        for i in 0..self.d {
            let c = (i + 1) as f64;
            let cut = (3.0 + c) / 10.0;
            for (o, &xi) in out.iter_mut().zip(block.axis(i)) {
                if xi >= cut {
                    *o = f64::NEG_INFINITY;
                } else {
                    *o += (c + 4.0) * xi;
                }
            }
        }
        for o in out.iter_mut() {
            *o = (*o).exp();
        }
    }
    fn true_value(&self) -> Option<f64> {
        let mut val = 1.0;
        for i in 1..=self.d {
            let c = (i + 4) as f64;
            let b = ((3 + i) as f64 / 10.0).min(1.0);
            val *= ((c * b).exp() - 1.0) / c;
        }
        Some(val)
    }
}

/// Error function via Abramowitz & Stegun 7.1.26-style rational
/// approximation refined with one Newton step — |err| < 1e-12 over the
/// range we use (the true values need ~1e-10; erf(12.5) == 1.0 in f64).
pub fn erf(x: f64) -> f64 {
    // For |x| > 6, erf saturates to +-1 at f64 precision.
    if x >= 6.0 {
        return 1.0;
    }
    if x <= -6.0 {
        return -1.0;
    }
    // Series/continued-fraction hybrid: use the Taylor series around 0
    // for small |x| and the complementary asymptotic for large |x|.
    let ax = x.abs();
    let val = if ax < 2.0 {
        // Taylor series: erf(x) = 2/sqrt(pi) sum (-1)^n x^(2n+1)/(n!(2n+1))
        let mut term = ax;
        let mut sum = ax;
        let x2 = ax * ax;
        for n in 1..200 {
            term *= -x2 / n as f64;
            let add = term / (2 * n + 1) as f64;
            sum += add;
            if add.abs() < 1e-18 * sum.abs() {
                break;
            }
        }
        sum * 2.0 / std::f64::consts::PI.sqrt()
    } else {
        // erfc via continued fraction (Lentz), then erf = 1 - erfc.
        1.0 - erfc_cf(ax)
    };
    if x < 0.0 {
        -val
    } else {
        val
    }
}

fn erfc_cf(x: f64) -> f64 {
    // erfc(x) = exp(-x^2)/sqrt(pi) * 1/(x + 1/(2x + 2/(x + 3/(2x + ...))))
    let mut f = 0.0f64;
    for k in (1..=60).rev() {
        f = (k as f64 / 2.0) / (x + f);
    }
    (-x * x).exp() / std::f64::consts::PI.sqrt() / (x + f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-15);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 1e-10);
        assert!((erf(2.0) - 0.9953222650189527).abs() < 1e-10);
        assert!((erf(0.5) - 0.5204998778130465).abs() < 1e-10);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 1e-10);
        assert_eq!(erf(12.5), 1.0);
    }

    #[test]
    fn spot_values_match_python() {
        // Mirrors python tests/test_integrands.py spot values.
        let f1 = F1::new(3);
        assert!((f1.eval(&[0.0, 0.0, 0.0]) - 1.0).abs() < 1e-15);
        let f2 = F2::new(4);
        assert!((f2.eval(&[0.5; 4]) - 2500.0f64.powi(4)).abs() / 2500.0f64.powi(4) < 1e-12);
        let f3 = F3::new(3);
        assert!((f3.eval(&[0.0; 3]) - 1.0).abs() < 1e-15);
        let f4 = F4::new(6);
        assert!((f4.eval(&[0.5; 6]) - 1.0).abs() < 1e-15);
        let f5 = F5::new(8);
        assert!((f5.eval(&[0.5; 8]) - 1.0).abs() < 1e-15);
        let f6 = F6::new(2);
        let inside = f6.eval(&[0.39, 0.49]);
        assert!((inside - (5.0 * 0.39 + 6.0 * 0.49f64).exp()).abs() < 1e-10);
        assert_eq!(f6.eval(&[0.41, 0.49]), 0.0);
    }

    #[test]
    fn true_values_match_python_formulas() {
        // Values from the python registry (see test_integrands.py).
        let f3 = F3::new(1);
        assert!((f3.true_value().unwrap() - 0.5).abs() < 1e-14);
        let f5 = F5::new(8);
        let one = 0.2 * (1.0 - (-5.0f64).exp());
        assert!((f5.true_value().unwrap() - one.powi(8)).abs() < 1e-18);
        // f2 d=6 true value ~ 1.28689e+13 (python registry prints the
        // same closed form; spot-check magnitude + formula shape)
        let f2 = F2::new(6);
        let tv = f2.true_value().unwrap();
        let one = 50.0 * 2.0 * 25.0f64.atan();
        assert!((tv - one.powi(6)).abs() / tv < 1e-15, "{tv}");
        assert!((tv / 1.28689e13 - 1.0).abs() < 1e-4, "{tv}");
    }

    #[test]
    fn batched_overrides_match_scalar_bitwise() {
        // Every Genz integrand's hand-batched column pass must return
        // the exact bits of the scalar eval — including f6's
        // discontinuity (dead points must come back as exactly 0.0).
        let d = 4;
        let fs: Vec<Box<dyn Integrand>> = vec![
            Box::new(F1::new(d)),
            Box::new(F2::new(d)),
            Box::new(F3::new(d)),
            Box::new(F4::new(d)),
            Box::new(F5::new(d)),
            Box::new(F6::new(d)),
        ];
        let pts: Vec<[f64; 4]> = vec![
            [0.1, 0.2, 0.3, 0.4],
            [0.5, 0.5, 0.5, 0.5],
            [0.99, 0.01, 0.6, 0.2], // dead on axis 0 for f6
            [0.2, 0.9, 0.1, 0.1],   // dead on axis 1 for f6
            [0.0, 0.0, 0.0, 0.0],
            [0.39, 0.49, 0.55, 0.65],
        ];
        let mut block = PointBlock::with_capacity(d, pts.len());
        for p in &pts {
            block.push_point(p, 1.0);
        }
        let mut out = vec![0.0f64; pts.len()];
        for f in &fs {
            f.eval_batch(&block, &mut out);
            for (k, p) in pts.iter().enumerate() {
                let want = f.eval(p);
                assert_eq!(
                    out[k].to_bits(),
                    want.to_bits(),
                    "{} point {k}: batch {} != scalar {want}",
                    f.name(),
                    out[k]
                );
            }
        }
    }

    #[test]
    fn f6_truncated_last_axis() {
        // For d >= 7, (3+i)/10 >= 1 for i >= 7 so the cutoff saturates.
        let f6 = F6::new(8);
        assert!(f6.true_value().unwrap() > 0.0);
    }

    #[test]
    fn low_dim_quadrature_agreement() {
        // Midpoint quadrature in 2-D must match the closed forms.
        for (f, tol) in [
            (&F1::new(2) as &dyn Integrand, 1e-4),
            (&F3::new(2), 1e-3),
            (&F5::new(2), 1e-4),
        ] {
            let n = 400;
            let mut sum = 0.0;
            for a in 0..n {
                for b in 0..n {
                    let x = [
                        (a as f64 + 0.5) / n as f64,
                        (b as f64 + 0.5) / n as f64,
                    ];
                    sum += f.eval(&x);
                }
            }
            let got = sum / (n * n) as f64;
            let want = f.true_value().unwrap();
            assert!(
                ((got - want) / want).abs() < tol,
                "{}: got {got}, want {want}",
                f.name()
            );
        }
    }
}
