//! Integrand registry — Rust twins of `python/compile/integrands.py`.
//!
//! The native engine and all CPU baselines evaluate these; the PJRT
//! path evaluates the jnp versions baked into the artifacts. Names,
//! formulas, domains, and true values must match the Python registry
//! exactly (cross-checked in tests and via golden files).

mod genz;
mod interp;
mod misc;

pub use genz::*;
pub use interp::Interp1D;
pub use misc::*;

use crate::engine::block::PointBlock;
use crate::engine::MAX_DIM;
use crate::error::{Error, Result};
use crate::strat::Bounds;
use std::sync::Arc;

/// A d-dimensional scalar integrand. `eval` receives one point in
/// integration-space coordinates (length d); `eval_batch` receives a
/// structure-of-arrays [`PointBlock`] of points — the engine, the
/// stratified engine, and every CPU baseline evaluate exclusively through
/// `eval_batch`, so overriding it is the one lever for making an
/// integrand's hot loop vectorize.
pub trait Integrand: Send + Sync {
    /// Registry name (matches the Python registry / artifact manifest).
    fn name(&self) -> &str;
    /// Dimensionality this instance integrates over.
    fn dim(&self) -> usize;
    /// Uniform-box lower corner (legacy; `bounds()` is authoritative).
    fn lo(&self) -> f64;
    /// Uniform-box upper corner (legacy; `bounds()` is authoritative).
    fn hi(&self) -> f64;
    /// Evaluate at one point (length `dim`).
    fn eval(&self, x: &[f64]) -> f64;
    /// Evaluate every point of `block`, writing `out[k]` for each
    /// `k < block.len()`. Implementations must **not** apply the
    /// block's Jacobians — the caller multiplies during reduction.
    ///
    /// The default gathers each point into a scratch row and calls the
    /// scalar [`Integrand::eval`]; hand-batched overrides (the Genz
    /// suite, the misc integrands, [`crate::api::FnBatchIntegrand`])
    /// run one contiguous pass per axis instead and must return
    /// bit-identical values to the scalar path (property-tested).
    fn eval_batch(&self, block: &PointBlock, out: &mut [f64]) {
        let d = block.dim();
        let n = block.len();
        assert!(out.len() >= n, "eval_batch output buffer too small");
        let mut small = [0.0f64; MAX_DIM];
        let mut big;
        let x: &mut [f64] = if d <= MAX_DIM {
            &mut small[..d]
        } else {
            big = vec![0.0f64; d];
            &mut big
        };
        for (k, slot) in out.iter_mut().enumerate().take(n) {
            block.gather(k, x);
            *slot = self.eval(x);
        }
    }
    /// Analytic / semi-analytic reference value, if known.
    fn true_value(&self) -> Option<f64>;
    /// Identical marginal density on all axes (m-Cubes1D is valid).
    fn symmetric(&self) -> bool {
        false
    }
    /// Per-axis integration bounds. The engine, driver, and all CPU
    /// baselines sample through this; the default reproduces the
    /// legacy uniform box `[lo, hi]^d`. Implementations with genuinely
    /// per-axis boxes (e.g. `api::FnIntegrand`) override it — their
    /// `lo()/hi()` then report the bounding hull for any remaining
    /// legacy uniform-box callers.
    fn bounds(&self) -> Bounds {
        Bounds::uniform(self.dim(), self.lo(), self.hi())
    }
}

/// Shared handle to an integrand.
pub type IntegrandRef = Arc<dyn Integrand>;

/// Instantiate a registry integrand at dimension `d`.
///
/// Fixed-dimension integrands (fA, fB, cosmo) reject other dims.
pub fn by_name(name: &str, d: usize) -> Result<IntegrandRef> {
    let f: IntegrandRef = match name {
        "f1" => Arc::new(F1::new(d)),
        "f2" => Arc::new(F2::new(d)),
        "f3" => Arc::new(F3::new(d)),
        "f4" => Arc::new(F4::new(d)),
        "f5" => Arc::new(F5::new(d)),
        "f6" => Arc::new(F6::new(d)),
        "fA" => {
            check_dim(name, d, 6)?;
            Arc::new(FaSin6::new())
        }
        "fB" => {
            check_dim(name, d, 9)?;
            Arc::new(FbGauss9::new())
        }
        "cosmo" => {
            check_dim(name, d, 6)?;
            Arc::new(Cosmo::with_default_tables())
        }
        _ => {
            return Err(Error::Unknown {
                kind: "integrand",
                name: name.to_string(),
            })
        }
    };
    Ok(f)
}

fn check_dim(name: &str, d: usize, want: usize) -> Result<()> {
    if d != want {
        return Err(Error::Config(format!(
            "integrand {name} is fixed at d={want}, got d={d}"
        )));
    }
    Ok(())
}

/// All registry names (paper suite order).
pub const ALL_NAMES: [&str; 9] = [
    "f1", "f2", "f3", "f4", "f5", "f6", "fA", "fB", "cosmo",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all() {
        for name in ALL_NAMES {
            let d = match name {
                "fA" => 6,
                "fB" => 9,
                "cosmo" => 6,
                _ => 5,
            };
            let f = by_name(name, d).unwrap();
            assert_eq!(f.name(), name);
            assert_eq!(f.dim(), d);
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(by_name("nope", 3).is_err());
    }

    #[test]
    fn fixed_dim_enforced() {
        assert!(by_name("fA", 5).is_err());
        assert!(by_name("fB", 9).is_ok());
        assert!(by_name("cosmo", 2).is_err());
    }
}
