//! 1-D linear interpolation over uniform knots — the paper's "supplied
//! data structures" (§6.1) that let stateful integrands carry tabular
//! data without the user writing any device code. Must match
//! `integrands._interp1d` in Python bit-for-bit (same clamping).

// Narrowing / float→int casts in this file are deliberate and
// audited by `cargo xtask lint` (MC001); see docs/invariants.md.
#![allow(clippy::cast_possible_truncation)]

/// Linear interpolator on `k` uniform knots spanning [lo, hi].
#[derive(Debug, Clone)]
pub struct Interp1D {
    values: Vec<f64>,
    lo: f64,
    hi: f64,
}

impl Interp1D {
    pub fn new(values: Vec<f64>, lo: f64, hi: f64) -> Self {
        assert!(values.len() >= 2, "need at least 2 knots");
        assert!(hi > lo);
        Interp1D { values, lo, hi }
    }

    pub fn knots(&self) -> usize {
        self.values.len()
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Evaluate at `x` (clamped to the knot range, as the Python twin).
    #[inline]
    pub fn eval(&self, x: f64) -> f64 {
        let k = self.values.len();
        let t = (x - self.lo) / (self.hi - self.lo) * (k - 1) as f64;
        // Same clamp constant as python `_interp1d`: [0, k - 1.000001].
        let t = t.clamp(0.0, k as f64 - 1.000001);
        let i0 = t.floor() as usize;
        let frac = t - i0 as f64;
        self.values[i0] + frac * (self.values[i0 + 1] - self.values[i0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_and_midpoints() {
        let t = Interp1D::new(vec![0.0, 1.0, 4.0], 0.0, 1.0);
        assert!((t.eval(0.0) - 0.0).abs() < 1e-12);
        assert!((t.eval(0.25) - 0.5).abs() < 1e-12);
        assert!((t.eval(0.5) - 1.0).abs() < 1e-12);
        assert!((t.eval(0.75) - 2.5).abs() < 1e-12);
        assert!((t.eval(1.0) - 4.0).abs() < 1e-4); // clamped just below knot
    }

    #[test]
    fn clamps_out_of_range() {
        let t = Interp1D::new(vec![2.0, 3.0], 0.0, 1.0);
        assert!((t.eval(-5.0) - 2.0).abs() < 1e-12);
        assert!((t.eval(7.0) - 3.0).abs() < 1e-4);
    }

    #[test]
    fn linear_function_is_exact() {
        let vals: Vec<f64> = (0..11).map(|i| 3.0 * i as f64 / 10.0 + 1.0).collect();
        let t = Interp1D::new(vals, 0.0, 1.0);
        for j in 0..100 {
            let x = j as f64 / 100.0;
            assert!((t.eval(x) - (3.0 * x + 1.0)).abs() < 1e-6, "x={x}");
        }
    }
}
