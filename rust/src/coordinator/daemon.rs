//! Spool-driven integration daemon.
//!
//! A [`Daemon`] owns a [`ServiceStore`] and drains its spool: each
//! `spool/*.json` job manifest is answered from the content-addressed
//! result cache when possible, resumed from a durable checkpoint when
//! one exists, and run cold otherwise — flushing a crash-safe
//! checkpoint every `checkpoint_interval` iterations and publishing a
//! sealed result manifest to the outbox. The per-job step order makes
//! *every* crash point recoverable on restart:
//!
//! 1. cache hit → publish (re-stamped) result → remove spool file
//! 2. run → periodic checkpoint flushes (durable before the next step)
//! 3. finish → cache put → outbox publish → checkpoint remove → spool
//!    remove
//!
//! Killed between 2 and 3: the restart finds spool file + checkpoint,
//! resumes bitwise. Killed inside 3: the restart finds spool file +
//! cache entry, serves the hit. Killed after the spool removal:
//! nothing is pending. No step requires the previous one to have
//! *not* happened — which is the whole crash-recovery state machine
//! (drawn out in docs/service.md).
//!
//! [`Daemon::run_pending`] is a single deterministic drain — no clocks
//! and no ambient randomness, so a given store content always produces
//! the same results (bitwise). The *watch* loop (poll, sleep, repeat)
//! lives in the `serve` CLI, keeping this module pure enough to test
//! exhaustively; crashes are injected through
//! [`Daemon::with_crash_after_flushes`], which stops the process-local
//! world with no cleanup at a durable instant, exactly like `kill -9`.

use crate::api::Session;
use crate::error::Result;
use crate::integrands::IntegrandRef;
use crate::store::manifest::{ResultManifest, ResultNumbers};
use crate::store::{JobManifest, ServiceStore, StoreResult};
use std::path::Path;

/// Resolves a job manifest's `integrand` name to an implementation.
/// The default resolver is `integrands::by_name`; embedders inject
/// their own to serve custom integrands (the tests use this to count
/// evaluations).
pub type IntegrandResolver = Box<dyn Fn(&JobManifest) -> Result<IntegrandRef> + Send>;

/// Tally of one [`Daemon::run_pending`] drain.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
#[non_exhaustive]
pub struct DaemonReport {
    /// Spool files consumed (completed, failed, or cache-answered).
    pub processed: usize,
    /// Successful results published (including cache hits).
    pub completed: usize,
    /// Results served from the content-addressed cache with zero new
    /// integrand evaluations.
    pub cache_hits: usize,
    /// Jobs that resumed from a durable checkpoint instead of starting
    /// cold.
    pub resumed: usize,
    /// Jobs answered with an error result (bad manifest, unknown
    /// integrand, engine failure).
    pub failures: usize,
    /// The injected crash fired: the drain stopped mid-scan with no
    /// cleanup (test hook; always false in production).
    pub crashed: bool,
}

/// The spool-driven service front-end. See the module docs for the
/// crash-recovery contract.
pub struct Daemon {
    store: ServiceStore,
    threads: usize,
    /// Shard workers per job (1 = ordinary single-worker backends).
    shards: usize,
    /// Spool directory handed to sharded jobs (external
    /// `mcubes shard-worker` processes); `None` keeps shards
    /// in-process.
    shard_dir: Option<String>,
    resolver: IntegrandResolver,
    /// Simulated `kill -9` after the Nth durable checkpoint flush.
    crash_after_flushes: Option<usize>,
    /// Flushes so far, across all jobs of this daemon's lifetime.
    flushes: usize,
}

impl Daemon {
    /// Open (creating as needed) the store at `root` and build a
    /// daemon over it with the default integrand registry resolver.
    pub fn open(root: impl AsRef<Path>) -> Result<Daemon> {
        let store = ServiceStore::open(root)?;
        Ok(Daemon {
            store,
            threads: 1,
            shards: 1,
            shard_dir: None,
            resolver: Box::new(|job| crate::integrands::by_name(&job.integrand, job.dim)),
            crash_after_flushes: None,
            flushes: 0,
        })
    }

    /// Worker threads per job. Results are bitwise thread-count
    /// invariant, so this is purely a throughput knob.
    pub fn with_threads(mut self, threads: usize) -> Daemon {
        self.threads = threads.max(1);
        self
    }

    /// Shard workers per job. Like `threads`, an execution knob the
    /// daemon owns (it is excluded from the job digest): the N-shard
    /// merge is bitwise the single-worker run, so sharded and
    /// unsharded daemons share cache entries and checkpoints.
    pub fn with_shards(mut self, shards: usize) -> Daemon {
        self.shards = shards.max(1);
        self
    }

    /// Spool directory for sharded jobs: tasks are scattered there for
    /// external `mcubes shard-worker` processes, with in-process
    /// recompute covering stragglers. Only meaningful with
    /// [`Daemon::with_shards`] > 1.
    pub fn with_shard_dir(mut self, dir: impl Into<String>) -> Daemon {
        self.shard_dir = Some(dir.into());
        self
    }

    /// Replace the integrand resolver (custom integrands, eval
    /// counters).
    pub fn with_resolver(
        mut self,
        resolver: impl Fn(&JobManifest) -> Result<IntegrandRef> + Send + 'static,
    ) -> Daemon {
        self.resolver = Box::new(resolver);
        self
    }

    /// Test hook: stop the drain with **no cleanup** immediately after
    /// the `n`-th durable checkpoint flush (counted across jobs),
    /// leaving the store exactly as a `kill -9` at that instant would.
    /// The durability tests restart a fresh daemon on the same store
    /// and assert bitwise-identical results.
    pub fn with_crash_after_flushes(mut self, n: usize) -> Daemon {
        self.crash_after_flushes = Some(n);
        self
    }

    /// The store this daemon operates on.
    pub fn store(&self) -> &ServiceStore {
        &self.store
    }

    /// Drain the spool once: load every pending submission (ordered by
    /// descending priority, then job id) and answer each. Per-job
    /// failures become error results in the outbox; only store-level
    /// I/O trouble (submission left in place, retried on the next
    /// drain) surfaces in [`DaemonReport::failures`] without an outbox
    /// entry.
    pub fn run_pending(&mut self) -> Result<DaemonReport> {
        let mut report = DaemonReport::default();
        let mut jobs: Vec<(std::path::PathBuf, Option<JobManifest>)> = Vec::new();
        for path in self.store.spool().pending()? {
            let job = self.store.spool().load(&path).ok();
            jobs.push((path, job));
        }
        // Higher priority first; ties (and unreadable submissions,
        // sorted as priority 0) break by file name for determinism.
        jobs.sort_by(|a, b| {
            let pa = a.1.as_ref().map_or(0, |j| j.priority);
            let pb = b.1.as_ref().map_or(0, |j| j.priority);
            pb.cmp(&pa).then_with(|| a.0.cmp(&b.0))
        });
        for (path, job) in jobs {
            match job {
                Some(job) => self.run_job(&path, &job, &mut report)?,
                None => self.reject_unreadable(&path, &mut report)?,
            }
            if report.crashed {
                break;
            }
        }
        Ok(report)
    }

    /// Answer a submission that failed to parse or validate: publish
    /// an error result under the file's stem (when that is a legal job
    /// id) and consume the file — never retry a manifest that can't
    /// ever become readable.
    fn reject_unreadable(&self, path: &Path, report: &mut DaemonReport) -> Result<()> {
        report.processed += 1;
        report.failures += 1;
        let detail = match self.store.spool().load(path) {
            Err(e) => e.to_string(),
            Ok(_) => "submission became readable mid-drain".to_string(),
        };
        let stem = path
            .file_stem()
            .and_then(std::ffi::OsStr::to_str)
            .unwrap_or_default();
        if crate::store::check_job_key(stem).is_ok() {
            let result = ResultManifest::failure(stem, "", 0, detail);
            self.store.spool().publish(&result)?;
        }
        self.store.spool().complete(path)?;
        Ok(())
    }

    /// Answer one readable submission (see module docs for the step
    /// order and why it is crash-safe).
    fn run_job(&mut self, path: &Path, job: &JobManifest, report: &mut DaemonReport) -> Result<()> {
        report.processed += 1;
        let digest = job.digest();

        // 1. Content-addressed cache: identical semantics → stored
        //    numbers, zero evaluations. A corrupt entry is treated as
        //    a miss and repaired by the recompute below.
        if let Ok(Some(hit)) = self.store.results().get(&digest) {
            let mut answered = hit;
            answered.job_id = job.job_id.clone();
            answered.cached = true;
            self.store.spool().publish(&answered)?;
            self.store.spool().complete(path)?;
            report.completed += 1;
            report.cache_hits += 1;
            return Ok(());
        }

        let f = match (self.resolver)(job) {
            Ok(f) => f,
            Err(e) => return self.publish_failure(path, job, e.to_string(), report),
        };
        let mut cfg = job.to_config(self.threads);
        cfg.shards = self.shards;
        cfg.shard_dir = self.shard_dir.clone();

        // 2. Durable checkpoint → bitwise resume. A corrupt or
        //    incompatible checkpoint degrades to a cold start (the
        //    recompute overwrites it at the next flush).
        let mut resumed_iteration = 0;
        let session = match self.store.checkpoints().load(&digest) {
            Ok(Some(cp)) => match Session::resume(f.clone(), cfg.clone(), &cp) {
                Ok(s) => {
                    resumed_iteration = cp.iteration();
                    Some(s)
                }
                Err(_) => None,
            },
            _ => None,
        };
        let mut session = match session {
            Some(s) => s,
            None => match Session::new(f, cfg) {
                Ok(s) => s,
                Err(e) => return self.publish_failure(path, job, e.to_string(), report),
            },
        };
        if resumed_iteration > 0 {
            report.resumed += 1;
        }

        // 3. Step loop with periodic durable flushes.
        let mut since_flush = 0;
        loop {
            match session.step() {
                Ok(Some(_)) => {
                    since_flush += 1;
                    if since_flush >= job.checkpoint_interval {
                        self.store.checkpoints().save(&digest, &session.suspend())?;
                        since_flush = 0;
                        self.flushes += 1;
                        if self.crash_after_flushes.is_some_and(|n| self.flushes >= n) {
                            // Simulated kill -9: stop the world at a
                            // durable instant, clean up nothing.
                            report.crashed = true;
                            return Ok(());
                        }
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    self.store.checkpoints().remove(&digest)?;
                    return self.publish_failure(path, job, e.to_string(), report);
                }
            }
        }
        let outcome = match session.finish() {
            Ok(o) => o,
            Err(e) => {
                self.store.checkpoints().remove(&digest)?;
                return self.publish_failure(path, job, e.to_string(), report);
            }
        };

        // 4. Durable completion: cache → outbox → drop checkpoint →
        //    consume submission.
        let numbers = ResultNumbers::from_output(&outcome.output, outcome.stop);
        let mut result = ResultManifest::success(job, digest.clone(), numbers);
        result.resumed_iteration = resumed_iteration;
        self.store.results().put(&digest, &result)?;
        self.store.spool().publish(&result)?;
        self.store.checkpoints().remove(&digest)?;
        self.store.spool().complete(path)?;
        report.completed += 1;
        Ok(())
    }

    /// Publish an error result and consume the submission.
    fn publish_failure(
        &self,
        path: &Path,
        job: &JobManifest,
        detail: String,
        report: &mut DaemonReport,
    ) -> Result<()> {
        let result = ResultManifest::failure(&job.job_id, &job.integrand, job.dim, detail);
        self.store.spool().publish(&result)?;
        self.store.spool().complete(path)?;
        report.failures += 1;
        Ok(())
    }
}

/// Convenience: submit a job to a store root without holding a daemon
/// (what the `serve --demo-jobs` path and the examples use).
pub fn submit_job(root: impl AsRef<Path>, job: &JobManifest) -> Result<std::path::PathBuf> {
    let store = ServiceStore::open(root)?;
    let path = store.spool().submit(job)?;
    Ok(path)
}

/// Convenience twin of [`submit_job`]: read a published result back.
pub fn read_result(root: impl AsRef<Path>, job_id: &str) -> Result<Option<ResultManifest>> {
    let store = ServiceStore::open(root)?;
    let r: StoreResult<Option<ResultManifest>> = store.spool().result(job_id);
    Ok(r?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::JobConfig;

    fn scratch(tag: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("mcubes-daemon-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn small_job(id: &str, integrand: &str, dim: usize) -> JobManifest {
        let mut cfg = JobConfig::default();
        cfg.maxcalls = 1 << 12;
        cfg.plan = crate::api::RunPlan::classic(5, 3, 1);
        cfg.tau_rel = 1e-12; // never converges early → deterministic length
        JobManifest::new(id, integrand, dim, cfg)
    }

    #[test]
    fn drains_spool_and_publishes_results() {
        let root = scratch("drain");
        submit_job(&root, &small_job("b-second", "f3", 3)).unwrap();
        submit_job(&root, &small_job("a-first", "f4", 5).with_priority(1)).unwrap();
        let mut d = Daemon::open(&root).unwrap();
        let report = d.run_pending().unwrap();
        assert_eq!((report.processed, report.completed), (2, 2));
        assert_eq!((report.cache_hits, report.failures), (0, 0));
        assert!(!report.crashed);
        let r = read_result(&root, "a-first").unwrap().unwrap();
        assert!(r.outcome.is_ok());
        assert!(!r.cached);
        // Spool drained, checkpoints cleaned up.
        assert!(d.store().spool().pending().unwrap().is_empty());
        assert!(d.store().checkpoints().digests().unwrap().is_empty());
    }

    #[test]
    fn unknown_integrand_becomes_error_result() {
        let root = scratch("unknown");
        submit_job(&root, &small_job("nope", "no_such_integrand", 3)).unwrap();
        let mut d = Daemon::open(&root).unwrap();
        let report = d.run_pending().unwrap();
        assert_eq!((report.processed, report.failures), (1, 1));
        let r = read_result(&root, "nope").unwrap().unwrap();
        assert!(r.outcome.is_err());
        assert!(d.store().spool().pending().unwrap().is_empty());
    }

    #[test]
    fn garbage_submission_is_consumed_not_retried() {
        let root = scratch("garbage");
        let store = ServiceStore::open(&root).unwrap();
        std::fs::write(store.spool().inbox_dir().join("mangled.json"), "{oops").unwrap();
        let mut d = Daemon::open(&root).unwrap();
        let report = d.run_pending().unwrap();
        assert_eq!((report.processed, report.failures), (1, 1));
        assert!(d.store().spool().pending().unwrap().is_empty());
        let r = read_result(&root, "mangled").unwrap().unwrap();
        assert!(r.outcome.is_err());
    }

    #[test]
    fn sharded_daemon_matches_single_worker_bitwise() {
        let root_a = scratch("shard-a");
        submit_job(&root_a, &small_job("j", "f4", 5)).unwrap();
        let mut d = Daemon::open(&root_a).unwrap();
        d.run_pending().unwrap();
        let a = read_result(&root_a, "j").unwrap().unwrap();

        let root_b = scratch("shard-b");
        submit_job(&root_b, &small_job("j", "f4", 5)).unwrap();
        let mut d = Daemon::open(&root_b).unwrap().with_shards(8);
        d.run_pending().unwrap();
        let b = read_result(&root_b, "j").unwrap().unwrap();

        assert_eq!(a.digest, b.digest, "shards are excluded from the digest");
        let (na, nb) = (a.outcome.unwrap(), b.outcome.unwrap());
        assert_eq!(na.integral.to_bits(), nb.integral.to_bits());
        assert_eq!(na.sigma.to_bits(), nb.sigma.to_bits());
        assert_eq!(na.calls_used, nb.calls_used);
    }

    #[test]
    fn identical_resubmission_hits_cache() {
        let root = scratch("cachehit");
        submit_job(&root, &small_job("orig", "f3", 3)).unwrap();
        let mut d = Daemon::open(&root).unwrap();
        d.run_pending().unwrap();
        let first = read_result(&root, "orig").unwrap().unwrap();
        // Same semantics, different id and service metadata.
        let again = small_job("again", "f3", 3)
            .with_priority(9)
            .with_checkpoint_interval(4);
        submit_job(&root, &again).unwrap();
        let report = d.run_pending().unwrap();
        assert_eq!((report.completed, report.cache_hits), (1, 1));
        let hit = read_result(&root, "again").unwrap().unwrap();
        assert!(hit.cached);
        let (a, b) = (first.outcome.unwrap(), hit.outcome.unwrap());
        assert_eq!(a.integral.to_bits(), b.integral.to_bits());
        assert_eq!(a.sigma.to_bits(), b.sigma.to_bits());
        assert_eq!(a.calls_used, b.calls_used);
    }
}
