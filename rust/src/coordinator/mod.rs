//! L3 coordinator — the paper's host-side contribution: the two-phase
//! m-Cubes iteration loop (Algorithm 2) as a resumable session core,
//! backend abstraction over PJRT artifacts / the native engine, and
//! the multi-job throughput [`Scheduler`].
//!
//! The stepping state machine (`SessionCore`) is shared by
//! `api::Session` (pull-based, suspend/resume) and [`drive`] (the
//! blocking loop for fixed-layout backends); the seed's free
//! functions remain as deprecated shims behind the on-by-default
//! `legacy-api` cargo feature (build with `--no-default-features` to
//! drop them). Most callers should go through `crate::api::Integrator`
//! instead of using this module directly.

mod backend;
mod daemon;
mod driver;
mod service;

pub use backend::{NativeBackend, PjrtBackend, StratifiedBackend, VSampleBackend};
pub use daemon::{read_result, submit_job, Daemon, DaemonReport, IntegrandResolver};
pub use driver::{drive, DriveOutcome, DriverOutput, IntegrationOutput, JobConfig};
#[cfg(feature = "legacy-api")]
#[allow(deprecated)]
pub use driver::{integrate_native, integrate_native_adaptive, run_driver, run_driver_traced};
pub(crate) use driver::{escalate_native, integrate_native_core, SessionCore, StepRecord};
#[cfg(feature = "legacy-api")]
#[allow(deprecated)]
pub use service::IntegrationService;
pub use service::{JobRequest, JobResult, ResultStream, Scheduler, ServiceMetrics};
