//! L3 coordinator — the paper's host-side contribution: the two-phase
//! m-Cubes iteration driver (Algorithm 2), backend abstraction over
//! PJRT artifacts / the native engine, and an integration job service.
//!
//! `drive` is the one driver core (warm-startable, observable); the
//! seed's free functions remain as deprecated shims behind the
//! on-by-default `legacy-api` cargo feature (build with
//! `--no-default-features` to drop them). Most callers should go
//! through `crate::api::Integrator` instead of using this module
//! directly.

mod backend;
mod driver;
mod service;

pub use backend::{NativeBackend, PjrtBackend, VSampleBackend};
pub use driver::{drive, DriveOutcome, DriverOutput, IntegrationOutput, JobConfig};
#[cfg(feature = "legacy-api")]
#[allow(deprecated)]
pub use driver::{integrate_native, integrate_native_adaptive, run_driver, run_driver_traced};
pub(crate) use driver::{escalate_native, integrate_native_core};
pub use service::{IntegrationService, JobRequest, JobResult, ServiceMetrics};
