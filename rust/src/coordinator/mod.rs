//! L3 coordinator — the paper's host-side contribution: the two-phase
//! m-Cubes iteration loop (Algorithm 2) as a resumable session core,
//! backend abstraction over PJRT artifacts / the native engine, and
//! the multi-job throughput [`Scheduler`].
//!
//! The stepping state machine (`SessionCore`) is shared by
//! `api::Session` (pull-based, suspend/resume) and [`drive`] (the
//! blocking loop for fixed-layout backends). Native sampling runs
//! through [`EngineBackend`], the driver adapter over any
//! `engine::Engine` impl. Most callers should go through
//! `crate::api::Integrator` instead of using this module directly.

mod backend;
mod daemon;
mod driver;
mod service;

pub use backend::{EngineBackend, PjrtBackend, VSampleBackend};
pub use daemon::{read_result, submit_job, Daemon, DaemonReport, IntegrandResolver};
pub use driver::{drive, DriveOutcome, IntegrationOutput, JobConfig};
pub(crate) use driver::{escalate_native, integrate_native_core, SessionCore, StepRecord};
pub use service::{JobRequest, JobResult, ResultStream, Scheduler, ServiceMetrics};
