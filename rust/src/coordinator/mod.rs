//! L3 coordinator — the paper's host-side contribution: the two-phase
//! m-Cubes iteration driver (Algorithm 2), backend abstraction over
//! PJRT artifacts / the native engine, and an integration job service.

mod backend;
mod driver;
mod service;

pub use backend::{NativeBackend, PjrtBackend, VSampleBackend};
pub use driver::{
    integrate_native, integrate_native_adaptive, run_driver, run_driver_traced, DriverOutput,
    IntegrationOutput, JobConfig,
};
pub use service::{IntegrationService, JobRequest, JobResult, ServiceMetrics};
